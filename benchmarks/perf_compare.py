"""Baseline vs optimized sweep comparison (§Perf closing table).

    PYTHONPATH=src python -m benchmarks.perf_compare \
        dryrun_results.json dryrun_results_optimized.json
"""
from __future__ import annotations

import json
import sys


def main():
    base_p = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    opt_p = (sys.argv[2] if len(sys.argv) > 2
             else "dryrun_results_optimized.json")
    base = {(r["arch"], r["shape"], r["mesh"]): r
            for r in json.load(open(base_p))}
    opt = {(r["arch"], r["shape"], r["mesh"]): r
           for r in json.load(open(opt_p))}
    print("| arch | shape | mem GB (base->opt) | T_m s | T_x s | note |")
    print("|---|---|---|---|---|---|")
    for key in base:
        if key[2] != "16x16":
            continue
        b, o = base.get(key), opt.get(key)
        if not (b and o and b["status"] == "OK" and o["status"] == "OK"):
            continue
        bm = b["bytes_per_device"]["total_gb"]
        om = o["bytes_per_device"]["total_gb"]
        brf, orf = b.get("roofline", {}), o.get("roofline", {})
        note = ""
        if abs(bm - om) / max(bm, 1e-9) > 0.03:
            note = f"{bm/max(om,1e-9):.1f}x mem"
        print(f"| {key[0]} | {key[1]} | {bm:.1f} -> {om:.1f} | "
              f"{brf.get('t_memory_s', 0):.3g} -> "
              f"{orf.get('t_memory_s', 0):.3g} | "
              f"{brf.get('t_collective_s', 0):.3g} -> "
              f"{orf.get('t_collective_s', 0):.3g} | {note} |")


if __name__ == "__main__":
    main()
