"""Performance comparisons.

Five modes:

1. Backend comparison (PhysicalSpec layer): run the LDBC query set through
   every registered execution backend, check row-for-row result parity, and
   emit per-query timings to ``BENCH_backends.json``:

       PYTHONPATH=src python -m benchmarks.perf_compare --backends \
           [--sf 0.2] [--queries ic,cbo] [--repeats 3] [--out ...]

2. Prepared-query comparison (GraphIrBuilder / prepared lifecycle,
   DESIGN.md §3): for each parameterized query, time per-execution latency
   of the unprepared path (full parse + type-inference + RBO + CBO on every
   run) against ``GOpt.prepare(...).execute(bindings)`` across several
   bindings, on every backend, checking row parity between the two paths;
   emits ``BENCH_prepared.json``:

       PYTHONPATH=src python -m benchmarks.perf_compare --prepared \
           [--sf 0.2] [--repeats 3] [--out BENCH_prepared.json]

3. Residency comparison (OperatorSet v2, DESIGN.md §7): run the query set
   on the jax backend twice — the device-resident v2 path vs the v1-style
   host-staging path (PR-3 data plane: host binding tables, padded-block
   device round trips per op) — recording wall time and per-phase transfer
   counts for both; emits ``BENCH_residency.json`` and exits nonzero on a
   result mismatch or on any mid-plan device->host transfer in the v2 path
   (the residency invariants).  ``--gate-perf`` additionally fails queries
   where the resident path is slower beyond the noise tolerance — that
   gate is meaningful on a real accelerator; on interpret-mode CPU the
   "device" is host RAM, so point queries are eager-dispatch-bound and the
   round-trip path wins them (the JSON records the truth either way):

       PYTHONPATH=src python -m benchmarks.perf_compare --residency \
           [--sf 0.2] [--queries ic,rbo,typeinf] [--repeats 3] \
           [--gate-perf] [--out ...]

4. Fusion comparison (DESIGN.md §8): run the query set on the jax backend
   three ways — fused single-dispatch chain programs, the per-hop v2 loop
   (``chain_dispatch=False``), and the host-staged baseline — recording
   walls plus per-query fused dispatch/compile counts; emits
   ``BENCH_fusion.json`` and exits nonzero on a result mismatch or when the
   fused path's geomean wall regresses against the per-hop loop on the
   ic/point-query set:

       PYTHONPATH=src python -m benchmarks.perf_compare --fusion \
           [--sf 0.2] [--queries ic,cbo,rbo,typeinf] [--repeats 3] [--out ...]

5. Serving comparison (QueryServer continuous batching, DESIGN.md §9): an
   open-loop seeded-Poisson request stream over an Appendix-A query mix is
   served two ways per backend — through the continuous-batching
   ``QueryServer`` (per-plan waves via ``execute_many``) and sequentially
   (one ``execute`` per request at its scheduled arrival) — recording
   p50/p99 latency against the *scheduled* arrivals, throughput, wave
   shapes, and per-wave compile counts; emits ``BENCH_serve.json`` and
   exits nonzero on a result mismatch, on a batched-throughput geomean
   <= 1.0x sequential, or when a warmed server's waves still compile
   fused-chain programs:

       PYTHONPATH=src python -m benchmarks.perf_compare --serve \
           [--sf 0.1] [--requests 240] [--rate 2000] [--max-wave 16] \
           [--backend-list numpy,jax] [--out BENCH_serve.json]

6. Legacy sweep comparison (§Perf closing table) of two dry-run result files:

       PYTHONPATH=src python -m benchmarks.perf_compare \
           dryrun_results.json dryrun_results_optimized.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

ROW_CAP = 8_000_000


# ------------------------------------------------------------ backend mode

def _tables_equal(a, b) -> bool:
    """Row-for-row equality of two engine Tables."""
    import numpy as np
    if a.nrows != b.nrows or set(a.cols) != set(b.cols):
        return False
    return all(np.array_equal(a.cols[k], b.cols[k]) for k in a.cols)


def run_backends(args) -> dict:
    import numpy as np

    from benchmarks import queries as Q
    from repro.core.gopt import GOpt
    from repro.graphdb.ldbc import generate_ldbc

    from repro.core.physical_spec import get_spec
    backends = args.backend_list.split(",")
    for b in backends:        # fail fast, before the store build
        get_spec(b)
    sets = {"ic": (Q.QIC, Q.QIC_PARAMS),
            "cbo": (Q.QC, {}),
            "rbo": (Q.QR, Q.QR_PARAMS),
            "typeinf": (Q.QT, {})}
    t0 = time.time()
    print(f"# building LDBC-like store sf={args.sf} + GLogue ...", flush=True)
    gopt = GOpt(generate_ldbc(sf=args.sf, seed=7))
    print(f"# store: V={gopt.store.n_vertices} E={gopt.store.n_edges} "
          f"({time.time() - t0:.1f}s); backends: {backends}", flush=True)

    results = []
    for setname in args.queries.split(","):
        queries, params = sets[setname]
        for name, text in queries.items():
            opt = gopt.optimize(text, params.get(name))
            rec: dict = {"set": setname, "query": name, "match": True}
            ref = None
            for backend in backends:
                try:
                    # warmup run absorbs jit/Pallas compilation, then time
                    tbl, _ = gopt.execute(opt, backend=backend,
                                          max_rows=ROW_CAP)
                    best = float("inf")
                    for _ in range(args.repeats):
                        t1 = time.perf_counter()
                        tbl, _ = gopt.execute(opt, backend=backend,
                                              max_rows=ROW_CAP)
                        best = min(best, time.perf_counter() - t1)
                except (RuntimeError, MemoryError) as exc:
                    rec[f"{backend}_s"] = None
                    rec[f"{backend}_error"] = str(exc)[:120]
                    continue
                rec[f"{backend}_s"] = best
                if ref is None:
                    ref = tbl
                    rec["rows"] = tbl.nrows
                elif not _tables_equal(ref, tbl):
                    rec["match"] = False
            results.append(rec)
            times = " ".join(
                f"{b}={rec[f'{b}_s']:.4f}s" if rec.get(f"{b}_s") is not None
                else f"{b}=OT" for b in backends)
            print(f"{setname}/{name}: {times} rows={rec.get('rows')} "
                  f"match={rec['match']}", flush=True)

    mismatches = [r["query"] for r in results if not r["match"]]
    # a backend erroring while another succeeds leaves parity unverified
    # for that query — count it as a failure, not a silent skip
    unverified = [r["query"] for r in results
                  if r["match"]
                  and any(r.get(f"{b}_s") is None for b in backends)
                  and not all(r.get(f"{b}_s") is None for b in backends)]
    geo = {}
    base = backends[0]
    for b in backends[1:]:
        ratios = [r[f"{base}_s"] / r[f"{b}_s"] for r in results
                  if r.get(f"{base}_s") and r.get(f"{b}_s")]
        geo[f"{base}_over_{b}_geomean"] = (
            float(np.exp(np.mean(np.log(ratios)))) if ratios else None)
    out = {"sf": args.sf, "backends": backends, "repeats": args.repeats,
           "results": results, "mismatches": mismatches,
           "unverified": unverified, "summary": geo}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"# wrote {args.out}; mismatches={mismatches or 'none'} "
          f"unverified={unverified or 'none'} "
          f"summary={geo} ({time.time() - t0:.1f}s total)")
    return out


# ----------------------------------------------------------- prepared mode

# 3 parameter bindings per query (the serving scenario: one prepared plan,
# many executions with fresh values)
_PREPARED_BINDINGS = {
    "ic": [{"pid": 3}, {"pid": 5}, {"pid": 9}],
    "rbo5": [{"id1": 3, "id2": 7}, {"id1": 1, "id2": 4}, {"id1": 2, "id2": 9}],
    "rbo6": [{"id1": 3, "id2": 7, "len": 64}, {"id1": 1, "id2": 4, "len": 32},
             {"id1": 2, "id2": 9, "len": 128}],
}


def run_prepared(args) -> dict:
    import numpy as np

    from benchmarks import queries as Q
    from repro.core.gopt import GOpt
    from repro.core.physical_spec import get_spec
    from repro.graphdb.ldbc import generate_ldbc

    backends = args.backend_list.split(",")
    for b in backends:
        get_spec(b)
    cases = [(name, text, _PREPARED_BINDINGS["ic"])
             for name, text in Q.QIC.items()]
    cases.append(("Qr5", Q.QR["Qr5"], _PREPARED_BINDINGS["rbo5"]))
    cases.append(("Qr6", Q.QR["Qr6"], _PREPARED_BINDINGS["rbo6"]))

    t0 = time.time()
    print(f"# building LDBC-like store sf={args.sf} + GLogue ...", flush=True)
    gopt = GOpt(generate_ldbc(sf=args.sf, seed=7))
    print(f"# store: V={gopt.store.n_vertices} E={gopt.store.n_edges} "
          f"({time.time() - t0:.1f}s); backends: {backends}", flush=True)

    results, mismatches, regressions = [], [], []
    for backend in backends:
        for name, text, bindings in cases:
            rec = {"query": name, "backend": backend, "match": True,
                   "executions": len(bindings) * args.repeats}
            # warmup both paths (absorbs jit/Pallas compilation on jax)
            opt = gopt.optimize(text, bindings[0], backend=backend)
            gopt.execute(opt, backend=backend, max_rows=ROW_CAP,
                         params=bindings[0])
            pq = gopt.prepare(text, bindings[0], backend=backend)
            pq.execute(bindings[0], max_rows=ROW_CAP)

            counters0 = dict(gopt.compile_counters)
            un_s = pr_s = 0.0
            for params in bindings:
                for _ in range(args.repeats):
                    t1 = time.perf_counter()
                    opt = gopt.optimize(text, params, backend=backend)
                    ref, _ = gopt.execute(opt, backend=backend,
                                          max_rows=ROW_CAP, params=params)
                    un_s += time.perf_counter() - t1
                    t1 = time.perf_counter()
                    tbl, _ = pq.execute(params, max_rows=ROW_CAP)
                    pr_s += time.perf_counter() - t1
                    if not _tables_equal(ref, tbl):
                        rec["match"] = False
            if dict(gopt.compile_counters) != {
                    k: v + rec["executions"] for k, v in counters0.items()}:
                # unprepared path compiles once per execution; the prepared
                # path must add nothing on top of that
                rec["recompiled"] = True
                rec["match"] = False
            n = rec["executions"]
            rec["unprepared_s"] = un_s / n
            rec["prepared_s"] = pr_s / n
            rec["speedup"] = un_s / pr_s if pr_s else None
            results.append(rec)
            if not rec["match"]:
                mismatches.append(f"{backend}/{name}")
            if rec["prepared_s"] >= rec["unprepared_s"]:
                regressions.append(f"{backend}/{name}")
            print(f"{backend}/{name}: unprepared={rec['unprepared_s']:.5f}s "
                  f"prepared={rec['prepared_s']:.5f}s "
                  f"speedup={rec['speedup']:.1f}x match={rec['match']}",
                  flush=True)

    verify_overhead = _measure_verify_overhead(gopt.store, cases)
    print(f"# verify overhead: off={verify_overhead['off_s']:.4f}s "
          f"cached={verify_overhead['cached_s']:.4f}s "
          f"ratio={verify_overhead['overhead']:.2%} "
          f"(gate <{VERIFY_OVERHEAD_TOL:.0%})", flush=True)

    geo = {}
    for backend in backends:
        sp = [r["speedup"] for r in results
              if r["backend"] == backend and r["speedup"]]
        geo[f"{backend}_speedup_geomean"] = (
            float(np.exp(np.mean(np.log(sp)))) if sp else None)
    # gate on the aggregate, not per-query regressions: single-query timing
    # flips are noise at smoke scale, but a backend whose *geomean* prepared
    # speedup drops to <=1x has lost the point of preparing
    slow_backends = [b for b in backends
                     if geo.get(f"{b}_speedup_geomean") is not None
                     and geo[f"{b}_speedup_geomean"] <= 1.0]
    out = {"sf": args.sf, "backends": backends, "repeats": args.repeats,
           "results": results, "mismatches": mismatches,
           "regressions": regressions, "slow_backends": slow_backends,
           "verify_overhead": verify_overhead, "summary": geo}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"# wrote {args.out}; mismatches={mismatches or 'none'} "
          f"regressions={regressions or 'none'} "
          f"slow_backends={slow_backends or 'none'} "
          f"verify_overhead={verify_overhead['overhead']:.2%} "
          f"summary={geo} ({time.time() - t0:.1f}s total)")
    return out


# verify="cached" must stay under 5% of total prepare time (DESIGN.md §12);
# the absolute slack keeps sub-millisecond totals from tripping the ratio
VERIFY_OVERHEAD_TOL = 0.05
VERIFY_OVERHEAD_SLACK_S = 0.025


def _measure_verify_overhead(store, cases, rounds: int = 3) -> dict:
    """Total prepare wall for the bench's case set with verification off vs
    ``verify="cached"`` — identical optimizer config in both arms.  The plan
    caches are cleared between rounds so every round pays the full pipeline,
    while the cached arm's verification memo persists (its steady state:
    one real verification per canonical plan form, memo hits after)."""
    from repro.core.gopt import GOpt

    totals = {}
    for mode in ("off", "cached"):
        gopt = GOpt(store, build_glogue=False)
        t = 0.0
        for _ in range(rounds):
            gopt._plan_cache.clear()
            gopt._text_cache.clear()
            t1 = time.perf_counter()
            for _name, text, bindings in cases:
                gopt.prepare(text, bindings[0], verify=mode)
            t += time.perf_counter() - t1
        totals[mode] = t
    overhead = ((totals["cached"] - totals["off"]) / totals["off"]
                if totals["off"] else 0.0)
    return {"off_s": totals["off"], "cached_s": totals["cached"],
            "overhead": overhead,
            "exceeded": (overhead >= VERIFY_OVERHEAD_TOL
                         and totals["cached"] - totals["off"]
                         > VERIFY_OVERHEAD_SLACK_S)}


# ---------------------------------------------------------- residency mode

# best-of-repeats still jitters a few percent at smoke scale; the gate
# flags a query only when the resident path loses beyond this factor
RESIDENCY_TOL = 1.10


def _mid_plan_d2h(transfers: dict | None) -> int:
    from repro.core.physical_spec import TransferStats
    return TransferStats.mid_plan_d2h(transfers)


def run_residency(args) -> dict:
    """Device-resident (v2) vs host-staged (v1-style) execution on the jax
    backend: same optimized plans, same store, two data planes."""
    import numpy as np

    from benchmarks import queries as Q
    from repro.core.gopt import GOpt
    from repro.core.physical_spec import get_spec
    from repro.graphdb.engine import Engine
    from repro.graphdb.host_staging import HostStagingOperators
    from repro.graphdb.ldbc import generate_ldbc

    sets = {"ic": (Q.QIC, Q.QIC_PARAMS),
            "cbo": (Q.QC, {}),
            "rbo": (Q.QR, Q.QR_PARAMS),
            "typeinf": (Q.QT, {})}
    t0 = time.time()
    print(f"# building LDBC-like store sf={args.sf} + GLogue ...", flush=True)
    gopt = GOpt(generate_ldbc(sf=args.sf, seed=7))
    print(f"# store: V={gopt.store.n_vertices} E={gopt.store.n_edges} "
          f"({time.time() - t0:.1f}s)", flush=True)
    resident = get_spec("jax").operators(gopt.store)
    staged = HostStagingOperators(resident)
    ts = resident.transfer_stats

    def timed(run, *a, **kw):
        run(*a, **kw)                     # warmup: jit/Pallas compilation
        best, tbl, stats = float("inf"), None, None
        for _ in range(args.repeats):
            t1 = time.perf_counter()
            tbl, stats = run(*a, **kw)
            best = min(best, time.perf_counter() - t1)
        return best, tbl, stats

    results, mismatches, leaks, regressions = [], [], [], []
    for setname in args.queries.split(","):
        queries, params = sets[setname]
        for name, text in queries.items():
            opt = gopt.optimize(text, params.get(name), backend="jax")
            try:
                ts.reset()
                v2_s, v2_tbl, v2_stats = timed(
                    gopt.execute, opt, backend="jax", max_rows=ROW_CAP)
                ts.reset()
                v1_s, v1_tbl, v1_stats = timed(
                    Engine(gopt.store, backend=staged,
                           max_rows=ROW_CAP).run, opt.logical, opt.physical)
            except (RuntimeError, MemoryError) as exc:
                results.append({"set": setname, "query": name,
                                "error": str(exc)[:120]})
                print(f"{setname}/{name}: ERROR {str(exc)[:80]}", flush=True)
                continue
            rec = {
                "set": setname, "query": name, "rows": v2_tbl.nrows,
                "match": _tables_equal(v1_tbl, v2_tbl),
                "v1_host_staged_s": v1_s, "v2_resident_s": v2_s,
                "speedup": v1_s / v2_s if v2_s else None,
                "v2_mid_plan_d2h": _mid_plan_d2h(v2_stats.transfers),
                "v1_mid_plan_d2h": _mid_plan_d2h(v1_stats.transfers),
                "v2_transfers": v2_stats.transfers,
            }
            results.append(rec)
            if not rec["match"]:
                mismatches.append(name)
            if rec["v2_mid_plan_d2h"]:
                leaks.append(name)
            if v2_s > v1_s * RESIDENCY_TOL:
                regressions.append(name)
            print(f"{setname}/{name}: v1={v1_s:.4f}s v2={v2_s:.4f}s "
                  f"speedup={rec['speedup']:.2f}x d2h(v1/v2)="
                  f"{rec['v1_mid_plan_d2h']}/{rec['v2_mid_plan_d2h']} "
                  f"rows={rec['rows']} match={rec['match']}", flush=True)

    ok = [r for r in results if "error" not in r and r["speedup"]]
    geo = (float(np.exp(np.mean(np.log([r["speedup"] for r in ok]))))
           if ok else None)
    out = {"sf": args.sf, "repeats": args.repeats, "tolerance": RESIDENCY_TOL,
           "results": results, "mismatches": mismatches,
           "mid_plan_d2h_leaks": leaks, "regressions": regressions,
           "summary": {"resident_over_staged_geomean": geo},
           "note": "interpret-mode CPU: the 'device' is host RAM, so "
                   "dispatch-bound point queries favor the host-staged "
                   "path; the resident path pays off where padded-block "
                   "transfer volume dominates, and the speedup column is "
                   "expected to flip broadly on a real accelerator "
                   "(ROADMAP: re-measure on TPU)"}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"# wrote {args.out}; mismatches={mismatches or 'none'} "
          f"leaks={leaks or 'none'} regressions={regressions or 'none'} "
          f"geomean={geo} ({time.time() - t0:.1f}s total)")
    return out


# ------------------------------------------------------------- fusion mode

def run_fusion(args) -> dict:
    """Fused single-dispatch chain execution vs the per-hop v2 loop vs the
    host-staged baseline on the jax backend (DESIGN.md §8): same optimized
    plans, three execution paths, with per-query dispatch/compile counts
    from the KernelStats ledger.  Gates on result parity and on the fused
    path's geomean wall being no worse than the per-hop v2 path over the
    ic/point-query set (the dispatch-bound workloads PR 4 measured)."""
    import numpy as np

    from benchmarks import queries as Q
    from repro.core.gopt import GOpt
    from repro.core.physical_spec import get_spec
    from repro.graphdb.engine import Engine
    from repro.graphdb.host_staging import HostStagingOperators
    from repro.graphdb.ldbc import generate_ldbc

    sets = {"ic": (Q.QIC, Q.QIC_PARAMS),
            "cbo": (Q.QC, {}),
            "rbo": (Q.QR, Q.QR_PARAMS),
            "typeinf": (Q.QT, {})}
    t0 = time.time()
    print(f"# building LDBC-like store sf={args.sf} + GLogue ...", flush=True)
    gopt = GOpt(generate_ldbc(sf=args.sf, seed=7))
    print(f"# store: V={gopt.store.n_vertices} E={gopt.store.n_edges} "
          f"({time.time() - t0:.1f}s)", flush=True)
    resident = get_spec("jax").operators(gopt.store)
    staged = HostStagingOperators(resident)

    def timed(run, *a, **kw):
        run(*a, **kw)                     # warmup (jit / chain measuring)
        run(*a, **kw)                     # warmup 2 (fused compile)
        best, stats = float("inf"), None
        tbl = None
        for _ in range(args.repeats):
            t1 = time.perf_counter()
            tbl, stats = run(*a, **kw)
            best = min(best, time.perf_counter() - t1)
        return best, tbl, stats

    results, mismatches, regressions = [], [], []
    for setname in args.queries.split(","):
        queries, params = sets[setname]
        for name, text in queries.items():
            opt = gopt.optimize(text, params.get(name), backend="jax")
            try:
                ref, _ = gopt.execute(opt, backend="numpy",
                                      max_rows=ROW_CAP)
                fused_s, f_tbl, f_stats = timed(
                    gopt.execute, opt, backend="jax", max_rows=ROW_CAP)
                hop_s, h_tbl, h_stats = timed(
                    gopt.execute, opt, backend="jax", max_rows=ROW_CAP,
                    chain_dispatch=False)
                v1_s, v1_tbl, _ = timed(
                    Engine(gopt.store, backend=staged,
                           max_rows=ROW_CAP).run, opt.logical, opt.physical)
            except (RuntimeError, MemoryError) as exc:
                results.append({"set": setname, "query": name,
                                "error": str(exc)[:120]})
                print(f"{setname}/{name}: ERROR {str(exc)[:80]}", flush=True)
                continue
            match = (_tables_equal(ref, f_tbl) and _tables_equal(ref, h_tbl)
                     and _tables_equal(ref, v1_tbl))
            kern = f_stats.kernels or {}
            rec = {
                "set": setname, "query": name, "rows": f_tbl.nrows,
                "match": match,
                "fused_s": fused_s, "perhop_v2_s": hop_s,
                "host_staged_s": v1_s,
                "fused_over_perhop": hop_s / fused_s if fused_s else None,
                "fused_dispatches": kern.get("dispatch:fused_chain", 0),
                "fused_compiles": kern.get("compile:fused_chain", 0),
                "fused_kernels": kern,
                "perhop_kernels": h_stats.kernels,
            }
            results.append(rec)
            if not match:
                mismatches.append(name)
            print(f"{setname}/{name}: fused={fused_s:.4f}s "
                  f"perhop={hop_s:.4f}s staged={v1_s:.4f}s "
                  f"speedup={rec['fused_over_perhop']:.2f}x "
                  f"chain_dispatches={rec['fused_dispatches']} "
                  f"match={match}", flush=True)

    ok = [r for r in results if "error" not in r and r["fused_over_perhop"]]
    geo = (float(np.exp(np.mean(np.log([r["fused_over_perhop"]
                                        for r in ok])))) if ok else None)
    # the ic/point set of the acceptance gate: the LDBC-interactive queries
    # plus the rbo point lookups — not the whole rbo set, whose join-heavy
    # members would average a point-query regression away
    ic_ok = [r for r in ok
             if r["set"] == "ic" or r["query"] in ("Qr5", "Qr6")]
    ic_geo = (float(np.exp(np.mean(np.log([r["fused_over_perhop"]
                                           for r in ic_ok]))))
              if ic_ok else None)
    # acceptance gate: fused geomean wall <= per-hop v2 on the ic/point set
    if ic_geo is not None and ic_geo < 1.0:
        regressions.append(f"ic/point geomean {ic_geo:.3f}x < 1.0")
    out = {"sf": args.sf, "repeats": args.repeats, "results": results,
           "mismatches": mismatches, "regressions": regressions,
           "summary": {"fused_over_perhop_geomean": geo,
                       "ic_point_fused_over_perhop_geomean": ic_geo},
           "note": "fused = single-dispatch chain programs (DESIGN.md §8); "
                   "perhop_v2 = chain_dispatch=False device-resident loop; "
                   "host_staged = PR-3-style padded-block round trips. "
                   "Timings are CPU/interpret; chain compile counts "
                   "amortize across the repeats (pow2-bucketed cache)."}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"# wrote {args.out}; mismatches={mismatches or 'none'} "
          f"regressions={regressions or 'none'} "
          f"geomean={geo} ic_point={ic_geo} ({time.time() - t0:.1f}s total)")
    return out


# ------------------------------------------------------------- legacy mode

def legacy_sweep(base_p: str, opt_p: str) -> None:
    base = {(r["arch"], r["shape"], r["mesh"]): r
            for r in json.load(open(base_p))}
    opt = {(r["arch"], r["shape"], r["mesh"]): r
           for r in json.load(open(opt_p))}
    print("| arch | shape | mem GB (base->opt) | T_m s | T_x s | note |")
    print("|---|---|---|---|---|---|")
    for key in base:
        if key[2] != "16x16":
            continue
        b, o = base.get(key), opt.get(key)
        if not (b and o and b["status"] == "OK" and o["status"] == "OK"):
            continue
        bm = b["bytes_per_device"]["total_gb"]
        om = o["bytes_per_device"]["total_gb"]
        brf, orf = b.get("roofline", {}), o.get("roofline", {})
        note = ""
        if abs(bm - om) / max(bm, 1e-9) > 0.03:
            note = f"{bm/max(om,1e-9):.1f}x mem"
        print(f"| {key[0]} | {key[1]} | {bm:.1f} -> {om:.1f} | "
              f"{brf.get('t_memory_s', 0):.3g} -> "
              f"{orf.get('t_memory_s', 0):.3g} | "
              f"{brf.get('t_collective_s', 0):.3g} -> "
              f"{orf.get('t_collective_s', 0):.3g} | {note} |")


# ------------------------------------------------------------- serve mode

def run_serve(args) -> dict:
    """Open-loop serving comparison (DESIGN.md §9): the same seeded-Poisson
    arrival schedule over an Appendix-A query mix, served through the
    continuous-batching QueryServer vs sequentially, per backend.  Latency
    is measured against the scheduled arrival time (open-loop: a slow
    server pays its own queueing), so the p99 comparison is honest about
    backlog.  Gates on row parity of every batched result against the
    per-binding reference, on batched throughput beating sequential
    (geomean across backends), and on a warmed server's waves recording
    zero fused-chain compiles."""
    import numpy as np

    from benchmarks import queries as Q
    from repro.core.gopt import GOpt
    from repro.graphdb.ldbc import generate_ldbc
    from repro.graphdb.serve import ServeStats, _percentile

    t0 = time.time()
    print(f"# building LDBC-like store sf={args.sf} + GLogue ...", flush=True)
    gopt = GOpt(generate_ldbc(sf=args.sf, seed=7))
    print(f"# store: V={gopt.store.n_vertices} E={gopt.store.n_edges} "
          f"({time.time() - t0:.1f}s)", flush=True)

    # Appendix-A serving mix: parameterized interactive/point lookups (the
    # natural batching workload) plus one parameter-free aggregate (perfect
    # plan coalescing).  Parameter values draw zipf-like from a small hot
    # set — serving traffic has hot keys, which is what within-wave
    # duplicate suppression and the union pattern pass both exploit.
    zw = 1.0 / np.arange(1, 41)
    zw /= zw.sum()

    def zipf_id(rng):
        return int(rng.choice(40, p=zw))

    def mix(rng):
        return [
            ("ic1", Q.QIC["ic1"], lambda: {"pid": zipf_id(rng)}),
            ("Qr5", Q.QR["Qr5"], lambda: {"id1": zipf_id(rng),
                                          "id2": zipf_id(rng)}),
            ("Qr6", Q.QR["Qr6"], lambda: {"id1": zipf_id(rng),
                                          "id2": zipf_id(rng),
                                          "len": 64}),
            ("Qt1", Q.QT["Qt1"], lambda: None),
        ][int(rng.integers(0, 4))]

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    schedule = []
    for at in arrivals:
        name, text, draw = mix(rng)
        schedule.append((float(at), (name, text, draw())))

    results, mismatches, regressions = [], [], []
    for backend in args.backend_list.split(","):
        pqs = {name: gopt.prepare(text, backend=backend)
               for _, (name, text, _p) in schedule}
        # per-binding references double as the warmup (jit, chains, tails)
        ref = {}
        for _, (name, _t, params) in schedule:
            k = (name, tuple(sorted((params or {}).items())))
            if k not in ref:
                ref[k] = pqs[name].execute(params, max_rows=ROW_CAP)[0]

        srv = gopt.serve(backend=backend, max_wave=args.max_wave,
                         max_pending=args.requests + 1, overlap=True)
        # warmup epochs: replay the full schedule through the server.  At
        # an offered rate above capacity the backlog makes wave formation
        # deterministic (FIFO pick + pow2 sizing over an already-full
        # queue), so the measured epoch re-forms the same waves and every
        # traced program — fused chains (capacity growth recompiles once),
        # bucketed tails, shape-dependent glue — is warm.
        wbase = time.perf_counter()
        for _ in range(2):
            for at, (name, text, params) in schedule:
                srv.submit(text, params, arrival_s=wbase + at)
            srv.drain()
        srv.stats = ServeStats()

        # measured epoch: the offered rate is far above service capacity,
        # so the server is backlog-bound from the first wave — pre-queuing
        # the arrival stream (with scheduled arrival stamps, which is what
        # latency is measured against) is the saturated open-loop regime,
        # and keeps wave formation identical to the warmup epochs
        base = time.perf_counter()
        reqs = []
        for at, (name, text, params) in schedule:
            reqs.append((name, srv.submit(text, params,
                                          arrival_s=base + at)))
        srv.drain()
        assert all(r.status == "done" for _, r in reqs)
        batch_span = max(r.finish_s for _, r in reqs) - base - schedule[0][0]
        batch_lat = [r.latency_s for _, r in reqs]
        for name, r in reqs:
            k = (name, tuple(sorted((r.params or {}).items())))
            if not _tables_equal(ref[k], r.table):
                mismatches.append(f"{backend}/{name}{r.params}")
        s = srv.stats.summary()
        warm_chain_compiles = sum(srv.stats.wave_chain_compiles)

        # containment overhead (DESIGN.md §13): the same saturated epoch
        # on the default contained path vs ``containment=False`` (the
        # legacy direct dispatch) — the happy-path cost of the wave
        # try/except + breaker bookkeeping, gated under 5% (min-of-2
        # epochs each to shed scheduler noise)
        def epoch_span(server):
            ebase = time.perf_counter()
            ereqs = [server.submit(text, params, arrival_s=ebase + at)
                     for at, (_n, text, params) in schedule]
            server.drain()
            assert all(r.status == "done" for r in ereqs)
            return max(r.finish_s for r in ereqs) - ebase - schedule[0][0]

        cont_span = min(epoch_span(srv), epoch_span(srv))
        srv.close()
        srv0 = gopt.serve(backend=backend, max_wave=args.max_wave,
                          max_pending=args.requests + 1, overlap=True,
                          containment=False)
        epoch_span(srv0)                                         # warmup
        plain_span = min(epoch_span(srv0), epoch_span(srv0))
        srv0.close()
        containment_overhead = cont_span / plain_span - 1.0

        # sequential baseline: same schedule, one execute per request at
        # its scheduled arrival
        base = time.perf_counter()
        seq_lat, last = [], 0.0
        for at, (name, _t, params) in schedule:
            now = time.perf_counter() - base
            if now < at:
                time.sleep(at - now)
            pqs[name].execute(params, max_rows=ROW_CAP)
            last = time.perf_counter() - base
            seq_lat.append(last - at)
        seq_span = last - schedule[0][0]

        rec = {
            "backend": backend,
            "requests": len(schedule),
            "offered_rate_rps": args.rate,
            "batched_throughput_rps": len(schedule) / batch_span,
            "sequential_throughput_rps": len(schedule) / seq_span,
            "throughput_speedup": seq_span / batch_span,
            "batched_p50_ms": _percentile(batch_lat, 50) * 1e3,
            "batched_p99_ms": _percentile(batch_lat, 99) * 1e3,
            "sequential_p50_ms": _percentile(seq_lat, 50) * 1e3,
            "sequential_p99_ms": _percentile(seq_lat, 99) * 1e3,
            "waves": s["waves"],
            "mean_wave_size": s["mean_wave_size"],
            "mean_occupancy": s["mean_occupancy"],
            "queue_delay_p50_ms": s["queue_delay_p50_ms"],
            "exec_p50_ms": s["exec_p50_ms"],
            "dropped": s["dropped"],
            "deduped": s["deduped"],
            "fallbacks": s["fallbacks"],
            "warm_chain_compiles": warm_chain_compiles,
            "compiles_per_wave": s["compiles_per_wave"],
            "containment_overhead": containment_overhead,
        }
        results.append(rec)
        if warm_chain_compiles:
            regressions.append(f"{backend}: warmed server compiled "
                               f"{warm_chain_compiles} chain program(s)")
        if containment_overhead > 0.05:
            regressions.append(
                f"{backend}: containment overhead "
                f"{containment_overhead * 100:.1f}% > 5% on the happy path")
        print(f"{backend}: batched {rec['batched_throughput_rps']:.1f} rps "
              f"(p99 {rec['batched_p99_ms']:.0f}ms) vs sequential "
              f"{rec['sequential_throughput_rps']:.1f} rps "
              f"(p99 {rec['sequential_p99_ms']:.0f}ms) -> "
              f"{rec['throughput_speedup']:.2f}x, "
              f"{s['waves']} waves mean={s['mean_wave_size']:.1f}, "
              f"containment overhead {containment_overhead * 100:+.1f}%",
              flush=True)

    speedups = [r["throughput_speedup"] for r in results]
    geo = (float(np.exp(np.mean(np.log(speedups)))) if speedups else None)
    if geo is not None and geo <= 1.0:
        regressions.append(f"batched/sequential throughput geomean "
                           f"{geo:.3f}x <= 1.0")
    out = {"sf": args.sf, "requests": args.requests, "rate": args.rate,
           "max_wave": args.max_wave, "seed": args.seed,
           "results": results, "mismatches": mismatches,
           "regressions": regressions,
           "summary": {"batched_over_sequential_geomean": geo},
           "note": "open-loop seeded-Poisson arrivals; latency measured "
                   "against scheduled arrival times, so queueing under an "
                   "overloaded sequential baseline shows up in its p99. "
                   "Timings are CPU/interpret-mode."}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"# wrote {args.out}; mismatches={mismatches or 'none'} "
          f"regressions={regressions or 'none'} geomean={geo} "
          f"({time.time() - t0:.1f}s total)")
    return out


def run_sharded(args) -> dict:
    """Sharded-backend scaling sweep (DESIGN.md §10): run the query set on
    the mesh-partitioned backend at each ``--shards`` count on a
    host-count-faked device mesh, checking row parity against numpy,
    proving the exchange contract (collectives recorded, zero mid-plan
    device->host transfers) and recording shard-count scaling curves to
    ``BENCH_sharded.json``.  The store comes from the *streamed* generator
    so ``--sf`` can exceed single-device generation sizes."""
    # the faked mesh must exist before the first jax import
    import os
    if "jax" not in sys.modules:
        os.environ.setdefault("XLA_FLAGS",
                              "--xla_force_host_platform_device_count=8")
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np

    from benchmarks import queries as Q
    from repro.core.gopt import GOpt
    from repro.core.physical_spec import TransferStats
    from repro.graphdb.ldbc import generate_ldbc_streamed

    sets = {"ic": (Q.QIC, Q.QIC_PARAMS),
            "cbo": (Q.QC, {}),
            "rbo": (Q.QR, Q.QR_PARAMS),
            "typeinf": (Q.QT, {})}
    shard_counts = [int(s) for s in args.shards.split(",")]
    t0 = time.time()
    print(f"# building streamed LDBC-like store sf={args.sf} ...",
          flush=True)
    store = generate_ldbc_streamed(sf=args.sf, seed=args.seed)
    gn = GOpt(store)                     # numpy parity reference
    import jax
    avail = len(jax.devices())
    print(f"# store: V={store.n_vertices} E={store.n_edges} "
          f"({time.time() - t0:.1f}s); mesh devices: {avail}; "
          f"shard sweep: {shard_counts}", flush=True)
    gs = {S: GOpt(store, backend="sharded", devices=S)
          for S in shard_counts}

    results = []
    mismatches, leaks, silent = [], [], []
    for setname in args.queries.split(","):
        queries, params = sets[setname]
        for name, text in queries.items():
            p = params.get(name)
            ref, _ = gn.run(text, params=p)
            rec: dict = {"set": setname, "query": name, "rows": ref.nrows,
                         "match": True, "shards": {}}
            for S in shard_counts:
                try:
                    tbl, st = gs[S].run(text, params=p)   # warmup/compile
                    best = float("inf")
                    for _ in range(args.repeats):
                        t1 = time.perf_counter()
                        tbl, st = gs[S].run(text, params=p)
                        best = min(best, time.perf_counter() - t1)
                except (RuntimeError, MemoryError) as exc:
                    rec["shards"][str(S)] = {"error": str(exc)[:120]}
                    silent.append(f"{name}@{S}")
                    continue
                ex = st.exchanges or {}
                srec = {
                    "wall_s": best,
                    "exchange_calls": sum(v["calls"] for v in ex.values()),
                    "exchange_elems": sum(v["elems"] for v in ex.values()),
                    "mid_plan_d2h": TransferStats.mid_plan_d2h(st.transfers),
                }
                rec["shards"][str(S)] = srec
                if not _tables_equal(ref, tbl):
                    rec["match"] = False
                if srec["mid_plan_d2h"]:
                    leaks.append(f"{name}@{S}")
                # the exchange proof: a multi-shard mesh must move frontier
                # data with recorded collectives, not silently on the host
                if S > 1 and ref.nrows and srec["exchange_calls"] == 0:
                    silent.append(f"{name}@{S}")
            if not rec["match"]:
                mismatches.append(name)
            results.append(rec)
            times = " ".join(
                f"S{S}={rec['shards'][str(S)]['wall_s']:.4f}s"
                if "wall_s" in rec["shards"].get(str(S), {}) else f"S{S}=ERR"
                for S in shard_counts)
            print(f"{setname}/{name}: {times} rows={rec['rows']} "
                  f"match={rec['match']}", flush=True)

    # shard-count scaling curve: geomean wall per shard count, relative to
    # the 1-shard mesh (collective overhead on a faked CPU mesh shows up
    # honestly as >1 walls; on a real interconnect this is the scaling
    # curve the cost model's alpha_exchange would be calibrated from)
    curve = {}
    base = str(shard_counts[0])
    for S in shard_counts:
        ratios = [r["shards"][base]["wall_s"] / r["shards"][str(S)]["wall_s"]
                  for r in results
                  if "wall_s" in r["shards"].get(base, {})
                  and "wall_s" in r["shards"].get(str(S), {})]
        curve[str(S)] = (float(np.exp(np.mean(np.log(ratios))))
                         if ratios else None)
    out = {"sf": args.sf, "shard_counts": shard_counts,
           "mesh_devices": avail, "repeats": args.repeats,
           "results": results, "mismatches": mismatches,
           "mid_plan_d2h_leaks": leaks, "silent_exchanges": silent,
           "speedup_vs_first_geomean": curve}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"# wrote {args.out}; mismatches={mismatches or 'none'} "
          f"leaks={leaks or 'none'} silent={silent or 'none'} "
          f"curve={curve} ({time.time() - t0:.1f}s total)")
    return out


def run_mutations(args) -> dict:
    """Mutation-under-serving sweep (DESIGN.md §11): read latency as a
    function of delta-overlay occupancy, per backend, plus the cost of
    compaction and the post-compaction recovery point.  Every rung gates
    on row parity against a frozen deep-copy oracle of the mutable store
    (MVCC snapshot semantics), and device backends gate on zero mid-plan
    device->host transfers with a non-empty overlay — the delta views
    must stay device-resident like the base CSR."""
    import copy

    import numpy as np

    from repro.core.gopt import GOpt
    from repro.core.physical_spec import TransferStats
    from repro.graphdb.delta import MutableGraphStore
    from repro.graphdb.ldbc import generate_ldbc

    t0 = time.time()
    print(f"# building LDBC-like store sf={args.sf} ...", flush=True)
    base = generate_ldbc(sf=args.sf, seed=7)
    print(f"# store: V={base.n_vertices} E={base.n_edges} "
          f"({time.time() - t0:.1f}s)", flush=True)
    queries = {
        "knows1": ("MATCH (a:PERSON)-[:KNOWS]->(b:PERSON) "
                   "RETURN a.id AS aid, b.id AS bid ORDER BY aid, bid"),
        "knows2": ("MATCH (a:PERSON)-[:KNOWS]->(b:PERSON)-[:KNOWS]->"
                   "(c:PERSON) RETURN a.id AS aid, count(c) AS n "
                   "ORDER BY aid"),
    }
    ladder = [0, 16, 64, 256, 1024]
    backends = args.backend_list.split(",")
    kt = next(t for t in base.out_csr if t.label == "KNOWS")
    off = base.v_offset["PERSON"]
    n_person = base.v_count["PERSON"]

    def rows(tbl):
        ks = sorted(tbl.cols)
        if tbl.nrows == 0:
            return []
        return sorted(zip(*[np.asarray(tbl.cols[k]).tolist() for k in ks]))

    results, mismatches, leaks = [], [], []
    for backend in backends:
        ms = MutableGraphStore(base)
        gopt = GOpt(ms, backend=backend)
        rng = np.random.default_rng(args.seed)
        rec = {"backend": backend, "rungs": [], "compaction": None}
        pre_rows = None
        for occ in ladder:
            while ms.overlay_edge_slots < occ:
                src = off + int(rng.integers(0, n_person))
                gid = ms.insert_vertex(
                    "PERSON", {"id": 700_000 + ms.overlay_edge_slots})
                ms.insert_edge(kt, src, gid)
            oracle = GOpt(copy.deepcopy(ms), backend="numpy")
            rung = {"overlay_edges": int(ms.overlay_edge_slots),
                    "queries": {}}
            for name, text in queries.items():
                gopt.run(text)                       # warm (compiles)
                walls = []
                for _ in range(max(args.repeats, 1)):
                    w0 = time.perf_counter()
                    tbl, stats = gopt.run(text)
                    walls.append(time.perf_counter() - w0)
                ref, _ = oracle.run(text)
                ok = rows(tbl) == rows(ref)
                if not ok:
                    mismatches.append(f"{backend}/{name}@{occ}")
                if backend != "numpy" and stats.transfers is not None:
                    d2h = TransferStats.mid_plan_d2h(stats.transfers)
                    if d2h:
                        leaks.append(f"{backend}/{name}@{occ}:{d2h}")
                rung["queries"][name] = {"wall_s": float(min(walls)),
                                         "rows": int(tbl.nrows),
                                         "match": ok}
            rec["rungs"].append(rung)
            print(f"#   {backend} occ={occ}: " +
                  " ".join(f"{n}={q['wall_s'] * 1e3:.1f}ms"
                           for n, q in rung["queries"].items()), flush=True)
        pre_rows = {n: rows(gopt.run(t)[0]) for n, t in queries.items()}
        w0 = time.perf_counter()
        ev = gopt.compact()
        compact_wall = time.perf_counter() - w0
        post = {}
        for name, text in queries.items():
            gopt.run(text)                           # recompile vs new base
            w0 = time.perf_counter()
            tbl, _ = gopt.run(text)
            post[name] = {"wall_s": float(time.perf_counter() - w0),
                          "match": rows(tbl) == pre_rows[name]}
            if not post[name]["match"]:
                mismatches.append(f"{backend}/{name}@post-compaction")
        rec["compaction"] = {"wall_s": float(compact_wall),
                             "merged_edges": ev["merged_edges"],
                             "ext_vertices": ev["ext_vertices"],
                             "post": post}
        print(f"#   {backend} compaction {compact_wall * 1e3:.0f}ms "
              f"(merged {ev['merged_edges']} edges); recovery " +
              " ".join(f"{n}={q['wall_s'] * 1e3:.1f}ms"
                       for n, q in post.items()), flush=True)
        results.append(rec)

    out = {"sf": args.sf, "ladder": ladder, "backends": backends,
           "repeats": args.repeats, "results": results,
           "mismatches": mismatches, "mid_plan_d2h_leaks": leaks}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"# wrote {args.out}; mismatches={mismatches or 'none'} "
          f"leaks={leaks or 'none'} ({time.time() - t0:.1f}s total)")
    return out


# ------------------------------------------------------------- CI registry

# the smoke-scale CI invocations: scripts/ci.sh drives these through
# --list-benches (name <TAB> argv) instead of hard-coding bench names
CI_BENCHES = [
    ("backends", "--backends --sf 0.05 --repeats 1 --queries ic "
                 "--out BENCH_backends_smoke.json"),
    ("prepared", "--prepared --sf 0.05 --repeats 1 "
                 "--out BENCH_prepared_smoke.json"),
    ("sharded", "--sharded --sf 0.05 --repeats 1 --queries ic "
                "--shards 1,4 --out BENCH_sharded_smoke.json"),
    ("mutations", "--mutations --sf 0.05 --repeats 1 "
                  "--out BENCH_mutations_smoke.json"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", action="store_true",
                    help="compare PhysicalSpec execution backends")
    ap.add_argument("--prepared", action="store_true",
                    help="compare prepared vs unprepared execution")
    ap.add_argument("--residency", action="store_true",
                    help="compare device-resident vs host-staged jax paths")
    ap.add_argument("--fusion", action="store_true",
                    help="compare fused single-dispatch chains vs the "
                         "per-hop v2 loop vs the host-staged baseline")
    ap.add_argument("--serve", action="store_true",
                    help="compare continuous-batching QueryServer serving "
                         "vs sequential execution on an open-loop stream")
    ap.add_argument("--sharded", action="store_true",
                    help="sharded-backend shard-count scaling sweep on a "
                         "host-count-faked device mesh")
    ap.add_argument("--mutations", action="store_true",
                    help="read-latency vs delta-overlay occupancy sweep "
                         "with compaction cost and recovery")
    ap.add_argument("--shards", default="1,2,4,8",
                    help="--sharded: comma list of shard counts to sweep")
    ap.add_argument("--list-benches", action="store_true",
                    help="print the CI smoke-bench registry "
                         "(name<TAB>argv per line) and exit")
    ap.add_argument("--requests", type=int, default=200,
                    help="--serve: number of open-loop requests")
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="--serve: offered Poisson arrival rate (req/s); "
                         "above sequential capacity, so queues build and "
                         "coalescing has something to coalesce")
    ap.add_argument("--max-wave", type=int, default=16,
                    help="--serve: max requests coalesced per wave")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--gate-perf", action="store_true",
                    help="with --residency: also fail on per-query wall-time"
                         " regressions (meaningful on a real accelerator)")
    ap.add_argument("--backend-list", default="numpy,jax")
    ap.add_argument("--sf", type=float, default=0.2)
    ap.add_argument("--queries", default="ic,cbo",
                    help="comma list of ic,cbo,rbo,typeinf (--backends mode)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None)
    ap.add_argument("files", nargs="*",
                    help="legacy mode: base/optimized dryrun result files")
    args = ap.parse_args()
    if args.list_benches:
        for name, argv in CI_BENCHES:
            print(f"{name}\t{argv}")
        sys.exit(0)
    if args.sharded:
        args.out = args.out or "BENCH_sharded.json"
        out = run_sharded(args)
        sys.exit(1 if out["mismatches"] or out["mid_plan_d2h_leaks"]
                 or out["silent_exchanges"] else 0)
    if args.mutations:
        args.out = args.out or "BENCH_mutations.json"
        out = run_mutations(args)
        sys.exit(1 if out["mismatches"] or out["mid_plan_d2h_leaks"] else 0)
    if args.backends:
        args.out = args.out or "BENCH_backends.json"
        out = run_backends(args)
        sys.exit(1 if out["mismatches"] or out["unverified"] else 0)
    if args.prepared:
        args.out = args.out or "BENCH_prepared.json"
        out = run_prepared(args)
        sys.exit(1 if out["mismatches"] or out["slow_backends"]
                 or out["verify_overhead"]["exceeded"] else 0)
    if args.residency:
        args.out = args.out or "BENCH_residency.json"
        out = run_residency(args)
        fail = bool(out["mismatches"] or out["mid_plan_d2h_leaks"])
        if args.gate_perf:
            fail = fail or bool(out["regressions"])
        sys.exit(1 if fail else 0)
    if args.fusion:
        args.out = args.out or "BENCH_fusion.json"
        out = run_fusion(args)
        sys.exit(1 if out["mismatches"] or out["regressions"] else 0)
    if args.serve:
        args.out = args.out or "BENCH_serve.json"
        out = run_serve(args)
        sys.exit(1 if out["mismatches"] or out["regressions"] else 0)
    base_p = args.files[0] if args.files else "dryrun_results.json"
    opt_p = (args.files[1] if len(args.files) > 1
             else "dryrun_results_optimized.json")
    legacy_sweep(base_p, opt_p)


if __name__ == "__main__":
    main()
