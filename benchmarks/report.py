"""Render dryrun_results.json into the EXPERIMENTS.md §Dry-run and
§Roofline markdown tables.

    PYTHONPATH=src python -m benchmarks.report dryrun_results.json
"""
from __future__ import annotations

import json
import sys


def _gb(x) -> str:
    return f"{x/2**30:.2f}"


def render(results: list[dict]) -> str:
    out = []
    out.append("### Dry-run matrix (lower+compile per cell)\n")
    out.append("| arch | shape | mesh | status | bytes/device (GB) | "
               "compile (s) | collectives |")
    out.append("|---|---|---|---|---|---|---|")
    for r in results:
        if r["status"] == "OK":
            mem = _gb(sum(r["bytes_per_device"][k]
                          for k in ("arguments", "outputs", "temps")))
            colls = ""
            if "roofline" in r:
                colls = ",".join(
                    f"{k.replace('all-','a-').replace('collective-','c-')}:"
                    f"{v/2**30:.2f}GB"
                    for k, v in sorted(
                        r["roofline"].get("collectives", {}).items()))
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
                       f"{mem} | {r['compile_s']} | {colls} |")
        elif r["status"] == "SKIPPED":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | "
                       f"— | — | {r['reason'][:60]} |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | "
                       f"— | — | {r.get('error','')[:60]} |")
    out.append("")
    out.append("### Roofline terms (single-pod 16x16, per chip, seconds)\n")
    out.append("| arch | shape | T_compute | T_memory | T_collective | "
               "dominant | MODEL_FLOPS/HLO_FLOPS | roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in results:
        if r["status"] != "OK" or r["mesh"] != "16x16" or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute_s']:.3g} | "
            f"{rf['t_memory_s']:.3g} | {rf['t_collective_s']:.3g} | "
            f"{rf['dominant']} | {rf['useful_flops_ratio']:.3f} | "
            f"{rf['roofline_fraction']:.4f} |")
    out.append("")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    print(render(results))


if __name__ == "__main__":
    main()
