"""One benchmark per paper table/figure (EXPERIMENTS.md §Repro).

Fig 7(a) type inference  -> table_type_inference
Fig 7(b) heuristic rules -> table_rbo
Fig 7(c) CBO vs plans    -> table_cbo
Fig 7(d) LDBC workloads  -> table_ldbc
Fig 8(a) data scaling    -> table_scaling
Fig 9/10 money mule      -> table_money_mule

Each returns a list of row dicts and appends CSV lines to the shared
collector. "OT" = exceeded the row cap (the paper's 1h timeout analogue).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import queries as Q
from repro.core.cbo import random_plan
from repro.core.gopt import GOpt
from repro.core.physical import ExpandNode, JoinNode, ScanNode, plan_signature
from repro.graphdb.ldbc import generate_ldbc

OT = float("nan")
ROW_CAP = 8_000_000


def _time_exec(gopt, opt, repeats=3, **kw) -> tuple[float, int]:
    """(best wall seconds, result count or -1 on OT)."""
    best = None
    count = -1
    for _ in range(repeats):
        try:
            t0 = time.perf_counter()
            tbl, stats = gopt.execute(opt, max_rows=ROW_CAP, **kw)
            dt = time.perf_counter() - t0
        except (RuntimeError, MemoryError):
            return OT, -1
        best = dt if best is None else min(best, dt)
        if tbl.nrows:
            first = tbl.cols[list(tbl.cols)[0]]
            count = int(first[0]) if first.shape[0] == 1 else tbl.nrows
    return best, count


def _fmt(x: float) -> str:
    return "OT" if x != x else f"{x*1e6:.0f}"


class Collector:
    def __init__(self):
        self.lines: list[str] = []

    def add(self, name: str, us: float, derived: str = ""):
        self.lines.append(f"{name},{_fmt(us)},{derived}")
        print(f"{name},{_fmt(us)},{derived}", flush=True)


def make_gopt(sf: float, seed: int = 7) -> GOpt:
    return GOpt(generate_ldbc(sf=sf, seed=seed))


# ---------------------------------------------------------------- Fig 7(a)
def table_type_inference(gopt: GOpt, coll: Collector):
    rows = []
    for name, text in Q.QT.items():
        on = gopt.optimize(text, type_inference=True)
        t_on, c_on = _time_exec(gopt, on)
        off = gopt.optimize(text, type_inference=False)
        t_off, c_off = _time_exec(gopt, off)
        assert c_on == c_off or c_off == -1, (name, c_on, c_off)
        speedup = (t_off / t_on) if t_off == t_off else float("inf")
        coll.add(f"typeinf/{name}/on", t_on, f"count={c_on}")
        coll.add(f"typeinf/{name}/off", t_off, f"speedup={speedup:.1f}x")
        rows.append({"query": name, "on_s": t_on, "off_s": t_off,
                     "speedup": speedup, "count": c_on})
    return rows


# ---------------------------------------------------------------- Fig 7(b)
def table_rbo(gopt: GOpt, coll: Collector):
    rows = []
    modes = {
        "Qr1": ("trim", {}), "Qr2": ("trim", {}),
        "Qr3": ("fuse", {}), "Qr4": ("fuse", {}),
        "Qr5": ("filter", {}), "Qr6": ("filter", {}),
    }
    for name, text in Q.QR.items():
        params = Q.QR_PARAMS.get(name)
        rule, _ = modes[name]
        if rule == "trim":
            on = gopt.optimize(text, params)
            t_on, c_on = _time_exec(gopt, on, trim_fields=True)
            t_off, c_off = _time_exec(gopt, on, trim_fields=False)
        elif rule == "fuse":
            on = gopt.optimize(text, params)
            t_on, c_on = _time_exec(gopt, on, fuse_expand=True)
            t_off, c_off = _time_exec(gopt, on, fuse_expand=False)
        else:  # FilterIntoMatchRule: rbo off keeps SELECT at the end
            on = gopt.optimize(text, params, rbo=True)
            t_on, c_on = _time_exec(gopt, on)
            off = gopt.optimize(text, params, rbo=False)
            t_off, c_off = _time_exec(gopt, off)
        assert c_on == c_off or -1 in (c_on, c_off), (name, c_on, c_off)
        speedup = (t_off / t_on) if t_off == t_off else float("inf")
        coll.add(f"rbo/{name}/{rule}-on", t_on, f"count={c_on}")
        coll.add(f"rbo/{name}/{rule}-off", t_off, f"speedup={speedup:.1f}x")
        rows.append({"query": name, "rule": rule, "on_s": t_on,
                     "off_s": t_off, "speedup": speedup})
    return rows


# ---------------------------------------------------------------- Fig 7(c)
def table_cbo(gopt: GOpt, coll: Collector, n_random: int = 10):
    import random as _r
    rows = []
    for name, text in Q.QC.items():
        opt = gopt.optimize(text)
        t_gopt, c = _time_exec(gopt, opt)
        # Neo4j-style low-order plan
        neo = gopt.neo4j_style_plan(opt.logical.pattern())
        opt_neo = type(opt)(opt.logical, neo, 0.0)
        t_neo, c_neo = _time_exec(gopt, opt_neo)
        # random plans
        rng = _r.Random(42)
        t_rand = []
        for i in range(n_random):
            rp = random_plan(opt.logical.pattern(), rng)
            t_r, _c = _time_exec(gopt, type(opt)(opt.logical, rp, 0.0),
                                 repeats=1)
            t_rand.append(t_r)
        finite = [t for t in t_rand if t == t]
        mean_rand = float(np.mean(finite)) if finite else OT
        n_ot = sum(1 for t in t_rand if t != t)
        coll.add(f"cbo/{name}/gopt", t_gopt,
                 f"count={c};plan={plan_signature(opt.physical)}")
        coll.add(f"cbo/{name}/neo4j-style", t_neo,
                 f"x{(t_neo/t_gopt) if t_neo==t_neo else float('inf'):.1f}")
        coll.add(f"cbo/{name}/random-mean", mean_rand,
                 f"n_ot={n_ot}/{n_random}")
        rows.append({"query": name, "gopt_s": t_gopt, "neo4j_s": t_neo,
                     "rand_mean_s": mean_rand, "rand_ot": n_ot})
    return rows


# ---------------------------------------------------------------- Fig 7(d)
def table_ldbc(gopt: GOpt, coll: Collector, n_random: int = 5):
    import random as _r
    rows = []
    for name, text in Q.QIC.items():
        params = Q.QIC_PARAMS[name]
        opt = gopt.optimize(text, params)
        t_gopt, c = _time_exec(gopt, opt)
        neo = gopt.neo4j_style_plan(opt.logical.pattern())
        t_neo, _ = _time_exec(gopt, type(opt)(opt.logical, neo, 0.0))
        rng = _r.Random(7)
        t_rand = []
        for _i in range(n_random):
            rp = random_plan(opt.logical.pattern(), rng)
            t_r, _c = _time_exec(gopt, type(opt)(opt.logical, rp, 0.0),
                                 repeats=1)
            t_rand.append(t_r)
        finite = [t for t in t_rand if t == t]
        coll.add(f"ldbc/{name}/gopt", t_gopt, f"rows={c}")
        coll.add(f"ldbc/{name}/neo4j-style", t_neo,
                 f"x{(t_neo/t_gopt) if t_neo==t_neo else float('inf'):.1f}")
        rand_mean = float(np.mean(finite)) if finite else OT
        coll.add(f"ldbc/{name}/random-mean", rand_mean,
                 f"n_ot={n_random-len(finite)}/{n_random}")
        rows.append({"query": name, "gopt_s": t_gopt, "neo4j_s": t_neo,
                     "rand_mean_s": rand_mean})
    return rows


# ---------------------------------------------------------------- Fig 8(a)
def table_scaling(coll: Collector, sfs=(0.3, 1.0, 3.0)):
    rows = []
    base: dict[str, float] = {}
    for sf in sfs:
        gopt = make_gopt(sf)
        for name, text in list(Q.QIC.items())[:4]:
            opt = gopt.optimize(text, Q.QIC_PARAMS[name])
            t, _ = _time_exec(gopt, opt)
            if sf == sfs[0]:
                base[name] = t
            coll.add(f"scaling/sf{sf}/{name}", t,
                     f"rel={t/base[name]:.2f}x" if base.get(name) else "")
            rows.append({"sf": sf, "query": name, "t_s": t})
    return rows


# ---------------------------------------------------------------- Fig 9/10
def table_money_mule(gopt: GOpt, coll: Collector, hops: int = 3):
    rng = np.random.default_rng(11)
    n_person = gopt.store.v_count["PERSON"]
    rows = []
    settings = [(3, 400), (400, 3), (30, 30), (2, 1500), (800, 800)]
    for si, (n1, n2) in enumerate(settings):
        S1 = sorted(rng.choice(n_person, size=n1, replace=False).tolist())
        S2 = sorted(rng.choice(n_person, size=n2, replace=False).tolist())
        params = {"S1": S1, "S2": S2, "hops": hops}
        opt = gopt.optimize(Q.MONEY_MULE, params)
        t_gopt, c = _time_exec(gopt, opt, repeats=2)
        pattern = opt.logical.pattern()
        # alternatives: join at every split position 0..hops (0/hops =
        # single-direction expansion)
        aliases = ["p1"] + [f"__k#{h}_h{0}_0" for h in range(hops)]
        # reconstruct hop aliases from the expanded pattern
        chain = _path_aliases(pattern, "p1", "p2")
        alts = {}
        for pos in range(0, hops + 1):
            alts[f"({pos},{hops-pos})"] = _split_plan(pattern, chain, pos)
        best_alt, results = None, {}
        for k, plan in alts.items():
            t_alt, _ = _time_exec(gopt, type(opt)(opt.logical, plan, 0.0),
                                  repeats=1)
            results[k] = t_alt
            if t_alt == t_alt and (best_alt is None or t_alt < best_alt):
                best_alt = t_alt
        coll.add(f"moneymule/ST{si+1}/gopt", t_gopt,
                 f"|S1|={n1};|S2|={n2};count={c};"
                 f"plan={plan_signature(opt.physical)}")
        for k, t in results.items():
            coll.add(f"moneymule/ST{si+1}/alt{k}", t, "")
        rows.append({"setting": si, "gopt_s": t_gopt, "alts": results})
    return rows


def _path_aliases(pattern, start, end):
    """Order path vertices from start to end."""
    chain = [start]
    prev = None
    cur = start
    while cur != end:
        for e in pattern.adjacent(cur):
            o = e.other(cur)
            if o != prev:
                chain.append(o)
                prev, cur = cur, o
                break
    return chain


def _split_plan(pattern, chain, pos):
    """Plan joining a left expansion of `pos` hops from p1 with a right
    expansion of the rest from p2; pos 0/len = single direction."""
    def left_deep(order):
        node = ScanNode(order[0])
        bound = {order[0]}
        for a in order[1:]:
            edges = [e for e in pattern.adjacent(a) if e.other(a) in bound]
            node = ExpandNode(node, a, edges)
            bound.add(a)
        return node
    if pos == 0:
        return left_deep(list(reversed(chain)))
    if pos == len(chain) - 1:
        return left_deep(chain)
    join_alias = chain[pos]
    left = left_deep(chain[:pos + 1])
    right = left_deep(list(reversed(chain[pos:])))
    return JoinNode(left, right, (join_alias,))
