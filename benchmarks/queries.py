"""Benchmark queries — the paper's Appendix A adapted to the LDBC-SNB
schema subset in repro.core.schema (PLACE split into CITY/COUNTRY; MESSAGE is
the POST|COMMENT union, written out explicitly)."""

# ---- Q_t[1..5]: type-inference evaluation (paper Listing 1) --------------
QT = {
    "Qt1": "Match (p)<-[:HASCREATOR]-(m)<-[:CONTAINEROF]-(f) "
           "Return count(p)",
    "Qt2": "Match (p)-[]->(o:ORGANISATION)-[]->(c:COUNTRY) Return count(p)",
    "Qt3": "Match (p)<-[:ISLOCATEDIN]-(x)-[]->(t:TAG) Return count(p)",
    "Qt4": "Match (p1)<-[]-(p2:POST), (p1)<-[:HASMODERATOR]-(f)-[]->(p2) "
           "Return count(p1)",
    "Qt5": "Match (p1:POST)-[]->(p2), (p2)-[]->(c:CITY) Return count(p2)",
}

# ---- Q_r[1..6]: RBO rules (paper Listing 2) ------------------------------
# Qr1/2 -> FieldTrimRule; Qr3/4 -> ExpandGetVFusionRule;
# Qr5/6 -> FilterIntoMatchRule
QR = {
    "Qr1": ("Match (message:COMMENT|POST)-[:HASCREATOR]->(person:PERSON), "
            "(message)-[:HASTAG]->(tag:TAG), "
            "(person)-[:HASINTEREST]->(tag) Return count(person)"),
    "Qr2": ("Match (p:COMMENT)-[]->(p2:PERSON)-[]->(c:CITY), "
            "(p)<-[]-(message), (message)-[]->(tag:TAG) Return count(c)"),
    "Qr3": ("Match (author:PERSON)<-[:HASCREATOR]-(msg1:POST|COMMENT) "
            "Return count(author)"),
    "Qr4": ("Match (author:PERSON)<-[:HASCREATOR]-(msg1:POST|COMMENT) "
            "Where msg1.length > $len Return count(author)"),
    "Qr5": ("Match (p1:PERSON)-[:KNOWS]->(p2:PERSON) "
            "Where p1.id = $id1 and p2.id = $id2 Return count(p1)"),
    "Qr6": ("Match (p1:PERSON)-[:KNOWS]->(p2:PERSON)-[:LIKES]->"
            "(comment:COMMENT) Where p1.id = $id1 and p2.id = $id2 and "
            "comment.length > $len Return count(p1)"),
}
QR_PARAMS = {"Qr4": {"len": 128}, "Qr5": {"id1": 3, "id2": 7},
             "Qr6": {"id1": 3, "id2": 7, "len": 64}}

# ---- Q_c[1..4(a|b)]: CBO (paper Listing 3) -------------------------------
QC = {
    "Qc1a": ("Match (message:POST|COMMENT)-[:HASCREATOR]->(person:PERSON), "
             "(message)-[:HASTAG]->(tag:TAG), "
             "(person)-[:HASINTEREST]->(tag) Return count(person)"),
    "Qc1b": ("Match (message:PERSON|FORUM)-[:KNOWS|HASMODERATOR]->"
             "(person:PERSON), (message)-[]->(tag:TAG), "
             "(person)-[]->(tag) Return count(person)"),
    "Qc2a": ("Match (person1:PERSON)-[:LIKES]->(message:POST|COMMENT), "
             "(message)-[:HASCREATOR]->(person2:PERSON), "
             "(person1)<-[:HASMODERATOR]-(place:FORUM), "
             "(person2)<-[:HASMODERATOR]-(place) Return count(person1)"),
    "Qc2b": ("Match (person1:PERSON)-[:LIKES]->(message:POST), "
             "(message)<-[:CONTAINEROF]-(person2:FORUM), "
             "(person1)-[:KNOWS|HASINTEREST]->(place:PERSON|TAG), "
             "(person2)-[:HASMODERATOR|HASTAG]->(place) "
             "Return count(person1)"),
    "Qc3a": ("Match (person1:PERSON)<-[:HASCREATOR]-(comment:COMMENT), "
             "(comment)-[:REPLYOF]->(post:POST), "
             "(post)<-[:CONTAINEROF]-(forum:FORUM), "
             "(forum)-[:HASMEMBER]->(person2:PERSON) Return count(person1)"),
    "Qc3b": ("Match (p:COMMENT)-[]->(pp:PERSON)-[]->(ct:CITY), "
             "(p)<-[]-(message), (message)-[]->(tag:TAG) Return count(p)"),
    "Qc4a": ("Match (forum:FORUM)-[:CONTAINEROF]->(post:POST), "
             "(forum)-[:HASMEMBER]->(person1:PERSON), "
             "(forum)-[:HASMEMBER]->(person2:PERSON), "
             "(person1)-[:KNOWS]->(person2), "
             "(person1)-[:LIKES]->(post), "
             "(person2)-[:LIKES]->(post) Return count(person1)"),
    "Qc4b": ("Match (forum:FORUM)-[:HASTAG]->(post:TAG), "
             "(forum)-[:HASMODERATOR]->(person1:PERSON), "
             "(forum)-[:HASMODERATOR|CONTAINEROF]->(person2:PERSON|POST), "
             "(person1)-[:KNOWS|LIKES]->(person2), "
             "(person1)-[:HASINTEREST]->(post), "
             "(person2)-[:HASINTEREST|HASTAG]->(post) "
             "Return count(person1)"),
}

# ---- LDBC-interactive-complex-like workload ------------------------------
# The official IC queries use WITH/OPTIONAL; these keep each query's pattern
# core + relational tail inside the supported subset.
QIC = {
    "ic1": ("MATCH (p:PERSON)-[:KNOWS*2]-(friend:PERSON) "
            "WHERE p.id = $pid RETURN friend, count(p) AS c "
            "ORDER BY c DESC LIMIT 20"),
    "ic3": ("MATCH (p:PERSON)-[:KNOWS]-(friend:PERSON), "
            "(friend)<-[:HASCREATOR]-(m:POST|COMMENT), "
            "(m)-[:HASTAG]->(t:TAG) WHERE p.id = $pid "
            "RETURN friend, count(m) AS cnt ORDER BY cnt DESC LIMIT 20"),
    "ic5": ("MATCH (p:PERSON)-[:KNOWS]-(friend:PERSON), "
            "(friend)<-[:HASMEMBER]-(f:FORUM), "
            "(f)-[:CONTAINEROF]->(post:POST), "
            "(post)-[:HASCREATOR]->(friend) WHERE p.id = $pid "
            "RETURN f, count(post) AS posts ORDER BY posts DESC LIMIT 20"),
    "ic6": ("MATCH (p:PERSON)-[:KNOWS*2]-(friend:PERSON), "
            "(friend)<-[:HASCREATOR]-(post:POST), "
            "(post)-[:HASTAG]->(t:TAG) WHERE p.id = $pid "
            "RETURN t, count(post) AS cnt ORDER BY cnt DESC LIMIT 10"),
    "ic11": ("MATCH (p:PERSON)-[:KNOWS]-(friend:PERSON), "
             "(friend)-[:WORKAT]->(org:ORGANISATION), "
             "(org)-[:ISLOCATEDIN]->(c:COUNTRY) WHERE p.id = $pid "
             "RETURN friend, org, count(c) AS n ORDER BY n LIMIT 10"),
    "ic12": ("MATCH (p:PERSON)-[:KNOWS]-(friend:PERSON), "
             "(friend)<-[:HASCREATOR]-(comment:COMMENT), "
             "(comment)-[:REPLYOF]->(post:POST), (post)-[:HASTAG]->(t:TAG), "
             "(t)-[:HASTYPE]->(tc:TAGCLASS) WHERE p.id = $pid "
             "RETURN friend, count(comment) AS cnt "
             "ORDER BY cnt DESC LIMIT 20"),
}
QIC_PARAMS = {k: {"pid": 5} for k in QIC}

MONEY_MULE = ("MATCH (p1:PERSON)-[k:KNOWS*$hops]-(p2:PERSON) "
              "WHERE p1.id IN $S1 and p2.id IN $S2 RETURN count(p1)")
