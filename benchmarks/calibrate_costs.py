"""Derive backend ``CostParams`` alphas from ``BENCH_backends.json``.

The PhysicalSpec cost model (DESIGN.md §2.3) weighs the CBO's Eq. 2/3 terms
per backend.  This script turns the measured per-query timings of
``perf_compare --backends`` into relative alphas for the non-reference
backends, using the benchmark queries as probes of each operator class:

- *expand-dominated* probes (chain patterns — no cycle-closing edges, so no
  WCOJ membership probes) measure the backend's neighbor-expansion cost
  relative to numpy;
- *intersect-heavy* probes (cyclic patterns whose CBO plans close edges via
  expand-and-intersect) measure the WCOJ membership-probe cost; the
  expand baseline is divided out.

The derived numbers are hard-coded into each backend's registration (see
``graphdb/jax_backend.py``) so the CBO can rank operators backend-optimally
without needing the bench file at import time.  Re-run after re-benchmarking:

    PYTHONPATH=src python -m benchmarks.perf_compare --backends
    PYTHONPATH=src python -m benchmarks.calibrate_costs [BENCH_backends.json]
"""
from __future__ import annotations

import json
import sys

import numpy as np

# Probe classes over the Appendix-A benchmark sets. Chains exercise scan +
# expand only; cycles additionally pay one-or-more intersect probes per
# result row (their CBO plans contain ExpandIntersect steps).
EXPAND_PROBES = ("Qc3a", "Qr3", "Qt1", "Qt2", "Qt3", "ic11", "ic12")
INTERSECT_PROBES = ("Qc1a", "Qc1b", "Qc2a", "Qc2b", "Qc4a", "Qc4b", "Qr1")


def _geomean(xs):
    xs = [x for x in xs if x and np.isfinite(x)]
    return float(np.exp(np.mean(np.log(xs)))) if xs else None


def calibrate(bench: dict, base: str = "numpy") -> dict:
    """Per-backend alpha suggestions relative to ``base``."""
    out = {}
    by_query = {r["query"]: r for r in bench["results"]}

    def ratios(backend, names):
        return [by_query[q][f"{backend}_s"] / by_query[q][f"{base}_s"]
                for q in names
                if by_query.get(q, {}).get(f"{backend}_s")
                and by_query.get(q, {}).get(f"{base}_s")]

    for backend in bench["backends"]:
        if backend == base:
            continue
        r_expand = _geomean(ratios(backend, EXPAND_PROBES))
        r_cycle = _geomean(ratios(backend, INTERSECT_PROBES))
        if r_expand is None or r_cycle is None:
            continue
        # cyclic queries pay expand AND intersect; attribute the slowdown
        # beyond the expand baseline to the membership probes
        alpha_intersect = max(r_cycle / r_expand, 1.0) * max(r_expand, 1.0)
        out[backend] = {
            "alpha_scan": 1.0,                     # range scans: trivial both
            "alpha_expand": round(max(r_expand, 0.5), 1),
            "alpha_intersect": round(alpha_intersect, 1),
            "alpha_join": 1.0,                     # host-path join inherited
            "evidence": {
                "expand_ratio_geomean": round(r_expand, 3),
                "cycle_ratio_geomean": round(r_cycle, 3),
                "expand_probes": EXPAND_PROBES,
                "intersect_probes": INTERSECT_PROBES,
            },
        }
    return out


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_backends.json"
    with open(path) as f:
        bench = json.load(f)
    out = calibrate(bench)
    print(json.dumps(out, indent=1))
    for backend, alphas in out.items():
        print(f"\n# suggested registration for {backend!r}:")
        print(f"cost=CostParams(alpha_scan={alphas['alpha_scan']}, "
              f"alpha_expand={alphas['alpha_expand']}, "
              f"alpha_intersect={alphas['alpha_intersect']}, "
              f"alpha_join={alphas['alpha_join']})")


if __name__ == "__main__":
    main()
