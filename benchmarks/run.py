"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus a summary) and writes
EXPERIMENTS-ready JSON to benchmarks/results.json.

    PYTHONPATH=src python -m benchmarks.run            # default scale
    PYTHONPATH=src python -m benchmarks.run --sf 1.0 --tables cbo,ldbc
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks import paper_tables as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=1.0,
                    help="LDBC-like scale factor (paper uses 30..1000; "
                    "CPU-budget default 1.0)")
    ap.add_argument("--tables", default="typeinf,rbo,cbo,ldbc,scaling,"
                    "moneymule")
    ap.add_argument("--out", default="benchmarks/results.json")
    args = ap.parse_args()
    tables = set(args.tables.split(","))

    coll = T.Collector()
    results = {"sf": args.sf}
    t0 = time.time()
    print(f"# building LDBC-like store sf={args.sf} + GLogue ...", flush=True)
    gopt = T.make_gopt(args.sf)
    print(f"# store: V={gopt.store.n_vertices} E={gopt.store.n_edges} "
          f"glogue={len(gopt.glogue.freq)} entries "
          f"({time.time()-t0:.1f}s)", flush=True)

    if "typeinf" in tables:
        results["type_inference"] = T.table_type_inference(gopt, coll)
    if "rbo" in tables:
        results["rbo"] = T.table_rbo(gopt, coll)
    if "cbo" in tables:
        results["cbo"] = T.table_cbo(gopt, coll)
    if "ldbc" in tables:
        results["ldbc"] = T.table_ldbc(gopt, coll)
    if "scaling" in tables:
        results["scaling"] = T.table_scaling(coll)
    if "moneymule" in tables:
        results["money_mule"] = T.table_money_mule(gopt, coll)

    # ------------------------------------------------------------- summary
    def _geo(xs):
        xs = [x for x in xs if x == x and np.isfinite(x) and x > 0]
        return float(np.exp(np.mean(np.log(xs)))) if xs else float("nan")

    summary = {}
    if "type_inference" in results:
        summary["typeinf_geomean_speedup"] = _geo(
            [r["speedup"] for r in results["type_inference"]])
    if "rbo" in results:
        for rule in ("trim", "fuse", "filter"):
            summary[f"rbo_{rule}_geomean_speedup"] = _geo(
                [r["speedup"] for r in results["rbo"] if r["rule"] == rule])
    if "cbo" in results:
        summary["cbo_vs_neo4j_geomean"] = _geo(
            [r["neo4j_s"] / r["gopt_s"] for r in results["cbo"]
             if r["neo4j_s"] == r["neo4j_s"]])
        summary["cbo_vs_random_geomean"] = _geo(
            [r["rand_mean_s"] / r["gopt_s"] for r in results["cbo"]
             if r["rand_mean_s"] == r["rand_mean_s"]])
    if "ldbc" in results:
        summary["ldbc_vs_neo4j_geomean"] = _geo(
            [r["neo4j_s"] / r["gopt_s"] for r in results["ldbc"]
             if r["neo4j_s"] == r["neo4j_s"]])
        summary["ldbc_vs_random_geomean"] = _geo(
            [r.get("rand_mean_s", float("nan")) / r["gopt_s"]
             for r in results["ldbc"]
             if r.get("rand_mean_s", float("nan")) == r.get("rand_mean_s")])
    results["summary"] = summary
    for k, v in summary.items():
        coll.add(f"summary/{k}", float("nan"), f"{v:.2f}")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"# wrote {args.out} ({time.time()-t0:.1f}s total)")


if __name__ == "__main__":
    main()
