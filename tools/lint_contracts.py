#!/usr/bin/env python
"""AST-based repo contract lints (DESIGN.md §12.4).

The ``PlanVerifier`` checks *plans*; this tool checks the *code* for the
cross-cutting conventions the verifier's contracts depend on.  Three rules:

R1  host-array discipline — the device backends' data plane
    (``jax_backend.py``, ``sharded_backend.py``, ``jaxops.py``) must not
    materialize host arrays (``np.asarray``, ``np.concatenate``, ...) or
    call ``.to_host`` outside a small allowlist of staging/transfer
    functions.  A stray ``np.*`` in an operator is a silent device->host
    sync that the transfer ledger never sees.

R2  ledger discipline — any function in a compiled backend that calls
    ``jit(`` must record on ``kernel_stats`` (compiles must be visible in
    PROFILE), and the named transfer entry points (``asarray``,
    ``_array_to_host``, ``_upload``, ``to_host``) must record on
    ``transfer_stats``.

R3  lock discipline — in ``graphdb/serve.py``, every admission-side call
    (``self.gopt.prepare(``, ``self.gopt.touch_plan(``) must sit lexically
    inside a ``with self._lock`` block, and worker-side methods (run on
    the wave path, outside the lock) must never touch admission-side
    mutable state (``self._queues`` / ``self._pending`` / ``self._rid``).

R4  containment discipline — in the serving path (``graphdb/serve.py``,
    ``graphdb/engine.py``), a function with a broad handler (``except
    Exception`` or bare ``except:``) must route the failure somewhere
    observable: terminal request accounting (``_mark_failed`` /
    ``_fail_crashed``), a stats/ledger attribute, or a recorded fallback.
    A broad handler that silently swallows (the pre-containment
    ``except Exception: continue``) leaves requests in limbo and failures
    invisible to EXPLAIN.

Exit status: 0 when clean; with ``--strict``, 1 on any violation (the CI
gate).  Violations print as ``path:line: R<n> message``.
"""
from __future__ import annotations

import argparse
import ast
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = REPO / "src" / "repro"

# ------------------------------------------------------------------ R1 config
# device data-plane modules: everything here runs per-operator, per-wave
DATA_PLANE = ("graphdb/jax_backend.py", "graphdb/sharded_backend.py",
              "graphdb/jaxops.py")

# np.<name> calls that materialize / force a host array.  Metadata-only
# helpers (np.iinfo, np.dtype, np.int32-as-dtype) are deliberately absent.
HOST_ARRAY_CALLS = frozenset({
    "asarray", "array", "ascontiguousarray", "frombuffer", "copy",
    "zeros", "ones", "empty", "full", "arange", "repeat", "tile",
    "concatenate", "stack", "hstack", "vstack", "pad",
    "unique", "sort", "argsort", "nonzero", "flatnonzero", "where",
    "searchsorted", "isin", "in1d", "intersect1d", "union1d",
    "cumsum", "bincount", "take", "add",
})

# functions allowed to touch host arrays: the staging/transfer boundary
# (they exist to move data and record it on transfer_stats) plus the fused
# chain's control-plane capacity probe, which is a documented sync point
R1_ALLOW = frozenset({
    "jax_backend.py:FusedChain.run",             # capacity probe (sync point)
    "jax_backend.py:JaxOperators.asarray",       # h2d entry, records ledger
    "jax_backend.py:JaxOperators._array_to_host",  # d2h exit, records ledger
    "jax_backend.py:JaxOperators._upload",       # structure upload, records
    "jax_backend.py:JaxOperators.isin",          # value-list staging via
                                                 # self.asarray (recorded)
    "jax_backend.py:JaxOperators._col_dev",      # one-time column staging
    "jax_backend.py:JaxOperators._vprop_dev",    # one-time property staging
    "jax_backend.py:JaxOperators._eprop_dev",    # one-time property staging
    "sharded_backend.py:ShardedOperators.__init__",  # mesh construction
})

# ------------------------------------------------------------------ R2 config
COMPILED_BACKENDS = ("graphdb/jax_backend.py", "graphdb/sharded_backend.py")
TRANSFER_ENTRY_POINTS = frozenset({"asarray", "to_host", "_array_to_host",
                                   "_upload"})
R2_ALLOW = frozenset({
    # _smap only builds the jitted callable; its callers go through _prog,
    # which records compile:<kind> on first build of each keyed program
    "sharded_backend.py:ShardedOperators._smap",
})

# ------------------------------------------------------------------ R3 config
SERVE = "graphdb/serve.py"
LOCKED_CALLS = ("prepare", "touch_plan")       # self.gopt.<name>( sites
ADMISSION_STATE = frozenset({"_queues", "_pending", "_rid"})
# worker-side methods: run on the wave path, must not reach admission state
WORKER_METHODS = frozenset({"_run_wave", "_run_write_wave", "_update_hotness",
                            "_set_pinned", "_chain_specs", "_exec_group",
                            "_contained_exec", "_level_kw", "_mark_deadline",
                            "_mark_failed", "_breaker", "_breaker_pick",
                            "_breaker_report"})

# ------------------------------------------------------------------ R4 config
CONTAINMENT_FILES = ("graphdb/serve.py", "graphdb/engine.py")
# attributes/calls that make a broad handler's failure observable
R4_SINKS = frozenset({"stats", "fault_stats", "transfer_stats",
                      "kernel_stats", "fallbacks", "record",
                      "_mark_failed", "_fail_crashed", "_mark_deadline",
                      "_contained_exec"})


def _qualname(stack: list[str]) -> str:
    return ".".join(stack) if stack else "<module>"


def _iter_funcs(tree: ast.AST):
    """Yield ``(qualname_stack, node)`` for every function/class scope."""
    def rec(node, stack):
        yield stack, node
        for ch in ast.iter_child_nodes(node):
            if isinstance(ch, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                yield from rec(ch, stack + [ch.name])
    yield from rec(tree, [])


def _own_statements(scope: ast.AST):
    """Walk a scope's body without descending into nested def/class scopes."""
    todo = list(ast.iter_child_nodes(scope))
    while todo:
        n = todo.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            continue
        yield n
        todo.extend(ast.iter_child_nodes(n))


def _is_self_attr(node: ast.AST, names) -> str | None:
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self" and node.attr in names):
        return node.attr
    return None


# --------------------------------------------------------------------------
# R1: no host-array materialization in device data-plane modules
# --------------------------------------------------------------------------

def check_host_arrays(violations: list):
    for rel in DATA_PLANE:
        path = SRC / rel
        tree = ast.parse(path.read_text())
        fname = path.name
        for stack, scope in _iter_funcs(tree):
            qual = f"{fname}:{_qualname(stack)}"
            allowed = qual in R1_ALLOW
            for n in _own_statements(scope):
                if not isinstance(n, ast.Call):
                    continue
                f = n.func
                hit = None
                if (isinstance(f, ast.Attribute)
                        and isinstance(f.value, ast.Name)
                        and f.value.id == "np"
                        and f.attr in HOST_ARRAY_CALLS):
                    hit = f"np.{f.attr}"
                elif isinstance(f, ast.Attribute) and f.attr == "to_host":
                    hit = ".to_host"
                if hit and not allowed:
                    violations.append(
                        (rel, n.lineno,
                         f"R1 host-array call {hit} in data-plane function "
                         f"{_qualname(stack)!r} (not in allowlist — either "
                         f"keep the operator on device or move the staging "
                         f"into a recorded transfer helper)"))


# --------------------------------------------------------------------------
# R2: ledger-recording discipline in compiled backends
# --------------------------------------------------------------------------

def _references_attr(scope, attr: str) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr == attr
               for n in _own_statements(scope))


def check_ledgers(violations: list):
    for rel in COMPILED_BACKENDS:
        path = SRC / rel
        tree = ast.parse(path.read_text())
        fname = path.name
        for stack, scope in _iter_funcs(tree):
            if not isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            qual = f"{fname}:{_qualname(stack)}"
            calls_jit = any(
                isinstance(n, ast.Call)
                and isinstance(n.func, ast.Attribute)
                and n.func.attr == "jit"
                for n in _own_statements(scope))
            if (calls_jit and qual not in R2_ALLOW
                    and not _references_attr(scope, "kernel_stats")):
                violations.append(
                    (rel, scope.lineno,
                     f"R2 {_qualname(stack)!r} calls jit() without "
                     f"recording on kernel_stats (compiles must be visible "
                     f"in PROFILE's kernel ledger)"))
            if (scope.name in TRANSFER_ENTRY_POINTS
                    and not _references_attr(scope, "transfer_stats")):
                violations.append(
                    (rel, scope.lineno,
                     f"R2 transfer entry point {_qualname(stack)!r} never "
                     f"records on transfer_stats"))


# --------------------------------------------------------------------------
# R3: lock discipline in graphdb/serve.py
# --------------------------------------------------------------------------

def _is_lock_with(node: ast.With) -> bool:
    return any(_is_self_attr(item.context_expr, {"_lock"})
               for item in node.items)


def check_serve_locks(violations: list):
    path = SRC / SERVE
    tree = ast.parse(path.read_text())

    def visit(node, in_lock: bool, method: str | None):
        for ch in ast.iter_child_nodes(node):
            if isinstance(ch, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # a nested def is a new execution context: the enclosing
                # `with self._lock` does not guard its (deferred) body
                visit(ch, False, ch.name if method is None else method)
                continue
            if isinstance(ch, ast.ClassDef):
                visit(ch, False, None)
                continue
            locked = in_lock or (isinstance(ch, ast.With)
                                 and _is_lock_with(ch))
            if isinstance(ch, ast.Call):
                f = ch.func
                if (isinstance(f, ast.Attribute) and f.attr in LOCKED_CALLS
                        and _is_self_attr(f.value, {"gopt"}) and not in_lock):
                    violations.append(
                        (SERVE, ch.lineno,
                         f"R3 self.gopt.{f.attr}() outside `with "
                         f"self._lock` (plan-cache admission must be "
                         f"serialized against the worker's touch path)"))
            if (method in WORKER_METHODS
                    and (attr := _is_self_attr(ch, ADMISSION_STATE))):
                violations.append(
                    (SERVE, ch.lineno,
                     f"R3 worker-side method {method!r} touches "
                     f"admission-side state self.{attr}"))
            visit(ch, locked, method)

    visit(tree, False, None)


# --------------------------------------------------------------------------
# R4: broad handlers in the serving path must route failures observably
# --------------------------------------------------------------------------

def _is_broad_handler(h: ast.ExceptHandler) -> bool:
    if h.type is None:
        return True                                       # bare except:
    names = []
    t = h.type
    for n in (t.elts if isinstance(t, ast.Tuple) else [t]):
        if isinstance(n, ast.Name):
            names.append(n.id)
        elif isinstance(n, ast.Attribute):
            names.append(n.attr)
    return any(n in ("Exception", "BaseException") for n in names)


def check_containment(violations: list):
    for rel in CONTAINMENT_FILES:
        path = SRC / rel
        tree = ast.parse(path.read_text())
        for stack, scope in _iter_funcs(tree):
            if not isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            handlers = [h for n in _own_statements(scope)
                        if isinstance(n, ast.Try)
                        for h in n.handlers if _is_broad_handler(h)]
            if not handlers:
                continue
            sinks = any(isinstance(n, ast.Attribute) and n.attr in R4_SINKS
                        for n in _own_statements(scope))
            reraises = any(isinstance(n, ast.Raise)
                           for h in handlers for n in ast.walk(h))
            if not sinks and not reraises:
                violations.append(
                    (rel, handlers[0].lineno,
                     f"R4 {_qualname(stack)!r} catches broad exceptions "
                     f"without recording the failure (must mark requests "
                     f"failed, record on a stats ledger, or re-raise — "
                     f"silent swallows leave requests in limbo)"))


# --------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any violation (CI gate)")
    args = ap.parse_args(argv)

    violations: list[tuple[str, int, str]] = []
    check_host_arrays(violations)
    check_ledgers(violations)
    check_serve_locks(violations)
    check_containment(violations)

    for rel, line, msg in sorted(violations):
        print(f"src/repro/{rel}:{line}: {msg}")
    n_files = (len(DATA_PLANE) + len(COMPILED_BACKENDS) + 1
               + len(CONTAINMENT_FILES))
    print(f"lint_contracts: {len(violations)} violation(s) across "
          f"{n_files} checked module(s)")
    return 1 if (args.strict and violations) else 0


if __name__ == "__main__":
    sys.exit(main())
