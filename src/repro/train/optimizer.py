"""Optimizers and distributed-training tricks (pure pytree functions).

- AdamW with decoupled weight decay and global-norm clipping.
- Cosine / linear-warmup schedules.
- Optional int8 error-feedback gradient compression: gradients are quantized
  per-leaf before the data-parallel all-reduce and the quantization error is
  carried to the next step (1-bit/8-bit SGD family). On the mesh this shrinks
  DP all-reduce bytes 4x; on CPU we simulate the quantize/dequantize exactly.
- ZeRO-1 style sharding is applied by the caller via sharding specs on the
  optimizer state pytree (see launch/shardings.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    ef_error: Any  # error-feedback residual (zeros when compression off)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    compress_grads: bool = False   # int8 error-feedback compression


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(cfg: AdamWConfig, params) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    ef = (jax.tree.map(zeros, params) if cfg.compress_grads
          else jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params))
    return AdamState(step=jnp.zeros((), jnp.int32),
                     mu=jax.tree.map(zeros, params),
                     nu=jax.tree.map(zeros, params),
                     ef_error=ef)


def _quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(g: jax.Array, err: jax.Array):
    """Error-feedback int8 round trip: returns (g_hat, new_err). The int8
    tensor is what crosses the DP all-reduce on a real mesh."""
    g_comp = g + err
    q, scale = _quantize_int8(g_comp)
    g_hat = q.astype(jnp.float32) * scale
    return g_hat, g_comp - g_hat


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamState, params):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.compress_grads:
        pairs = jax.tree.map(compress_decompress, grads, state.ef_error)
        grads = jax.tree.map(lambda _, p: p[0], state.ef_error, pairs)
        new_err = jax.tree.map(lambda _, p: p[1], state.ef_error, pairs)
    else:
        new_err = state.ef_error
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * (
            p.astype(jnp.float32))
        return (p - lr * delta.astype(p.dtype)).astype(p.dtype), m, v

    triples = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda _, t: t[0], params, triples)
    mu = jax.tree.map(lambda _, t: t[1], params, triples)
    nu = jax.tree.map(lambda _, t: t[2], params, triples)
    return new_params, AdamState(step, mu, nu, new_err), {
        "grad_norm": gnorm, "lr": lr}
