"""Elastic scaling: re-shard a training state onto a different mesh.

Checkpoints are mesh-independent host pytrees (train/checkpoint.py), so
elasticity is: load -> build new mesh -> ``jax.device_put`` each leaf with the
new NamedSharding -> re-lower the step. ``reshard`` also handles live state
(device-to-device) by round-tripping through host when layouts are
incompatible. Tested by shrinking/growing the host-device mesh in
tests/test_train_substrate.py.
"""
from __future__ import annotations

import jax
import numpy as np


def reshard(state, shardings):
    """Place (host or device) pytree onto new shardings leaf-by-leaf."""
    def place(x, s):
        arr = np.asarray(x) if not isinstance(x, np.ndarray) else x
        return jax.device_put(arr, s)
    return jax.tree.map(place, state, shardings)


def elastic_restart(ckpt, like, new_mesh, sharding_fn):
    """Restore latest checkpoint and place it on ``new_mesh``.

    sharding_fn(mesh) -> sharding pytree matching ``like``.
    Returns (step, sharded_state) or (None, None)."""
    step, host_state = ckpt.restore_latest(like)
    if step is None:
        return None, None
    return step, reshard(host_state, sharding_fn(new_mesh))
