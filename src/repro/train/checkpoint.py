"""Checkpointing: atomic, async, retention-managed, mesh-independent.

Checkpoints are host pytrees serialized as one ``.npz`` per step plus a
msgpack-able structure descriptor — no sharding baked in, so a checkpoint
written on a 256-chip mesh restores onto any other mesh (elastic scaling,
see train/elastic.py). Writes happen on a background thread with an atomic
rename; ``restore_latest`` skips corrupt/partial checkpoints (fault
tolerance across preemption mid-write).
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._async = async_write
        self._err: Exception | None = None
        if async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------ api
    def save(self, step: int, state) -> None:
        """Snapshot device arrays to host, then write (async by default)."""
        host = jax.tree.map(lambda x: np.asarray(x), state)
        if self._async:
            self._q.put((step, host))
        else:
            self._write(step, host)

    def wait(self) -> None:
        if self._async:
            self._q.join()
        if self._err:
            raise self._err

    def restore_latest(self, like):
        """Restore the newest readable checkpoint as a pytree shaped like
        ``like``. Returns (step, state) or (None, None)."""
        for step in sorted(self.steps(), reverse=True):
            try:
                return step, self.restore(step, like)
            except Exception:      # noqa: BLE001 — corrupt/partial ckpt
                continue
        return None, None

    def restore(self, step: int, like):
        path = os.path.join(self.dir, f"step_{step:09d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            leaves = [z[f"arr_{i}"] for i in range(len(z.files))]
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        _, treedef = _flatten(like)
        if meta["n_leaves"] != treedef.num_leaves:
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves, expected "
                f"{treedef.num_leaves}")
        return jax.tree.unflatten(treedef, leaves)

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.match(r"step_(\d+)$", name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    # ------------------------------------------------------------- internal
    def _worker(self):
        while True:
            step, host = self._q.get()
            try:
                self._write(step, host)
            except Exception as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.task_done()

    def _write(self, step: int, host) -> None:
        leaves, treedef = _flatten(host)
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + f".tmp.{os.getpid()}.{int(time.time()*1e6)}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"arr_{i}": leaf for i, leaf in enumerate(leaves)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(leaves),
                       "time": time.time()}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)       # atomic publish
        self._gc()

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
