"""Deterministic, resumable synthetic token pipeline.

At 1000+ nodes the data layer must (a) never re-read state to resume — batch
``i`` is a pure function of (seed, i); (b) shard by host without overlap.
This pipeline is exactly that: ``batch_at(step)`` is stateless, so restart
after preemption resumes mid-epoch for free and elastic re-scales only change
``n_hosts``/``host_id``.

Synthetic text: a mixture of Zipfian unigrams and deterministic "skip-gram"
structure so a real LM loss signal exists (tests assert learnability).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def batch_at(cfg: DataConfig, step: int) -> dict:
    """The host's shard of global batch ``step`` — pure and deterministic."""
    rng = _batch_rng(cfg, step)
    B, S, V = cfg.host_batch, cfg.seq_len, cfg.vocab_size
    z = rng.zipf(1.3, size=(B, S)).astype(np.int64)
    toks = (z - 1) % V
    # inject learnable structure: token[t] == token[t-2] + 1 on even runs
    runs = rng.random((B, S)) < 0.35
    shifted = np.roll(toks, 2, axis=1) + 1
    toks = np.where(runs, shifted % V, toks)
    return {"tokens": toks.astype(np.int32)}


class TokenPipeline:
    """Iterator facade with prefetch-free determinism."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = batch_at(self.cfg, self.step)
        self.step += 1
        return b
