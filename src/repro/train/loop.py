"""Fault-tolerant training loop.

Production behaviors implemented (and simulated in tests):
- checkpoint/restart: periodic async checkpoints; on (re)start the loop
  restores the latest readable checkpoint and resumes the data pipeline at
  the exact step (data is stateless — train/data.py);
- bounded retry on transient step failures (a flaky host raising once must
  not kill the job) with re-materialization from the last checkpoint after
  repeated failures;
- preemption handling: a `should_preempt` callback (SIGTERM hook at scale)
  triggers a final checkpoint + clean exit;
- straggler watchdog: per-step wall-time EMA; steps slower than
  ``straggler_factor`` x EMA are logged and counted (at scale this feeds the
  scheduler's hot-swap policy — documented in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    max_retries: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


@dataclasses.dataclass
class LoopResult:
    final_step: int
    metrics_history: list
    retries: int
    straggler_steps: int
    preempted: bool


def run_loop(step_fn: Callable, state, batch_fn: Callable,
             ckpt: CheckpointManager, cfg: LoopConfig,
             should_preempt: Callable[[], bool] = lambda: False,
             log_fn: Callable = print) -> LoopResult:
    """state: pytree passed to/returned by ``step_fn(state, batch)`` (plus a
    metrics dict). ``batch_fn(step)`` supplies data."""
    start, restored = ckpt.restore_latest(state)
    if start is not None:
        state = jax.tree.map(jax.numpy.asarray, restored)
        log_fn(f"[loop] restored checkpoint at step {start}")
        step = start
    else:
        step = 0

    history = []
    retries = 0
    stragglers = 0
    ema = None
    preempted = False
    while step < cfg.total_steps:
        if should_preempt():
            log_fn(f"[loop] preemption signal at step {step}; checkpointing")
            ckpt.save(step, state)
            ckpt.wait()
            preempted = True
            break
        batch = batch_fn(step)
        t0 = time.perf_counter()
        attempt = 0
        while True:
            try:
                state, metrics = step_fn(state, batch)
                break
            except Exception as e:  # noqa: BLE001 — transient failure path
                attempt += 1
                retries += 1
                log_fn(f"[loop] step {step} failed ({type(e).__name__}: {e});"
                       f" retry {attempt}/{cfg.max_retries}")
                if attempt > cfg.max_retries:
                    s, restored = ckpt.restore_latest(state)
                    if s is None:
                        raise
                    log_fn(f"[loop] re-materializing from checkpoint {s}")
                    state = jax.tree.map(jax.numpy.asarray, restored)
                    step = s
                    batch = batch_fn(step)
                    attempt = 0
        dt = time.perf_counter() - t0
        ema = dt if ema is None else 0.9 * ema + 0.1 * dt
        if dt > cfg.straggler_factor * ema and step > 5:
            stragglers += 1
            log_fn(f"[loop] straggler step {step}: {dt:.3f}s vs ema "
                   f"{ema:.3f}s")
        step += 1
        if step % cfg.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            history.append((step, m))
            log_fn(f"[loop] step {step}: " +
                   " ".join(f"{k}={v:.4g}" for k, v in m.items()))
        if step % cfg.ckpt_every == 0:
            ckpt.save(step, state)
    ckpt.save(step, state)
    ckpt.wait()
    return LoopResult(step, history, retries, stragglers, preempted)
