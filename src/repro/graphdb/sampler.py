"""Fanout neighbor sampler over the CSR store.

This is the data pipeline for the ``minibatch_lg`` GNN shape (GraphSAGE-style
fanout sampling, e.g. 15-10). It is deliberately built on the same CSR arrays
the pattern engine expands — GOpt's EXPAND with sampling — which is the point
of contact between the paper's engine and the assigned GNN architectures
(DESIGN.md §4).

Returns padded, fixed-shape arrays ready for a jit'd train step:
  nodes:   int32[max_nodes]      (global ids, -1 pad; seeds first)
  edges:   int32[2, max_edges]   (COO into the *local* node index, -1 pad)
  n_nodes, n_edges: actual counts
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class HomoCSR:
    """A homogeneous (single node type) CSR graph for GNN workloads."""
    indptr: np.ndarray
    indices: np.ndarray
    n_nodes: int

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                   symmetric: bool = True) -> "HomoCSR":
        if symmetric:
            src, dst = (np.concatenate([src, dst]),
                        np.concatenate([dst, src]))
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        return HomoCSR(np.cumsum(indptr), dst.astype(np.int64), n_nodes)


def sample_fanout(csr: HomoCSR, seeds: np.ndarray, fanouts: list[int],
                  rng: np.random.Generator,
                  max_nodes: int, max_edges: int):
    """Multi-hop uniform fanout sampling; dedupes nodes per layer."""
    nodes = list(seeds.astype(np.int64))
    node_pos = {int(n): i for i, n in enumerate(nodes)}
    e_src, e_dst = [], []
    frontier = seeds.astype(np.int64)
    for f in fanouts:
        nxt = []
        if frontier.size == 0:
            break
        deg = csr.indptr[frontier + 1] - csr.indptr[frontier]
        for u, d in zip(frontier, deg):
            if d == 0:
                continue
            k = min(int(d), f)
            sel = (rng.choice(int(d), size=k, replace=False) if d > f
                   else np.arange(int(d)))
            nbrs = csr.indices[csr.indptr[u] + sel]
            for v in nbrs:
                v = int(v)
                if v not in node_pos:
                    if len(nodes) >= max_nodes:
                        continue
                    node_pos[v] = len(nodes)
                    nodes.append(v)
                if len(e_src) < max_edges:
                    # message flows neighbor -> center
                    e_src.append(node_pos[v])
                    e_dst.append(node_pos[int(u)])
                nxt.append(v)
        frontier = np.unique(np.asarray(nxt, dtype=np.int64))

    n_nodes, n_edges = len(nodes), len(e_src)
    nodes_arr = np.full(max_nodes, -1, dtype=np.int32)
    nodes_arr[:n_nodes] = nodes
    edges_arr = np.full((2, max_edges), -1, dtype=np.int32)
    if n_edges:
        edges_arr[0, :n_edges] = e_src
        edges_arr[1, :n_edges] = e_dst
    return nodes_arr, edges_arr, n_nodes, n_edges


def random_power_law_graph(n_nodes: int, avg_degree: int, seed: int = 0,
                           zipf_a: float = 1.5) -> HomoCSR:
    """Synthetic graph with power-law in-degree (test/bench substrate)."""
    rng = np.random.default_rng(seed)
    m = n_nodes * avg_degree // 2
    src = rng.integers(0, n_nodes, size=m, dtype=np.int64)
    ranks = rng.zipf(zipf_a, size=m).astype(np.int64)
    dst = (ranks - 1) % n_nodes
    keep = src != dst
    return HomoCSR.from_edges(src[keep], dst[keep], n_nodes)
