"""jit'd JAX mirrors of the engine's hot primitives.

On TPU these (and their Pallas variants in ``repro.kernels``) execute the
fixed-shape inner loops of pattern matching; the numpy twins in ``vecops`` are
the host path. Shapes must be static under jit, so the expansion primitive
works on a padded row block and returns a validity mask — the same contract
the Pallas kernels use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("max_degree",))
def expand_padded(indptr: jax.Array, indices: jax.Array,
                  rows_local: jax.Array, max_degree: int):
    """Expand each row to at most ``max_degree`` neighbors.

    Returns (nbr[R, max_degree], valid[R, max_degree], flat_pos[R, max_degree]).
    Rows with degree > max_degree are truncated (caller splits such rows).
    """
    start = indptr[rows_local]
    deg = indptr[rows_local + 1] - start
    offs = jnp.arange(max_degree, dtype=indptr.dtype)[None, :]
    valid = offs < deg[:, None]
    flat = jnp.clip(start[:, None] + offs, 0, indices.shape[0] - 1)
    nbr = jnp.where(valid, indices[flat], -1)
    return nbr, valid, jnp.where(valid, flat, -1)


@jax.jit
def bounded_binary_search(indices: jax.Array, lo: jax.Array, hi: jax.Array,
                          targets: jax.Array):
    """jnp twin of vecops.bounded_binary_search (found, pos)."""
    hi_orig = hi
    n = indices.shape[0]

    def cond(state):
        lo, hi = state
        return jnp.any(lo < hi)

    def body(state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi) // 2
        v = indices[jnp.minimum(mid, n - 1)]
        go_right = active & (v < targets)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        return lo, hi

    lo, hi = jax.lax.while_loop(cond, body, (lo, hi))
    pos = lo
    in_range = pos < jnp.minimum(hi_orig, n)
    found = in_range & (indices[jnp.minimum(pos, n - 1)] == targets)
    return found, pos


def range_flatten(start: jax.Array, counts: jax.Array, total: int):
    """Row-major flattening of per-row index ranges ``[start_i, start_i +
    counts_i)``: returns ``(row_idx[total], flat_pos[total])``.

    The device twin of the ``np.repeat``-based expansion in
    ``vecops.expand_csr`` — built from cumsum + searchsorted + gathers
    because both ``jnp.repeat`` and scatter-based alternatives serialize
    (or pay heavy eager machinery) on CPU XLA.  ``total`` is the
    data-dependent output size, synced by the caller and static under jit.
    """
    cum = jnp.cumsum(counts)
    pos = jnp.arange(total, dtype=jnp.int32)
    ridx = jnp.searchsorted(cum, pos, side="right").astype(jnp.int32)
    offs = pos - jnp.take(cum - counts, ridx, axis=0, mode="clip")
    flat = jnp.take(start, ridx, axis=0, mode="clip") + offs
    return ridx, flat


@jax.jit
def csr_expand_total(indptr: jax.Array, rows: jax.Array):
    """Predictive output size of a CSR expansion (one dispatch; the caller
    syncs it for the blow-up guard and the static expand shape).  Returns
    ``(total_i32, total_f32)``: the int32 sum is exact below 2^31 but
    wraps above it, so the float32 estimate lets the caller catch the
    wrap and still raise the blow-up guard instead of silently building
    an empty/garbled expansion."""
    deg = (jnp.take(indptr, rows + 1, axis=0, mode="clip")
           - jnp.take(indptr, rows, axis=0, mode="clip"))
    return deg.sum(), deg.astype(jnp.float32).sum()


@functools.partial(jax.jit, static_argnames=("total", "has_pos"))
def csr_expand_flat(indptr: jax.Array, indices: jax.Array, pos: jax.Array,
                    rows: jax.Array, total: int, has_pos: bool):
    """Fused expand step: degree lookup + row-major flattening + neighbor /
    edge-position gathers in ONE dispatch (eager would be ~10).  Keyed by
    (rows.shape, total); the caller syncs ``total`` from the degrees first.
    ``pos`` is ignored (pass ``indices``) when ``has_pos`` is False."""
    start = jnp.take(indptr, rows, axis=0, mode="clip")
    deg = jnp.take(indptr, rows + 1, axis=0, mode="clip") - start
    ridx, flat = range_flatten(start, deg, total)
    nbr = jnp.take(indices, flat, axis=0, mode="clip")
    epos = jnp.take(pos, flat, axis=0, mode="clip") if has_pos else flat
    return ridx, nbr, epos


@jax.jit
def lex_ranks(cols: list[jax.Array]) -> jax.Array:
    """Dense lexicographic ranks of row tuples (``cols[0]`` most
    significant): equal tuples share a rank, and rank order equals the
    tuples' lexicographic sort order — the device-native equivalent of
    ``vecops.combine_keys``'s factorized packing (identical grouping and
    identical ascending order, so cross-backend row order is preserved).

    Sort/gather-shaped on purpose: a scatter (``.at[order].set``)
    serializes on CPU XLA, so the group ids are carried back through an
    argsort-based inverse permutation.  jit'd into one dispatch, keyed by
    (n, len(cols)).
    """
    n = cols[0].shape[0]
    if n == 0:
        return jnp.zeros(0, jnp.int32)
    order = jnp.lexsort(tuple(reversed(cols)))
    ne = jnp.zeros(n - 1, bool)
    for c in cols:
        s = jnp.take(c, order, axis=0, mode="clip")
        ne = ne | (s[1:] != s[:-1])
    gid_sorted = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(ne.astype(jnp.int32))])
    inv_order = jnp.argsort(order)
    return jnp.take(gid_sorted, inv_order, axis=0, mode="clip")


# ------------------------------------------------------- bucketed tail twins
# The compound tail kernels below jit on *padded* pow2 shapes: the caller
# pads its inputs up to a capacity bucket and passes the true row count
# ``n_valid`` as a traced scalar, so jittered serving-wave sizes re-hit one
# compiled program per bucket instead of re-tracing per exact shape.  Pad
# rows are ordered strictly last by an explicit pad-flag used as the
# *primary* lexsort key (never by a sentinel value, which real data could
# collide with); every output is exact on ``[:n_valid]`` / ``[:n_groups]``
# and the caller slices the pads away.


def _pad_flag(n: int, n_valid) -> jax.Array:
    return jnp.arange(n, dtype=jnp.int32) >= n_valid


@jax.jit
def lex_ranks_padded(cols: list[jax.Array], n_valid) -> jax.Array:
    """``lex_ranks`` over pow2-padded columns: pad rows sort after every
    valid tuple (pad-flag primary) and land on ranks >= the valid rank
    count, so ``[:n_valid]`` of the result equals the unpadded ranks."""
    n = cols[0].shape[0]
    pf = _pad_flag(n, n_valid)
    order = jnp.lexsort(tuple(reversed(cols)) + (pf,))
    ne = jnp.zeros(n - 1, bool)
    for c in list(cols) + [pf]:
        s = jnp.take(c, order, axis=0, mode="clip")
        ne = ne | (s[1:] != s[:-1])
    gid_sorted = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(ne.astype(jnp.int32))])
    inv_order = jnp.argsort(order)
    return jnp.take(gid_sorted, inv_order, axis=0, mode="clip")


@jax.jit
def group_boundaries(keys: jax.Array):
    """Stage 1 of sorted-run grouping: stable sort by key and flag run
    starts.  Returns ``(order, start_flags, flag_order, n_groups0d)`` — the
    caller syncs ``n_groups`` and slices ``flag_order[:n_groups]`` to get
    the run-start positions (ascending, since argsort is stable)."""
    n = keys.shape[0]
    order = jnp.argsort(keys)
    sk = jnp.take(keys, order, axis=0, mode="clip")
    flags = jnp.concatenate([jnp.ones(1, bool), sk[1:] != sk[:-1]])
    flag_order = jnp.argsort(~flags)
    return order, flags, flag_order, flags.sum()


@jax.jit
def group_boundaries_padded(keys: jax.Array, n_valid):
    """``group_boundaries`` over a pow2-padded key column.  Pad rows sort
    last (pad-flag primary, stable within each side) and never start a
    counted run; ``n_groups`` counts valid runs only, and
    ``flag_order[:n_groups]`` are their ascending sorted-domain starts."""
    n = keys.shape[0]
    pf = _pad_flag(n, n_valid)
    order = jnp.lexsort((keys, pf))
    sk = jnp.take(keys, order, axis=0, mode="clip")
    spf = jnp.take(pf, order, axis=0, mode="clip")
    flags = jnp.concatenate(
        [jnp.ones(1, bool), (sk[1:] != sk[:-1]) | (spf[1:] != spf[:-1])])
    vstart = flags & ~spf
    flag_order = jnp.argsort(~vstart)
    return order, vstart, flag_order, vstart.sum()


# ------------------------------------------------------------ double-single
# Widened SUM/AVG accumulation (x64 is disabled): values are carried as
# exact (hi, lo) float32 pairs — "double-single" arithmetic — and the
# running prefix is built with a compensated TwoSum combiner under
# ``lax.associative_scan`` (log-depth, vectorized; no scatter).  Group sums
# are then boundary differences of the compensated prefix, so the error is
# ~2^-48 *relative to the running total* instead of float32's 2^-24 (and
# int32 SUM no longer wraps just because the running total across all
# preceding groups passed 2^31 — only a group's own total exceeding the
# int32 output envelope is unrepresentable).

def _ds_from_col(col):
    """Exact double-single representation of an int32/float32 column.
    Integers split as ``v = (v >> 12 << 12) + (v & 0xFFF)``: a multiple of
    4096 with <= 19 significant bits plus a 12-bit remainder — both sides
    exact in float32 across the whole int32 range."""
    if col.dtype.kind == "f":
        return col.astype(jnp.float32), jnp.zeros_like(col, jnp.float32)
    hi = ((col >> 12) << 12).astype(jnp.float32)
    lo = (col & 0xFFF).astype(jnp.float32)
    return hi, lo


def _ds_add(a, b):
    """Compensated (TwoSum + renormalize) double-single addition."""
    ah, al = a
    bh, bl = b
    s = ah + bh
    bv = s - ah
    err = (ah - (s - bv)) + (bh - bv)
    t = al + bl + err
    hi = s + t
    return hi, t - (hi - s)


# largest float32 below 2^31: clamping the hi word here keeps the int32
# reconstruction exact (the clamp shift folds into the low word)
_F32_I32_EDGE = 2147483520.0


def _ds_to_int32(hi, lo):
    c = jnp.clip(hi, -_F32_I32_EDGE, _F32_I32_EDGE)
    return c.astype(jnp.int32) + (lo + (hi - c)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("fns",))
def group_aggregate(order: jax.Array, starts: jax.Array, keys: jax.Array,
                    cols: tuple, fns: tuple):
    """Stage 2 of sorted-run grouping, one dispatch for every aggregate:
    counts via boundary differences, SUM/AVG via a compensated
    double-single prefix scan (see ``_ds_add`` — exact while running totals
    stay within ~2^48, vs the naive float32 cumsum that drifted once the
    running total across *all* groups grew large), MIN/MAX via a secondary
    value sort within key runs.  ``fns`` is the static aggregate spec
    aligned with ``cols``.  SUM results are exact whenever the group's own
    total fits the int32 output envelope."""
    n = order.shape[0]
    bounds = jnp.concatenate([starts, jnp.asarray([n], starts.dtype)])
    ends = bounds[1:] - 1
    counts = (bounds[1:] - bounds[:-1]).astype(jnp.int32)
    first = jnp.take(order, starts, axis=0, mode="clip")
    outs = []
    for fn, col in zip(fns, cols):
        if fn == "COUNT":
            outs.append(counts)
            continue
        if fn in ("SUM", "AVG"):
            sorted_col = jnp.take(col, order, axis=0, mode="clip")
            ch, cl = jax.lax.associative_scan(_ds_add, _ds_from_col(sorted_col))
            eh = jnp.take(ch, ends, axis=0, mode="clip")
            el = jnp.take(cl, ends, axis=0, mode="clip")
            ph = jnp.concatenate([jnp.zeros(1, jnp.float32), eh[:-1]])
            pl = jnp.concatenate([jnp.zeros(1, jnp.float32), el[:-1]])
            sh, sl = _ds_add((eh, el), (-ph, -pl))
            outs.append((sh + sl) / jnp.maximum(counts, 1)
                        if fn == "AVG" else _ds_to_int32(sh, sl))
            continue
        # MIN/MAX: secondary sort by value within each key run — minima at
        # run starts, maxima at run ends
        sv = jnp.take(col, jnp.lexsort((col, keys)), axis=0, mode="clip")
        outs.append(jnp.take(sv, starts if fn == "MIN" else ends,
                             axis=0, mode="clip"))
    return first, tuple(outs)


@functools.partial(jax.jit, static_argnames=("fns",))
def group_aggregate_padded(order: jax.Array, starts: jax.Array,
                           keys: jax.Array, n_valid, cols: tuple, fns: tuple):
    """``group_aggregate`` over pow2-padded inputs: ``order``/``keys``/
    ``cols`` are padded to one row bucket (pads sorted last in ``order``),
    ``starts`` is padded to a pow2 group bucket with the terminal bound
    ``n_valid`` — so dummy trailing groups have count 0 and every real
    group's boundary math is untouched.  Outputs are exact on
    ``[:n_groups]``; the caller slices the dummy groups away.  Keyed by
    (row bucket, group bucket, fns)."""
    n = order.shape[0]
    pf = _pad_flag(n, n_valid)
    nv = jnp.asarray(n_valid, starts.dtype)
    bounds = jnp.concatenate([starts, nv[None]])
    ends = bounds[1:] - 1
    counts = (bounds[1:] - bounds[:-1]).astype(jnp.int32)
    first = jnp.take(order, starts, axis=0, mode="clip")
    outs = []
    for fn, col in zip(fns, cols):
        if fn == "COUNT":
            outs.append(counts)
            continue
        if fn in ("SUM", "AVG"):
            sorted_col = jnp.take(col, order, axis=0, mode="clip")
            ch, cl = jax.lax.associative_scan(_ds_add, _ds_from_col(sorted_col))
            eh = jnp.take(ch, ends, axis=0, mode="clip")
            el = jnp.take(cl, ends, axis=0, mode="clip")
            ph = jnp.concatenate([jnp.zeros(1, jnp.float32), eh[:-1]])
            pl = jnp.concatenate([jnp.zeros(1, jnp.float32), el[:-1]])
            sh, sl = _ds_add((eh, el), (-ph, -pl))
            outs.append((sh + sl) / jnp.maximum(counts, 1)
                        if fn == "AVG" else _ds_to_int32(sh, sl))
            continue
        # MIN/MAX secondary value sort: the pad flag stays primary so pad
        # rows cannot land inside a valid key run regardless of value
        sv = jnp.take(col, jnp.lexsort((col, keys, pf)), axis=0, mode="clip")
        outs.append(jnp.take(sv, starts if fn == "MIN" else ends,
                             axis=0, mode="clip"))
    return first, tuple(outs)


@jax.jit
def sortmerge_bounds(lkeys: jax.Array, rkeys: jax.Array):
    """Stage 1 of the sort-merge join (one dispatch): stable sorts + the
    per-left-row matching right range.  Returns ``(lorder, rorder, lo,
    cnt, total0d)``; the caller syncs ``total`` for the pair expansion."""
    lorder = jnp.argsort(lkeys)
    rorder = jnp.argsort(rkeys)
    ls = jnp.take(lkeys, lorder, axis=0, mode="clip")
    rs = jnp.take(rkeys, rorder, axis=0, mode="clip")
    lo = jnp.searchsorted(rs, ls, side="left")
    cnt = jnp.searchsorted(rs, ls, side="right") - lo
    # int32 total (exact below 2^31) + float32 estimate (wrap detector)
    return lorder, rorder, lo, cnt, cnt.sum(), cnt.astype(jnp.float32).sum()


@jax.jit
def sortmerge_bounds_padded(lkeys: jax.Array, rkeys: jax.Array,
                            n_left, n_right):
    """``sortmerge_bounds`` over pow2-padded key columns.  The caller pads
    both sides with INT32_MAX so the right sorted column stays globally
    non-decreasing for ``searchsorted``; the pad flag (primary sort key)
    pins pads to the tail even when real keys equal the pad value, the
    match range is clamped to the valid right prefix, and pad left rows
    contribute zero matches."""
    L = lkeys.shape[0]
    lpf = _pad_flag(L, n_left)
    rpf = _pad_flag(rkeys.shape[0], n_right)
    lorder = jnp.lexsort((lkeys, lpf))
    rorder = jnp.lexsort((rkeys, rpf))
    ls = jnp.take(lkeys, lorder, axis=0, mode="clip")
    rs = jnp.take(rkeys, rorder, axis=0, mode="clip")
    lo = jnp.minimum(jnp.searchsorted(rs, ls, side="left"), n_right)
    hi = jnp.minimum(jnp.searchsorted(rs, ls, side="right"), n_right)
    cnt = jnp.where(jnp.arange(L, dtype=jnp.int32) < n_left, hi - lo, 0)
    # int32 total (exact below 2^31) + float32 estimate (wrap detector)
    return lorder, rorder, lo, cnt, cnt.sum(), cnt.astype(jnp.float32).sum()


@functools.partial(jax.jit, static_argnames=("total",))
def sortmerge_pairs(lorder: jax.Array, rorder: jax.Array, lo: jax.Array,
                    cnt: jax.Array, total: int):
    """Fused pair expansion of the sort-merge join (one dispatch).

    ``total`` may be a pow2 bucket >= the true pair count: positions past
    ``sum(cnt)`` produce clipped garbage pairs the caller slices away."""
    lrep, rpos = range_flatten(lo, cnt, total)
    return (jnp.take(lorder, lrep, axis=0, mode="clip").astype(jnp.int32),
            jnp.take(rorder, rpos, axis=0, mode="clip").astype(jnp.int32))


# --------------------------------------------------------------------------
# Fused chain programs (DESIGN.md §8)
# --------------------------------------------------------------------------
# One ExpandChainNode = ONE compiled program: every hop's degree lookup,
# row-major flattening, neighbor/edge gathers, trailing WCOJ membership
# probes, and folded predicate masks trace into a single jit dispatch.
# Data-dependent sizes stay on device: each hop writes into a *static
# capacity* (``caps[k]``, pow2-bucketed by the backend), rows beyond a
# hop's true total are dead slots carried by a validity mask, and filtered
# rows simply contribute zero degree to the next hop — so emission order is
# exactly the per-hop loop's orientation-major, row-major order without any
# mid-program compaction.  The program returns the padded columns (valid
# rows compacted to the front by one stable argsort), the true row count,
# and the per-hop totals the caller syncs once — for the blow-up guard and
# to grow the capacity schedule when a hop overflowed.

_CHAIN_CMP = {"=": lambda a, b: a == b, "<>": lambda a, b: a != b,
              "<": lambda a, b: a < b, ">": lambda a, b: a > b,
              "<=": lambda a, b: a <= b, ">=": lambda a, b: a >= b}

_CHAIN_I32_MIN = -2147483648


def build_fused_chain(desc: tuple, caps: tuple, in_bucket: int,
                      interpret: bool, empty_values: tuple = ()):
    """Build the traced whole-chain function for one static chain shape.

    ``desc`` = ``(source_col, hops)``; each hop is ``(from_col, alias,
    edge_alias, orients, probes, pred)`` with orients ``(lo, tidx,
    has_pos)``, probes ``(from_col, edge_alias, lo, tidx, has_pos, mode,
    d_max, block_rows)`` and ``pred`` a resolved predicate signature whose
    column refs are ``("col", name) | ("vprop", name, idx) | ("eprop",
    edge_alias, idx)`` and whose leaves read runtime slots.  The caller
    jits the result; the jit cache is keyed by (desc, caps, in_bucket)
    through the builder's own memoization, so recurring bucketed shapes
    never re-trace."""
    from repro.kernels.wcoj_intersect.ops import gather_rows, wcoj_intersect
    source_col, hops = desc
    i32 = jnp.int32

    def eval_ref(ref, cols, vprops, eprops):
        if ref[0] == "col":
            return cols[ref[1]]
        if ref[0] == "vprop":
            _, name, pidx = ref
            return jnp.take(vprops[pidx], cols[name], axis=0, mode="clip")
        _, ealias, pidx = ref
        offsets, flat = eprops[pidx]
        if flat.shape[0] == 0:
            return jnp.full(cols[f"{ealias}#p"].shape, _CHAIN_I32_MIN, i32)
        base = jnp.take(offsets, cols[f"{ealias}#t"], axis=0, mode="clip")
        return jnp.take(flat, base + cols[f"{ealias}#p"], axis=0,
                        mode="clip")

    def eval_pred(sig, cols, scalars, values, vprops, eprops):
        kind = sig[0]
        if kind == "cmp":
            _, op, ref, slot = sig
            return _CHAIN_CMP[op](eval_ref(ref, cols, vprops, eprops),
                                  scalars[slot])
        if kind == "in":
            _, ref, vidx = sig
            lhs = eval_ref(ref, cols, vprops, eprops)
            if vidx in empty_values:     # static: empty IN-set matches nothing
                return jnp.zeros(lhs.shape, bool)
            return jnp.isin(lhs, values[vidx])
        if kind == "not":
            return ~eval_pred(sig[1][0], cols, scalars, values, vprops,
                              eprops)
        acc = eval_pred(sig[1][0], cols, scalars, values, vprops, eprops)
        for s in sig[1][1:]:
            m = eval_pred(s, cols, scalars, values, vprops, eprops)
            acc = (acc & m) if kind == "and" else (acc | m)
        return acc

    def run(src, n0, csrs, vprops, eprops, scalars, values):
        cols = {"__rows": jnp.arange(in_bucket, dtype=i32), source_col: src}
        valid = jnp.arange(in_bucket, dtype=i32) < n0
        needed, needed_f = [], []
        for k, (from_col, alias, ealias, orients, probes, pred) in \
                enumerate(hops):
            cap = caps[k]
            frm = cols[from_col]
            degs, row_starts = [], []
            for j, (lo, hi, tidx, has_pos) in enumerate(orients):
                indptr = csrs[k][0][j][0]
                local = jnp.clip(frm - lo, 0, indptr.shape[0] - 2)
                s0 = jnp.take(indptr, local, axis=0, mode="clip")
                d = jnp.take(indptr, local + 1, axis=0, mode="clip") - s0
                # the keyed-type range membership mask: rows of a
                # mixed-type frontier outside [lo, hi) expand to nothing,
                # exactly like the per-hop loop's nonzero() subset
                in_range = valid & (frm >= lo) & (frm < hi)
                degs.append(jnp.where(in_range, d, 0))
                row_starts.append(s0)
            totals, offs = [], []
            running = jnp.asarray(0, i32)
            for d in degs:
                offs.append(running)
                totals.append(d.sum().astype(i32))
                running = running + totals[-1]
            needed.append(running)
            needed_f.append(sum(d.astype(jnp.float32).sum() for d in degs))
            pos_out = jnp.arange(cap, dtype=i32)
            acc_r = jnp.zeros(cap, i32)
            acc_nbr = jnp.zeros(cap, i32)
            acc_tv = jnp.zeros(cap, i32)
            acc_p = jnp.zeros(cap, i32)
            for j, (lo, hi, tidx, has_pos) in enumerate(orients):
                _, indices, pos = csrs[k][0][j]
                in_j = (pos_out >= offs[j]) & (pos_out < offs[j] + totals[j])
                lp = pos_out - offs[j]
                cum = jnp.cumsum(degs[j])
                r = jnp.searchsorted(cum, lp, side="right").astype(i32)
                o = lp - jnp.take(cum - degs[j], r, axis=0, mode="clip")
                flat = jnp.take(row_starts[j], r, axis=0, mode="clip") + o
                nb = jnp.take(indices, flat, axis=0, mode="clip")
                ep = (jnp.take(pos, flat, axis=0, mode="clip") if has_pos
                      else flat)
                acc_r = jnp.where(in_j, r, acc_r)
                acc_nbr = jnp.where(in_j, nb, acc_nbr)
                acc_tv = jnp.where(in_j, tidx, acc_tv)
                acc_p = jnp.where(in_j, ep, acc_p)
            cols = {nm: jnp.take(c, acc_r, axis=0, mode="clip")
                    for nm, c in cols.items()}
            cols[alias] = acc_nbr
            cols[f"{ealias}#t"] = acc_tv
            cols[f"{ealias}#p"] = acc_p
            valid = pos_out < jnp.minimum(running, cap)
            for pj, (p_from, p_ealias, lo, hi, vlo, vhi, tidx, has_pos,
                     mode, d_max, block_rows) in enumerate(probes):
                indptr, indices, pos = csrs[k][1][pj]
                pfrm = cols[p_from]
                local = jnp.clip(pfrm - lo, 0, indptr.shape[0] - 2)
                # rows outside the keyed/value type ranges fail the probe
                # (the per-hop loop's membership masks); -2 never matches
                # a real id (>= 0) or an ELL pad (-1)
                ok = (valid & (pfrm >= lo) & (pfrm < hi)
                      & (cols[alias] >= vlo) & (cols[alias] < vhi))
                tgt = jnp.where(ok, cols[alias], -2)
                if mode == "ell":
                    adj = gather_rows(indices, indptr, local, d_max)
                    found, prow = wcoj_intersect(adj, tgt,
                                                 block_rows=block_rows,
                                                 interpret=interpret)
                    fpos = (jnp.take(indptr, local, axis=0, mode="clip")
                            + prow.astype(i32))
                else:
                    lo_b = jnp.take(indptr, local, axis=0, mode="clip")
                    hi_b = jnp.take(indptr, local + 1, axis=0, mode="clip")
                    found, fpos = bounded_binary_search(indices, lo_b, hi_b,
                                                        tgt)
                ep = (jnp.take(pos, fpos.astype(i32), axis=0, mode="clip")
                      if has_pos else fpos.astype(i32))
                cols[f"{p_ealias}#t"] = jnp.full(cap, tidx, i32)
                cols[f"{p_ealias}#p"] = jnp.where(found, ep, 0)
                valid = valid & found
            if pred is not None:
                valid = valid & eval_pred(pred, cols, scalars, values,
                                          vprops, eprops)
        order = jnp.argsort(~valid).astype(i32)   # stable: valid rows first
        out = {nm: jnp.take(c, order, axis=0, mode="clip")
               for nm, c in cols.items()}
        return (out, valid.sum().astype(i32), jnp.stack(needed),
                jnp.stack(needed_f))

    return run


@jax.jit
def segment_count(segment_ids: jax.Array, num_segments: int):
    return jax.ops.segment_sum(jnp.ones_like(segment_ids), segment_ids,
                               num_segments=num_segments)


@functools.partial(jax.jit, static_argnames=("num_segments",))
def group_count(keys: jax.Array, num_segments: int):
    """Count per dense key in [0, num_segments)."""
    return jax.ops.segment_sum(
        jnp.ones(keys.shape[0], jnp.int32), keys, num_segments=num_segments)
