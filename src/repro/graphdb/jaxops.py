"""jit'd JAX mirrors of the engine's hot primitives.

On TPU these (and their Pallas variants in ``repro.kernels``) execute the
fixed-shape inner loops of pattern matching; the numpy twins in ``vecops`` are
the host path. Shapes must be static under jit, so the expansion primitive
works on a padded row block and returns a validity mask — the same contract
the Pallas kernels use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("max_degree",))
def expand_padded(indptr: jax.Array, indices: jax.Array,
                  rows_local: jax.Array, max_degree: int):
    """Expand each row to at most ``max_degree`` neighbors.

    Returns (nbr[R, max_degree], valid[R, max_degree], flat_pos[R, max_degree]).
    Rows with degree > max_degree are truncated (caller splits such rows).
    """
    start = indptr[rows_local]
    deg = indptr[rows_local + 1] - start
    offs = jnp.arange(max_degree, dtype=indptr.dtype)[None, :]
    valid = offs < deg[:, None]
    flat = jnp.clip(start[:, None] + offs, 0, indices.shape[0] - 1)
    nbr = jnp.where(valid, indices[flat], -1)
    return nbr, valid, jnp.where(valid, flat, -1)


@jax.jit
def bounded_binary_search(indices: jax.Array, lo: jax.Array, hi: jax.Array,
                          targets: jax.Array):
    """jnp twin of vecops.bounded_binary_search (found, pos)."""
    hi_orig = hi
    n = indices.shape[0]

    def cond(state):
        lo, hi = state
        return jnp.any(lo < hi)

    def body(state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi) // 2
        v = indices[jnp.minimum(mid, n - 1)]
        go_right = active & (v < targets)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        return lo, hi

    lo, hi = jax.lax.while_loop(cond, body, (lo, hi))
    pos = lo
    in_range = pos < jnp.minimum(hi_orig, n)
    found = in_range & (indices[jnp.minimum(pos, n - 1)] == targets)
    return found, pos


def range_flatten(start: jax.Array, counts: jax.Array, total: int):
    """Row-major flattening of per-row index ranges ``[start_i, start_i +
    counts_i)``: returns ``(row_idx[total], flat_pos[total])``.

    The device twin of the ``np.repeat``-based expansion in
    ``vecops.expand_csr`` — built from cumsum + searchsorted + gathers
    because both ``jnp.repeat`` and scatter-based alternatives serialize
    (or pay heavy eager machinery) on CPU XLA.  ``total`` is the
    data-dependent output size, synced by the caller and static under jit.
    """
    cum = jnp.cumsum(counts)
    pos = jnp.arange(total, dtype=jnp.int32)
    ridx = jnp.searchsorted(cum, pos, side="right").astype(jnp.int32)
    offs = pos - jnp.take(cum - counts, ridx, axis=0, mode="clip")
    flat = jnp.take(start, ridx, axis=0, mode="clip") + offs
    return ridx, flat


@jax.jit
def csr_expand_total(indptr: jax.Array, rows: jax.Array):
    """Predictive output size of a CSR expansion (one dispatch; the caller
    syncs it for the blow-up guard and the static expand shape).  Returns
    ``(total_i32, total_f32)``: the int32 sum is exact below 2^31 but
    wraps above it, so the float32 estimate lets the caller catch the
    wrap and still raise the blow-up guard instead of silently building
    an empty/garbled expansion."""
    deg = (jnp.take(indptr, rows + 1, axis=0, mode="clip")
           - jnp.take(indptr, rows, axis=0, mode="clip"))
    return deg.sum(), deg.astype(jnp.float32).sum()


@functools.partial(jax.jit, static_argnames=("total", "has_pos"))
def csr_expand_flat(indptr: jax.Array, indices: jax.Array, pos: jax.Array,
                    rows: jax.Array, total: int, has_pos: bool):
    """Fused expand step: degree lookup + row-major flattening + neighbor /
    edge-position gathers in ONE dispatch (eager would be ~10).  Keyed by
    (rows.shape, total); the caller syncs ``total`` from the degrees first.
    ``pos`` is ignored (pass ``indices``) when ``has_pos`` is False."""
    start = jnp.take(indptr, rows, axis=0, mode="clip")
    deg = jnp.take(indptr, rows + 1, axis=0, mode="clip") - start
    ridx, flat = range_flatten(start, deg, total)
    nbr = jnp.take(indices, flat, axis=0, mode="clip")
    epos = jnp.take(pos, flat, axis=0, mode="clip") if has_pos else flat
    return ridx, nbr, epos


@jax.jit
def lex_ranks(cols: list[jax.Array]) -> jax.Array:
    """Dense lexicographic ranks of row tuples (``cols[0]`` most
    significant): equal tuples share a rank, and rank order equals the
    tuples' lexicographic sort order — the device-native equivalent of
    ``vecops.combine_keys``'s factorized packing (identical grouping and
    identical ascending order, so cross-backend row order is preserved).

    Sort/gather-shaped on purpose: a scatter (``.at[order].set``)
    serializes on CPU XLA, so the group ids are carried back through an
    argsort-based inverse permutation.  jit'd into one dispatch, keyed by
    (n, len(cols)).
    """
    n = cols[0].shape[0]
    if n == 0:
        return jnp.zeros(0, jnp.int32)
    order = jnp.lexsort(tuple(reversed(cols)))
    ne = jnp.zeros(n - 1, bool)
    for c in cols:
        s = jnp.take(c, order, axis=0, mode="clip")
        ne = ne | (s[1:] != s[:-1])
    gid_sorted = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(ne.astype(jnp.int32))])
    inv_order = jnp.argsort(order)
    return jnp.take(gid_sorted, inv_order, axis=0, mode="clip")


@jax.jit
def group_boundaries(keys: jax.Array):
    """Stage 1 of sorted-run grouping: stable sort by key and flag run
    starts.  Returns ``(order, start_flags, flag_order, n_groups0d)`` — the
    caller syncs ``n_groups`` and slices ``flag_order[:n_groups]`` to get
    the run-start positions (ascending, since argsort is stable)."""
    n = keys.shape[0]
    order = jnp.argsort(keys)
    sk = jnp.take(keys, order, axis=0, mode="clip")
    flags = jnp.concatenate([jnp.ones(1, bool), sk[1:] != sk[:-1]])
    flag_order = jnp.argsort(~flags)
    return order, flags, flag_order, flags.sum()


@functools.partial(jax.jit, static_argnames=("fns",))
def group_aggregate(order: jax.Array, starts: jax.Array, keys: jax.Array,
                    cols: tuple, fns: tuple):
    """Stage 2 of sorted-run grouping, one dispatch for every aggregate:
    counts/sums via cumsum + boundary gathers, MIN/MAX via a secondary
    value sort within key runs.  ``fns`` is the static aggregate spec
    aligned with ``cols``.

    Staging envelope: SUM/AVG accumulate through an int32/float32 cumsum
    (x64 is disabled), so running totals past 2^31 wrap where the numpy
    backend's int64 path stays exact — a known limit, tracked in the
    ROADMAP (widen to pairwise or i64-emulated accumulation before
    hub-scale stores)."""
    n = order.shape[0]
    bounds = jnp.concatenate([starts, jnp.asarray([n], starts.dtype)])
    ends = bounds[1:] - 1
    counts = (bounds[1:] - bounds[:-1]).astype(jnp.int32)
    first = jnp.take(order, starts, axis=0, mode="clip")
    outs = []
    for fn, col in zip(fns, cols):
        if fn == "COUNT":
            outs.append(counts)
            continue
        if fn in ("SUM", "AVG"):
            cs = jnp.cumsum(jnp.take(col, order, axis=0, mode="clip"))
            ce = jnp.take(cs, ends, axis=0, mode="clip")
            sums = ce - jnp.concatenate([jnp.zeros(1, cs.dtype), ce[:-1]])
            outs.append(sums.astype(jnp.float32) / jnp.maximum(counts, 1)
                        if fn == "AVG" else sums.astype(jnp.int32))
            continue
        # MIN/MAX: secondary sort by value within each key run — minima at
        # run starts, maxima at run ends
        sv = jnp.take(col, jnp.lexsort((col, keys)), axis=0, mode="clip")
        outs.append(jnp.take(sv, starts if fn == "MIN" else ends,
                             axis=0, mode="clip"))
    return first, tuple(outs)


@jax.jit
def sortmerge_bounds(lkeys: jax.Array, rkeys: jax.Array):
    """Stage 1 of the sort-merge join (one dispatch): stable sorts + the
    per-left-row matching right range.  Returns ``(lorder, rorder, lo,
    cnt, total0d)``; the caller syncs ``total`` for the pair expansion."""
    lorder = jnp.argsort(lkeys)
    rorder = jnp.argsort(rkeys)
    ls = jnp.take(lkeys, lorder, axis=0, mode="clip")
    rs = jnp.take(rkeys, rorder, axis=0, mode="clip")
    lo = jnp.searchsorted(rs, ls, side="left")
    cnt = jnp.searchsorted(rs, ls, side="right") - lo
    # int32 total (exact below 2^31) + float32 estimate (wrap detector)
    return lorder, rorder, lo, cnt, cnt.sum(), cnt.astype(jnp.float32).sum()


@functools.partial(jax.jit, static_argnames=("total",))
def sortmerge_pairs(lorder: jax.Array, rorder: jax.Array, lo: jax.Array,
                    cnt: jax.Array, total: int):
    """Fused pair expansion of the sort-merge join (one dispatch)."""
    lrep, rpos = range_flatten(lo, cnt, total)
    return (jnp.take(lorder, lrep, axis=0, mode="clip").astype(jnp.int32),
            jnp.take(rorder, rpos, axis=0, mode="clip").astype(jnp.int32))


@jax.jit
def segment_count(segment_ids: jax.Array, num_segments: int):
    return jax.ops.segment_sum(jnp.ones_like(segment_ids), segment_ids,
                               num_segments=num_segments)


@functools.partial(jax.jit, static_argnames=("num_segments",))
def group_count(keys: jax.Array, num_segments: int):
    """Count per dense key in [0, num_segments)."""
    return jax.ops.segment_sum(
        jnp.ones(keys.shape[0], jnp.int32), keys, num_segments=num_segments)
