"""jit'd JAX mirrors of the engine's hot primitives.

On TPU these (and their Pallas variants in ``repro.kernels``) execute the
fixed-shape inner loops of pattern matching; the numpy twins in ``vecops`` are
the host path. Shapes must be static under jit, so the expansion primitive
works on a padded row block and returns a validity mask — the same contract
the Pallas kernels use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("max_degree",))
def expand_padded(indptr: jax.Array, indices: jax.Array,
                  rows_local: jax.Array, max_degree: int):
    """Expand each row to at most ``max_degree`` neighbors.

    Returns (nbr[R, max_degree], valid[R, max_degree], flat_pos[R, max_degree]).
    Rows with degree > max_degree are truncated (caller splits such rows).
    """
    start = indptr[rows_local]
    deg = indptr[rows_local + 1] - start
    offs = jnp.arange(max_degree, dtype=indptr.dtype)[None, :]
    valid = offs < deg[:, None]
    flat = jnp.clip(start[:, None] + offs, 0, indices.shape[0] - 1)
    nbr = jnp.where(valid, indices[flat], -1)
    return nbr, valid, jnp.where(valid, flat, -1)


@jax.jit
def bounded_binary_search(indices: jax.Array, lo: jax.Array, hi: jax.Array,
                          targets: jax.Array):
    """jnp twin of vecops.bounded_binary_search (found, pos)."""
    hi_orig = hi
    n = indices.shape[0]

    def cond(state):
        lo, hi = state
        return jnp.any(lo < hi)

    def body(state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi) // 2
        v = indices[jnp.minimum(mid, n - 1)]
        go_right = active & (v < targets)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        return lo, hi

    lo, hi = jax.lax.while_loop(cond, body, (lo, hi))
    pos = lo
    in_range = pos < jnp.minimum(hi_orig, n)
    found = in_range & (indices[jnp.minimum(pos, n - 1)] == targets)
    return found, pos


@jax.jit
def segment_count(segment_ids: jax.Array, num_segments: int):
    return jax.ops.segment_sum(jnp.ones_like(segment_ids), segment_ids,
                               num_segments=num_segments)


@functools.partial(jax.jit, static_argnames=("num_segments",))
def group_count(keys: jax.Array, num_segments: int):
    """Count per dense key in [0, num_segments)."""
    return jax.ops.segment_sum(
        jnp.ones(keys.shape[0], jnp.int32), keys, num_segments=num_segments)
