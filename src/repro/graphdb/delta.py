"""Delta overlay + MVCC-lite snapshots over the frozen CSR store.

``MutableGraphStore`` wraps a frozen :class:`~repro.graphdb.storage.GraphStore`
with an append-friendly overlay:

- per-triple **sorted insert buffers** exposed to the engine as compact-row
  CSR *views* (:class:`DeltaAdj`) that flow through the existing
  expand/intersect kernels of every backend unchanged,
- **edge tombstones** (a second compact-row CSR view per (triple, direction))
  probed with the same intersect primitive,
- **vertex tombstones** (small sorted id arrays) and **extension vertices**
  with ids appended *above* the base id space (``gid >= base.n_vertices``) so
  the base type ranges never shift,
- **overlay property columns** for new vertices/edges; properties are
  version-immutable (insert/delete only, no in-place updates), so property
  gathers never need snapshot filtering — only the id/slot -> value mapping
  grows.

**MVCC-lite**: every mutation bumps ``version``. ``snapshot()`` returns an
immutable :class:`Snapshot` — built arrays, not live dicts — that sees
``base ∪ inserts − tombstones`` as of its pin. Writers never block readers:
later mutations build *new* views; views for untouched (triple, direction)
pairs are reused by object identity, which keeps the backends' ``id()``-keyed
device caches warm across snapshots. View capacities are pow2-bucketed
(rows and nnz independently) so device uploads and kernel shapes plateau.

``compact()`` merges the overlay into a rebuilt base via
:func:`~repro.graphdb.storage.build_store` with *canonical renumbering*
(per type: surviving base vertices in original order, then extension
vertices in insertion order), which makes the compacted store array-identical
to a from-scratch build over the same logical graph. Snapshots pinned below
the compaction version are retired (``Snapshot.retired``) — the low-water
mark is the compaction itself.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import weakref

import numpy as np

from repro.core.schema import EdgeTriple
from repro.graphdb.storage import CSR, GraphStore, build_store

INT64_MIN = np.iinfo(np.int64).min
# Sorted row-key sentinel: larger than any real id that fits the backends'
# int32 staging envelope, so searchsorted(keys, gid) never lands past the
# trailing sentinel block and the sentinel row is always empty.
SENTINEL_KEY = 2**31 - 2


def _pow2(n: int, lo: int = 8) -> int:
    c = lo
    while c < n:
        c <<= 1
    return c


@dataclasses.dataclass(frozen=True)
class DeltaAdj:
    """A compact-row CSR view over one (triple, direction) of the overlay.

    ``keys[:n_rows]`` are the sorted global ids that have overlay entries;
    the tail is padded with ``SENTINEL_KEY``. ``csr`` has ``len(keys)`` rows
    (+1 sentinel offsets row): real rows first, then empty padded rows, so any
    ``searchsorted(keys, gid)`` result indexes a valid (possibly empty) row.
    ``csr.indices``/``csr.pos`` are pow2-padded beyond ``nnz``; the padding is
    unreachable through ``indptr``.
    """
    keys: np.ndarray        # int64[row_cap] sorted, SENTINEL_KEY padded
    csr: CSR                # indptr int64[row_cap+1]; indices/pos int64[nnz_cap]
    n_rows: int
    nnz: int

    @property
    def row_cap(self) -> int:
        return int(self.keys.shape[0])

    @property
    def nnz_cap(self) -> int:
        return int(self.csr.indices.shape[0])


def _build_adj(keys: np.ndarray, nbrs: np.ndarray,
               pos: np.ndarray | None) -> DeltaAdj | None:
    """Assemble a DeltaAdj from parallel (key gid, neighbor gid[, pos]) arrays."""
    if keys.shape[0] == 0:
        return None
    order = np.lexsort((nbrs, keys))
    k, v = keys[order], nbrs[order]
    p = pos[order] if pos is not None else None
    uk, counts = np.unique(k, return_counts=True)
    r, nnz = int(uk.shape[0]), int(v.shape[0])
    row_cap = _pow2(r + 1, 4)
    nnz_cap = _pow2(nnz, 8)
    key_col = np.full(row_cap, SENTINEL_KEY, dtype=np.int64)
    key_col[:r] = uk
    indptr = np.full(row_cap + 1, nnz, dtype=np.int64)
    indptr[0] = 0
    indptr[1:r + 1] = np.cumsum(counts)
    indices = np.zeros(nnz_cap, dtype=np.int64)
    indices[:nnz] = v
    pcol = None
    if p is not None:
        pcol = np.zeros(nnz_cap, dtype=np.int64)
        pcol[:nnz] = p
    return DeltaAdj(keys=key_col, csr=CSR(indptr, indices, pcol),
                    n_rows=r, nnz=nnz)


@dataclasses.dataclass
class Snapshot:
    """Immutable pin of the overlay state at one version.

    ``ins``/``dels`` map ``(triple, "out"|"in")`` to DeltaAdj views (only
    non-empty entries present). ``ext`` maps vertex type -> sorted alive
    extension gids; ``dead`` maps vertex type -> sorted tombstoned gids
    (base and extension). ``retired`` flips when a compaction rebases the
    store underneath — executing a retired snapshot raises.
    """
    version: int
    ins: dict[tuple[EdgeTriple, str], DeltaAdj]
    dels: dict[tuple[EdgeTriple, str], DeltaAdj]
    ext: dict[str, np.ndarray]
    dead: dict[str, np.ndarray]
    retired: bool = False

    def __post_init__(self):
        self._touched = frozenset(t for (t, _k) in self.ins) | \
            frozenset(t for (t, _k) in self.dels)

    @property
    def is_empty(self) -> bool:
        return not (self.ins or self.dels or self.ext or self.dead)

    @property
    def touched_triples(self) -> frozenset:
        return self._touched

    @property
    def has_vertex_delta(self) -> bool:
        return bool(self.ext or self.dead)

    def dead_for(self, vtype: str) -> np.ndarray | None:
        return self.dead.get(vtype)

    def affects_chain(self, triples) -> bool:
        """Fused chains must fall back to the per-hop loop when the snapshot
        could change any hop's adjacency: tombstoned vertices filter every
        expansion target, and overlay/tombstoned edges change hop outputs.
        Extension-only snapshots (new isolated vertices) leave chains exact:
        an extension id can only enter a pattern through a scan, never
        mid-chain."""
        if self.dead:
            return True
        tt = self._touched
        if not tt:
            return False
        return any(t in tt for t in triples)


class StaleSnapshotError(RuntimeError):
    """Raised when executing against a snapshot retired by compaction."""


class MutableGraphStore:
    """A GraphStore-shaped mutable overlay. Duck-types the frozen store:

    - ``type_range``/``v_offset``/``out_csr``/``in_csr``/... delegate to the
      base (engine addressing stays base-layout; extension ids live above),
    - ``v_count``/``n_vertices``/``n_edges`` report *live* counts (the cost
      model sees overlay occupancy),
    - ``vertex_prop``/``edge_prop``/``type_of_ids`` are overlay-aware.

    Thread-safe: mutations, ``snapshot()`` and ``compact()`` serialize on an
    internal lock (QueryServer applies writes on its worker thread while the
    admission thread pins snapshots).
    """

    def __init__(self, base: GraphStore):
        if isinstance(base, MutableGraphStore):
            raise TypeError("cannot wrap a MutableGraphStore")
        self._base = base
        self._lock = threading.RLock()
        self._base_vertices = int(base.n_vertices)
        self._base_edges = int(base.n_edges)
        self.version = 0
        self.mutations = 0
        self.compactions: list[dict] = []
        # edge overlay: triple -> {(gsrc, gdst): slot} / {(gsrc, gdst)}
        self._ins: dict[EdgeTriple, dict[tuple[int, int], int]] = {}
        self._dels: dict[EdgeTriple, set[tuple[int, int]]] = {}
        self._edge_touched: dict[EdgeTriple, int] = {}
        self._next_slot = 0
        # vertex overlay (extension ids = base_vertices + slot)
        self._ext_type: list[str] = []
        self._ext_alive: list[bool] = []
        self._dead_base: set[int] = set()
        self._vtx_touched = 0
        # overlay property stores: prop -> {slot: int64 value}
        self._ext_props: dict[str, dict[int, int]] = {}
        self._eprops_over: dict[str, dict[int, int]] = {}
        self._prop_ver = 0          # bumps when overlay prop columns change
        # live per-type counts (kept incrementally; v_count reads this)
        self._live_count = dict(base.v_count)
        # snapshot machinery
        self._cur_snap: Snapshot | None = None
        self._view_cache: dict[tuple, tuple[int, DeltaAdj | None]] = {}
        self._vtx_views: tuple[int, dict, dict] | None = None
        self._snapshots: list = []      # weakrefs to issued snapshots
        self._col_cache: dict[tuple, np.ndarray] = {}

    def __deepcopy__(self, memo):
        """Frozen logical copy: overlay state is cloned, the immutable base
        CSR (and any operator-set caches living on it) is *shared*.  This is
        the snapshot-isolation test oracle — a copy taken at version V keeps
        answering at V while the original keeps mutating."""
        with self._lock:
            clone = MutableGraphStore(self._base)
            clone.version = self.version
            clone.mutations = self.mutations
            clone.compactions = [dict(e) for e in self.compactions]
            clone._ins = {t: dict(m) for t, m in self._ins.items()}
            clone._dels = {t: set(s) for t, s in self._dels.items()}
            clone._edge_touched = dict(self._edge_touched)
            clone._next_slot = self._next_slot
            clone._ext_type = list(self._ext_type)
            clone._ext_alive = list(self._ext_alive)
            clone._dead_base = set(self._dead_base)
            clone._vtx_touched = self._vtx_touched
            clone._ext_props = {k: dict(v) for k, v in self._ext_props.items()}
            clone._eprops_over = {k: dict(v)
                                  for k, v in self._eprops_over.items()}
            clone._prop_ver = self._prop_ver
            clone._live_count = dict(self._live_count)
            memo[id(self)] = clone
            return clone

    # ------------------------------------------------------------ delegation
    @property
    def base(self) -> GraphStore:
        return self._base

    @property
    def schema(self):
        return self._base.schema

    @property
    def v_offset(self):
        return self._base.v_offset

    @property
    def out_csr(self):
        return self._base.out_csr

    @property
    def in_csr(self):
        return self._base.in_csr

    @property
    def v_props(self):
        return self._base.v_props

    @property
    def e_props(self):
        return self._base.e_props

    @property
    def str_vocab(self):
        return self._base.str_vocab

    def type_range(self, vtype: str):
        return self._base.type_range(vtype)

    def _sorted_types(self):
        return self._base._sorted_types()

    def triple_index(self):
        return self._base.triple_index()

    def encode_str(self, prop: str, value: str) -> int:
        return self._base.encode_str(prop, value)

    # ------------------------------------------------------------ live meta
    @property
    def v_count(self) -> dict[str, int]:
        return self._live_count

    @property
    def n_vertices(self) -> int:
        return sum(self._live_count.values())

    @property
    def n_edges(self) -> int:
        d = sum(len(m) for m in self._ins.values()) - \
            sum(len(s) for s in self._dels.values())
        return self._base_edges + d

    @property
    def base_n_vertices(self) -> int:
        return self._base_vertices

    @property
    def id_space(self) -> int:
        """Upper bound of the global id space (base + extension slots)."""
        return self._base_vertices + len(self._ext_type)

    @property
    def overlay_edge_slots(self) -> int:
        """Allocated overlay edge slots (overlay ``pos`` values live in
        ``[base_edges, base_edges + overlay_edge_slots)``)."""
        return self._next_slot

    @property
    def compaction_epoch(self) -> int:
        """Bumps only when compaction swaps the base CSR objects — the
        cache-invalidation key for anything derived from base arrays
        (fused-chain specs, device property columns)."""
        return len(self.compactions)

    def delta_edge_counts(self) -> dict[EdgeTriple, int]:
        """Net overlay edge count per triple (Statistics hook)."""
        out: dict[EdgeTriple, int] = {}
        for t, m in self._ins.items():
            if m:
                out[t] = out.get(t, 0) + len(m)
        for t, s in self._dels.items():
            if s:
                out[t] = out.get(t, 0) - len(s)
        return out

    # --------------------------------------------------- overlay-aware reads
    def type_of_ids(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        bv = self._base_vertices
        out = self._base.type_of_ids(np.where(ids < bv, ids, 0))
        m = ids >= bv
        if m.any():
            ti = {t: i for i, t in enumerate(self._base._sorted_types())}
            ext_ti = np.array([ti[t] for t in self._ext_type], dtype=np.int64)
            out = np.where(m, ext_ti[np.clip(ids - bv, 0, len(ext_ti) - 1)],
                           out)
        return out

    def ext_vertex_prop_column(self, prop: str) -> np.ndarray:
        """Dense pow2-padded column over extension slots (INT64_MIN missing)."""
        with self._lock:
            key = ("v", prop, self._prop_ver, len(self._ext_type))
            col = self._col_cache.get(key)
            if col is None:
                cap = _pow2(max(len(self._ext_type), 1))
                col = np.full(cap, INT64_MIN, dtype=np.int64)
                for slot, v in self._ext_props.get(prop, {}).items():
                    col[slot] = v
                self._col_cache[key] = col
            return col

    def overlay_edge_prop_column(self, prop: str) -> np.ndarray:
        """Dense pow2-padded column over overlay edge slots."""
        with self._lock:
            key = ("e", prop, self._prop_ver, self._next_slot)
            col = self._col_cache.get(key)
            if col is None:
                cap = _pow2(max(self._next_slot, 1))
                col = np.full(cap, INT64_MIN, dtype=np.int64)
                for slot, v in self._eprops_over.get(prop, {}).items():
                    col[slot] = v
                self._col_cache[key] = col
            return col

    def vertex_prop(self, ids: np.ndarray, prop: str) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        bv = self._base_vertices
        out = self._base.vertex_prop(np.where(ids < bv, ids, 0), prop)
        m = ids >= bv
        if m.any():
            col = self.ext_vertex_prop_column(prop)
            out = np.where(m, col[np.clip(ids - bv, 0, col.shape[0] - 1)], out)
        return out

    def edge_prop(self, triple_ids: np.ndarray, pos: np.ndarray,
                  prop: str) -> np.ndarray:
        triple_ids = np.asarray(triple_ids, dtype=np.int64)
        pos = np.asarray(pos, dtype=np.int64)
        be = self._base_edges
        over = pos >= be
        out = self._base.edge_prop(np.where(over, -1, triple_ids),
                                   np.where(over, 0, pos), prop)
        if over.any():
            col = self.overlay_edge_prop_column(prop)
            out = np.where(
                over, col[np.clip(pos - be, 0, col.shape[0] - 1)], out)
        return out

    # ------------------------------------------------------------- mutations
    def _encode(self, prop: str, value) -> int:
        if isinstance(value, str):
            code = self._base.encode_str(prop, value)
            if code < 0:
                raise ValueError(
                    f"unknown string {value!r} for {prop!r}: the string "
                    "vocabulary is frozen with the base store")
            return code
        return int(value)

    def _bump(self, triple: EdgeTriple | None = None, vertex: bool = False):
        self.version += 1
        self.mutations += 1
        self._cur_snap = None
        if triple is not None:
            self._edge_touched[triple] = self.version
        if vertex:
            self._vtx_touched = self.version

    def _alive(self, gid: int, vtype: str) -> bool:
        bv = self._base_vertices
        if gid < bv:
            lo, hi = self._base.type_range(vtype)
            return lo <= gid < hi and gid not in self._dead_base
        slot = gid - bv
        return (slot < len(self._ext_type)
                and self._ext_type[slot] == vtype and self._ext_alive[slot])

    def _resolve_triple(self, triple) -> EdgeTriple:
        if not isinstance(triple, EdgeTriple):
            triple = EdgeTriple(*triple)
        if triple not in self._base.out_csr:
            raise KeyError(f"unknown edge triple {triple}")
        return triple

    def _base_has_edge(self, t: EdgeTriple, src: int, dst: int) -> bool:
        if src >= self._base_vertices:
            return False
        csr = self._base.out_csr[t]
        lo, hi = self._base.type_range(t.src)
        if not (lo <= src < hi):
            return False
        i0, i1 = int(csr.indptr[src - lo]), int(csr.indptr[src - lo + 1])
        j = int(np.searchsorted(csr.indices[i0:i1], dst))
        return j < i1 - i0 and int(csr.indices[i0 + j]) == dst

    def insert_vertex(self, vtype: str, props: dict | None = None) -> int:
        """Insert a vertex; returns its (extension) global id."""
        with self._lock:
            if vtype not in self._base.v_offset:
                raise KeyError(f"unknown vertex type {vtype!r}")
            slot = len(self._ext_type)
            self._ext_type.append(vtype)
            self._ext_alive.append(True)
            for k, v in (props or {}).items():
                self._ext_props.setdefault(k, {})[slot] = self._encode(k, v)
            if props:
                self._prop_ver += 1
            self._live_count[vtype] += 1
            self._bump(vertex=True)
            return self._base_vertices + slot

    def delete_vertex(self, gid: int) -> bool:
        """Tombstone a vertex. Incident edges are hidden at read time and
        dropped physically at compaction."""
        with self._lock:
            gid = int(gid)
            bv = self._base_vertices
            if gid >= bv:
                slot = gid - bv
                if slot >= len(self._ext_type) or not self._ext_alive[slot]:
                    return False
                self._ext_alive[slot] = False
                self._live_count[self._ext_type[slot]] -= 1
            else:
                if gid in self._dead_base:
                    return False
                self._dead_base.add(gid)
                types = self._base._sorted_types()
                tname = types[int(self._base.type_of_ids(
                    np.array([gid], dtype=np.int64))[0])]
                self._live_count[tname] -= 1
            self._bump(vertex=True)
            return True

    def insert_edge(self, triple, src: int, dst: int,
                    props: dict | None = None) -> bool:
        """Insert an edge between live vertices. Returns False if it already
        exists. Re-inserting a tombstoned base edge resurrects it with its
        original properties (``props`` must be None in that case)."""
        with self._lock:
            t = self._resolve_triple(triple)
            src, dst = int(src), int(dst)
            if not self._alive(src, t.src):
                raise ValueError(f"src {src} is not a live {t.src!r} vertex")
            if not self._alive(dst, t.dst):
                raise ValueError(f"dst {dst} is not a live {t.dst!r} vertex")
            key = (src, dst)
            dels = self._dels.get(t)
            if dels is not None and key in dels:
                if props:
                    raise ValueError(
                        "cannot attach new properties when resurrecting a "
                        "tombstoned base edge")
                dels.discard(key)
                self._bump(triple=t)
                return True
            if self._base_has_edge(t, src, dst):
                return False
            ins = self._ins.setdefault(t, {})
            if key in ins:
                return False
            slot = self._next_slot
            self._next_slot += 1
            ins[key] = slot
            for k, v in (props or {}).items():
                self._eprops_over.setdefault(k, {})[slot] = self._encode(k, v)
            if props:
                self._prop_ver += 1
            self._bump(triple=t)
            return True

    def delete_edge(self, triple, src: int, dst: int) -> bool:
        with self._lock:
            t = self._resolve_triple(triple)
            key = (int(src), int(dst))
            ins = self._ins.get(t)
            if ins is not None and key in ins:
                del ins[key]
                self._bump(triple=t)
                return True
            if self._base_has_edge(t, key[0], key[1]):
                dels = self._dels.setdefault(t, set())
                if key in dels:
                    return False
                dels.add(key)
                self._bump(triple=t)
                return True
            return False

    # ------------------------------------------------------------- snapshots
    def _view(self, t: EdgeTriple, kind: str, which: str) -> DeltaAdj | None:
        key = (t, kind, which)
        ent = self._view_cache.get(key)
        need = self._edge_touched.get(t, 0)
        if ent is not None and ent[0] >= need:
            return ent[1]
        if which == "ins":
            items = self._ins.get(t) or {}
            if items:
                src = np.fromiter((k[0] for k in items), np.int64, len(items))
                dst = np.fromiter((k[1] for k in items), np.int64, len(items))
                pos = np.fromiter(items.values(), np.int64, len(items))
                pos = pos + self._base_edges
                adj = (_build_adj(src, dst, pos) if kind == "out"
                       else _build_adj(dst, src, pos))
            else:
                adj = None
        else:
            pairs = self._dels.get(t) or ()
            if pairs:
                src = np.fromiter((k[0] for k in pairs), np.int64, len(pairs))
                dst = np.fromiter((k[1] for k in pairs), np.int64, len(pairs))
                adj = (_build_adj(src, dst, None) if kind == "out"
                       else _build_adj(dst, src, None))
            else:
                adj = None
        self._view_cache[key] = (self.version, adj)
        return adj

    def _vertex_views(self) -> tuple[dict, dict]:
        ent = self._vtx_views
        if ent is not None and ent[0] >= self._vtx_touched:
            return ent[1], ent[2]
        bv = self._base_vertices
        ext: dict[str, list[int]] = {}
        dead: dict[str, list[int]] = {}
        for slot, t in enumerate(self._ext_type):
            (ext if self._ext_alive[slot] else dead).setdefault(t, []).append(
                bv + slot)
        if self._dead_base:
            types = self._base._sorted_types()
            gids = np.array(sorted(self._dead_base), dtype=np.int64)
            for ti, gid in zip(self._base.type_of_ids(gids), gids):
                dead.setdefault(types[int(ti)], []).append(int(gid))
        ext_a = {t: np.array(sorted(v), dtype=np.int64)
                 for t, v in ext.items()}
        dead_a = {t: np.array(sorted(v), dtype=np.int64)
                  for t, v in dead.items()}
        self._vtx_views = (self.version, ext_a, dead_a)
        return ext_a, dead_a

    def snapshot(self) -> Snapshot:
        """Pin the current version. Cheap: views for untouched (triple,
        direction) pairs are reused by identity across snapshots."""
        with self._lock:
            if self._cur_snap is not None:
                return self._cur_snap
            ins: dict = {}
            dels: dict = {}
            for t in self._edge_touched:
                for kind in ("out", "in"):
                    a = self._view(t, kind, "ins")
                    if a is not None:
                        ins[(t, kind)] = a
                    a = self._view(t, kind, "del")
                    if a is not None:
                        dels[(t, kind)] = a
            ext, dead = self._vertex_views()
            snap = Snapshot(version=self.version, ins=ins, dels=dels,
                            ext=ext, dead=dead)
            self._snapshots.append(weakref.ref(snap))
            self._cur_snap = snap
            return snap

    def _live_snapshots(self) -> list[Snapshot]:
        out, keep = [], []
        for ref in self._snapshots:
            s = ref()
            if s is not None:
                keep.append(ref)
                if not s.retired:
                    out.append(s)
        self._snapshots = keep
        return out

    # ------------------------------------------------------------ compaction
    def compact(self) -> dict:
        """Merge the overlay into a rebuilt base CSR (canonical renumbering:
        identical arrays to a from-scratch ``build_store`` over the same
        logical graph). Retires snapshots pinned below the new version."""
        with self._lock:
            t0 = time.perf_counter()
            base = self._base
            bv = self._base_vertices
            schema = base.schema
            # --- vertex renumbering: old global id -> new LOCAL id, per type
            old2new = np.full(self.id_space, -1, dtype=np.int64)
            new_count: dict[str, int] = {}
            new_vprops: dict[str, dict[str, np.ndarray]] = {}
            for t in schema.vertex_types:
                lo, hi = base.type_range(t)
                base_ids = np.arange(lo, hi, dtype=np.int64)
                if self._dead_base:
                    dead = np.array(sorted(self._dead_base), dtype=np.int64)
                    base_ids = base_ids[~np.isin(base_ids, dead)]
                ext_ids = np.array(
                    [bv + s for s, et in enumerate(self._ext_type)
                     if et == t and self._ext_alive[s]], dtype=np.int64)
                keep = np.concatenate([base_ids, ext_ids])
                old2new[keep] = np.arange(keep.shape[0], dtype=np.int64)
                new_count[t] = int(keep.shape[0])
                props = set(base.v_props.get(t, {}))
                for p, slots in self._ext_props.items():
                    if any(self._ext_type[s] == t and self._ext_alive[s]
                           for s in slots):
                        props.add(p)
                cols: dict[str, np.ndarray] = {}
                for p in props:
                    col = np.full(keep.shape[0], INT64_MIN, dtype=np.int64)
                    bcol = base.v_props.get(t, {}).get(p)
                    if bcol is not None:
                        col[:base_ids.shape[0]] = bcol[base_ids - lo]
                    over = self._ext_props.get(p, {})
                    for j, gid in enumerate(ext_ids):
                        v = over.get(int(gid) - bv)
                        if v is not None:
                            col[base_ids.shape[0] + j] = v
                    cols[p] = col
                if cols:
                    new_vprops[t] = cols
            # --- edges: surviving base ∪ overlay, filtered by live endpoints
            alive = old2new >= 0
            edges: dict[EdgeTriple, tuple[np.ndarray, np.ndarray]] = {}
            new_eprops: dict[EdgeTriple, dict[str, np.ndarray]] = {}
            merged = dropped = 0
            for t, csr in base.out_csr.items():
                lo, _ = base.type_range(t.src)
                deg = np.diff(csr.indptr)
                gsrc = np.repeat(
                    np.arange(deg.shape[0], dtype=np.int64) + lo, deg)
                gdst = csr.indices
                epos = np.arange(gdst.shape[0], dtype=np.int64)
                keep = alive[gsrc] & alive[gdst]
                dset = self._dels.get(t)
                if dset:
                    dk = np.array([s * self.id_space + d for s, d in dset],
                                  dtype=np.int64)
                    keep &= ~np.isin(gsrc * self.id_space + gdst, dk)
                dropped += int((~keep).sum())
                gsrc, gdst, epos = gsrc[keep], gdst[keep], epos[keep]
                ins = self._ins.get(t) or {}
                islots = np.fromiter(ins.values(), np.int64, len(ins))
                isrc = np.fromiter((k[0] for k in ins), np.int64, len(ins))
                idst = np.fromiter((k[1] for k in ins), np.int64, len(ins))
                ikeep = alive[isrc] & alive[idst]
                merged += int(ikeep.sum())
                isrc, idst, islots = isrc[ikeep], idst[ikeep], islots[ikeep]
                all_src = old2new[np.concatenate([gsrc, isrc])]
                all_dst = old2new[np.concatenate([gdst, idst])]
                edges[t] = (all_src, all_dst)
                props = set(base.e_props.get(t, {}))
                for p, slots in self._eprops_over.items():
                    if any(s in slots for s in islots):
                        props.add(p)
                cols = {}
                for p in props:
                    col = np.full(all_src.shape[0], INT64_MIN, dtype=np.int64)
                    bcol = base.e_props.get(t, {}).get(p)
                    if bcol is not None:
                        col[:gsrc.shape[0]] = bcol[epos]
                    over = self._eprops_over.get(p, {})
                    for j, s in enumerate(islots):
                        v = over.get(int(s))
                        if v is not None:
                            col[gsrc.shape[0] + j] = v
                    cols[p] = col
                if cols:
                    new_eprops[t] = cols
            new_base = build_store(schema, new_count, edges,
                                   v_props=new_vprops, e_props=new_eprops,
                                   str_vocab=base.str_vocab)
            retired = 0
            for s in self._live_snapshots():
                if s.version <= self.version:
                    s.retired = True
                    retired += 1
            event = {
                "version": self.version + 1,
                "merged_edges": merged,
                "dropped_edges": dropped,
                "ext_vertices": sum(self._ext_alive),
                "dead_vertices": len(self._dead_base)
                + self._ext_alive.count(False),
                "retired_snapshots": retired,
                "wall_s": round(time.perf_counter() - t0, 6),
            }
            self._base = new_base
            self._base_vertices = int(new_base.n_vertices)
            self._base_edges = int(new_base.n_edges)
            self._ins.clear()
            self._dels.clear()
            self._edge_touched.clear()
            self._next_slot = 0
            self._ext_type = []
            self._ext_alive = []
            self._dead_base = set()
            self._ext_props = {}
            self._eprops_over = {}
            self._prop_ver += 1
            self._live_count = dict(new_base.v_count)
            self._view_cache.clear()
            self._vtx_views = None
            self._col_cache.clear()
            self._cur_snap = None
            self.version += 1
            event["wall_s"] = round(time.perf_counter() - t0, 6)
            self.compactions.append(event)
            return event

    # ---------------------------------------------------------------- ledger
    def delta_info(self) -> dict:
        """Overlay occupancy / snapshot spread / compaction events, rendered
        as the ``-- delta --`` EXPLAIN section."""
        with self._lock:
            ins_e = sum(len(m) for m in self._ins.values())
            del_e = sum(len(s) for s in self._dels.values())
            live = [s.version for s in self._live_snapshots()]
            info = {
                "version": self.version,
                "mutations": self.mutations,
                "overlay_edges": ins_e,
                "tombstoned_edges": del_e,
                "ext_vertices": self._ext_alive.count(True),
                "dead_vertices": len(self._dead_base)
                + self._ext_alive.count(False),
                "overlay_triples": sum(
                    1 for t in set(self._ins) | set(self._dels)
                    if self._ins.get(t) or self._dels.get(t)),
                "snapshots_live": len(live),
                "snapshot_spread": (f"{min(live)}..{max(live)}"
                                    if live else "-"),
                "compactions": len(self.compactions),
            }
            if self.compactions:
                ev = self.compactions[-1]
                info["last_compaction"] = (
                    f"v{ev['version']} merged={ev['merged_edges']} "
                    f"dropped={ev['dropped_edges']} wall_s={ev['wall_s']}")
            return info
