"""Host-staging adapter — the pre-v2 (PR 3) executor↔backend contract,
preserved verbatim as a measurable baseline.

``HostStagingOperators`` reproduces the PR-3 era jax data plane exactly:
binding-table columns live in host numpy, the relational tail runs on the
host path, and the pattern kernels run on device *per call* — uploading the
row block, materializing the padded ``[R, D_max]`` neighbor/validity blocks
that jit's static shapes demand, downloading those padded blocks, and
compacting them back to flat rows **on the host**.  All transfers register
on the wrapped set's ``TransferStats``, so ``benchmarks/perf_compare.py
--residency`` can put a number on exactly what OperatorSet v2 removes
(zero mid-plan ``d2h``, no padded-block round trips), query by query,
against the device-resident path.
"""
from __future__ import annotations

import numpy as np

from repro.core.physical_spec import OperatorSet
from repro.graphdb import jax_backend as _jb
from repro.graphdb.numpy_backend import NumpyOperators


_pow2 = _jb._pow2        # the device path's rounding, not a diverging copy


class HostStagingOperators(NumpyOperators):
    """PR-3-style round-trip execution over a device operator set."""

    def __init__(self, inner: OperatorSet):
        super().__init__(inner.store)
        self.inner = inner
        self.name = f"host_staged[{inner.name}]"
        # shared ledger: the wrapper's per-op round trips show up exactly
        # where the device backend would have avoided them
        self.transfer_stats = inner.transfer_stats

    # PR-3 helpers: host pad + recorded up/downloads -----------------------
    @staticmethod
    def _pad_rows(a: np.ndarray, n: int, fill) -> np.ndarray:
        out = np.full(n, fill, dtype=a.dtype)
        out[:a.shape[0]] = a
        return out

    def _up(self, a: np.ndarray):
        return self.inner.asarray(a)

    def _down(self, x) -> np.ndarray:
        return np.asarray(self.inner.to_host(x))

    # ------------------------------------------------------------- expand
    def expand(self, csr, rows_local, max_out=None):
        """PR-3 expand: jit'd padded block on device, flattened on host."""
        rows_local = np.asarray(rows_local, dtype=np.int64)
        R = rows_local.shape[0]
        deg = csr.indptr[rows_local + 1] - csr.indptr[rows_local]
        total = int(deg.sum())
        if max_out is not None and total > max_out:
            raise RuntimeError(f"intermediate blow-up: expansion would "
                               f"produce {total} rows > cap {max_out}")
        if total == 0:
            z = np.zeros(0, dtype=np.int64)
            return z, z, z
        parts = []
        for s in range(0, R, _jb._SLAB_ROWS):
            e = min(s + _jb._SLAB_ROWS, R)
            self._expand_chunk(csr, rows_local[s:e], deg[s:e], s, parts)
        ridx = np.concatenate([p[0] for p in parts])
        nbr = np.concatenate([p[1] for p in parts])
        fpos = np.concatenate([p[2] for p in parts])
        epos = csr.pos[fpos] if csr.pos is not None else fpos
        return ridx, nbr, epos

    def _expand_chunk(self, csr, rows_local, deg, base, parts):
        """Halve the chunk while the padded [rows, d_max] block would bust
        the element budget (verbatim PR-3 degree-skew isolation)."""
        if int(deg.sum()) == 0:
            return
        d_hi = int(deg.max())
        R = rows_local.shape[0]
        if R > 1 and (_pow2(R, _jb._MIN_BLOCK_ROWS) * _pow2(d_hi)
                      > _jb._EXPAND_ELEMS):
            h = R // 2
            self._expand_chunk(csr, rows_local[:h], deg[:h], base, parts)
            self._expand_chunk(csr, rows_local[h:], deg[h:], base + h, parts)
            return
        ridx, nbr, fpos = self._expand_slab(csr, rows_local, d_hi)
        parts.append((ridx + base, nbr, fpos))

    def _expand_slab(self, csr, rows_local, d_hi):
        indptr_d, indices_d, _pos = self.inner._csr_dev(csr)
        d_max = _pow2(d_hi)
        rp = _pow2(rows_local.shape[0], _jb._MIN_BLOCK_ROWS)
        rows_p = self._pad_rows(rows_local, rp, 0).astype(np.int32)
        nbr, valid, flat = self.inner._jaxops.expand_padded(
            indptr_d, indices_d, self._up(rows_p), d_max)
        # PR-3 compaction: download the PADDED blocks, flatten on host
        R = rows_local.shape[0]
        valid = self._down(valid)[:R]
        ridx, _slot = np.nonzero(valid)
        nbr_flat = self._down(nbr)[:R][valid].astype(np.int64)
        fpos = self._down(flat)[:R][valid].astype(np.int64)
        return ridx.astype(np.int64), nbr_flat, fpos

    # ---------------------------------------------------------- intersect
    def intersect(self, csr, rows_local, targets):
        rows_local = np.asarray(rows_local, dtype=np.int64)
        targets = np.asarray(targets, dtype=np.int64)
        R = rows_local.shape[0]
        found = np.zeros(R, dtype=bool)
        fpos = np.zeros(R, dtype=np.int64)
        if R == 0:
            return found, fpos
        deg = csr.indptr[rows_local + 1] - csr.indptr[rows_local]
        for s in range(0, R, _jb._SLAB_ROWS):
            e = min(s + _jb._SLAB_ROWS, R)
            d_hi = int(deg[s:e].max())
            if d_hi == 0:
                continue
            if d_hi <= _jb.MAX_ELL_DEGREE:
                f, p = self._intersect_ell(csr, rows_local[s:e],
                                           targets[s:e], d_hi)
            else:
                f, p = self._intersect_bsearch(csr, rows_local[s:e],
                                               targets[s:e])
            found[s:e] = f
            fpos[s:e] = p
        epos = np.zeros(R, dtype=np.int64)
        if found.any():
            hp = fpos[found]
            epos[found] = csr.pos[hp] if csr.pos is not None else hp
        return found, epos

    def _intersect_ell(self, csr, rows_local, targets, d_hi):
        from repro.kernels.wcoj_intersect.ops import gather_rows
        indptr_d, indices_d, _pos = self.inner._csr_dev(csr)
        d_max = _pow2(d_hi)
        R = rows_local.shape[0]
        rp = _pow2(R, _jb._MIN_BLOCK_ROWS)
        block_rows = max(_jb._MIN_BLOCK_ROWS,
                         min(rp, _jb._pow2_floor(_jb._TILE_ELEMS // d_max)))
        rows_p = self._pad_rows(rows_local, rp, 0).astype(np.int32)
        tgt_p = self._pad_rows(targets, rp, -2).astype(np.int32)
        adj = gather_rows(indices_d, indptr_d, self._up(rows_p), d_max)
        found_d, pos_d = self.inner._wcoj(adj, self._up(tgt_p),
                                          block_rows=block_rows,
                                          interpret=self.inner._interpret)
        found = self._down(found_d)[:R].astype(bool)
        pos_in_row = self._down(pos_d)[:R].astype(np.int64)
        return found, csr.indptr[rows_local] + pos_in_row

    def _intersect_bsearch(self, csr, rows_local, targets):
        indptr_d, indices_d, _pos = self.inner._csr_dev(csr)
        R = rows_local.shape[0]
        rp = _pow2(R, _jb._MIN_BLOCK_ROWS)
        lo = self._pad_rows(csr.indptr[rows_local], rp, 0).astype(np.int32)
        hi = self._pad_rows(csr.indptr[rows_local + 1], rp,
                            0).astype(np.int32)
        tgt = self._pad_rows(targets, rp, -2).astype(np.int32)
        found_d, pos_d = self.inner._jaxops.bounded_binary_search(
            indices_d, self._up(lo), self._up(hi), self._up(tgt))
        found = self._down(found_d)[:R].astype(bool)
        return found, self._down(pos_d)[:R].astype(np.int64)
