"""Vertex-cut CSR partitioning for the sharded backend (DESIGN.md §10).

A ``CSR`` keys rows by the *local* id of one vertex type; the sharded
backend splits that row space into ``n_shards`` contiguous ranges — shard
``s`` owns local rows ``[s*rows_per_shard, (s+1)*rows_per_shard)`` — and
each shard carries the sub-CSR of exactly its rows.  Because a triple's two
directions are keyed by different endpoints (OUT by source, IN by
destination), partitioning both directions this way is a *vertex cut*: a
vertex's out-edges live on the shard that owns it as a source while its
in-edges live wherever their destinations land, and an expansion must
route each frontier vertex to its owning shard before any adjacency is
readable.

The partition is host-side numpy and shape-stacked for ``shard_map``:
every per-shard array is padded to one common capacity so the blocks stack
into ``[n_shards, ...]`` device arrays sharded over the mesh's data axis.
Padding is inert by construction — padded indptr rows repeat the last real
offset (degree 0) and padded ``indices``/``pos`` slots are never addressed
because no real row's range reaches them.

``owner_of`` is the single source of truth for the ownership function; the
device kernels in ``sharded_backend`` recompute it with the same integer
arithmetic (``local_row // rows_per_shard``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pow2(n: int, floor: int = 1) -> int:
    p = floor
    while p < n:
        p <<= 1
    return p


@dataclasses.dataclass(frozen=True)
class CsrShards:
    """One CSR partitioned into row-range shards, stacked for a device mesh.

    ``indptr[s]`` is shard ``s``'s *local* indptr (``indptr[s][0] == 0``);
    ``edge_base[s]`` is the global flat position of the shard's first edge,
    so a local flat offset maps back to the CSR's global edge position as
    ``edge_base[s] + local_offset`` — the OUT direction's edge identity.
    For the IN direction the global ``pos`` mapping is partitioned
    alongside ``indices`` (``pos[s][local_offset]`` is already the global
    OUT-order position)."""
    n_shards: int
    n_rows: int                    # keyed rows of the original CSR
    rows_per_shard: int            # contiguous row-range size per shard
    indptr: np.ndarray             # int32[n_shards, rows_per_shard + 1]
    indices: np.ndarray            # int32[n_shards, nnz_cap]
    pos: np.ndarray | None         # int32[n_shards, nnz_cap] | None
    edge_base: np.ndarray          # int32[n_shards] global base edge position

    def owner_of(self, local_rows: np.ndarray) -> np.ndarray:
        """Owning shard per local row id — the ownership function the
        device kernels mirror."""
        return np.minimum(np.asarray(local_rows) // self.rows_per_shard,
                          self.n_shards - 1)


def partition_csr(csr, n_shards: int, min_nnz_cap: int = 8) -> CsrShards:
    """Range-partition ``csr``'s keyed rows into ``n_shards`` stacked
    sub-CSRs (see module docstring for the layout contract).

    The per-shard ``nnz`` capacity is the pow2 envelope of the fattest
    shard, so one partition's blocks always stack; empty shards (when
    ``n_rows < n_shards``) carry all-zero indptr rows and are inert.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    n_rows = indptr.shape[0] - 1
    rps = max(1, _ceil_div(n_rows, n_shards))
    shard_nnz = []
    for s in range(n_shards):
        lo = min(s * rps, n_rows)
        hi = min(lo + rps, n_rows)
        shard_nnz.append(int(indptr[hi] - indptr[lo]))
    nnz_cap = _pow2(max(shard_nnz), min_nnz_cap)

    ip = np.zeros((n_shards, rps + 1), dtype=np.int32)
    ix = np.zeros((n_shards, nnz_cap), dtype=np.int32)
    ps = (np.zeros((n_shards, nnz_cap), dtype=np.int32)
          if csr.pos is not None else None)
    base = np.zeros(n_shards, dtype=np.int32)
    for s in range(n_shards):
        lo = min(s * rps, n_rows)
        hi = min(lo + rps, n_rows)
        local = (indptr[lo:hi + 1] - indptr[lo]).astype(np.int32)
        ip[s, :hi - lo + 1] = local
        # padded rows (hi-lo < rps) repeat the last offset: degree 0
        ip[s, hi - lo + 1:] = local[-1] if local.size else 0
        e0, e1 = int(indptr[lo]), int(indptr[hi])
        ix[s, :e1 - e0] = csr.indices[e0:e1]
        if ps is not None:
            ps[s, :e1 - e0] = csr.pos[e0:e1]
        base[s] = e0
    return CsrShards(n_shards=n_shards, n_rows=n_rows, rows_per_shard=rps,
                     indptr=ip, indices=ix, pos=ps, edge_base=base)


def reassemble_csr(shards: CsrShards) -> tuple[np.ndarray, np.ndarray,
                                               np.ndarray | None]:
    """Inverse of ``partition_csr`` (tests): rebuild the flat
    ``(indptr, indices, pos)`` from the stacked shards."""
    n = shards.n_rows
    rps = shards.rows_per_shard
    indptr = [0]
    indices, pos = [], []
    for s in range(shards.n_shards):
        lo = min(s * rps, n)
        hi = min(lo + rps, n)
        local = shards.indptr[s]
        for r in range(hi - lo):
            indptr.append(indptr[-1] + int(local[r + 1] - local[r]))
        e1 = int(local[hi - lo]) if hi > lo else 0
        indices.append(shards.indices[s, :e1])
        if shards.pos is not None:
            pos.append(shards.pos[s, :e1])
    return (np.asarray(indptr, dtype=np.int64),
            np.concatenate(indices) if indices else np.zeros(0, np.int64),
            (np.concatenate(pos) if pos else np.zeros(0, np.int64))
            if shards.pos is not None else None)
