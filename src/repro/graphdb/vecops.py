"""Vectorized engine primitives (numpy host path).

These are the TPU-shaped bulk operators of the binding-table engine: every one
is a flat gather / segmented reduction / sorted search over dense arrays — the
same dataflow the Pallas kernels implement for TPU (`kernels/wcoj_intersect`,
`kernels/segment_matmul`). `repro.graphdb.jaxops` holds jit'd jnp mirrors used
for parity testing and as the on-device path.
"""
from __future__ import annotations

import numpy as np


def expand_csr(indptr: np.ndarray, indices: np.ndarray,
               rows_local: np.ndarray,
               pos: np.ndarray | None = None,
               max_out: int | None = None):
    """Expand each row's vertex (local id into this CSR) to all neighbors.

    Returns (row_index, neighbor_global_id, edge_pos): ``row_index[i]`` is the
    originating binding-table row of output i. ``max_out`` is a *predictive*
    blow-up guard: the count is known from degrees before any gather runs.
    """
    start = indptr[rows_local]
    cnt = indptr[rows_local + 1] - start
    total = int(cnt.sum())
    if max_out is not None and total > max_out:
        raise RuntimeError(f"intermediate blow-up: expansion would produce "
                           f"{total} rows > cap {max_out}")
    row_idx = np.repeat(np.arange(rows_local.shape[0], dtype=np.int64), cnt)
    # flat positions: start[row] + intra-row offset
    offs = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(cnt) - cnt, cnt)
    flat = np.repeat(start, cnt) + offs
    nbr = indices[flat]
    epos = pos[flat] if pos is not None else flat
    return row_idx, nbr, epos


def bounded_binary_search(indices: np.ndarray, lo: np.ndarray,
                          hi: np.ndarray, targets: np.ndarray):
    """For each i, find ``targets[i]`` within sorted ``indices[lo[i]:hi[i]]``.

    Returns (found: bool[n], pos: int64[n]) — pos is the flat index into
    ``indices`` where the target sits (undefined when not found). This is the
    membership probe of the worst-case-optimal intersection step; the Pallas
    `wcoj_intersect` kernel is its TPU twin.
    """
    lo = lo.astype(np.int64).copy()
    hi = hi.astype(np.int64).copy()
    hi_orig = hi.copy()
    # classic vectorized binary search on per-row bounds
    while True:
        active = lo < hi
        if not active.any():
            break
        mid = (lo + hi) // 2
        v = np.where(active, indices[np.minimum(mid, indices.shape[0] - 1)], 0)
        go_right = active & (v < targets)
        lo = np.where(go_right, mid + 1, lo)
        hi = np.where(active & ~go_right, mid, hi)
    pos = lo
    # a hit must land strictly inside the row's own [lo, hi_orig) range —
    # pos == hi_orig means "not present" (indices[pos] is the next row!)
    in_range = pos < np.minimum(hi_orig, indices.shape[0])
    found = np.zeros(targets.shape, dtype=bool)
    idx = pos[in_range]
    found[in_range] = indices[idx] == targets[in_range]
    return found, pos


def equi_join(lkeys: np.ndarray, rkeys: np.ndarray,
              max_out: int | None = None):
    """All-pairs equi join of two key columns (int64).

    Returns (lidx, ridx): row index pairs with ``lkeys[lidx] == rkeys[ridx]``.
    Sort-merge: O((L+R) log) with fully vectorized pair expansion.
    """
    lorder = np.argsort(lkeys, kind="stable")
    rorder = np.argsort(rkeys, kind="stable")
    ls, rs = lkeys[lorder], rkeys[rorder]
    # for each left row, the matching right range
    lo = np.searchsorted(rs, ls, side="left")
    hi = np.searchsorted(rs, ls, side="right")
    cnt = hi - lo
    total = int(cnt.sum())
    if max_out is not None and total > max_out:
        raise RuntimeError(f"intermediate blow-up: join would produce "
                           f"{total} rows > cap {max_out}")
    if total == 0:
        return (np.zeros(0, dtype=np.int64),) * 2
    lrep = np.repeat(np.arange(ls.shape[0], dtype=np.int64), cnt)
    offs = np.arange(total, dtype=np.int64) - np.repeat(np.cumsum(cnt) - cnt, cnt)
    rpos = np.repeat(lo, cnt) + offs
    return lorder[lrep], rorder[rpos]


def combine_keys(cols: list[np.ndarray]) -> np.ndarray:
    """Pack multiple int64 key columns into one comparable int64 key.
    Uses factorization so values never overflow."""
    if len(cols) == 1:
        return cols[0]
    key = None
    for c in cols:
        _, inv = np.unique(c, return_inverse=True)
        card = int(inv.max()) + 1 if inv.size else 1
        key = inv if key is None else key * card + inv
    return key


def group_reduce(keys: np.ndarray, values: dict[str, tuple[str, np.ndarray]]):
    """Group by packed keys. values: name -> (fn, column). Returns
    (unique_key_first_row_index, {name: aggregated}) where the first element
    indexes a representative row per group (for key column extraction)."""
    uniq, first, inv = np.unique(keys, return_index=True, return_inverse=True)
    n = uniq.shape[0]
    out = {}
    for name, (fn, col) in values.items():
        if fn == "COUNT":
            out[name] = np.bincount(inv, minlength=n).astype(np.int64)
        elif fn == "SUM":
            out[name] = np.bincount(inv, weights=col, minlength=n).astype(np.int64)
        elif fn == "AVG":
            s = np.bincount(inv, weights=col, minlength=n)
            c = np.bincount(inv, minlength=n)
            out[name] = s / np.maximum(c, 1)
        elif fn == "MIN":
            acc = np.full(n, np.iinfo(np.int64).max, dtype=np.int64)
            np.minimum.at(acc, inv, col)
            out[name] = acc
        elif fn == "MAX":
            acc = np.full(n, np.iinfo(np.int64).min, dtype=np.int64)
            np.maximum.at(acc, inv, col)
            out[name] = acc
        else:
            raise ValueError(f"unknown aggregate {fn}")
    return first, out
