"""Deterministic synthetic LDBC-SNB-like data generator.

The paper evaluates on LDBC SNB graphs G30..G1000 (Table 1). The real
generator is out of scope here; this module produces graphs with the same
*schema*, power-law degree structure and correlated attributes, parameterized
by a scale factor, so every query in the paper's Appendix A runs and the
optimizer faces realistic skew. Deterministic per (sf, seed).
"""
from __future__ import annotations

import numpy as np

from repro.core.schema import EdgeTriple, GraphSchema, ldbc_schema, motivating_schema
from repro.graphdb.storage import GraphStore, build_store, encode_strings

_COUNTRY_NAMES = ["China", "India", "Germany", "France", "Brazil", "Japan",
                  "Mexico", "Egypt", "Spain", "Italy", "Kenya", "Peru"]
_TAG_NAMES = [f"tag_{i}" for i in range(200)]
_FIRST_NAMES = ["Jan", "Yang", "Maria", "Ahmed", "Li", "Anna", "Jose", "Ken"]


def _zipf_targets(rng: np.random.Generator, n_edges: int, n_targets: int,
                  a: float = 1.3) -> np.ndarray:
    """Skewed target sampling (power-law in-degree)."""
    if n_targets <= 0:
        return np.zeros(0, dtype=np.int64)
    ranks = rng.zipf(a, size=n_edges).astype(np.int64)
    return (ranks - 1) % n_targets


def _uniform(rng, n_edges, n) -> np.ndarray:
    return rng.integers(0, max(n, 1), size=n_edges, dtype=np.int64)


def generate_ldbc(sf: float = 1.0, seed: int = 7) -> GraphStore:
    """Scale factor 1.0 ~= 20k vertices / 140k edges; scales linearly."""
    rng = np.random.default_rng(seed)
    sch = ldbc_schema()
    n = {
        "PERSON": int(1800 * sf),
        "POST": int(5200 * sf),
        "COMMENT": int(8600 * sf),
        "FORUM": int(900 * sf),
        "TAG": 200,
        "TAGCLASS": 20,
        "CITY": 60,
        "COUNTRY": 12,
        "ORGANISATION": int(200 * max(sf, 0.25)),
    }
    E = EdgeTriple
    deg = {  # avg out-degree per triple (LDBC-ish ratios)
        E("PERSON", "KNOWS", "PERSON"): 18,
        E("PERSON", "LIKES", "POST"): 12,
        E("PERSON", "LIKES", "COMMENT"): 9,
        E("PERSON", "HASINTEREST", "TAG"): 5,
        E("PERSON", "ISLOCATEDIN", "CITY"): 1,
        E("PERSON", "WORKAT", "ORGANISATION"): 1,
        E("POST", "HASCREATOR", "PERSON"): 1,
        E("COMMENT", "HASCREATOR", "PERSON"): 1,
        E("COMMENT", "REPLYOF", "POST"): 1,
        E("COMMENT", "REPLYOF", "COMMENT"): 1,
        E("POST", "HASTAG", "TAG"): 2,
        E("COMMENT", "HASTAG", "TAG"): 1,
        E("FORUM", "CONTAINEROF", "POST"): 6,
        E("FORUM", "HASMEMBER", "PERSON"): 30,
        E("FORUM", "HASMODERATOR", "PERSON"): 1,
        E("FORUM", "HASTAG", "TAG"): 2,
        E("TAG", "HASTYPE", "TAGCLASS"): 1,
        E("CITY", "ISPARTOF", "COUNTRY"): 1,
        E("ORGANISATION", "ISLOCATEDIN", "COUNTRY"): 1,
    }
    edges: dict[EdgeTriple, tuple[np.ndarray, np.ndarray]] = {}
    for t, d in deg.items():
        ns, nd = n[t.src], n[t.dst]
        if d == 1:
            src = np.arange(ns, dtype=np.int64)
            if t.label in ("ISPARTOF", "HASTYPE", "ISLOCATEDIN"):
                dst = _uniform(rng, ns, nd)
            else:
                dst = _zipf_targets(rng, ns, nd)
        else:
            m = ns * d
            src = rng.integers(0, ns, size=m, dtype=np.int64)
            dst = _zipf_targets(rng, m, nd)
        if t.src == t.dst:
            keep = src != dst
            src, dst = src[keep], dst[keep]
        edges[t] = (src, dst)

    vocab: dict[str, dict[str, int]] = {"name": {}, "firstName": {}}
    dates = lambda k: rng.integers(1_262_304_000, 1_356_998_400, size=k)
    v_props = {
        "PERSON": {
            "id": np.arange(n["PERSON"], dtype=np.int64),
            "firstName": encode_strings(
                [_FIRST_NAMES[i % len(_FIRST_NAMES)]
                 for i in rng.integers(0, len(_FIRST_NAMES), n["PERSON"])],
                vocab["firstName"]),
            "creationDate": dates(n["PERSON"]),
        },
        "POST": {
            "id": np.arange(n["POST"], dtype=np.int64),
            "length": rng.integers(0, 256, size=n["POST"]).astype(np.int64),
            "creationDate": dates(n["POST"]),
        },
        "COMMENT": {
            "id": np.arange(n["COMMENT"], dtype=np.int64),
            "length": rng.integers(0, 256, size=n["COMMENT"]).astype(np.int64),
            "creationDate": dates(n["COMMENT"]),
        },
        "FORUM": {"id": np.arange(n["FORUM"], dtype=np.int64),
                  "creationDate": dates(n["FORUM"])},
        "TAG": {"id": np.arange(n["TAG"], dtype=np.int64),
                "name": encode_strings(_TAG_NAMES[:n["TAG"]], vocab["name"])},
        "TAGCLASS": {"id": np.arange(n["TAGCLASS"], dtype=np.int64),
                     "name": encode_strings(
                         [f"class_{i}" for i in range(n["TAGCLASS"])],
                         vocab["name"])},
        "CITY": {"id": np.arange(n["CITY"], dtype=np.int64),
                 "name": encode_strings(
                     [f"city_{i}" for i in range(n["CITY"])], vocab["name"])},
        "COUNTRY": {"id": np.arange(n["COUNTRY"], dtype=np.int64),
                    "name": encode_strings(
                        _COUNTRY_NAMES[:n["COUNTRY"]], vocab["name"])},
        "ORGANISATION": {"id": np.arange(n["ORGANISATION"], dtype=np.int64),
                         "name": encode_strings(
                             [f"org_{i}" for i in range(n["ORGANISATION"])],
                             vocab["name"])},
    }
    e_props = {E("PERSON", "KNOWS", "PERSON"):
               {"creationDate": dates(len(edges[E("PERSON", "KNOWS", "PERSON")][0]))}}
    return build_store(sch, n, edges, v_props, e_props, vocab)


# --------------------------------------------------------------------------
# Streamed generation (sharded-backend scale sweeps)
# --------------------------------------------------------------------------

# fixed source-range unit of the streamed generator: every (triple, chunk)
# and (vertex type, chunk) draws from its own SeedSequence-derived RNG, so
# the dataset is a pure function of (sf, seed) — independent of how many
# chunks a consumer materializes at once or which shard generates which
# range.  generate_ldbc consumes ONE sequential rng, which makes its output
# depend on generation order; the streamed layout trades stream identity
# (different data for the same seed) for order-free determinism.
_STREAM_CHUNK = 4096


def _stream_chunks(seed: int, tag: tuple, total: int, fn):
    """Concatenate ``fn(rng, lo, hi)`` over fixed ``_STREAM_CHUNK`` source
    ranges, each with an independent ``SeedSequence((seed, *tag, chunk))``
    RNG.  Peak working memory is one chunk's output."""
    parts = []
    key = [seed] + [hash(t) & 0x7FFFFFFF if isinstance(t, str) else t
                    for t in tag]
    for ci, lo in enumerate(range(0, max(total, 0), _STREAM_CHUNK)):
        hi = min(lo + _STREAM_CHUNK, total)
        rng = np.random.default_rng(np.random.SeedSequence(key + [ci]))
        parts.append(fn(rng, lo, hi))
    if not parts:
        return np.zeros(0, dtype=np.int64)
    # 1-D chunks stack end-to-end; (k, m) chunks (e.g. src/dst pairs)
    # stack along their last axis
    return np.concatenate(parts, axis=parts[0].ndim - 1)


def generate_ldbc_streamed(sf: float = 1.0, seed: int = 7) -> GraphStore:
    """``generate_ldbc``'s schema and skew, generated streamed: edges and
    properties materialize in fixed per-source-range chunks with
    independent seeded RNGs (see ``_STREAM_CHUNK``), so scale factors
    beyond a single generation buffer stream through bounded memory and
    any shard can regenerate exactly its own ranges.  Deterministic per
    ``(sf, seed)``; **not** stream-identical to ``generate_ldbc``."""
    sch = ldbc_schema()
    n = {
        "PERSON": int(1800 * sf),
        "POST": int(5200 * sf),
        "COMMENT": int(8600 * sf),
        "FORUM": int(900 * sf),
        "TAG": 200,
        "TAGCLASS": 20,
        "CITY": 60,
        "COUNTRY": 12,
        "ORGANISATION": int(200 * max(sf, 0.25)),
    }
    E = EdgeTriple
    deg = {
        E("PERSON", "KNOWS", "PERSON"): 18,
        E("PERSON", "LIKES", "POST"): 12,
        E("PERSON", "LIKES", "COMMENT"): 9,
        E("PERSON", "HASINTEREST", "TAG"): 5,
        E("PERSON", "ISLOCATEDIN", "CITY"): 1,
        E("PERSON", "WORKAT", "ORGANISATION"): 1,
        E("POST", "HASCREATOR", "PERSON"): 1,
        E("COMMENT", "HASCREATOR", "PERSON"): 1,
        E("COMMENT", "REPLYOF", "POST"): 1,
        E("COMMENT", "REPLYOF", "COMMENT"): 1,
        E("POST", "HASTAG", "TAG"): 2,
        E("COMMENT", "HASTAG", "TAG"): 1,
        E("FORUM", "CONTAINEROF", "POST"): 6,
        E("FORUM", "HASMEMBER", "PERSON"): 30,
        E("FORUM", "HASMODERATOR", "PERSON"): 1,
        E("FORUM", "HASTAG", "TAG"): 2,
        E("TAG", "HASTYPE", "TAGCLASS"): 1,
        E("CITY", "ISPARTOF", "COUNTRY"): 1,
        E("ORGANISATION", "ISLOCATEDIN", "COUNTRY"): 1,
    }
    uniform_labels = ("ISPARTOF", "HASTYPE", "ISLOCATEDIN")
    edges: dict[EdgeTriple, tuple[np.ndarray, np.ndarray]] = {}
    for ti, (t, d) in enumerate(sorted(deg.items(),
                                       key=lambda kv: repr(kv[0]))):
        ns, nd = n[t.src], n[t.dst]
        if d == 1:
            src = np.arange(ns, dtype=np.int64)
            if t.label in uniform_labels:
                dst = _stream_chunks(seed, ("e", ti), ns,
                                     lambda r, lo, hi: _uniform(r, hi - lo,
                                                                nd))
            else:
                dst = _stream_chunks(seed, ("e", ti), ns,
                                     lambda r, lo, hi: _zipf_targets(
                                         r, hi - lo, nd))
        else:
            def mk(r, lo, hi, _d=d, _nd=nd):
                m = (hi - lo) * _d
                s = r.integers(lo, hi, size=m, dtype=np.int64)
                return np.stack([s, _zipf_targets(r, m, _nd)])
            both = _stream_chunks(seed, ("e", ti), ns, mk)
            if both.ndim == 1:                      # ns == 0: no chunks
                both = both.reshape(2, 0)
            src, dst = both[0], both[1]
        if t.src == t.dst:
            keep = src != dst
            src, dst = src[keep], dst[keep]
        edges[t] = (src, dst)

    vocab: dict[str, dict[str, int]] = {"name": {}, "firstName": {}}

    def dates(ty, k):
        return _stream_chunks(seed, ("d", ty), k,
                              lambda r, lo, hi: r.integers(
                                  1_262_304_000, 1_356_998_400,
                                  size=hi - lo))

    def first_names(k):
        idx = _stream_chunks(seed, ("fn",), k,
                             lambda r, lo, hi: r.integers(
                                 0, len(_FIRST_NAMES), hi - lo))
        return encode_strings([_FIRST_NAMES[i % len(_FIRST_NAMES)]
                               for i in idx], vocab["firstName"])

    def lengths(ty, k):
        return _stream_chunks(seed, ("len", ty), k,
                              lambda r, lo, hi: r.integers(
                                  0, 256, size=hi - lo).astype(np.int64))

    v_props = {
        "PERSON": {"id": np.arange(n["PERSON"], dtype=np.int64),
                   "firstName": first_names(n["PERSON"]),
                   "creationDate": dates("PERSON", n["PERSON"])},
        "POST": {"id": np.arange(n["POST"], dtype=np.int64),
                 "length": lengths("POST", n["POST"]),
                 "creationDate": dates("POST", n["POST"])},
        "COMMENT": {"id": np.arange(n["COMMENT"], dtype=np.int64),
                    "length": lengths("COMMENT", n["COMMENT"]),
                    "creationDate": dates("COMMENT", n["COMMENT"])},
        "FORUM": {"id": np.arange(n["FORUM"], dtype=np.int64),
                  "creationDate": dates("FORUM", n["FORUM"])},
        "TAG": {"id": np.arange(n["TAG"], dtype=np.int64),
                "name": encode_strings(_TAG_NAMES[:n["TAG"]], vocab["name"])},
        "TAGCLASS": {"id": np.arange(n["TAGCLASS"], dtype=np.int64),
                     "name": encode_strings(
                         [f"class_{i}" for i in range(n["TAGCLASS"])],
                         vocab["name"])},
        "CITY": {"id": np.arange(n["CITY"], dtype=np.int64),
                 "name": encode_strings(
                     [f"city_{i}" for i in range(n["CITY"])], vocab["name"])},
        "COUNTRY": {"id": np.arange(n["COUNTRY"], dtype=np.int64),
                    "name": encode_strings(
                        _COUNTRY_NAMES[:n["COUNTRY"]], vocab["name"])},
        "ORGANISATION": {"id": np.arange(n["ORGANISATION"], dtype=np.int64),
                         "name": encode_strings(
                             [f"org_{i}" for i in range(n["ORGANISATION"])],
                             vocab["name"])},
    }
    knows = E("PERSON", "KNOWS", "PERSON")
    e_props = {knows: {"creationDate": dates("E_KNOWS",
                                             len(edges[knows][0]))}}
    return build_store(sch, n, edges, v_props, e_props, vocab)


def generate_motivating(n_person=300, n_product=120, n_place=30,
                        seed: int = 3) -> GraphStore:
    """Small Fig.1 graph for unit tests and the quickstart example."""
    rng = np.random.default_rng(seed)
    sch = motivating_schema()
    E = EdgeTriple
    n = {"PERSON": n_person, "PRODUCT": n_product, "PLACE": n_place}
    mk = lambda ns, nd, d: (rng.integers(0, ns, ns * d),
                            _zipf_targets(rng, ns * d, nd))
    edges = {
        E("PERSON", "KNOWS", "PERSON"): mk(n_person, n_person, 6),
        E("PERSON", "PURCHASES", "PRODUCT"): mk(n_person, n_product, 4),
        E("PERSON", "LOCATEDIN", "PLACE"): (np.arange(n_person),
                                            _uniform(rng, n_person, n_place)),
        E("PRODUCT", "PRODUCEDIN", "PLACE"): (np.arange(n_product),
                                              _uniform(rng, n_product, n_place)),
    }
    s, d = edges[E("PERSON", "KNOWS", "PERSON")]
    keep = s != d
    edges[E("PERSON", "KNOWS", "PERSON")] = (s[keep], d[keep])
    vocab = {"name": {}}
    v_props = {
        "PERSON": {"id": np.arange(n_person, dtype=np.int64),
                   "name": encode_strings([f"p{i}" for i in range(n_person)],
                                          vocab["name"])},
        "PRODUCT": {"id": np.arange(n_product, dtype=np.int64),
                    "name": encode_strings([f"prod{i}" for i in range(n_product)],
                                           vocab["name"])},
        "PLACE": {"id": np.arange(n_place, dtype=np.int64),
                  "name": encode_strings(
                      (_COUNTRY_NAMES * ((n_place // len(_COUNTRY_NAMES)) + 1)
                       )[:n_place], vocab["name"])},
    }
    return build_store(sch, n, edges, v_props, None, vocab)
