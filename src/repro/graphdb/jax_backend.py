"""JAX backend — jit'd padded-block execution of the pattern primitives.

Registers the ``"jax"`` PhysicalSpec. Shapes must be static under jit, so the
primitives run on padded row blocks with validity masks (the same contract the
Pallas kernels use); this module hides that layout behind the ``OperatorSet``
interface — callers see flat int64 numpy arrays exactly like the numpy
backend, row-for-row in the same order.

- ``expand``    -> ``jaxops.expand_padded``: [R, D_max] neighbor block +
  validity mask, flattened on the host.
- ``intersect`` -> the ``wcoj_intersect`` Pallas kernel (vectorized
  compare-scan over a padded-ELL adjacency tile; interpret mode on CPU,
  compiled on TPU) for row degrees up to ``MAX_ELL_DEGREE``; beyond that the
  jit'd ``jaxops.bounded_binary_search`` probes the CSR directly, matching
  the kernel's documented degree envelope.

Row counts and block widths are rounded up to powers of two so the number of
distinct jit/Pallas compilations stays logarithmic in table size. The
relational tail (join/group) stays on the host numpy path — it is
bandwidth-bound gather/sort work that the paper leaves to the wrapped system.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.physical import (ChainStep, ExpandChainNode, ExpandNode,
                                 JoinNode, PlanNode)
from repro.core.physical_spec import CostParams, PhysicalSpec, register_spec
from repro.graphdb.numpy_backend import NumpyOperators

# degree ceiling for the padded-ELL kernel layout (DESIGN.md §3: the VPU
# compare-scan beats log-step gathers only while a row block fits in VMEM)
MAX_ELL_DEGREE = 1024
_MIN_BLOCK_ROWS = 8
# rows per device slab: padded blocks are [slab, D_max]; slabbing bounds the
# padded footprint and lets D_max adapt to each slab's real degree skew
_SLAB_ROWS = 1 << 15
# padded-block element budget per Pallas input tile (~2 MB of int32)
_TILE_ELEMS = 1 << 19
# element budget for one [rows, D_max] expand block (~128 MB of int32);
# slabs exceeding it split recursively so a lone hub vertex cannot force a
# rows x hub-degree allocation
_EXPAND_ELEMS = 1 << 25


def _pow2(n: int, floor: int = 1) -> int:
    return max(floor, 1 << max(int(n) - 1, 0).bit_length())


def _pow2_floor(n: int) -> int:
    return 1 << (max(int(n), 1).bit_length() - 1)


class JaxOperators(NumpyOperators):
    """Overrides the two pattern-matching hot loops with device primitives;
    scan/join/group stay on the inherited host path."""

    name = "jax"

    def __init__(self, store):
        super().__init__(store)
        import jax  # deferred so the registry import stays light
        import jax.numpy as jnp
        from repro.graphdb import jaxops
        from repro.kernels.wcoj_intersect.ops import wcoj_intersect
        self._jnp = jnp
        self._jaxops = jaxops
        self._wcoj = wcoj_intersect
        self._interpret = jax.default_backend() != "tpu"
        if max(store.n_vertices, store.n_edges) >= np.iinfo(np.int32).max:
            raise ValueError(
                "jax backend stages vertex ids and CSR offsets through "
                f"int32; store has {store.n_vertices} vertices / "
                f"{store.n_edges} edges")
        self._dev = {}   # id(csr) -> (indptr_dev, indices_dev_i32)

    def _csr_dev(self, csr):
        key = id(csr)
        ent = self._dev.get(key)
        if ent is None:
            ent = (self._jnp.asarray(csr.indptr.astype(np.int32)),
                   self._jnp.asarray(csr.indices.astype(np.int32)))
            self._dev[key] = ent
        return ent

    @staticmethod
    def _pad_rows(a: np.ndarray, n: int, fill) -> np.ndarray:
        out = np.full(n, fill, dtype=a.dtype)
        out[:a.shape[0]] = a
        return out

    # ------------------------------------------------------------- expand
    def expand(self, csr, rows_local, max_out=None):
        rows_local = np.asarray(rows_local, dtype=np.int64)
        R = rows_local.shape[0]
        deg = csr.indptr[rows_local + 1] - csr.indptr[rows_local]
        total = int(deg.sum())
        if max_out is not None and total > max_out:
            raise RuntimeError(f"intermediate blow-up: expansion would "
                               f"produce {total} rows > cap {max_out}")
        if total == 0:
            z = np.zeros(0, dtype=np.int64)
            return z, z, z
        parts = []
        for s in range(0, R, _SLAB_ROWS):
            e = min(s + _SLAB_ROWS, R)
            self._expand_chunk(csr, rows_local[s:e], deg[s:e], s, parts)
        ridx = np.concatenate([p[0] for p in parts])
        nbr = np.concatenate([p[1] for p in parts])
        fpos = np.concatenate([p[2] for p in parts])
        epos = csr.pos[fpos] if csr.pos is not None else fpos
        return ridx, nbr, epos

    def _expand_chunk(self, csr, rows_local, deg, base, parts):
        """Expand one row chunk, halving it while the padded [rows, d_max]
        block would bust the element budget (degree skew isolates hub rows
        into small sub-chunks instead of widening the whole slab)."""
        if int(deg.sum()) == 0:
            return
        d_hi = int(deg.max())
        R = rows_local.shape[0]
        if R > 1 and _pow2(R, _MIN_BLOCK_ROWS) * _pow2(d_hi) > _EXPAND_ELEMS:
            h = R // 2
            self._expand_chunk(csr, rows_local[:h], deg[:h], base, parts)
            self._expand_chunk(csr, rows_local[h:], deg[h:], base + h, parts)
            return
        ridx, nbr, fpos = self._expand_slab(csr, rows_local, d_hi)
        parts.append((ridx + base, nbr, fpos))

    def _expand_slab(self, csr, rows_local, d_hi):
        R = rows_local.shape[0]
        indptr_d, indices_d = self._csr_dev(csr)
        d_max = _pow2(d_hi)
        rp = _pow2(R, _MIN_BLOCK_ROWS)
        rows_p = self._pad_rows(rows_local, rp, 0).astype(np.int32)
        nbr, valid, flat = self._jaxops.expand_padded(
            indptr_d, indices_d, self._jnp.asarray(rows_p), d_max)
        # padded-block -> flat binding-table rows (drop pad rows + pad slots)
        valid = np.asarray(valid)[:R]
        ridx, _slot = np.nonzero(valid)
        nbr_flat = np.asarray(nbr)[:R][valid].astype(np.int64)
        fpos = np.asarray(flat)[:R][valid].astype(np.int64)
        return ridx.astype(np.int64), nbr_flat, fpos

    # ---------------------------------------------------------- intersect
    def intersect(self, csr, rows_local, targets):
        rows_local = np.asarray(rows_local, dtype=np.int64)
        targets = np.asarray(targets, dtype=np.int64)
        R = rows_local.shape[0]
        found = np.zeros(R, dtype=bool)
        fpos = np.zeros(R, dtype=np.int64)
        if R == 0:
            return found, fpos
        deg = csr.indptr[rows_local + 1] - csr.indptr[rows_local]
        for s in range(0, R, _SLAB_ROWS):
            e = min(s + _SLAB_ROWS, R)
            d_hi = int(deg[s:e].max())
            if d_hi == 0:
                continue
            if d_hi <= MAX_ELL_DEGREE:
                f, p = self._intersect_ell(csr, rows_local[s:e],
                                           targets[s:e], d_hi)
            else:
                f, p = self._intersect_bsearch(csr, rows_local[s:e],
                                               targets[s:e])
            found[s:e] = f
            fpos[s:e] = p
        epos = np.zeros(R, dtype=np.int64)
        if found.any():
            hp = fpos[found]
            epos[found] = csr.pos[hp] if csr.pos is not None else hp
        return found, epos

    def _intersect_ell(self, csr, rows_local, targets, d_hi):
        """Pallas kernel path: gather padded-ELL rows, compare-scan probe."""
        from repro.kernels.wcoj_intersect.ops import gather_rows
        jnp = self._jnp
        indptr_d, indices_d = self._csr_dev(csr)
        d_max = _pow2(d_hi)
        R = rows_local.shape[0]
        rp = _pow2(R, _MIN_BLOCK_ROWS)
        # tile rows so one [block_rows, d_max] ELL block stays ~VMEM-sized
        # (and interpret mode on CPU runs few, fat grid steps)
        block_rows = max(_MIN_BLOCK_ROWS,
                         min(rp, _pow2_floor(_TILE_ELEMS // d_max)))
        rows_p = self._pad_rows(rows_local, rp, 0).astype(np.int32)
        # pad targets with -2: never matches a real id (>=0) or ELL pad (-1)
        tgt_p = self._pad_rows(targets, rp, -2).astype(np.int32)
        adj = gather_rows(indices_d, indptr_d, jnp.asarray(rows_p), d_max)
        found_d, pos_d = self._wcoj(adj, jnp.asarray(tgt_p),
                                    block_rows=block_rows,
                                    interpret=self._interpret)
        found = np.asarray(found_d)[:R].astype(bool)
        pos_in_row = np.asarray(pos_d)[:R].astype(np.int64)
        return found, csr.indptr[rows_local] + pos_in_row

    def _intersect_bsearch(self, csr, rows_local, targets):
        """High-degree fallback: jit'd per-row bounded binary search."""
        jnp = self._jnp
        indptr_d, indices_d = self._csr_dev(csr)
        R = rows_local.shape[0]
        rp = _pow2(R, _MIN_BLOCK_ROWS)
        lo = self._pad_rows(csr.indptr[rows_local], rp, 0).astype(np.int32)
        hi = self._pad_rows(csr.indptr[rows_local + 1], rp, 0).astype(np.int32)
        tgt = self._pad_rows(targets, rp, -2).astype(np.int32)
        found_d, pos_d = self._jaxops.bounded_binary_search(
            jnp.asarray(indices_d), jnp.asarray(lo), jnp.asarray(hi),
            jnp.asarray(tgt))
        found = np.asarray(found_d)[:R].astype(bool)
        return found, np.asarray(pos_d)[:R].astype(np.int64)


def fuse_expand_chain(node: PlanNode, ctx) -> PlanNode:
    """Post-CBO physical rewrite (the ``PhysicalSpec.physical_rules`` hook):
    fuse runs of >= 2 consecutive single-edge expansions into one
    ``ExpandChainNode``.

    Motivation (ROADMAP follow-up): this backend round-trips the binding
    table host<->device per operator — every ``Expand`` gathers *all* bound
    columns of the table for each surviving row.  A fused chain expands a
    thin frontier (just the hop columns) hop-by-hop and gathers the full
    table once at the end, amortizing the transfers.  Only predicate-free
    hops fuse (a filter must run at its own hop to bound intermediates),
    and each hop's source alias must be bound by the chain itself (or be
    the first hop's source), so the thin frontier always carries it.
    Fusion is packaging, not planning: ``ExpandChainNode.unfused()``
    recovers the exact pre-fusion plan, and results are row-identical."""
    pattern = ctx.pattern()
    fused = False

    def rewrite(n: PlanNode) -> PlanNode:
        if isinstance(n, JoinNode):
            return dataclasses.replace(n, left=rewrite(n.left),
                                       right=rewrite(n.right))
        if not isinstance(n, ExpandNode):
            return n
        run = [n]                       # the maximal expand run, bottom-up
        cur = n.child
        while isinstance(cur, ExpandNode):
            run.append(cur)
            cur = cur.child
        run.reverse()                   # execution order
        out = rewrite(cur)
        pending: list[tuple[ExpandNode, str]] = []

        def flush():
            nonlocal out, fused
            if len(pending) >= 2:
                fused = True
                steps = [ChainStep(h.edges[0], frm, h.new_alias,
                                   h.est_frequency, h.est_cost)
                         for h, frm in pending]
                out = ExpandChainNode(out, steps,
                                      est_frequency=steps[-1].est_frequency,
                                      est_cost=steps[-1].est_cost)
            else:
                for h, frm in pending:
                    out = ExpandNode(out, h.new_alias, h.edges,
                                     est_frequency=h.est_frequency,
                                     est_cost=h.est_cost)
            pending.clear()

        for h in run:
            v = pattern.vertices[h.new_alias]
            fusable = (len(h.edges) == 1 and not v.predicates
                       and not h.edges[0].predicates)
            frm = h.edges[0].other(h.new_alias) if h.edges else None
            if fusable and pending:
                carried = {pending[0][1]} | {x.new_alias for x, _ in pending}
                if frm not in carried:
                    # source bound below the current run (e.g. by a join
                    # child): close this chain and anchor a new one here
                    flush()
            if fusable:
                pending.append((h, frm))
            else:
                flush()
                out = ExpandNode(out, h.new_alias, h.edges,
                                 est_frequency=h.est_frequency,
                                 est_cost=h.est_cost)
        flush()
        return out

    out = rewrite(node)
    # no run fused: hand back the input so PhysicalRulesPass (and its
    # trace) correctly records the plan as unchanged
    return out if fused else node


# Calibrated from BENCH_backends.json (sf=0.2 CPU/interpret timings) via
# benchmarks/calibrate_costs.py: expand-dominated chain probes run ~5.3x the
# numpy host path (dispatch + padded-block overhead), while cyclic queries
# whose plans close edges with WCOJ membership probes run ~34x — so the CBO
# should spend joins/expansions to avoid intersections on this backend.
# Scan and the (host-inherited) join stay at the numpy baseline. Re-derive
# after re-benchmarking (e.g. on real TPU, where these flip dramatically).
JAX_SPEC = register_spec(PhysicalSpec(
    name="jax",
    make_operators=JaxOperators,
    cost=CostParams(alpha_scan=1.0, alpha_expand=5.3,
                    alpha_intersect=34.0, alpha_join=1.0),
    description="jit'd padded-block primitives + wcoj_intersect Pallas "
                "kernel (interpret on CPU, compiled on TPU)",
    physical_rules=(fuse_expand_chain,),
))
