"""JAX backend — device-resident binding tables + jit'd padded-block kernels.

Registers the ``"jax"`` PhysicalSpec. OperatorSet v2 (DESIGN.md §7): every
operator takes and returns ``jax.Array`` columns, so the engine's binding
table stays on device across *all* plan steps — pattern loop and relational
tail alike — and crosses to the host exactly once, at result delivery
(``to_host``). ``transfer_stats`` records each host<->device data movement;
the residency tests assert zero ``d2h`` events outside the delivery phase.

- ``expand``    -> ``jaxops.expand_padded``: [R, D_max] neighbor block +
  validity mask, compacted to flat rows on device.
- ``intersect`` -> the ``wcoj_intersect`` Pallas kernel (vectorized
  compare-scan over a padded-ELL adjacency tile; interpret mode on CPU,
  compiled on TPU) for row degrees up to ``MAX_ELL_DEGREE``; beyond that the
  jit'd ``jaxops.bounded_binary_search`` probes the CSR directly.
- relational tail on device: ``join`` is a sort-merge join (stable argsort +
  searchsorted), ``group_reduce`` rides ``jax.ops.segment_*``, and
  ``combine_keys`` packs tuples into dense lexicographic ranks
  (``jaxops.lex_ranks``) — rank order matches the numpy backend's packed-key
  order, so group/join row order stays row-identical across backends.

Shapes must be static under jit.  The intersect path pads row blocks to
powers of two (compile count logarithmic in table size); the fused
expand/join/group/combine kernels jit on exact data-dependent shapes —
their cache grows with distinct intermediate sizes, which recurring
serving/benchmark shapes amortize (pow2 size-bucketing for these paths is
a ROADMAP follow-up). Vertex ids, CSR offsets and property columns
stage through int32 (guarded at construction); ``to_host`` widens back to
int64 and canonicalizes the missing-property sentinel.  Control-plane
scalar syncs (row counts, blow-up guards) are not data transfers and are
not recorded.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.physical import (ChainStep, ExpandChainNode, ExpandNode,
                                 JoinNode, PlanNode)
from repro.core.physical_spec import (CostParams, OperatorSet, PhysicalSpec,
                                      register_spec)

# degree ceiling for the padded-ELL kernel layout (DESIGN.md §3: the VPU
# compare-scan beats log-step gathers only while a row block fits in VMEM)
MAX_ELL_DEGREE = 1024
_MIN_BLOCK_ROWS = 8
# rows per device slab: padded blocks are [slab, D_max]; slabbing bounds the
# padded footprint and lets D_max adapt to each slab's real degree skew
_SLAB_ROWS = 1 << 15
# padded-block element budget per Pallas input tile (~2 MB of int32)
_TILE_ELEMS = 1 << 19
# element budget for one [rows, D_max] padded expand block.  The v2 expand
# is a flat repeat-based CSR gather (no padded block, footprint == exact
# output rows, capped by max_out), so this only governs the jit/TPU padded
# variant (``jaxops.expand_padded``)
_EXPAND_ELEMS = 1 << 25

_I32_MIN = np.iinfo(np.int32).min
_I32_MAX = np.iinfo(np.int32).max
_I64_MIN = np.iinfo(np.int64).min


def _pow2(n: int, floor: int = 1) -> int:
    return max(floor, 1 << max(int(n) - 1, 0).bit_length())


def _pow2_floor(n: int) -> int:
    return 1 << (max(int(n), 1).bit_length() - 1)


class JaxOperators(OperatorSet):
    """Device-resident operator set: columns are ``jax.Array`` int32."""

    name = "jax"

    def __init__(self, store):
        super().__init__(store)
        import jax  # deferred so the registry import stays light
        import jax.numpy as jnp
        from repro.graphdb import jaxops
        from repro.kernels.wcoj_intersect.ops import wcoj_intersect
        self._jax = jax
        self._jnp = jnp
        self._jaxops = jaxops
        self._wcoj = wcoj_intersect
        self._interpret = jax.default_backend() != "tpu"
        if max(store.n_vertices, store.n_edges) >= _I32_MAX:
            raise ValueError(
                "jax backend stages vertex ids and CSR offsets through "
                f"int32; store has {store.n_vertices} vertices / "
                f"{store.n_edges} edges")
        self._dev = {}    # id(csr) -> (indptr_dev, indices_dev, pos_dev|None)
        self._props = {}  # ("v"|"e", prop) -> device property column(s)

    # ------------------------------------------------------------ transfers
    def asarray(self, values):
        if isinstance(values, self._jax.Array):
            return values
        a = np.asarray(values)
        self.transfer_stats.record("h2d", a.size)
        return self._jnp.asarray(a)

    def _array_to_host(self, a) -> np.ndarray:
        if not isinstance(a, self._jax.Array):
            return np.asarray(a)
        self.transfer_stats.record("d2h", a.size)
        h = np.asarray(a)
        if h.dtype == np.int32:
            h64 = h.astype(np.int64)
            h64[h64 == _I32_MIN] = _I64_MIN   # missing-prop sentinel widens
            return h64
        if h.dtype == np.float32:
            return h.astype(np.float64)
        return h

    def _upload(self, a: np.ndarray):
        """Graph-structure/property upload (cached by callers): int32 on
        device, recorded as h2d."""
        if a.dtype.kind == "i" and a.size and (
                a.max() > _I32_MAX or a.min() < _I32_MIN):
            raise ValueError("column exceeds the jax backend's int32 "
                             "staging envelope")
        self.transfer_stats.record("h2d", a.size)
        return self._jnp.asarray(a.astype(np.int32)
                                 if a.dtype.kind == "i" else a)

    # ------------------------------------------------------ array primitives
    def take(self, a, idx):
        # jnp.take(mode="clip") skips the eager advanced-indexing rewrite
        # machinery (~0.5ms of host python per gather); engine indices are
        # in-range by construction
        return self._jnp.take(self._jnp.asarray(a), idx, axis=0, mode="clip")

    def mask(self, a, m):
        return self._jnp.asarray(a)[self._jnp.asarray(m)]

    def concat(self, parts: list):
        if not parts:
            return self._jnp.zeros(0, self._jnp.int32)
        if len(parts) == 1:
            return self._jnp.asarray(parts[0])
        return self._jnp.concatenate([self._jnp.asarray(p) for p in parts])

    def nonzero(self, m):
        # argsort-shaped flatnonzero: jnp.nonzero's eager path rides heavy
        # python machinery per call.  A stable sort puts True positions
        # first in original order; the count sync sizes the slice.
        jnp = self._jnp
        m = jnp.asarray(m)
        cnt = int(m.sum())                           # control-plane sync
        if cnt == 0:
            return jnp.zeros(0, jnp.int32)
        order = jnp.argsort(~m)                      # stable
        return order[:cnt].astype(jnp.int32)

    def full(self, n: int, value):
        return self._jnp.full(n, value)

    def arange(self, n: int):
        return self._jnp.arange(n, dtype=self._jnp.int32)

    def isin(self, a, values):
        vals = np.asarray(list(values), dtype=np.int64)
        # values outside the int32 envelope cannot match any staged column
        vals = vals[(vals <= _I32_MAX) & (vals > _I32_MIN)]
        return self._jnp.isin(self._jnp.asarray(a), self.asarray(vals))

    def searchsorted(self, sorted_arr, values, side: str = "left"):
        return self._jnp.searchsorted(self._jnp.asarray(sorted_arr),
                                      self._jnp.asarray(values), side=side)

    def lexsort(self, cols: list):
        return self._jnp.lexsort(tuple(self._jnp.asarray(c) for c in cols))

    def distinct_indices(self, key):
        jnp = self._jnp
        key = jnp.asarray(key)
        n = key.shape[0]
        if n == 0:
            return jnp.zeros(0, jnp.int32)
        order = jnp.argsort(key)                   # stable -> minimal index
        sk = self.take(key, order)
        flag = jnp.concatenate([jnp.ones(1, bool), sk[1:] != sk[:-1]])
        return jnp.sort(self.take(order, self.nonzero(flag)))

    # ------------------------------------------------------ property gathers
    def _vprop_dev(self, prop: str):
        """One device column per vertex property, indexed by *global* id
        (missing types filled with the int32 sentinel) — a property gather
        is then a single device take instead of a per-type where-loop."""
        ent = self._props.get(("v", prop))
        if ent is None:
            st = self.store
            # in-band missing sentinel, like the host path's INT64_MIN:
            # only a stored value of exactly INT32_MIN would collide
            col = np.full(st.n_vertices, _I32_MIN, dtype=np.int64)
            for t in st._sorted_types():
                tc = st.v_props.get(t, {}).get(prop)
                if tc is None or tc.shape[0] == 0:
                    continue
                off = st.v_offset[t]
                col[off:off + tc.shape[0]] = tc
            ent = self._props[("v", prop)] = self._upload(col)
        return ent

    def _eprop_dev(self, prop: str):
        """Per-triple edge-property columns concatenated on device, plus the
        per-triple base offsets: ``col[offset[tidx] + pos]``."""
        ent = self._props.get(("e", prop))
        if ent is None:
            st = self.store
            triples = sorted(st.out_csr, key=repr)
            offsets, parts, off = [], [], 0
            for t in triples:
                tc = st.e_props.get(t, {}).get(prop)
                n = st.out_csr[t].nnz
                offsets.append(off)
                part = np.full(n, _I32_MIN, dtype=np.int64)
                if tc is not None and tc.shape[0]:
                    part[:tc.shape[0]] = tc
                parts.append(part)
                off += n
            flat = (np.concatenate(parts) if parts
                    else np.zeros(0, np.int64))
            ent = self._props[("e", prop)] = (
                self._upload(np.asarray(offsets, dtype=np.int64)),
                self._upload(flat))
        return ent

    def vertex_prop(self, ids, prop: str):
        return self.take(self._vprop_dev(prop), self._jnp.asarray(ids))

    def edge_prop(self, triple_ids, pos, prop: str):
        offsets, flat = self._eprop_dev(prop)
        if flat.shape[0] == 0:
            return self._jnp.full(self._jnp.asarray(pos).shape, _I32_MIN,
                                  self._jnp.int32)
        base = self.take(offsets, self._jnp.asarray(triple_ids))
        return self.take(flat, base + self._jnp.asarray(pos))

    # --------------------------------------------------------------- pattern
    def _csr_dev(self, csr):
        key = id(csr)
        ent = self._dev.get(key)
        if ent is None:
            ent = (self._upload(csr.indptr), self._upload(csr.indices),
                   self._upload(csr.pos) if csr.pos is not None else None)
            self._dev[key] = ent
        return ent

    def _pad(self, a, n: int, fill=0):
        return self._jnp.pad(a, (0, n - a.shape[0]), constant_values=fill)

    def scan(self, lo: int, hi: int):
        return self._jnp.arange(lo, hi, dtype=self._jnp.int32)

    def expand(self, csr, rows_local, max_out=None):
        """Device twin of ``vecops.expand_csr``: repeat-based flat CSR
        gather (row-major order, exactly the host path's rows).  Sort- and
        scatter-free — on CPU XLA a scatter serializes, and a padded
        [R, D_max] block (``jaxops.expand_padded``, the jit/TPU-shaped
        variant) would cost an extra materialization + compaction pass;
        the flat gather materializes exactly ``total`` rows, which
        ``max_out`` caps *before* any device work."""
        jnp = self._jnp
        rows = jnp.asarray(rows_local)
        R = rows.shape[0]
        z = jnp.zeros(0, jnp.int32)
        if R == 0:
            return z, z, z
        indptr_d, indices_d, pos_d = self._csr_dev(csr)
        total0, approx0 = self._jaxops.csr_expand_total(indptr_d, rows)
        total = int(total0)                          # control-plane sync
        if float(approx0) > _I32_MAX - 256:          # int32 sum wrapped
            raise RuntimeError(f"intermediate blow-up: expansion would "
                               f"produce ~{float(approx0):.3g} rows "
                               f"(beyond the int32 staging envelope)")
        if max_out is not None and total > max_out:
            raise RuntimeError(f"intermediate blow-up: expansion would "
                               f"produce {total} rows > cap {max_out}")
        if total == 0:
            return z, z, z
        return self._jaxops.csr_expand_flat(
            indptr_d, indices_d,
            pos_d if pos_d is not None else indices_d, rows,
            total=total, has_pos=pos_d is not None)

    # ------------------------------------------------------------- intersect
    def intersect(self, csr, rows_local, targets):
        jnp = self._jnp
        rows = jnp.asarray(rows_local)
        tgt = jnp.asarray(targets)
        R = rows.shape[0]
        if R == 0:
            return jnp.zeros(0, bool), jnp.zeros(0, jnp.int32)
        indptr_d, indices_d, pos_d = self._csr_dev(csr)
        deg = self.take(indptr_d, rows + 1) - self.take(indptr_d, rows)
        founds, fposs = [], []
        for s in range(0, R, _SLAB_ROWS):
            e = min(s + _SLAB_ROWS, R)
            d_hi = int(deg[s:e].max())               # control-plane sync
            if d_hi == 0:
                founds.append(jnp.zeros(e - s, bool))
                fposs.append(jnp.zeros(e - s, jnp.int32))
            elif d_hi <= MAX_ELL_DEGREE:
                f, p = self._intersect_ell(indptr_d, indices_d, rows[s:e],
                                           tgt[s:e], d_hi)
                founds.append(f)
                fposs.append(p)
            else:
                f, p = self._intersect_bsearch(indptr_d, indices_d,
                                               rows[s:e], tgt[s:e])
                founds.append(f)
                fposs.append(p)
        found = founds[0] if len(founds) == 1 else jnp.concatenate(founds)
        fpos = fposs[0] if len(fposs) == 1 else jnp.concatenate(fposs)
        mapped = self.take(pos_d, fpos) if pos_d is not None else fpos
        epos = jnp.where(found, mapped, 0)
        return found, epos

    def _intersect_ell(self, indptr_d, indices_d, rows, targets, d_hi):
        """Pallas kernel path: gather padded-ELL rows, compare-scan probe."""
        from repro.kernels.wcoj_intersect.ops import gather_rows
        jnp = self._jnp
        d_max = _pow2(d_hi)
        R = rows.shape[0]
        rp = _pow2(R, _MIN_BLOCK_ROWS)
        # tile rows so one [block_rows, d_max] ELL block stays ~VMEM-sized
        # (and interpret mode on CPU runs few, fat grid steps)
        block_rows = max(_MIN_BLOCK_ROWS,
                         min(rp, _pow2_floor(_TILE_ELEMS // d_max)))
        rows_p = self._pad(rows, rp)
        # pad targets with -2: never matches a real id (>=0) or ELL pad (-1)
        tgt_p = self._pad(targets, rp, -2)
        adj = gather_rows(indices_d, indptr_d, rows_p, d_max)
        found_d, pos_d = self._wcoj(adj, tgt_p, block_rows=block_rows,
                                    interpret=self._interpret)
        pos_in_row = pos_d[:R].astype(jnp.int32)
        return found_d[:R], self.take(indptr_d, rows) + pos_in_row

    def _intersect_bsearch(self, indptr_d, indices_d, rows, targets):
        """High-degree fallback: jit'd per-row bounded binary search."""
        jnp = self._jnp
        R = rows.shape[0]
        rp = _pow2(R, _MIN_BLOCK_ROWS)
        lo = self._pad(self.take(indptr_d, rows), rp)
        hi = self._pad(self.take(indptr_d, rows + 1), rp)
        tgt = self._pad(targets, rp, -2)
        found_d, pos_d = self._jaxops.bounded_binary_search(
            indices_d, lo, hi, tgt)
        return found_d[:R], pos_d[:R].astype(jnp.int32)

    # --------------------------------------------------------- relational tail
    def join(self, lkeys, rkeys, max_out=None):
        jnp = self._jnp
        lk = jnp.asarray(lkeys)
        rk = jnp.asarray(rkeys)
        L, R = lk.shape[0], rk.shape[0]
        z = jnp.zeros(0, jnp.int32)
        if L == 0 or R == 0:
            return z, z
        lorder, rorder, lo, cnt, total0, approx0 = \
            self._jaxops.sortmerge_bounds(lk, rk)
        total = int(total0)                         # control-plane sync
        if float(approx0) > _I32_MAX - 256:         # int32 sum wrapped
            raise RuntimeError(f"intermediate blow-up: join would produce "
                               f"~{float(approx0):.3g} rows (beyond the "
                               f"int32 staging envelope)")
        if max_out is not None and total > max_out:
            raise RuntimeError(f"intermediate blow-up: join would produce "
                               f"{total} rows > cap {max_out}")
        if total == 0:
            return z, z
        return self._jaxops.sortmerge_pairs(lorder, rorder, lo, cnt,
                                            total=total)

    def combine_keys(self, cols: list):
        cols = [self._jnp.asarray(c) for c in cols]
        if len(cols) == 1:
            return cols[0]
        return self._jaxops.lex_ranks(cols)

    def group_reduce(self, keys, values):
        """Sorted-run grouping: one stable sort by key, then every
        aggregate is a cumsum/boundary gather over the sorted runs —
        sort/gather-shaped on purpose (XLA scatter, hence
        ``jax.ops.segment_*``, serializes on CPU).  Groups ascend by key;
        ``first`` is each group's minimal original row (stable sort)."""
        jnp = self._jnp
        keys = jnp.asarray(keys)
        n = keys.shape[0]
        if n == 0:
            z = jnp.zeros(0, jnp.int32)
            return z, {name: z for name in values}
        bad = [fn for fn, _ in values.values()
               if fn not in ("COUNT", "SUM", "AVG", "MIN", "MAX")]
        if bad:
            raise ValueError(f"unknown aggregate {bad[0]}")
        order, _flags, flag_order, ng0 = self._jaxops.group_boundaries(keys)
        ng = int(ng0)                                # control-plane sync
        starts = flag_order[:ng]                     # ascending run starts
        names = list(values)
        first, outs = self._jaxops.group_aggregate(
            order, starts, keys,
            tuple(jnp.asarray(values[nm][1]) for nm in names),
            tuple(values[nm][0] for nm in names))
        return first, dict(zip(names, outs))


def fuse_expand_chain(node: PlanNode, ctx) -> PlanNode:
    """Post-CBO physical rewrite (the ``PhysicalSpec.physical_rules`` hook):
    fuse runs of >= 2 consecutive single-edge expansions into one
    ``ExpandChainNode``.

    With device-resident tables (OperatorSet v2) every hop already stays on
    device; chaining still pays because the thin frontier carries only the
    hop columns through the per-hop gathers — the full binding table is
    gathered once at the end.  Only predicate-free hops fuse (a filter must
    run at its own hop to bound intermediates), and each hop's source alias
    must be bound by the chain itself (or be the first hop's source), so
    the thin frontier always carries it.  Fusion is packaging, not
    planning: ``ExpandChainNode.unfused()`` recovers the exact pre-fusion
    plan, and results are row-identical."""
    pattern = ctx.pattern()
    fused = False

    def rewrite(n: PlanNode) -> PlanNode:
        if isinstance(n, JoinNode):
            return dataclasses.replace(n, left=rewrite(n.left),
                                       right=rewrite(n.right))
        if not isinstance(n, ExpandNode):
            return n
        run = [n]                       # the maximal expand run, bottom-up
        cur = n.child
        while isinstance(cur, ExpandNode):
            run.append(cur)
            cur = cur.child
        run.reverse()                   # execution order
        out = rewrite(cur)
        pending: list[tuple[ExpandNode, str]] = []

        def flush():
            nonlocal out, fused
            if len(pending) >= 2:
                fused = True
                steps = [ChainStep(h.edges[0], frm, h.new_alias,
                                   h.est_frequency, h.est_cost)
                         for h, frm in pending]
                out = ExpandChainNode(out, steps,
                                      est_frequency=steps[-1].est_frequency,
                                      est_cost=steps[-1].est_cost)
            else:
                for h, frm in pending:
                    out = ExpandNode(out, h.new_alias, h.edges,
                                     est_frequency=h.est_frequency,
                                     est_cost=h.est_cost)
            pending.clear()

        for h in run:
            v = pattern.vertices[h.new_alias]
            fusable = (len(h.edges) == 1 and not v.predicates
                       and not h.edges[0].predicates)
            frm = h.edges[0].other(h.new_alias) if h.edges else None
            if fusable and pending:
                carried = {pending[0][1]} | {x.new_alias for x, _ in pending}
                if frm not in carried:
                    # source bound below the current run (e.g. by a join
                    # child): close this chain and anchor a new one here
                    flush()
            if fusable:
                pending.append((h, frm))
            else:
                flush()
                out = ExpandNode(out, h.new_alias, h.edges,
                                 est_frequency=h.est_frequency,
                                 est_cost=h.est_cost)
        flush()
        return out

    out = rewrite(node)
    # no run fused: hand back the input so PhysicalRulesPass (and its
    # trace) correctly records the plan as unchanged
    return out if fused else node


# Calibrated from BENCH_backends.json (sf=0.2 CPU/interpret timings) via
# benchmarks/calibrate_costs.py: expand-dominated chain probes run ~5.3x the
# numpy host path (dispatch + padded-block overhead), while cyclic queries
# whose plans close edges with WCOJ membership probes run ~34x — so the CBO
# should spend joins/expansions to avoid intersections on this backend.
# Scan and the (now device-native) join stay at the numpy baseline.
# Re-derive after re-benchmarking (e.g. on real TPU, where these flip
# dramatically).
JAX_SPEC = register_spec(PhysicalSpec(
    name="jax",
    make_operators=JaxOperators,
    cost=CostParams(alpha_scan=1.0, alpha_expand=5.3,
                    alpha_intersect=34.0, alpha_join=1.0),
    description="device-resident columns; jit'd padded-block primitives + "
                "wcoj_intersect Pallas kernel (interpret on CPU, compiled "
                "on TPU); segment-reduce/sort-merge relational tail",
    physical_rules=(fuse_expand_chain,),
))
