"""JAX backend — device-resident binding tables + jit'd padded-block kernels.

Registers the ``"jax"`` PhysicalSpec. OperatorSet v2 (DESIGN.md §7): every
operator takes and returns ``jax.Array`` columns, so the engine's binding
table stays on device across *all* plan steps — pattern loop and relational
tail alike — and crosses to the host exactly once, at result delivery
(``to_host``). ``transfer_stats`` records each host<->device data movement;
the residency tests assert zero ``d2h`` events outside the delivery phase.

- ``expand``    -> ``jaxops.expand_padded``: [R, D_max] neighbor block +
  validity mask, compacted to flat rows on device.
- ``intersect`` -> the ``wcoj_intersect`` Pallas kernel (vectorized
  compare-scan over a padded-ELL adjacency tile; interpret mode on CPU,
  compiled on TPU) for row degrees up to ``MAX_ELL_DEGREE``; beyond that the
  jit'd ``jaxops.bounded_binary_search`` probes the CSR directly.
- relational tail on device: ``join`` is a sort-merge join (stable argsort +
  searchsorted), ``group_reduce`` rides ``jax.ops.segment_*``, and
  ``combine_keys`` packs tuples into dense lexicographic ranks
  (``jaxops.lex_ranks``) — rank order matches the numpy backend's packed-key
  order, so group/join row order stays row-identical across backends.

- ``chain_program`` -> ``FusedChain``: every ``ExpandChainNode`` compiles
  into ONE jit program (``jaxops.build_fused_chain``) — a single device
  dispatch per chain, with pow2 shape-bucketed capacities bounding the
  compile cache and the ``KernelStats`` ledger counter-proving the
  dispatch contract (DESIGN.md §8).

Shapes must be static under jit.  The intersect path pads row blocks to
powers of two (compile count logarithmic in table size), fused chains
bucket their input and per-hop capacities the same way, and the compound
tail kernels (join / group_reduce / combine_keys) pad their inputs to
pow2 capacity buckets too (``jaxops.*_padded``; pad rows are ordered by
an explicit pad flag, never a sentinel value) — so jittered serving-wave
sizes re-hit one compiled program per bucket, counter-proved by the
``compile:join`` / ``compile:group`` / ``compile:lex_ranks``
``KernelStats`` events recorded on first sighting of each bucket key.
Vertex ids, CSR offsets and property columns
stage through int32 (guarded at construction); ``to_host`` widens back to
int64 and canonicalizes the missing-property sentinel.  Control-plane
scalar syncs (row counts, blow-up guards) are not data transfers and are
not recorded.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.pattern import BOTH
from repro.core.physical import (ChainStep, ExpandChainNode, ExpandNode,
                                 JoinNode, PlanNode,
                                 chain_fusable_predicates)
from repro.core.physical_spec import (CostParams, OperatorSet, PhysicalSpec,
                                      register_spec)

# degree ceiling for the padded-ELL kernel layout (DESIGN.md §3: the VPU
# compare-scan beats log-step gathers only while a row block fits in VMEM)
MAX_ELL_DEGREE = 1024
_MIN_BLOCK_ROWS = 8
# rows per device slab: padded blocks are [slab, D_max]; slabbing bounds the
# padded footprint and lets D_max adapt to each slab's real degree skew
_SLAB_ROWS = 1 << 15
# padded-block element budget per Pallas input tile (~2 MB of int32)
_TILE_ELEMS = 1 << 19
# element budget for one [rows, D_max] padded expand block.  The v2 expand
# is a flat repeat-based CSR gather (no padded block, footprint == exact
# output rows, capped by max_out), so this only governs the jit/TPU padded
# variant (``jaxops.expand_padded``)
_EXPAND_ELEMS = 1 << 25

_I32_MIN = np.iinfo(np.int32).min
_I32_MAX = np.iinfo(np.int32).max
_I64_MIN = np.iinfo(np.int64).min

# fused-chain bucketing (DESIGN.md §8): frontier sizes and per-hop
# capacities round up to powers of two with this floor, so the compile
# cache is logarithmic in the size range a chain shape ever sees
_CHAIN_MIN_BUCKET = 8
_CHAIN_PROGRAMS_PER_SHAPE = 4     # bucketed jit programs kept per chain
_CHAIN_SHAPES = 64                # chain handles kept per operator set
# under CPU interpret, fusion pays off while chains are *dispatch-bound*;
# once a hop's capacity grows past this, the pow2 padding + final-argsort
# work of the fused program outweighs the saved launches and the per-hop
# loop is faster (BENCH_fusion.json: ic5 at 2^17 wins fused 3.6x, ic6 at
# 2^18 loses) — volume-bound chains stay on the loop.  On a real
# accelerator one large launch still wins, so the cutoff is interpret-only.
_CHAIN_VOLUME_CUTOFF = 1 << 17

# capacity-bucket floor for the compound relational-tail kernels (the tail
# twin of _CHAIN_MIN_BUCKET): join/group/combine inputs pad up to pow2 so
# the per-kernel compile count is logarithmic in the size range seen
_TAIL_MIN_BUCKET = 16


def _pow2(n: int, floor: int = 1) -> int:
    return max(floor, 1 << max(int(n) - 1, 0).bit_length())


def _pow2_floor(n: int) -> int:
    return 1 << (max(int(n), 1).bit_length() - 1)


class FusedChain:
    """One chain shape's fused-program handle (OperatorSet.chain_program).

    Lifecycle: the engine's first execution of the chain runs the per-hop
    loop and reports the observed per-hop expansion totals via
    ``observe()``; that fixes the pow2 capacity schedule (``caps``), and
    every later execution compiles/reuses ONE jit program per (caps,
    input-bucket, IN-set buckets) key and dispatches the whole chain in a
    single launch.  Capacities only grow (element-wise pow2 max), so the
    compile count for one shape is bounded by the log of the largest size
    it ever sees; an execution whose true totals overflow the current caps
    returns ``None`` (the engine re-runs that one through the loop) and
    regrows the schedule for the next execution."""

    def __init__(self, ops: "JaxOperators", spec):
        self.ops = ops
        self.spec = spec
        self.caps: tuple | None = None
        self._progs: dict = {}    # (caps, in_bucket, value_buckets) -> entry
        # pinned handles survive the operator set's chain-LRU eviction
        # (QueryServer hotness protection, DESIGN.md §9)
        self.pinned = False

    def ready(self) -> bool:
        if self.caps is None:
            return False
        return not (self.ops._interpret
                    and max(self.caps) > _CHAIN_VOLUME_CUTOFF)

    def observe(self, sizes):
        caps = tuple(_pow2(max(int(s), 1), _CHAIN_MIN_BUCKET) for s in sizes)
        if self.caps is not None and len(self.caps) == len(caps):
            caps = tuple(max(a, b) for a, b in zip(self.caps, caps))
        self.caps = caps

    # ------------------------------------------------------------ marshaling
    def _build_desc(self, caps):
        """Static program description for ``jaxops.build_fused_chain`` +
        the ordered property-column requirements."""
        spec = self.spec
        vprops: list[str] = []
        eprops: list[str] = []

        def ref(r):
            if r[0] == "vprop":
                if r[2] not in vprops:
                    vprops.append(r[2])
                return ("vprop", r[1], vprops.index(r[2]))
            if r[0] == "eprop":
                if r[2] not in eprops:
                    eprops.append(r[2])
                return ("eprop", r[1], eprops.index(r[2]))
            return r

        s_map: dict[int, int] = {}
        v_map: dict[int, int] = {}
        for i, s in enumerate(spec.slots):
            if s[0] == "scalar":
                s_map[i] = len(s_map)
            else:
                v_map[i] = len(v_map)

        def sig(p):
            if p is None:
                return None
            if p[0] == "cmp":
                return ("cmp", p[1], ref(p[2]), s_map[p[3]])
            if p[0] == "in":
                return ("in", ref(p[1]), v_map[p[2]])
            return (p[0], tuple(sig(s) for s in p[1]))

        hops = []
        for k, h in enumerate(spec.hops):
            orients = tuple((o.lo, o.hi, o.tidx, o.csr.pos is not None)
                            for o in h.orients)
            probes = []
            for p in h.probes:
                d_hi = self.ops._csr_max_degree(p.orient.csr)
                d_max = _pow2(max(d_hi, 1))
                # Pallas ELL tiles on compiled backends (and for tiny
                # shapes under interpret, to keep the path tested on CPU);
                # per-row bounded binary search otherwise
                ell = (d_hi > 0 and d_hi <= MAX_ELL_DEGREE
                       and (not self.ops._interpret
                            or (d_max <= 64 and caps[k] <= 4096)))
                block_rows = max(_MIN_BLOCK_ROWS,
                                 min(caps[k],
                                     _pow2_floor(_TILE_ELEMS // d_max)))
                probes.append((p.from_alias, p.edge_alias, p.orient.lo,
                               p.orient.hi, p.vlo, p.vhi,
                               p.orient.tidx, p.orient.csr.pos is not None,
                               "ell" if ell else "bsearch", d_max,
                               block_rows))
            hops.append((h.from_alias, h.alias, h.edge_alias, orients,
                         tuple(probes), sig(h.pred_sig)))
        return (spec.source, tuple(hops)), tuple(vprops), tuple(eprops)

    def _csr_args(self, o):
        indptr, indices, pos = self.ops._csr_dev(o.csr)
        return (indptr, indices, pos if pos is not None else indices)

    # -------------------------------------------------------------- dispatch
    def run(self, src, nrows, scalars, value_lists, max_rows):
        """One fused dispatch; returns ``(rows, cols, n)`` with exact-size
        device columns, or ``None`` after a capacity overflow (caps regrow;
        the caller falls back to the per-hop loop for this execution)."""
        ops = self.ops
        jnp = ops._jnp
        n = int(nrows)
        in_bucket = _pow2(n, _CHAIN_MIN_BUCKET)
        vb = tuple(_pow2(max(len(v), 1)) for v in value_lists)
        # a runtime-empty IN-set is a *static* program variant (matches
        # nothing even under NOT/OR), part of the bucketed cache key
        empties = tuple(i for i, v in enumerate(value_lists) if len(v) == 0)
        key = (self.caps, in_bucket, vb, empties)
        entry = self._progs.get(key)
        if entry is not None:
            self._progs[key] = self._progs.pop(key)   # LRU touch
        else:
            from repro.graphdb import jaxops
            desc, vprops, eprops = self._build_desc(self.caps)
            fn = ops._jax.jit(jaxops.build_fused_chain(
                desc, self.caps, in_bucket, ops._interpret,
                empty_values=empties))
            entry = (fn, vprops, eprops)
            if len(self._progs) >= _CHAIN_PROGRAMS_PER_SHAPE:
                self._progs.pop(next(iter(self._progs)))
            self._progs[key] = entry
            ops.kernel_stats.record("compile", "fused_chain")
        fn, vprops, eprops = entry
        src = jnp.asarray(src)
        if in_bucket > n:
            src = jnp.pad(src, (0, in_bucket - n))
        csrs = tuple((tuple(self._csr_args(o) for o in h.orients),
                      tuple(self._csr_args(p.orient) for p in h.probes))
                     for h in self.spec.hops)
        vp = tuple(ops._vprop_dev(p) for p in vprops)
        # base columns only ((offsets, flat) — drop the nnz count): chains
        # decline whenever the snapshot touches their triples, so overlay
        # edge positions never reach a fused program
        ep = tuple(ops._eprop_dev(p)[:2] for p in eprops)
        scal = ops.asarray(np.asarray(list(scalars), dtype=np.int32))
        vals = []
        for v, b in zip(value_lists, vb):
            a = np.asarray(v, dtype=np.int32)
            if a.shape[0] == 0:
                a = np.zeros(b, np.int32)          # dead arg (empty variant)
            elif a.shape[0] < b:                   # duplicate-pad: same set
                a = np.concatenate([a, np.full(b - a.shape[0], a[0],
                                               np.int32)])
            vals.append(ops.asarray(a))
        out, n0, needed, needed_f = fn(src, n, csrs, vp, ep, scal,
                                       tuple(vals))
        ops.kernel_stats.record("dispatch", "fused_chain")
        needed_h = np.asarray(needed)              # control-plane sync
        nf = np.asarray(needed_f)
        if nf.size and float(nf.max()) > _I32_MAX - 256:
            raise RuntimeError(
                f"intermediate blow-up: chain expansion would produce "
                f"~{float(nf.max()):.3g} rows (beyond the int32 staging "
                f"envelope)")
        if (needed_h > max_rows).any():
            raise RuntimeError(
                f"intermediate blow-up: chain expansion would produce "
                f"{int(needed_h.max())} rows > cap {max_rows}")
        if (needed_h > np.asarray(self.caps)).any():
            self.observe(needed_h.tolist())
            return None
        n_out = int(n0)
        rows = out["__rows"][:n_out]
        cols = {k: v[:n_out] for k, v in out.items()
                if k not in ("__rows", self.spec.source)}
        return rows, cols, n_out


class JaxOperators(OperatorSet):
    """Device-resident operator set: columns are ``jax.Array`` int32."""

    name = "jax"
    supports_chains = True
    compiled = True

    def __init__(self, store):
        super().__init__(store)
        import jax  # deferred so the registry import stays light
        import jax.numpy as jnp
        from repro.graphdb import jaxops
        from repro.kernels.wcoj_intersect.ops import wcoj_intersect
        self._jax = jax
        self._jnp = jnp
        self._jaxops = jaxops
        self._wcoj = wcoj_intersect
        self._interpret = jax.default_backend() != "tpu"
        id_space = getattr(store, "id_space", store.n_vertices)
        if max(id_space, store.n_edges) >= _I32_MAX:
            raise ValueError(
                "jax backend stages vertex ids and CSR offsets through "
                f"int32; store has {store.n_vertices} vertices / "
                f"{store.n_edges} edges")
        self._dev = {}    # id(csr) -> (indptr_dev, indices_dev, pos_dev|None)
        self._props = {}  # ("v"|"e", prop, epoch) -> device property column(s)
        self._cols = {}   # id(host col) -> (host col ref, device twin)
        self._chains = {}     # (chain signature, csr ids) -> FusedChain
        self._max_deg = {}    # id(csr) -> int global max degree
        # tail-kernel bucket keys already traced: mirrors the module-level
        # jit caches so KernelStats can record one compile per bucket
        self._tail_shapes: set = set()

    # ---------------------------------------------------------- fused chains
    @staticmethod
    def _chain_key(spec):
        return (spec.signature(),
                tuple(id(o.csr) for h in spec.hops
                      for o in list(h.orients) + [p.orient
                                                  for p in h.probes]))

    def chain_program(self, spec) -> FusedChain:
        key = self._chain_key(spec)
        prog = self._chains.get(key)
        if prog is not None:
            self._chains[key] = self._chains.pop(key)   # LRU touch
        else:
            if len(self._chains) >= _CHAIN_SHAPES:
                victim = next((k for k, v in self._chains.items()
                               if not v.pinned), None)
                # all pinned: evict the coldest anyway (capacity wins)
                self._chains.pop(victim if victim is not None
                                 else next(iter(self._chains)))
            prog = self._chains[key] = FusedChain(self, spec)
        return prog

    def pin_chain(self, spec, pinned: bool = True) -> bool:
        """Protect (or release) an existing chain handle — with its bucketed
        compiled programs — from chain-LRU eviction.  Only handles that
        already exist are pinned: a plan with no executed chain has nothing
        worth protecting."""
        prog = self._chains.get(self._chain_key(spec))
        if prog is None:
            return False
        prog.pinned = bool(pinned)
        return True

    def _tail_compile(self, kind: str, key: tuple):
        """Record ``compile:<kind>`` on the first sighting of a bucketed
        tail-kernel shape key (mirroring the jit cache, which is keyed by
        exactly these padded shapes)."""
        if (kind, key) not in self._tail_shapes:
            self._tail_shapes.add((kind, key))
            self.kernel_stats.record("compile", kind)

    def _csr_max_degree(self, csr) -> int:
        d = self._max_deg.get(id(csr))
        if d is None:
            deg = csr.indptr[1:] - csr.indptr[:-1]
            d = self._max_deg[id(csr)] = int(deg.max()) if deg.size else 0
        return d

    def block_ready(self, arrays):
        return self._jax.block_until_ready(arrays)

    # ------------------------------------------------------------ transfers
    def asarray(self, values):
        if isinstance(values, self._jax.Array):
            return values
        a = np.asarray(values)
        self.transfer_stats.record("h2d", a.size)
        return self._jnp.asarray(a)

    def _array_to_host(self, a) -> np.ndarray:
        if not isinstance(a, self._jax.Array):
            return np.asarray(a)
        self.transfer_stats.record("d2h", a.size)
        h = np.asarray(a)
        if h.dtype == np.int32:
            h64 = h.astype(np.int64)
            h64[h64 == _I32_MIN] = _I64_MIN   # missing-prop sentinel widens
            return h64
        if h.dtype == np.float32:
            return h.astype(np.float64)
        return h

    def _upload(self, a: np.ndarray):
        """Graph-structure/property upload (cached by callers): int32 on
        device, recorded as h2d."""
        if a.dtype.kind == "i" and a.size and (
                a.max() > _I32_MAX or a.min() < _I32_MIN):
            raise ValueError("column exceeds the jax backend's int32 "
                             "staging envelope")
        self.transfer_stats.record("h2d", a.size)
        return self._jnp.asarray(a.astype(np.int32)
                                 if a.dtype.kind == "i" else a)

    # ------------------------------------------------------ array primitives
    def take(self, a, idx):
        # jnp.take(mode="clip") skips the eager advanced-indexing rewrite
        # machinery (~0.5ms of host python per gather); engine indices are
        # in-range by construction
        return self._jnp.take(self._jnp.asarray(a), idx, axis=0, mode="clip")

    def mask(self, a, m):
        return self._jnp.asarray(a)[self._jnp.asarray(m)]

    def concat(self, parts: list):
        if not parts:
            return self._jnp.zeros(0, self._jnp.int32)
        if len(parts) == 1:
            return self._jnp.asarray(parts[0])
        return self._jnp.concatenate([self._jnp.asarray(p) for p in parts])

    def nonzero(self, m):
        # argsort-shaped flatnonzero: jnp.nonzero's eager path rides heavy
        # python machinery per call.  A stable sort puts True positions
        # first in original order; the count sync sizes the slice.  The
        # mask pads to a pow2 capacity bucket (pads False, so they sort
        # last among the dropped rows) — mask/compaction sites key compiles
        # on the bucket, not the exact table length.
        jnp = self._jnp
        m = jnp.asarray(m)
        if m.dtype != bool:
            m = m != 0          # int 0/1 masks: sum/argsort need real bools
        n = m.shape[0]
        cnt = int(m.sum())                           # control-plane sync
        if cnt == 0:
            return jnp.zeros(0, jnp.int32)
        np2 = _pow2(n, _TAIL_MIN_BUCKET)
        self._tail_compile("nonzero", (np2,))
        self.kernel_stats.record("dispatch", "nonzero")
        order = jnp.argsort(~self._pad(m, np2, False))   # stable
        return order[:cnt].astype(jnp.int32)

    def full(self, n: int, value):
        return self._jnp.full(n, value)

    def arange(self, n: int):
        return self._jnp.arange(n, dtype=self._jnp.int32)

    def isin(self, a, values):
        vals = np.asarray(list(values), dtype=np.int64)
        # values outside the int32 envelope cannot match any staged column
        vals = vals[(vals <= _I32_MAX) & (vals > _I32_MIN)]
        return self._jnp.isin(self._jnp.asarray(a), self.asarray(vals))

    def searchsorted(self, sorted_arr, values, side: str = "left"):
        return self._jnp.searchsorted(self._jnp.asarray(sorted_arr),
                                      self._jnp.asarray(values), side=side)

    def where(self, cond, a, b):
        return self._jnp.where(self._jnp.asarray(cond),
                               self._jnp.asarray(a), self._jnp.asarray(b))

    def lexsort(self, cols: list):
        return self._jnp.lexsort(tuple(self._jnp.asarray(c) for c in cols))

    def distinct_indices(self, key):
        # pow2-bucketed like the compound tail kernels: pad rows sort last
        # by an explicit pad flag (any key value stays distinct-correct)
        # and never start a counted run
        jnp = self._jnp
        key = jnp.asarray(key)
        n = key.shape[0]
        if n == 0:
            return jnp.zeros(0, jnp.int32)
        np2 = _pow2(n, _TAIL_MIN_BUCKET)
        self._tail_compile("distinct", (np2,))
        self.kernel_stats.record("dispatch", "distinct")
        pf = jnp.arange(np2) >= n
        kp = self._pad(key, np2)
        order = jnp.lexsort((kp, pf))              # stable -> minimal index
        sk = self.take(kp, order)
        spf = self.take(pf, order)
        flag = jnp.concatenate([jnp.ones(1, bool),
                                sk[1:] != sk[:-1]]) & ~spf
        return jnp.sort(self.take(order, self.nonzero(flag)))

    # ------------------------------------------------------ property gathers
    def _col_dev(self, host_col: np.ndarray):
        """Device twin of a host overlay column, keyed by object identity
        (the mutable store retains every column it publishes, so an id is
        stable while the entry is valid; the stored host ref guards against
        address reuse after a gc).  The host INT64_MIN missing sentinel is
        narrowed to the in-band int32 one before staging."""
        key = id(host_col)
        ent = self._cols.get(key)
        if ent is None or ent[0] is not host_col:
            staged = np.where(host_col == _I64_MIN, _I32_MIN, host_col)
            ent = self._cols[key] = (host_col, self._upload(staged))
        return ent[1]

    def _vprop_dev(self, prop: str):
        """One device column per vertex property over the *base* store,
        indexed by *global* id (missing types filled with the int32
        sentinel) — a property gather is then a single device take instead
        of a per-type where-loop.  Keyed by compaction epoch so a rebuilt
        base CSR re-stages."""
        key = ("v", prop, getattr(self.store, "compaction_epoch", 0))
        ent = self._props.get(key)
        if ent is None:
            st = getattr(self.store, "base", self.store)
            # in-band missing sentinel, like the host path's INT64_MIN:
            # only a stored value of exactly INT32_MIN would collide
            col = np.full(st.n_vertices, _I32_MIN, dtype=np.int64)
            for t in st._sorted_types():
                tc = st.v_props.get(t, {}).get(prop)
                if tc is None or tc.shape[0] == 0:
                    continue
                off = st.v_offset[t]
                col[off:off + tc.shape[0]] = tc
            ent = self._props[key] = self._upload(col)
        return ent

    def _eprop_dev(self, prop: str):
        """Per-triple edge-property columns of the *base* store concatenated
        on device, plus the per-triple base offsets:
        ``col[offset[tidx] + pos]``.  The total base nnz rides along so the
        overlay merge can split positions."""
        key = ("e", prop, getattr(self.store, "compaction_epoch", 0))
        ent = self._props.get(key)
        if ent is None:
            st = getattr(self.store, "base", self.store)
            triples = sorted(st.out_csr, key=repr)
            offsets, parts, off = [], [], 0
            for t in triples:
                tc = st.e_props.get(t, {}).get(prop)
                n = st.out_csr[t].nnz
                offsets.append(off)
                part = np.full(n, _I32_MIN, dtype=np.int64)
                if tc is not None and tc.shape[0]:
                    part[:tc.shape[0]] = tc
                parts.append(part)
                off += n
            flat = (np.concatenate(parts) if parts
                    else np.zeros(0, np.int64))
            ent = self._props[key] = (
                self._upload(np.asarray(offsets, dtype=np.int64)),
                self._upload(flat), off)
        return ent

    def vertex_prop(self, ids, prop: str):
        ids = self._jnp.asarray(ids)
        out = self.take(self._vprop_dev(prop), ids)
        st = self.store
        bv = getattr(st, "base_n_vertices", None)
        if bv is not None and getattr(st, "id_space", bv) > bv:
            ext = self._col_dev(st.ext_vertex_prop_column(prop))
            out = self._jnp.where(ids < bv, out, self.take(ext, ids - bv))
        return out

    def edge_prop(self, triple_ids, pos, prop: str):
        jnp = self._jnp
        pos = jnp.asarray(pos)
        offsets, flat, nbase = self._eprop_dev(prop)
        if flat.shape[0] == 0:
            out = jnp.full(pos.shape, _I32_MIN, jnp.int32)
        else:
            # clip-mode take keeps overlay positions (>= nbase) harmless
            # here; the where below overwrites those lanes
            out = self.take(flat, self.take(offsets,
                                            jnp.asarray(triple_ids)) + pos)
        st = self.store
        if getattr(st, "overlay_edge_slots", 0) > 0:
            ov = self._col_dev(st.overlay_edge_prop_column(prop))
            out = jnp.where(pos < nbase, out, self.take(ov, pos - nbase))
        return out

    # --------------------------------------------------------------- pattern
    def _csr_dev(self, csr):
        key = id(csr)
        ent = self._dev.get(key)
        if ent is None:
            ent = (self._upload(csr.indptr), self._upload(csr.indices),
                   self._upload(csr.pos) if csr.pos is not None else None)
            self._dev[key] = ent
        return ent

    def _pad(self, a, n: int, fill=0):
        return self._jnp.pad(a, (0, n - a.shape[0]), constant_values=fill)

    def scan(self, lo: int, hi: int):
        return self._jnp.arange(lo, hi, dtype=self._jnp.int32)

    def expand(self, csr, rows_local, max_out=None):
        """Device twin of ``vecops.expand_csr``: repeat-based flat CSR
        gather (row-major order, exactly the host path's rows).  Sort- and
        scatter-free — on CPU XLA a scatter serializes, and a padded
        [R, D_max] block (``jaxops.expand_padded``, the jit/TPU-shaped
        variant) would cost an extra materialization + compaction pass;
        the flat gather materializes exactly ``total`` rows, which
        ``max_out`` caps *before* any device work."""
        jnp = self._jnp
        rows = jnp.asarray(rows_local)
        R = rows.shape[0]
        z = jnp.zeros(0, jnp.int32)
        if R == 0:
            return z, z, z
        indptr_d, indices_d, pos_d = self._csr_dev(csr)
        total0, approx0 = self._jaxops.csr_expand_total(indptr_d, rows)
        total = int(total0)                          # control-plane sync
        if float(approx0) > _I32_MAX - 256:          # int32 sum wrapped
            raise RuntimeError(f"intermediate blow-up: expansion would "
                               f"produce ~{float(approx0):.3g} rows "
                               f"(beyond the int32 staging envelope)")
        if max_out is not None and total > max_out:
            raise RuntimeError(f"intermediate blow-up: expansion would "
                               f"produce {total} rows > cap {max_out}")
        self.kernel_stats.record("dispatch", "expand", 1 + (total > 0))
        if total == 0:
            return z, z, z
        return self._jaxops.csr_expand_flat(
            indptr_d, indices_d,
            pos_d if pos_d is not None else indices_d, rows,
            total=total, has_pos=pos_d is not None)

    # ------------------------------------------------------------- intersect
    def intersect(self, csr, rows_local, targets):
        jnp = self._jnp
        rows = jnp.asarray(rows_local)
        tgt = jnp.asarray(targets)
        R = rows.shape[0]
        if R == 0:
            return jnp.zeros(0, bool), jnp.zeros(0, jnp.int32)
        indptr_d, indices_d, pos_d = self._csr_dev(csr)
        deg = self.take(indptr_d, rows + 1) - self.take(indptr_d, rows)
        founds, fposs = [], []
        for s in range(0, R, _SLAB_ROWS):
            e = min(s + _SLAB_ROWS, R)
            d_hi = int(deg[s:e].max())               # control-plane sync
            if d_hi == 0:
                founds.append(jnp.zeros(e - s, bool))
                fposs.append(jnp.zeros(e - s, jnp.int32))
            elif d_hi <= MAX_ELL_DEGREE:
                self.kernel_stats.record("dispatch", "intersect", 2)
                f, p = self._intersect_ell(indptr_d, indices_d, rows[s:e],
                                           tgt[s:e], d_hi)
                founds.append(f)
                fposs.append(p)
            else:
                self.kernel_stats.record("dispatch", "intersect", 1)
                f, p = self._intersect_bsearch(indptr_d, indices_d,
                                               rows[s:e], tgt[s:e])
                founds.append(f)
                fposs.append(p)
        found = founds[0] if len(founds) == 1 else jnp.concatenate(founds)
        # the ELL kernel emits an int 0/1 found column; the operator contract
        # is a bool mask (callers compose it with ~/& — bitwise on ints
        # silently corrupts)
        found = found.astype(bool)
        fpos = fposs[0] if len(fposs) == 1 else jnp.concatenate(fposs)
        mapped = self.take(pos_d, fpos) if pos_d is not None else fpos
        epos = jnp.where(found, mapped, 0)
        return found, epos

    def _intersect_ell(self, indptr_d, indices_d, rows, targets, d_hi):
        """Pallas kernel path: gather padded-ELL rows, compare-scan probe."""
        from repro.kernels.wcoj_intersect.ops import gather_rows
        jnp = self._jnp
        d_max = _pow2(d_hi)
        R = rows.shape[0]
        rp = _pow2(R, _MIN_BLOCK_ROWS)
        # tile rows so one [block_rows, d_max] ELL block stays ~VMEM-sized
        # (and interpret mode on CPU runs few, fat grid steps)
        block_rows = max(_MIN_BLOCK_ROWS,
                         min(rp, _pow2_floor(_TILE_ELEMS // d_max)))
        rows_p = self._pad(rows, rp)
        # pad targets with -2: never matches a real id (>=0) or ELL pad (-1)
        tgt_p = self._pad(targets, rp, -2)
        adj = gather_rows(indices_d, indptr_d, rows_p, d_max)
        found_d, pos_d = self._wcoj(adj, tgt_p, block_rows=block_rows,
                                    interpret=self._interpret)
        pos_in_row = pos_d[:R].astype(jnp.int32)
        return found_d[:R], self.take(indptr_d, rows) + pos_in_row

    def _intersect_bsearch(self, indptr_d, indices_d, rows, targets):
        """High-degree fallback: jit'd per-row bounded binary search."""
        jnp = self._jnp
        R = rows.shape[0]
        rp = _pow2(R, _MIN_BLOCK_ROWS)
        lo = self._pad(self.take(indptr_d, rows), rp)
        hi = self._pad(self.take(indptr_d, rows + 1), rp)
        tgt = self._pad(targets, rp, -2)
        found_d, pos_d = self._jaxops.bounded_binary_search(
            indices_d, lo, hi, tgt)
        return found_d[:R], pos_d[:R].astype(jnp.int32)

    # --------------------------------------------------------- relational tail
    # The compound tail kernels pad their inputs to pow2 capacity buckets
    # (pad rows ordered last by an explicit pad flag, exact results sliced
    # to the true counts) so recurring jittered sizes — serving waves —
    # re-hit one compiled program per bucket; _tail_compile counter-proves
    # the plateau.

    def join(self, lkeys, rkeys, max_out=None):
        jnp = self._jnp
        lk = jnp.asarray(lkeys)
        rk = jnp.asarray(rkeys)
        L, R = lk.shape[0], rk.shape[0]
        z = jnp.zeros(0, jnp.int32)
        if L == 0 or R == 0:
            return z, z
        Lp = _pow2(L, _TAIL_MIN_BUCKET)
        Rp = _pow2(R, _TAIL_MIN_BUCKET)
        self._tail_compile("join", (Lp, Rp))
        self.kernel_stats.record("dispatch", "join")
        # INT32_MAX padding keeps the right sorted column non-decreasing
        # for searchsorted; ordering itself rides the pad flag, so real
        # keys equal to the pad value still join correctly
        lorder, rorder, lo, cnt, total0, approx0 = \
            self._jaxops.sortmerge_bounds_padded(
                self._pad(lk, Lp, _I32_MAX), self._pad(rk, Rp, _I32_MAX),
                L, R)
        total = int(total0)                         # control-plane sync
        if float(approx0) > _I32_MAX - 256:         # int32 sum wrapped
            raise RuntimeError(f"intermediate blow-up: join would produce "
                               f"~{float(approx0):.3g} rows (beyond the "
                               f"int32 staging envelope)")
        if max_out is not None and total > max_out:
            raise RuntimeError(f"intermediate blow-up: join would produce "
                               f"{total} rows > cap {max_out}")
        if total == 0:
            return z, z
        Tp = _pow2(total, _TAIL_MIN_BUCKET)
        self._tail_compile("join_pairs", (Lp, Tp))
        self.kernel_stats.record("dispatch", "join")
        lidx, ridx = self._jaxops.sortmerge_pairs(lorder, rorder, lo, cnt,
                                                  total=Tp)
        return lidx[:total], ridx[:total]

    def combine_keys(self, cols: list):
        jnp = self._jnp
        cols = [jnp.asarray(c) for c in cols]
        if len(cols) == 1:
            return cols[0]
        n = cols[0].shape[0]
        if n == 0:
            return jnp.zeros(0, jnp.int32)
        np2 = _pow2(n, _TAIL_MIN_BUCKET)
        self._tail_compile("lex_ranks", (np2, len(cols)))
        self.kernel_stats.record("dispatch", "lex_ranks")
        ranks = self._jaxops.lex_ranks_padded(
            [self._pad(c, np2) for c in cols], n)
        return ranks[:n]

    def group_reduce(self, keys, values):
        """Sorted-run grouping: one stable sort by key, then every
        aggregate is a cumsum/boundary gather over the sorted runs —
        sort/gather-shaped on purpose (XLA scatter, hence
        ``jax.ops.segment_*``, serializes on CPU).  Groups ascend by key;
        ``first`` is each group's minimal original row (stable sort)."""
        jnp = self._jnp
        keys = jnp.asarray(keys)
        n = keys.shape[0]
        if n == 0:
            z = jnp.zeros(0, jnp.int32)
            return z, {name: z for name in values}
        bad = [fn for fn, _ in values.values()
               if fn not in ("COUNT", "SUM", "AVG", "MIN", "MAX")]
        if bad:
            raise ValueError(f"unknown aggregate {bad[0]}")
        np2 = _pow2(n, _TAIL_MIN_BUCKET)
        self._tail_compile("group", (np2,))
        self.kernel_stats.record("dispatch", "group", 2)
        keys_p = self._pad(keys, np2)
        order, _vstart, flag_order, ng0 = \
            self._jaxops.group_boundaries_padded(keys_p, n)
        ng = int(ng0)                                # control-plane sync
        starts = flag_order[:ng]                     # ascending run starts
        gp = _pow2(ng, _TAIL_MIN_BUCKET)
        names = list(values)
        cols_p = tuple(self._pad(jnp.asarray(values[nm][1]), np2)
                       for nm in names)
        fns = tuple(values[nm][0] for nm in names)
        self._tail_compile("group_agg",
                           (np2, gp, fns,
                            tuple(str(c.dtype) for c in cols_p)))
        # starts pad with the terminal bound n: dummy trailing groups get
        # count 0 and are sliced off below
        first, outs = self._jaxops.group_aggregate_padded(
            order, self._pad(starts, gp, n), keys_p, n, cols_p, fns)
        return first[:ng], {nm: o[:ng] for nm, o in zip(names, outs)}


def _hop_predicates(pattern, h: ExpandNode) -> list:
    preds = list(pattern.vertices[h.new_alias].predicates or [])
    for e in h.edges:
        preds.extend(e.predicates or [])
    return preds


def fuse_expand_chain(node: PlanNode, ctx) -> PlanNode:
    """Post-CBO physical rewrite (the ``PhysicalSpec.physical_rules`` hook):
    fuse runs of >= 2 consecutive expansions into one ``ExpandChainNode``.

    With device-resident tables (OperatorSet v2) every hop already stays on
    device; chaining pays twice: the thin frontier carries only the hop
    columns through the per-hop gathers, and the backend compiles the whole
    chain into ONE jit program — a single device dispatch instead of one
    per hop (DESIGN.md §8).  A hop fuses when its source alias is carried
    by the chain (or anchors it) and its predicates are chain-fusable
    (``core.physical.chain_fusable_predicates``: comparisons/IN-sets over
    carried aliases against literals or parameters — the folded filter
    still runs *at its own hop* inside the program, so intermediates stay
    bounded); other predicates close the chain, keeping their hop on the
    per-hop path.  A trailing expand-and-intersect whose probe edges read
    carried aliases folds in as the chain's final WCOJ step.  Fusion is
    packaging, not planning: ``ExpandChainNode.unfused()`` recovers the
    exact pre-fusion plan, and results are row-identical."""
    pattern = ctx.pattern()
    fused = False

    def rewrite(n: PlanNode) -> PlanNode:
        if isinstance(n, JoinNode):
            return dataclasses.replace(n, left=rewrite(n.left),
                                       right=rewrite(n.right))
        if not isinstance(n, ExpandNode):
            return n
        run = [n]                       # the maximal expand run, bottom-up
        cur = n.child
        while isinstance(cur, ExpandNode):
            run.append(cur)
            cur = cur.child
        run.reverse()                   # execution order
        out = rewrite(cur)
        pending: list[tuple[ExpandNode, str]] = []

        def flush():
            nonlocal out, fused
            if len(pending) >= 2:
                fused = True
                steps = [ChainStep(h.edges[0], frm, h.new_alias,
                                   h.est_frequency, h.est_cost,
                                   intersect_edges=tuple(h.edges[1:]))
                         for h, frm in pending]
                out = ExpandChainNode(out, steps,
                                      est_frequency=steps[-1].est_frequency,
                                      est_cost=steps[-1].est_cost)
            else:
                for h, frm in pending:
                    out = ExpandNode(out, h.new_alias, h.edges,
                                     est_frequency=h.est_frequency,
                                     est_cost=h.est_cost)
            pending.clear()

        def preds_fusable(h, frm):
            va = ({pending[0][1]} if pending else {frm})
            va |= {x.new_alias for x, _ in pending} | {h.new_alias}
            ea = {x.edges[0].alias for x, _ in pending} | \
                 {e.alias for e in h.edges}
            return chain_fusable_predicates(_hop_predicates(pattern, h),
                                            va, ea)

        for h in run:
            frm = h.edges[0].other(h.new_alias) if h.edges else None
            if len(h.edges) == 1:
                fusable = preds_fusable(h, frm)
                tail = False
            else:
                # expand-and-intersect: fold as the chain's final WCOJ step
                # when every probe edge reads a carried alias and each is a
                # pure filter (one orientation: directional, single triple)
                carried = ({pending[0][1]} | {x.new_alias
                                              for x, _ in pending}
                           if pending else set())
                tail = fusable = bool(pending) and frm in carried and all(
                    e.other(h.new_alias) in carried
                    and e.direction != BOTH and len(e.triples) == 1
                    for e in h.edges[1:]) and preds_fusable(h, frm)
            if fusable and not tail and pending:
                carried = {pending[0][1]} | {x.new_alias for x, _ in pending}
                if frm not in carried:
                    # source bound below the current run (e.g. by a join
                    # child): close this chain and anchor a new one here
                    flush()
                    fusable = preds_fusable(h, frm)
            if fusable:
                pending.append((h, frm))
                if tail:                # the wcoj step ends its chain
                    flush()
            else:
                flush()
                out = ExpandNode(out, h.new_alias, h.edges,
                                 est_frequency=h.est_frequency,
                                 est_cost=h.est_cost)
        flush()
        return out

    out = rewrite(node)
    # no run fused: hand back the input so PhysicalRulesPass (and its
    # trace) correctly records the plan as unchanged
    return out if fused else node


# Calibrated from BENCH_backends.json (sf=0.2 CPU/interpret timings) via
# benchmarks/calibrate_costs.py: expand-dominated chain probes run ~5.3x the
# numpy host path (dispatch + padded-block overhead), while cyclic queries
# whose plans close edges with WCOJ membership probes run ~34x — so the CBO
# should spend joins/expansions to avoid intersections on this backend.
# Scan and the (now device-native) join stay at the numpy baseline.
# Re-derive after re-benchmarking (e.g. on real TPU, where these flip
# dramatically).
JAX_SPEC = register_spec(PhysicalSpec(
    name="jax",
    make_operators=JaxOperators,
    cost=CostParams(alpha_scan=1.0, alpha_expand=5.3,
                    alpha_intersect=34.0, alpha_join=1.0),
    description="device-resident columns; jit'd padded-block primitives + "
                "wcoj_intersect Pallas kernel (interpret on CPU, compiled "
                "on TPU); segment-reduce/sort-merge relational tail",
    physical_rules=(fuse_expand_chain,),
))
