"""Chain specs — the engine<->backend contract for fused ``ExpandChainNode``
execution (DESIGN.md §8).

The engine compiles a chain node against its pattern + store into a
``ChainSpec``: per hop, the CSR orientations the expansion concatenates (in
the exact order of the per-hop loop), the trailing WCOJ membership probes of
an expand-and-intersect tail, and the hop predicates in chain-fusable form
(static signature + runtime slots, ``core.physical.compile_chain_predicate``).
A backend that advertises fused-chain support (``OperatorSet.chain_program``)
turns the spec into one compiled program — a single device dispatch for the
whole chain.  ``build_chain_spec`` returns ``None`` whenever any hop falls
outside the fusable envelope (mixed-type aliases, multi-orientation probes,
uncompilable predicates); the engine then runs its per-hop loop, which stays
the semantics oracle either way.

``ChainSpec.signature()`` is purely structural (no CSR identity): one
compiled program serves every chain with the same shape against the same
store, and parameter/literal values ride in runtime slots so rebinding a
parameter never recompiles.
"""
from __future__ import annotations

import dataclasses

from repro.core import ir
from repro.core.pattern import BOTH, IN, OUT, PatternEdge
from repro.core.physical import ExpandChainNode, compile_chain_predicate


class ChainFallback(Exception):
    """A runtime condition the fused program cannot honor (non-integer or
    out-of-envelope slot value): the engine falls back to the per-hop loop
    for this execution only."""


def orientations(e: PatternEdge, from_alias: str):
    """Yield (csr_kind, triple) pairs for expanding edge ``e`` from
    ``from_alias`` — csr_kind 'out' keys the CSR by the data-edge source.
    The single source of truth for orientation order: the engine's per-hop
    loop and the fused chain program must concatenate identically."""
    dirs = [OUT, IN] if e.direction == BOTH else [e.direction]
    for d in dirs:
        data_src, data_dst = (e.src, e.dst) if d == OUT else (e.dst, e.src)
        use_out = from_alias == data_src
        for t in sorted(e.triples, key=repr):
            yield ("out" if use_out else "in"), t


@dataclasses.dataclass
class OrientSpec:
    """One CSR the expansion (or probe) reads: local row = global id - lo.
    ``[lo, hi)`` is the keyed type's id range — rows outside it (a
    mixed-type frontier alias) contribute zero degree, exactly like the
    per-hop loop's membership mask."""
    kind: str            # "out" | "in"
    csr: object          # storage.CSR (backend uploads/caches device twins)
    lo: int              # keyed-type range start
    hi: int              # keyed-type range end (exclusive)
    tidx: int            # triple index for the edge's '#t' identity column

    def sig(self) -> tuple:
        return (self.kind, self.lo, self.hi, self.tidx,
                self.csr.pos is not None)


@dataclasses.dataclass
class ProbeSpec:
    """A trailing WCOJ membership probe: is (from_alias, hop alias) an edge
    of ``orient``?  Restricted to a single orientation so the probe is a
    pure filter (a multi-orientation intersect concatenates per-orientation
    parts and can emit a row twice — that stays on the per-hop loop).
    ``[vlo, vhi)`` is the probed value type's id range: rows whose target
    falls outside (mixed-type hop alias) fail the probe, like the loop's
    candidate mask."""
    edge_alias: str
    from_alias: str
    orient: OrientSpec
    vlo: int
    vhi: int

    def sig(self) -> tuple:
        return (self.edge_alias, self.from_alias, self.orient.sig(),
                self.vlo, self.vhi)


@dataclasses.dataclass
class HopSpec:
    from_alias: str
    alias: str
    edge_alias: str
    orients: list[OrientSpec]
    probes: list[ProbeSpec]
    pred_sig: tuple | None     # combined hop predicate (over global slots)

    def sig(self) -> tuple:
        return (self.from_alias, self.alias, self.edge_alias,
                tuple(o.sig() for o in self.orients),
                tuple(p.sig() for p in self.probes), self.pred_sig)


@dataclasses.dataclass
class ChainSpec:
    source: str
    hops: list[HopSpec]
    # runtime slot descriptors, ("scalar", lhs, rhs) | ("values", item, vals);
    # indices in pred_sig refer into this list — the engine evaluates them
    # per execution (encoding, parameter resolution)
    slots: list

    def signature(self) -> tuple:
        return (self.source, tuple(h.sig() for h in self.hops), len(self.slots))

    @property
    def has_params(self) -> bool:
        # s[2] is the slot's value side: the Cmp rhs or the InSet values
        # (a whole-list ``$S`` rides as a single Param node)
        return any(isinstance(s[2], ir.Param) for s in self.slots)


def build_chain_spec(store, tindex, pattern, node: ExpandChainNode
                     ) -> ChainSpec | None:
    """Compile ``node`` into a ``ChainSpec``, or ``None`` when any hop is
    outside the fusable envelope (the per-hop loop then executes it)."""
    first = node.steps[0].from_alias
    vertex_aliases = {first} | {s.alias for s in node.steps}
    edge_aliases = {e.alias for s in node.steps for e in s.all_edges()}
    slots: list = []
    hops: list[HopSpec] = []
    for s in node.steps:
        src_types = pattern.vertices[s.from_alias].types
        new_types = pattern.vertices[s.alias].types
        if s.from_alias not in vertex_aliases:
            return None
        orients = []
        for kind, t in orientations(s.edge, s.from_alias):
            keyed = t.src if kind == "out" else t.dst
            value = t.dst if kind == "out" else t.src
            if value not in new_types or keyed not in src_types:
                continue
            lo, hi = store.type_range(keyed)
            csr = (store.out_csr if kind == "out" else store.in_csr)[t]
            orients.append(OrientSpec(kind, csr, lo, hi, tindex[t]))
        if not orients:
            return None                      # provably-empty hop: loop it
        probes = []
        for e in s.intersect_edges:
            frm = e.other(s.alias)
            cand_types = new_types
            frm_types = pattern.vertices[frm].types
            if frm not in vertex_aliases:
                return None
            po = []
            for kind, t in orientations(e, frm):
                keyed = t.src if kind == "out" else t.dst
                value = t.dst if kind == "out" else t.src
                if keyed not in frm_types or value not in cand_types:
                    continue
                lo, hi = store.type_range(keyed)
                vlo, vhi = store.type_range(value)
                csr = (store.out_csr if kind == "out" else store.in_csr)[t]
                po.append((OrientSpec(kind, csr, lo, hi, tindex[t]),
                           vlo, vhi))
            if len(po) != 1:                 # pure-filter probes only
                return None
            probes.append(ProbeSpec(e.alias, frm, po[0][0],
                                    po[0][1], po[0][2]))
        # hop predicates, in the per-hop loop's application order: vertex
        # predicates, then each edge's predicates
        preds = list(pattern.vertices[s.alias].predicates or [])
        for e in s.all_edges():
            preds.extend(e.predicates or [])
        parts = tuple(compile_chain_predicate(p, vertex_aliases, edge_aliases,
                                              slots)
                      for p in preds)
        if any(p is None for p in parts):
            return None
        pred_sig = ("and", parts) if parts else None
        hops.append(HopSpec(s.from_alias, s.alias, s.edge.alias,
                            orients, probes, pred_sig))
    return ChainSpec(first, hops, slots)
