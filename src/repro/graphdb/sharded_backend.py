"""Sharded multi-device backend: mesh-partitioned CSR + collective operators.

The third registered ``OperatorSet`` (DESIGN.md §10).  The CSR graph is
vertex-cut partitioned across a JAX device mesh (``graphdb.partition``):
each shard owns a contiguous range of a CSR's keyed rows, so the adjacency
of a frontier vertex is readable only on its owning shard.  Every pattern
operator is a ``shard_map`` program over the mesh's ``data`` axis built
from real collectives:

- **expand** — the frontier's per-row degrees are resolved by each shard
  contributing the rows it owns and combining with ``lax.psum`` (the
  frontier exchange: every shard learns the full degree vector), then each
  shard materializes the neighbor/edge-position values of its owned rows
  at their row-major output offsets and a ``lax.psum_scatter`` both
  combines the per-shard contributions and leaves the output *sharded* —
  each device holds one contiguous chunk of the expansion.
- **intersect** — probes route the same way: owning shards run the bounded
  binary search locally and ``lax.psum`` combines the (owner-unique)
  found/edge-position vectors.
- the **relational tail** (sort-merge join, combine_keys, distinct,
  order/limit keys) gathers its sharded operand columns with explicit
  ``lax.all_gather`` collectives and reuses the jax backend's bucketed
  tail kernels on the gathered replicas, while **group_reduce** runs a
  genuinely distributed two-phase aggregation: per-shard partial
  aggregates over each shard's row chunk, combined across the mesh with
  ``lax.psum`` / ``lax.pmin`` / ``lax.pmax``.

Every collective is recorded in the ``ExchangeStats`` ledger
(``physical_spec``), the third sibling of ``TransferStats``/``KernelStats``
— together they prove the distributed residency contract: frontier
exchanges happen device-to-device (exchange events > 0, zero mid-plan
``d2h``) and the only host gather is the engine's single ``to_host`` at
delivery.

Row-order contract: the expansion writes each output value at its exact
global row-major offset (cumulative-degree position), so emission order is
identical to the single-device backends' and the v2 conformance suite
passes unchanged.

On CPU the mesh is host-count-faked
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before importing
jax) so tests and CI exercise the real collective lowering; shard counts
are clamped to the pow2 envelope of the devices actually present, so code
written against ``devices=8`` degrades to a 1-device mesh (collectives
over a world of 1) instead of failing where the flag is unset.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.physical_spec import (CostParams, PhysicalSpec,
                                      register_spec)
from repro.graphdb.jax_backend import JaxOperators, _pow2, _pow2_floor
from repro.graphdb.partition import CsrShards, partition_csr

# minimum pow2 capacity of the collective programs' padded shapes: keeps
# the compile universe bounded exactly like the jax backend's tail buckets
_MESH_MIN_BUCKET = 16


class ShardedOperators(JaxOperators):
    """Jax operator set re-based on a device mesh (see module docstring).

    Inherits the jax backend's array primitives, property gathers, int32
    staging envelope and transfer ledger; overrides the pattern operators
    (collective expansion/probing over partitioned CSRs) and the
    relational tail (explicit gather collectives + distributed
    aggregation).  Chains stay on the engine's per-hop loop
    (``supports_chains = False``): each hop is a collective program.
    """

    name = "sharded"
    supports_chains = False
    compiled = True

    def __init__(self, store, devices: int | None = None):
        super().__init__(store)
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec
        avail = len(jax.devices())
        want = avail if devices is None else max(1, min(int(devices), avail))
        self.n_shards = _pow2_floor(want)
        self.mesh = Mesh(np.array(jax.devices()[:self.n_shards]), ("data",))
        self._shard_map = shard_map
        self._P = PartitionSpec
        self._lax = jax.lax
        self._shards: dict[int, tuple[CsrShards, tuple]] = {}
        self._progs: dict[tuple, object] = {}

    # ------------------------------------------------------------- plumbing
    def _record_exchange(self, kind: str, label: str, elems: int, n: int = 1):
        for _ in range(n):
            self.exchange_stats.record(kind, label, elems)

    def _smap(self, fn, in_specs, out_specs):
        import jax
        # check_rep=False: psum/pmin/pmax outputs ARE replicated but the
        # static replication checker can't infer it through searchsorted/
        # while_loop bodies on this jax version
        return jax.jit(self._shard_map(fn, mesh=self.mesh,
                                       in_specs=in_specs,
                                       out_specs=out_specs,
                                       check_rep=False))

    def _prog(self, key: tuple, build):
        prog = self._progs.get(key)
        if prog is None:
            prog = self._progs[key] = build()
            self.kernel_stats.record("compile", key[0])
        return prog

    def _csr_shards(self, csr):
        """Partition + upload one CSR's stacked shard blocks (cached by
        CSR identity, like the jax backend's ``_csr_dev``)."""
        ent = self._shards.get(id(csr))
        if ent is None:
            sh = partition_csr(csr, self.n_shards)
            dev = (self._upload(sh.indptr), self._upload(sh.indices),
                   self._upload(sh.pos) if sh.pos is not None else None,
                   self._upload(sh.edge_base))
            ent = self._shards[id(csr)] = (sh, dev)
        return ent

    # -------------------------------------------------- collective expansion
    def _deg_prog(self, fcap: int, rps: int):
        jnp, lax, P = self._jnp, self._lax, self._P

        def kernel(rows, ip_blk):
            s = lax.axis_index("data")
            ipb = ip_blk[0]
            lr = rows - s * rps
            mine = (rows >= 0) & (lr >= 0) & (lr < rps)
            lrc = jnp.clip(lr, 0, rps - 1)
            d = (jnp.take(ipb, lrc + 1, axis=0, mode="clip")
                 - jnp.take(ipb, lrc, axis=0, mode="clip"))
            d = jnp.where(mine, d, 0)
            deg = lax.psum(d, "data")          # frontier degree exchange
            return deg, deg.sum(), deg.astype(jnp.float32).sum()

        return self._smap(kernel, (P(), P("data", None)), (P(), P(), P()))

    def _expand_prog(self, fcap: int, out_cap: int, rps: int, nnz_cap: int,
                     has_pos: bool):
        jnp, lax, P = self._jnp, self._lax, self._P
        i32 = jnp.int32

        def kernel(rows, deg, total, ip_blk, ix_blk, ps_blk, ebase):
            s = lax.axis_index("data")
            ipb, ixb = ip_blk[0], ix_blk[0]
            cum = jnp.cumsum(deg)
            j = jnp.arange(out_cap, dtype=i32)
            i = jnp.searchsorted(cum, j, side="right").astype(i32)
            ic = jnp.minimum(i, fcap - 1)
            off = j - jnp.take(cum - deg, ic, axis=0, mode="clip")
            row = jnp.take(rows, ic, axis=0, mode="clip")
            lr = row - s * rps
            mine = (j < total) & (row >= 0) & (lr >= 0) & (lr < rps)
            lrc = jnp.clip(lr, 0, rps - 1)
            flat = jnp.clip(jnp.take(ipb, lrc, axis=0, mode="clip") + off,
                            0, nnz_cap - 1)
            nbr = jnp.take(ixb, flat, axis=0, mode="clip")
            ep = (jnp.take(ps_blk[0], flat, axis=0, mode="clip") if has_pos
                  else ebase[0] + flat)
            # psum_scatter: combine owner-unique contributions AND leave
            # each device holding its contiguous chunk of the expansion
            sc = functools.partial(lax.psum_scatter, axis_name="data",
                                   scatter_dimension=0, tiled=True)
            return (sc(jnp.where(mine, ic, 0)),
                    sc(jnp.where(mine, nbr, 0)),
                    sc(jnp.where(mine, ep, 0)))

        in_specs = (P(), P(), P(), P("data", None), P("data", None),
                    P("data", None), P("data"))
        return self._smap(kernel, in_specs, (P("data"),) * 3)

    def expand(self, csr, rows_local, max_out=None):
        jnp = self._jnp
        rows = jnp.asarray(rows_local)
        R = rows.shape[0]
        z = jnp.zeros(0, jnp.int32)
        if R == 0:
            return z, z, z
        sh, (ip_d, ix_d, ps_d, eb_d) = self._csr_shards(csr)
        S, rps = self.n_shards, sh.rows_per_shard
        nnz_cap = sh.indices.shape[1]
        fcap = _pow2(R, _MESH_MIN_BUCKET)
        rows_p = self._pad(rows, fcap, -1)      # -1: owned by nobody
        dkey = ("sharded_deg", fcap, rps)
        deg, t0, tf0 = self._prog(dkey, lambda: self._deg_prog(fcap, rps))(
            rows_p, ip_d)
        self.kernel_stats.record("dispatch", "sharded_deg")
        self._record_exchange("psum", "expand_frontier", fcap)
        total = int(t0)                          # control-plane sync
        if float(tf0) > 2147483391.0:            # int32 sum wrapped
            raise RuntimeError(f"intermediate blow-up: expansion would "
                               f"produce ~{float(tf0):.3g} rows (beyond "
                               f"the int32 staging envelope)")
        if max_out is not None and total > max_out:
            raise RuntimeError(f"intermediate blow-up: expansion would "
                               f"produce {total} rows > cap {max_out}")
        if total == 0:
            return z, z, z
        out_cap = _pow2(total, max(_MESH_MIN_BUCKET, S))
        has_pos = ps_d is not None
        ekey = ("sharded_expand", fcap, out_cap, rps, nnz_cap, has_pos)
        prog = self._prog(ekey, lambda: self._expand_prog(
            fcap, out_cap, rps, nnz_cap, has_pos))
        ridx, nbr, ep = prog(rows_p, deg, jnp.asarray(total, jnp.int32),
                             ip_d, ix_d, ps_d if has_pos else ix_d, eb_d)
        self.kernel_stats.record("dispatch", "sharded_expand")
        self._record_exchange("psum_scatter", "expand_emit", out_cap, n=3)
        return ridx[:total], nbr[:total], ep[:total]

    # ---------------------------------------------------- collective probing
    def _probe_prog(self, rcap: int, rps: int, nnz_cap: int, has_pos: bool):
        jnp, lax, P = self._jnp, self._lax, self._P
        from repro.graphdb.jaxops import bounded_binary_search

        def kernel(rows, tgt, ip_blk, ix_blk, ps_blk, ebase):
            s = lax.axis_index("data")
            ipb, ixb = ip_blk[0], ix_blk[0]
            lr = rows - s * rps
            mine = (rows >= 0) & (lr >= 0) & (lr < rps)
            lrc = jnp.clip(lr, 0, rps - 1)
            lo = jnp.take(ipb, lrc, axis=0, mode="clip")
            hi = jnp.take(ipb, lrc + 1, axis=0, mode="clip")
            # -2 never matches a real id (>= 0): non-owned rows probe inert
            found, pos = bounded_binary_search(
                ixb, lo, hi, jnp.where(mine, tgt, -2))
            posc = jnp.clip(pos, 0, nnz_cap - 1).astype(jnp.int32)
            ep = (jnp.take(ps_blk[0], posc, axis=0, mode="clip") if has_pos
                  else ebase[0] + posc)
            hit = mine & found
            return (lax.psum(hit.astype(jnp.int32), "data"),
                    lax.psum(jnp.where(hit, ep, 0), "data"))

        in_specs = (P(), P(), P("data", None), P("data", None),
                    P("data", None), P("data"))
        return self._smap(kernel, in_specs, (P(), P()))

    def intersect(self, csr, rows_local, targets):
        jnp = self._jnp
        rows = jnp.asarray(rows_local)
        tgt = jnp.asarray(targets)
        R = rows.shape[0]
        if R == 0:
            return jnp.zeros(0, bool), jnp.zeros(0, jnp.int32)
        sh, (ip_d, ix_d, ps_d, eb_d) = self._csr_shards(csr)
        rps = sh.rows_per_shard
        nnz_cap = sh.indices.shape[1]
        rcap = _pow2(R, _MESH_MIN_BUCKET)
        has_pos = ps_d is not None
        key = ("sharded_probe", rcap, rps, nnz_cap, has_pos)
        prog = self._prog(key, lambda: self._probe_prog(rcap, rps, nnz_cap,
                                                        has_pos))
        f, ep = prog(self._pad(rows, rcap, -1), self._pad(tgt, rcap, -2),
                     ip_d, ix_d, ps_d if has_pos else ix_d, eb_d)
        self.kernel_stats.record("dispatch", "sharded_probe")
        self._record_exchange("psum", "probe", rcap, n=2)
        found = f[:R] > 0
        return found, jnp.where(found, ep[:R], 0)

    # ------------------------------------------------------- tail collectives
    def _gather_prog(self, padlen: int):
        lax, P = self._lax, self._P

        def kernel(x):
            return lax.all_gather(x, "data", tiled=True)

        return self._smap(kernel, (P("data"),), P())

    def _collect(self, label: str, arrays: list):
        """Gather sharded operand columns to mesh-wide replicas with an
        explicit (recorded) ``all_gather`` per column — the relational
        tail's exchange step."""
        jnp = self._jnp
        out = []
        for a in arrays:
            a = jnp.asarray(a)
            n = a.shape[0]
            if n == 0 or self.n_shards == 1:
                out.append(a)
                continue
            padlen = _pow2(n, max(_MESH_MIN_BUCKET, self.n_shards))
            key = ("sharded_gather", padlen, str(a.dtype))
            prog = self._prog(key, lambda: self._gather_prog(padlen))
            g = prog(self._pad(a, padlen))
            self.kernel_stats.record("dispatch", "sharded_gather")
            self._record_exchange("all_gather", label, padlen)
            out.append(g[:n])
        return out

    def join(self, lkeys, rkeys, max_out=None):
        lk, rk = self._collect("join", [lkeys, rkeys])
        return super().join(lk, rk, max_out=max_out)

    def combine_keys(self, cols: list):
        if len(cols) <= 1:
            return super().combine_keys(cols)
        return super().combine_keys(self._collect("combine_keys", cols))

    def lexsort(self, cols: list):
        return super().lexsort(self._collect("order", cols))

    def distinct_indices(self, key):
        return super().distinct_indices(self._collect("distinct", [key])[0])

    # ------------------------------------------- distributed group aggregation
    def _groupagg_prog(self, npad: int, ng_cap: int, fns: tuple,
                       dtypes: tuple):
        import jax
        jnp, lax, P = self._jnp, self._lax, self._P

        def kernel(gids, rowidx, *cols):
            seg = functools.partial(jax.ops.segment_sum,
                                    num_segments=ng_cap)
            cnt = lax.psum(seg(jnp.ones_like(gids), gids), "data")
            first = lax.pmin(
                jax.ops.segment_min(rowidx, gids, num_segments=ng_cap),
                "data")
            outs = [first, cnt]
            for fn, c in zip(fns, cols):
                if fn == "COUNT":
                    outs.append(cnt)
                elif fn == "SUM":
                    outs.append(lax.psum(seg(c, gids), "data"))
                elif fn == "AVG":
                    s = lax.psum(seg(c.astype(jnp.float32), gids), "data")
                    outs.append(s / jnp.maximum(cnt, 1))
                elif fn == "MIN":
                    outs.append(lax.pmin(
                        jax.ops.segment_min(c, gids, num_segments=ng_cap),
                        "data"))
                else:                                       # MAX
                    outs.append(lax.pmax(
                        jax.ops.segment_max(c, gids, num_segments=ng_cap),
                        "data"))
            return tuple(outs)

        in_specs = (P("data"),) * (2 + len(fns))
        return self._smap(kernel, in_specs, (P(),) * (2 + len(fns)))

    def group_reduce(self, keys, values):
        """Two-phase distributed aggregation: group identities are resolved
        once on gathered keys (ascending-key group ids, exactly the
        single-device backends' group order), then every shard reduces its
        own chunk of the value rows into per-group partials and the mesh
        combines them — ``psum`` for COUNT/SUM/AVG, ``pmin``/``pmax`` for
        MIN/MAX and the first-row index.  Row membership never moves; only
        ``O(n_groups)`` partials cross the mesh per shard."""
        jnp = self._jnp
        keys = jnp.asarray(keys)
        n = keys.shape[0]
        if n == 0:
            z = jnp.zeros(0, jnp.int32)
            return z, {name: z for name in values}
        bad = [fn for fn, _ in values.values()
               if fn not in ("COUNT", "SUM", "AVG", "MIN", "MAX")]
        if bad:
            raise ValueError(f"unknown aggregate {bad[0]}")
        keys_g = self._collect("group_keys", [keys])[0]
        np2 = _pow2(n, _MESH_MIN_BUCKET)
        self._tail_compile("group", (np2,))
        self.kernel_stats.record("dispatch", "group")
        order, vstart, _flag_order, ng0 = \
            self._jaxops.group_boundaries_padded(self._pad(keys_g, np2), n)
        ng = int(ng0)                                # control-plane sync
        # ascending-rank group id per original row: cumsum over the sorted
        # domain carried back through the inverse permutation
        gid_sorted = jnp.cumsum(vstart.astype(jnp.int32)) - 1
        gids = jnp.take(gid_sorted, jnp.argsort(order), axis=0,
                        mode="clip")[:n]
        ng_cap = _pow2(ng + 1, _MESH_MIN_BUCKET)
        S = self.n_shards
        npad = _pow2(n, max(_MESH_MIN_BUCKET, S))
        names = list(values)
        fns = tuple(values[nm][0] for nm in names)
        cols = [jnp.asarray(values[nm][1]) for nm in names]
        dtypes = tuple(str(c.dtype) for c in cols)
        key = ("sharded_group", npad, ng_cap, fns, dtypes)
        prog = self._prog(key, lambda: self._groupagg_prog(npad, ng_cap,
                                                           fns, dtypes))
        # pads land in the dummy top group slot (ng_cap-1 >= ng) and their
        # row index pads high, so no real group's partials see them
        args = [self._pad(gids, npad, ng_cap - 1),
                self._pad(jnp.arange(n, dtype=jnp.int32), npad, npad)]
        args += [self._pad(c, npad) for c in cols]
        out = prog(*args)
        self.kernel_stats.record("dispatch", "sharded_group")
        n_sum = sum(1 for fn in fns if fn in ("COUNT", "SUM", "AVG"))
        self._record_exchange("psum", "group_reduce", ng_cap, n=1 + n_sum)
        n_min = 1 + sum(1 for fn in fns if fn == "MIN")
        self._record_exchange("pmin", "group_reduce", ng_cap, n=n_min)
        n_max = sum(1 for fn in fns if fn == "MAX")
        if n_max:
            self._record_exchange("pmax", "group_reduce", ng_cap, n=n_max)
        first = out[0][:ng]
        return first, {nm: o[:ng] for nm, o in zip(names, out[2:])}


# --------------------------------------------------------------------------
# registration
# --------------------------------------------------------------------------

# alpha_scan/expand/intersect/join carry over from the jax calibration
# (benchmarks/calibrate_costs.py — same kernels do the local work);
# alpha_exchange is an uncalibrated CPU-faked-mesh placeholder: it prices
# each operator's frontier collective at a few local-work units so the CBO
# visibly trades communication against intersection work.  Re-calibrate on
# a real interconnect (ROADMAP).
SHARDED_COST = CostParams(alpha_scan=1.0, alpha_expand=5.3,
                          alpha_intersect=34.0, alpha_join=1.0,
                          alpha_exchange=2.0)

SHARDED_SPEC = register_spec(PhysicalSpec(
    name="sharded",
    make_operators=ShardedOperators,
    cost=SHARDED_COST,
    description=("mesh-partitioned CSR shards with collective "
                 "(shard_map) expansion/probing, gather-exchanged tail "
                 "kernels and psum-combined aggregation; exchanges "
                 "recorded in ExchangeStats (DESIGN.md §10)"),
))

_DEVICE_SPECS: dict[int, PhysicalSpec] = {}


def sharded_spec(devices: int | None = None) -> PhysicalSpec:
    """The sharded backend's spec pinned to an explicit shard count
    (``GOpt(store, backend="sharded", devices=8)``).  Each count gets its
    own registered spec name (``sharded[8]``) so plan caches and the
    per-store operator cache never mix shard layouts; ``devices=None`` is
    the auto spec over every local device."""
    if devices is None:
        return SHARDED_SPEC
    devices = int(devices)
    spec = _DEVICE_SPECS.get(devices)
    if spec is None:
        spec = PhysicalSpec(
            name=f"sharded[{devices}]",
            make_operators=functools.partial(ShardedOperators,
                                             devices=devices),
            cost=SHARDED_COST,
            description=SHARDED_SPEC.description +
            f" (pinned to {devices} shards)")
        register_spec(spec)
        _DEVICE_SPECS[devices] = spec
    return spec
