"""Brute-force pattern-matching oracle (tests only).

Backtracking homomorphism enumeration over the GraphStore; counts
(vertex-binding x edge-binding) matches exactly like the engine. Exponential —
use on small graphs only.
"""
from __future__ import annotations

import numpy as np

from repro.core.pattern import BOTH, IN, OUT, Pattern
from repro.graphdb.storage import GraphStore


def _edge_multiplicity(store: GraphStore, e, su: int, sv: int) -> int:
    """Number of data-edge bindings for pattern edge e when its (src,dst)
    pattern vertices are assigned data vertices (su, sv)."""
    count = 0
    orientations = []
    if e.direction in (OUT, BOTH):
        orientations.append((su, sv))
    if e.direction in (IN, BOTH):
        orientations.append((sv, su))
    for (a, b) in orientations:
        for t in e.triples:
            lo_a, hi_a = store.type_range(t.src)
            lo_b, hi_b = store.type_range(t.dst)
            if not (lo_a <= a < hi_a and lo_b <= b < hi_b):
                continue
            csr = store.out_csr[t]
            s, epos = csr.indptr[a - lo_a], csr.indptr[a - lo_a + 1]
            row = csr.indices[s:epos]
            j = np.searchsorted(row, b)
            if j < row.shape[0] and row[j] == b:
                count += 1
    return count


def count_matches(store: GraphStore, pattern: Pattern,
                  vertex_filter=None) -> int:
    """Total homomorphism count (with edge bindings) of pattern in store.
    ``vertex_filter(alias, np_ids) -> mask`` optionally restricts candidates.
    """
    aliases = sorted(pattern.vertices)
    # candidates per alias
    cand: dict[str, np.ndarray] = {}
    for a in aliases:
        ids = []
        for t in sorted(pattern.vertices[a].types):
            lo, hi = store.type_range(t)
            ids.append(np.arange(lo, hi, dtype=np.int64))
        c = np.concatenate(ids) if ids else np.zeros(0, np.int64)
        if vertex_filter is not None:
            c = c[vertex_filter(a, c)]
        cand[a] = c
    order = sorted(aliases, key=lambda a: cand[a].shape[0])

    total = 0
    assign: dict[str, int] = {}

    def rec(i: int, mult: int):
        nonlocal total
        if i == len(order):
            total += mult
            return
        a = order[i]
        for v in cand[a]:
            assign[a] = int(v)
            m = mult
            ok = True
            for e in pattern.edges:
                if a not in (e.src, e.dst):
                    continue
                o = e.other(a)
                if o not in assign:
                    continue
                su = assign[e.src] if e.src in assign else None
                sv = assign[e.dst] if e.dst in assign else None
                k = _edge_multiplicity(store, e, su, sv)
                if k == 0:
                    ok = False
                    break
                m *= k
            if ok:
                rec(i + 1, m)
            del assign[a]

    rec(0, 1)
    return total
