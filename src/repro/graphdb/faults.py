"""Deterministic fault injection at operator boundaries (DESIGN.md §13.1).

A ``FaultPlan`` is a seeded schedule of failures; ``faulty_spec`` wraps any
registered backend (numpy, jax, sharded) in a ``FaultyOperatorSet`` that
consults the plan before delegating each operator call.  The wrapper is a
fully conforming ``OperatorSet`` — with no armed rules it passes the
OperatorSet-v2 conformance suite verbatim for whatever backend it wraps —
so the serving stack runs unmodified against it and the chaos harness
(``scripts/chaos_smoke.py``) can prove containment end to end.

Fault kinds:

``transient``
    raises ``InjectedFault(kind="transient")`` — a flake a bounded retry
    clears (the rule's ``count`` bounds how many calls fire).
``permanent``
    raises ``InjectedFault(kind="permanent")`` — retrying cannot help; the
    serving layer must fail/quarantine the offending binding or degrade.
``capacity``
    raises ``InjectedFault(kind="transient")`` flavored as a simulated
    capacity overflow (oversized intermediate); retryable by contract.
``latency``
    sleeps ``latency_s`` at the boundary, then delegates — for exercising
    the engine's cooperative deadline checks.

Determinism: rules fire on exact per-operator call counts (``after`` /
``count``) or via a ``random.Random(seed)`` coin (``p``); the same plan on
the same stream injects the same schedule.  Every injection is recorded on
the wrapper's ``FaultStats`` ledger (``physical_spec.FaultStats``), the
fourth sibling of the transfer/kernel/exchange ledgers.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
import time

from repro.core.errors import ExecError
from repro.core.physical_spec import (ARRAY_PRIMITIVES, REQUIRED_OPERATORS,
                                      FaultStats, OperatorSet, PhysicalSpec,
                                      get_spec)

__all__ = ["FaultRule", "FaultPlan", "InjectedFault", "FaultyOperatorSet",
           "faulty_spec"]

#: operator boundaries the wrapper injects at: the six required operators,
#: the fused-chain dispatch (so chain-level faults can demote the
#: degradation ladder to the per-hop loop), and the engine's ``bind``
#: boundary — the one point where parameter binding *values* are visible
#: below the engine, so ``FaultRule(value=...)`` can poison one binding.
FAULT_POINTS = REQUIRED_OPERATORS + ("chain", "bind")


class InjectedFault(ExecError):
    """A failure raised by a ``FaultPlan`` at an operator boundary.  Carries
    the standard ``ExecError`` context (kind / operator / phase)."""


@dataclasses.dataclass
class FaultRule:
    """One entry in a ``FaultPlan``'s schedule.

    ``op`` names the boundary (one of ``FAULT_POINTS``, or ``"*"`` for
    any).  The rule arms after the boundary's ``after``-th matching call
    and fires on the next ``count`` calls (``count=None`` -> forever).
    Alternatively ``p`` fires with seeded probability per call.  ``value``
    restricts the rule to calls whose scalar arguments contain ``value`` —
    a deterministic way to poison one *binding* (parameter values reach
    operators like ``full``/``isin`` as scalars), not just one call index.
    """
    op: str = "*"
    kind: str = "transient"         # transient | permanent | capacity | latency
    after: int = 0
    count: int | None = 1
    p: float = 0.0
    latency_s: float = 0.0
    value: object = None

    def __post_init__(self):
        if self.kind not in ("transient", "permanent", "capacity", "latency"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.op != "*" and self.op not in FAULT_POINTS \
                and self.op not in ARRAY_PRIMITIVES:
            raise ValueError(f"unknown fault point {self.op!r}; "
                             f"expected one of {FAULT_POINTS}, an array "
                             f"primitive, or '*'")


class FaultPlan:
    """Seeded, deterministic injection schedule over operator boundaries.

    One plan instance carries mutable per-rule counters, so it must wrap
    exactly one operator set at a time (``faulty_spec`` enforces a fresh
    spec name per plan).  ``fired`` counts total injections; ``reset()``
    rewinds the schedule to replay it.
    """

    def __init__(self, rules: list[FaultRule] | tuple = (), seed: int = 0):
        self.rules = list(rules)
        self.seed = seed
        self._rng = random.Random(seed)
        self._calls: dict[tuple[int, str], int] = {}   # (rule_idx, op) -> n
        self._fired: dict[int, int] = {}               # rule_idx -> n fired
        self.fired = 0

    def reset(self):
        self._rng = random.Random(self.seed)
        self._calls.clear()
        self._fired.clear()
        self.fired = 0

    def _matches_value(self, rule: FaultRule, scalars) -> bool:
        if rule.value is None:
            return True
        return any(s == rule.value for s in scalars)

    def check(self, op: str, scalars=(),
              wildcard: bool = True) -> FaultRule | None:
        """Advance the schedule for one call at boundary ``op`` and return
        the rule that fires, if any (first matching rule wins).
        ``wildcard=False`` (primitive boundaries) matches only rules that
        name ``op`` explicitly — ``"*"`` covers the logical operators."""
        for i, rule in enumerate(self.rules):
            if rule.op != op and (rule.op != "*" or not wildcard):
                continue
            if not self._matches_value(rule, scalars):
                continue
            key = (i, rule.op if rule.op != "*" else op)
            n = self._calls.get(key, 0)
            self._calls[key] = n + 1
            if rule.p > 0.0:
                if self._rng.random() >= rule.p:
                    continue
            elif n < rule.after:
                continue
            if rule.count is not None and self._fired.get(i, 0) >= rule.count:
                continue
            self._fired[i] = self._fired.get(i, 0) + 1
            self.fired += 1
            return rule
        return None


def _scalar_args(args) -> tuple:
    """The plain-scalar positional arguments of an operator call — the
    hook ``FaultRule.value`` matches against (binding parameters surface
    here via ``full(n, value)`` / ``searchsorted`` probes)."""
    return tuple(a for a in args if isinstance(a, (int, float, str, bool)))


class _FaultyChainProgram:
    """Chain-program proxy: delegates to the wrapped backend's compiled
    program, injecting at the ``chain`` boundary on each ``run``."""

    def __init__(self, prog, owner: "FaultyOperatorSet"):
        self._prog = prog
        self._owner = owner

    def ready(self) -> bool:
        return self._prog.ready()

    def observe(self, hop_sizes):
        return self._prog.observe(hop_sizes)

    def run(self, src_col, nrows, scalars, value_lists, max_rows):
        self._owner._boundary("chain", tuple(scalars))
        return self._prog.run(src_col, nrows, scalars, value_lists, max_rows)

    def __getattr__(self, name):
        return getattr(self._prog, name)


class FaultyOperatorSet(OperatorSet):
    """Conforming wrapper around any ``OperatorSet`` that injects a
    ``FaultPlan`` at operator boundaries.

    Transfer/kernel/exchange ledgers are the *inner* set's (so residency
    and compile accounting flow through unchanged); the fault ledger is the
    wrapper's own.  All required operators and array primitives are defined
    on this class (delegators installed below) so
    ``validate_operator_set``'s defined-on-the-class check passes.
    """

    def __init__(self, inner: OperatorSet, plan: FaultPlan, name: str):
        # no super().__init__: ledgers delegate to the wrapped set
        self.inner = inner
        self.plan = plan
        self.store = inner.store
        self.name = name
        self.supports_chains = inner.supports_chains
        self.compiled = inner.compiled
        self.fault_stats = FaultStats()

    # shared ledgers -------------------------------------------------------
    @property
    def transfer_stats(self):
        return self.inner.transfer_stats

    @property
    def kernel_stats(self):
        return self.inner.kernel_stats

    @property
    def exchange_stats(self):
        return self.inner.exchange_stats

    def reset_ledgers(self):
        self.inner.reset_ledgers()
        self.fault_stats.reset()

    # injection ------------------------------------------------------------
    def _boundary(self, op: str, scalars=(), wildcard: bool = True):
        rule = self.plan.check(op, scalars, wildcard)
        if rule is None:
            return
        self.fault_stats.record(rule.kind, op)
        phase = self.inner.transfer_stats.phase or None
        if rule.kind == "latency":
            time.sleep(rule.latency_s)
            return
        if rule.kind == "capacity":
            raise InjectedFault(
                f"injected capacity overflow at {op!r}", kind="transient",
                operator=op, phase=phase)
        raise InjectedFault(f"injected {rule.kind} fault at {op!r}",
                            kind=rule.kind, operator=op, phase=phase)

    def binding_boundary(self, binding: dict | None):
        """Engine hook (``Engine._offer_bindings``): one call per parameter
        binding at execution start.  Matches only rules that name ``"bind"``
        explicitly — a wildcard firing here would fail every execution
        before its first operator."""
        scalars = _scalar_args(tuple((binding or {}).values()))
        self._boundary("bind", scalars, wildcard=False)

    # capabilities ---------------------------------------------------------
    def chain_program(self, spec):
        prog = self.inner.chain_program(spec)
        if prog is None:
            return None
        return _FaultyChainProgram(prog, self)

    def pin_chain(self, spec, pinned: bool = True) -> bool:
        return self.inner.pin_chain(spec, pinned)

    def block_ready(self, arrays):
        return self.inner.block_ready(arrays)


def _delegator(name: str, inject: bool, wildcard: bool = True):
    def method(self, *args, **kwargs):
        if inject:
            self._boundary(name, _scalar_args(args), wildcard)
        return getattr(self.inner, name)(*args, **kwargs)
    method.__name__ = name
    method.__qualname__ = f"FaultyOperatorSet.{name}"
    method.__doc__ = (f"Delegates to the wrapped set's ``{name}``"
                      + (", after the fault boundary." if inject else "."))
    return method


# install explicit delegators: required operators pass through the fault
# boundary, and ``"*"`` rules match them; array primitives pass through too
# but only fire rules that *name* them (``"*"`` on take/mask/... would fire
# inside fused programs unpredictably across backends) — naming a primitive
# like ``full`` is how a rule poisons one binding value deterministically.
for _n in REQUIRED_OPERATORS:
    setattr(FaultyOperatorSet, _n, _delegator(_n, inject=True))
for _n in ARRAY_PRIMITIVES:
    setattr(FaultyOperatorSet, _n, _delegator(_n, inject=True,
                                              wildcard=False))
for _n in ("_array_to_host", "vertex_prop", "edge_prop"):
    setattr(FaultyOperatorSet, _n, _delegator(_n, inject=False))
del _n

_SPEC_IDS = itertools.count()


def faulty_spec(backend: str | PhysicalSpec, plan: FaultPlan,
                name: str | None = None) -> PhysicalSpec:
    """A ``PhysicalSpec`` wrapping ``backend``'s operator set in ``plan``.

    The spec gets a unique name (operator-set caches and plan caches are
    keyed by spec name, so two fault plans never share a wrapper) and is
    *not* registered globally — pass the spec object itself wherever a
    backend is accepted (``GOpt.prepare(backend=...)``,
    ``QueryServer(backend=...)``).
    """
    base = get_spec(backend)
    if name is None:
        name = f"faulty:{base.name}:{next(_SPEC_IDS)}"

    def make(store, _base=base, _plan=plan, _name=name):
        return FaultyOperatorSet(_base.operators(store), _plan, _name)

    return PhysicalSpec(name=name, make_operators=make, cost=base.cost,
                        description=f"fault-injecting wrapper over "
                                    f"{base.name!r} ({len(plan.rules)} rules)",
                        physical_rules=base.physical_rules)
