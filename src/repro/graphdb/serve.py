"""QueryServer — continuous batching for prepared graph queries (DESIGN.md §9).

The graph twin of the LM slot scheduler in ``repro.serve.engine``: where the
LM engine coalesces decode steps of whatever requests currently occupy its
slot pool, the QueryServer coalesces *queries that share a cached plan* into
``Engine.run_batch`` waves.  Requests are admitted into per-plan queues
keyed by the canonical plan-cache key (``PreparedQuery.cache_key``); wave
formation takes the queue with the oldest waiting request (FIFO fairness
across plans) and coalesces up to ``max_wave`` requests, rounding the wave
size down to a power of two while the queue still has a remainder — so a
warmed server's recurring wave sizes land on the same pow2 capacity buckets
the backend's compiled programs (fused chains, bucketed tail kernels) are
keyed by, re-hitting the compile cache instead of thrashing it.

Scheduling/latency mechanics:

- **admission control** — the total pending queue is bounded
  (``max_pending``); ``submit`` raises ``ServeOverload`` when full
  (backpressure, counted in ``ServeStats.rejected``).  Parameter bindings
  are validated at admission (host-side), so a malformed request is
  rejected before it ever occupies a wave slot.
- **deadline drop** — a request carrying ``deadline_s`` that expires before
  its wave forms is dropped at formation time (``ServeStats.dropped``),
  never dispatched.
- **overlap** — with ``overlap=True`` waves execute on a single worker
  thread: while wave *k* runs its device program, the main thread admits,
  validates, and forms wave *k+1* (every backend/array call stays on the
  one worker thread; host-side bookkeeping stays on the caller's thread).
- **duplicate suppression** — identical bindings within a wave execute
  once and fan the result out (hot-key traffic makes these common), so a
  wave's device cost scales with its *distinct* bindings.
- **hotness LRU** — per-plan hit counts keep the ``hot_plans`` hottest
  plans pinned: their plan-cache entries are LRU-touched and their fused
  chains' compiled programs are protected from backend cache eviction
  (``OperatorSet.pin_chain``), so a burst of cold plans cannot evict a hot
  plan's warmed programs.
- **ledger scoping** — both backend instrumentation ledgers
  (``TransferStats`` / ``KernelStats``) are reset at each wave start
  (``OperatorSet.reset_ledgers``): one request's PROFILE window can never
  report a neighboring wave's dispatches or transfers, and the ledgers
  stay bounded under sustained traffic.

Failure containment (DESIGN.md §13): every wave executes under a
containment boundary.  A failed wave is bisected to isolate the poison
binding (healthy co-batched requests still succeed), transient failures
retry with capped exponential backoff, repeat-offender bindings are
quarantined at admission, and a per-(plan, backend) circuit breaker walks
the graceful-degradation ladder — fused-chain dispatch -> per-hop loop ->
``fallback_spec`` (numpy) — on persistent failures, with half-open probes
to step back up.  Failed requests terminate with ``status="failed"`` and a
structured ``ExecError``; under overlap the worker is supervised (a crash
respawns the pool and re-forms the in-flight wave exactly once).  No
admitted request ever ends without a terminal status: done / failed /
dropped / cancelled.

``ServeStats`` is the serving ledger — wave sizes, batch occupancy, queue
delay vs execution time, fallback-to-loop counts, per-wave compile counts,
failure/retry/degradation counters — and surfaces through the existing
EXPLAIN/PROFILE reporting: ``QueryServer.explain(query)`` attaches the
plan's serving summary to the ``ExplainReport`` (rendered as a
``-- serve --`` section).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import ThreadPoolExecutor

from repro.core.errors import (DeadlineExceeded, ExecError, ParamError,
                               classify_error)
from repro.core.gopt import _freeze


def _pow2(n: int, floor: int = 1) -> int:
    return max(floor, 1 << max(int(n) - 1, 0).bit_length())


def _pow2_floor(n: int) -> int:
    return 1 << (max(int(n), 1).bit_length() - 1)


def _percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) without numpy."""
    if not values:
        return 0.0
    s = sorted(values)
    idx = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return float(s[idx])


class ServeOverload(RuntimeError):
    """Admission rejected: the bounded pending queue is full."""


class ServeQuarantined(RuntimeError):
    """Admission rejected: this exact (plan, binding) pair failed
    permanently ``quarantine_after`` times and is quarantined — resubmitting
    it would poison another wave (counted in ``ServeStats.quarantined``)."""


# the update stream's queue key: writes ride the same admission path and
# FIFO-fair wave formation as reads, on a dedicated queue
_WRITE_KEY = ("__update__",)
_WRITE_KINDS = ("insert_vertex", "insert_edge",
                "delete_vertex", "delete_edge")


@dataclasses.dataclass
class ServeRequest:
    """One admitted query request and its lifecycle record."""
    rid: int
    prepared: object                 # PreparedQuery (None for updates)
    params: dict | None
    arrival_s: float                 # perf_counter-domain arrival time
    deadline_s: float | None = None  # absolute; expired requests are dropped
    status: str = "pending"   # pending | done | dropped | failed | cancelled
    table: object | None = None
    stats: object | None = None      # ExecStats of this request's execution
    error: object | None = None      # structured ExecError when failed
    # worker-supervision marker: set when this request's wave was re-formed
    # after a worker crash — a second crash fails it instead of re-executing
    respawned: bool = False
    start_s: float = 0.0             # wave execution start
    finish_s: float = 0.0
    kind: str = "query"              # query | update
    update: tuple | None = None      # (mutation name, args, kwargs)
    result: object | None = None     # mutation return value (updates)
    # MVCC-lite: the store snapshot pinned at admission — this request
    # answers as-of its admission version no matter when its wave runs
    snapshot: object | None = None
    snap_version: int = -1

    @property
    def queue_delay_s(self) -> float:
        return max(0.0, self.start_s - self.arrival_s)

    @property
    def latency_s(self) -> float:
        return max(0.0, self.finish_s - self.arrival_s)


class ServeStats:
    """The serving ledger: wave shapes, latency decomposition, drops."""

    def __init__(self):
        self.submitted = 0
        self.completed = 0
        self.rejected = 0          # backpressure (ServeOverload)
        self.dropped = 0           # deadline drops (formation + mid-wave)
        self.deduped = 0           # duplicate bindings suppressed in waves
        self.writes = 0            # applied mutations (update stream)
        # containment counters (DESIGN.md §13)
        self.failed = 0            # requests terminated status="failed"
        self.cancelled = 0         # still-queued requests rejected at close()
        self.retries = 0           # transient retry attempts (all waves)
        self.bisections = 0        # failed-wave splits while isolating poison
        self.quarantined = 0       # admissions rejected by quarantine
        self.deadline_aborts = 0   # mid-execution cooperative deadline aborts
        self.worker_respawns = 0   # overlap-worker crashes survived
        self.breaker_trips = 0     # degradation-ladder steps down
        self.breaker_recoveries = 0  # half-open probes that stepped back up
        self.breaker_probes = 0    # half-open probes attempted
        self.waves = 0
        self.wave_sizes: list[int] = []
        # wave size / its pow2 capacity bucket — 1.0 means the wave exactly
        # fills the bucket its compiled programs are keyed by
        self.occupancy: list[float] = []
        self.queue_delay_s: list[float] = []   # per completed request
        self.exec_s: list[float] = []          # per wave
        self.latency_s: list[float] = []       # per completed request
        self.fallbacks: dict[str, int] = {}    # engine fallback counters
        # per-wave compile-event counts from the (wave-scoped) KernelStats
        # window — a warmed server holds these flat at zero
        self.wave_compiles: list[int] = []
        self.wave_chain_compiles: list[int] = []
        self.per_plan: dict = {}               # cache_key -> summary dict

    # ------------------------------------------------------------ recording
    def _plan(self, key) -> dict:
        return self.per_plan.setdefault(key, {
            "waves": 0, "requests": 0, "failed": 0, "queue_delay_s": [],
            "exec_s": [], "fallbacks": {}, "compiles": 0})

    def record_wave(self, key, reqs, bucket: int, exec_s: float,
                    kernels: dict | None):
        self.waves += 1
        self.wave_sizes.append(len(reqs))
        self.occupancy.append(len(reqs) / max(bucket, 1))
        self.exec_s.append(exec_s)
        kernels = kernels or {}
        compiles = sum(v for k, v in kernels.items()
                       if k.startswith("compile:"))
        self.wave_compiles.append(compiles)
        self.wave_chain_compiles.append(kernels.get("compile:fused_chain", 0))
        plan = self._plan(key)
        plan["waves"] += 1
        plan["exec_s"].append(exec_s)
        plan["compiles"] += compiles
        for r in reqs:
            if r.status != "done":
                # failed/dropped mid-wave: terminal accounting happened at
                # marking time; only completions feed the latency ledgers
                continue
            self.completed += 1
            self.queue_delay_s.append(r.queue_delay_s)
            self.latency_s.append(r.latency_s)
            plan["requests"] += 1
            plan["queue_delay_s"].append(r.queue_delay_s)
            for reason, n in (getattr(r.stats, "fallbacks", None) or {}).items():
                self.fallbacks[reason] = self.fallbacks.get(reason, 0) + n
                pf = plan["fallbacks"]
                pf[reason] = pf.get(reason, 0) + n

    def record_failure(self, key):
        self.failed += 1
        self._plan(key)["failed"] += 1

    # ------------------------------------------------------------- summaries
    def summary(self) -> dict:
        n_w = max(self.waves, 1)
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "dropped": self.dropped,
            "deduped": self.deduped,
            "writes": self.writes,
            "waves": self.waves,
            "mean_wave_size": sum(self.wave_sizes) / n_w,
            "mean_occupancy": sum(self.occupancy) / n_w,
            "queue_delay_p50_ms": _percentile(self.queue_delay_s, 50) * 1e3,
            "queue_delay_p99_ms": _percentile(self.queue_delay_s, 99) * 1e3,
            "exec_p50_ms": _percentile(self.exec_s, 50) * 1e3,
            "latency_p50_ms": _percentile(self.latency_s, 50) * 1e3,
            "latency_p99_ms": _percentile(self.latency_s, 99) * 1e3,
            "fallbacks": dict(self.fallbacks),
            "compiles_per_wave": list(self.wave_compiles),
            "failed": self.failed,
            "cancelled": self.cancelled,
            "retries": self.retries,
            "bisections": self.bisections,
            "quarantined": self.quarantined,
            "deadline_aborts": self.deadline_aborts,
            "worker_respawns": self.worker_respawns,
            "breaker_trips": self.breaker_trips,
            "breaker_recoveries": self.breaker_recoveries,
            "breaker_probes": self.breaker_probes,
        }

    def plan_summary(self, key) -> dict:
        """Per-plan serving section for ``ExplainReport.serve``."""
        plan = self.per_plan.get(key)
        if plan is None:
            return {"waves": 0, "requests": 0}
        n_w = max(plan["waves"], 1)
        return {
            "waves": plan["waves"],
            "requests": plan["requests"],
            "failed": plan["failed"],
            "mean_wave_size": round(plan["requests"] / n_w, 2),
            "queue_delay_p50_ms":
                round(_percentile(plan["queue_delay_s"], 50) * 1e3, 3),
            "queue_delay_p99_ms":
                round(_percentile(plan["queue_delay_s"], 99) * 1e3, 3),
            "exec_p50_ms": round(_percentile(plan["exec_s"], 50) * 1e3, 3),
            "fallbacks": dict(plan["fallbacks"]),
            "compiles": plan["compiles"],
        }

    def render(self) -> str:
        s = self.summary()
        lines = [
            f"ServeStats: {s['completed']}/{s['submitted']} completed over "
            f"{s['waves']} waves "
            f"(rejected={s['rejected']}, dropped={s['dropped']}, "
            f"deduped={s['deduped']})",
            f"  wave size mean={s['mean_wave_size']:.1f} "
            f"occupancy={s['mean_occupancy']:.2f}",
            f"  queue delay p50={s['queue_delay_p50_ms']:.2f}ms "
            f"p99={s['queue_delay_p99_ms']:.2f}ms | "
            f"exec p50={s['exec_p50_ms']:.2f}ms",
            f"  latency p50={s['latency_p50_ms']:.2f}ms "
            f"p99={s['latency_p99_ms']:.2f}ms",
            f"  fallbacks={s['fallbacks'] or '{}'} "
            f"compiles/wave={s['compiles_per_wave']}",
            f"  containment: failed={s['failed']} retries={s['retries']} "
            f"bisections={s['bisections']} quarantined={s['quarantined']} "
            f"cancelled={s['cancelled']} deadline_aborts="
            f"{s['deadline_aborts']}",
            f"  breaker: trips={s['breaker_trips']} "
            f"recoveries={s['breaker_recoveries']} "
            f"probes={s['breaker_probes']} "
            f"respawns={s['worker_respawns']}",
        ]
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()


class QueryServer:
    """Continuous-batching service over a ``GOpt`` (DESIGN.md §9).

    >>> srv = gopt.serve(max_wave=32)
    >>> reqs = [srv.submit(Q, {"pid": p}) for p in people]
    >>> srv.drain()
    >>> reqs[0].table, reqs[0].stats
    """

    def __init__(self, gopt, backend=None, max_pending: int = 1024,
                 max_wave: int = 64, hot_plans: int = 4,
                 overlap: bool = True, bucket_waves: bool = True,
                 pad_waves: bool | None = None, containment: bool = True,
                 max_retries: int = 2, retry_backoff_s: float = 0.005,
                 quarantine_after: int = 2, breaker_threshold: int = 3,
                 probe_after: int = 2, fallback_spec="numpy", **exec_kw):
        self.gopt = gopt
        self.backend = backend
        self.max_pending = max_pending
        self.max_wave = max_wave
        self.hot_plans = hot_plans
        self.bucket_waves = bucket_waves
        # None = auto: pad executed batches to pow2 on compiling backends
        self.pad_waves = pad_waves
        # failure containment (DESIGN.md §13): containment=False restores
        # the uncontained execution path (exceptions escape the wave) — the
        # perf harness's baseline for measuring containment overhead
        self.containment = containment
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.quarantine_after = quarantine_after
        self.breaker_threshold = breaker_threshold
        self.probe_after = probe_after
        # the degradation ladder's last rung: any backend name/spec — the
        # plain host interpreter by default
        self.fallback_spec = fallback_spec
        self.exec_kw = exec_kw
        self.stats = ServeStats()
        self._queues: "OrderedDict[tuple, deque[ServeRequest]]" = OrderedDict()
        self._plans: dict = {}            # cache_key -> PreparedQuery
        self._hot: dict = {}              # cache_key -> hit count
        self._samples: dict = {}          # cache_key -> a recent binding
        self._pinned: set = set()         # cache_keys currently pinned
        self._pending = 0
        self._rid = 0
        self._inflight = None             # (future, key, reqs) under overlap
        self._lock = threading.Lock()     # guards the gopt plan-cache LRU
        # admission lock: submit()/submit_update() may be called from many
        # client threads, so queue/pending/rid mutations are serialized
        # against each other and against wave formation; worker-side code
        # never takes it (R3: the worker never touches admission state)
        self._alock = threading.Lock()
        # containment state: (cache_key, frozen binding) -> permanent-failure
        # count (quarantine), cache_key -> circuit-breaker ladder state
        self._offenders: dict = {}
        self._breakers: dict = {}
        self._pool = (ThreadPoolExecutor(max_workers=1,
                                         thread_name_prefix="serve-wave")
                      if overlap else None)

    # ------------------------------------------------------------- admission
    def submit(self, query, params: dict | None = None,
               deadline_s: float | None = None,
               arrival_s: float | None = None) -> ServeRequest:
        """Admit one request: resolve the plan through the prepared-plan
        cache, validate its bindings host-side, and enqueue it on its
        plan's queue.  ``deadline_s`` is an absolute ``perf_counter``-domain
        deadline; ``arrival_s`` backdates the arrival (open-loop benchmark
        drivers use the scheduled arrival time so queueing delay is
        measured against the arrival process, not the submit call).
        Raises ``ServeOverload`` when the bounded queue is full,
        ``ServeQuarantined`` for a quarantined (plan, binding) pair, and
        ``ParamError`` on a malformed binding."""
        if hasattr(query, "cache_key") and hasattr(query, "execute_many"):
            pq = query
        else:
            with self._lock:
                pq = self.gopt.prepare(query, backend=self.backend)
        self._validate(pq, params)
        key = pq.cache_key
        # quarantine: a binding that failed permanently quarantine_after
        # times is rejected here, before it can poison another wave
        fails = self._offenders.get((key, _freeze(params or {})), 0)
        if fails >= self.quarantine_after:
            self.stats.quarantined += 1
            raise ServeQuarantined(
                f"binding quarantined after {fails} permanent failures "
                f"on plan {key!r}")
        now = time.perf_counter() if arrival_s is None else arrival_s
        # MVCC-lite: pin the store snapshot *at admission* — the request
        # answers as-of this version even when writes land before its wave
        snap = self.gopt.snapshot()
        with self._alock:
            if self._pending >= self.max_pending:
                self.stats.rejected += 1
                raise ServeOverload(
                    f"pending queue full ({self._pending}/{self.max_pending})")
            self._rid += 1
            req = ServeRequest(self._rid, pq, params, now, deadline_s)
            if snap is not None:
                req.snapshot = snap
                req.snap_version = snap.version
            self._plans[key] = pq
            self._queues.setdefault(key, deque()).append(req)
            self._pending += 1
            self.stats.submitted += 1
        return req

    def submit_update(self, kind: str, *args,
                      deadline_s: float | None = None,
                      arrival_s: float | None = None, **kw) -> ServeRequest:
        """Admit one mutation (``insert_vertex``/``insert_edge``/
        ``delete_vertex``/``delete_edge``) through the same admission path
        as queries: bounded queue, FIFO-fair wave formation.  Updates ride
        a dedicated queue and apply on the worker thread in wave order;
        the mutation's return value lands in ``req.result``.  Reads pinned
        their snapshot at admission, so an update wave never disturbs an
        already-admitted read."""
        if kind not in _WRITE_KINDS:
            raise ValueError(f"unknown update kind {kind!r}; "
                             f"expected one of {_WRITE_KINDS}")
        if not callable(getattr(self.gopt.store, kind, None)):
            raise TypeError("store is frozen; serve mutations require a "
                            "repro.graphdb.delta.MutableGraphStore")
        now = time.perf_counter() if arrival_s is None else arrival_s
        with self._alock:
            if self._pending >= self.max_pending:
                self.stats.rejected += 1
                raise ServeOverload(
                    f"pending queue full ({self._pending}/{self.max_pending})")
            self._rid += 1
            req = ServeRequest(self._rid, None, None, now, deadline_s,
                               kind="update", update=(kind, args, kw))
            self._queues.setdefault(_WRITE_KEY, deque()).append(req)
            self._pending += 1
            self.stats.submitted += 1
        return req

    @staticmethod
    def _validate(pq, params: dict | None):
        """Host-side admission validation (mirrors ``Engine.bind_params``'s
        strict checks) so malformed requests never occupy a wave slot."""
        referenced = pq.logical.referenced_params()
        declared = referenced | set(pq.logical.params)
        provided = set(params or {})
        extra = provided - declared
        if extra:
            raise ParamError("binding names no declared parameter",
                             extra=extra, declared=declared)
        missing = referenced - set(pq.logical.params) - provided
        if missing:
            raise ParamError("unbound parameter(s)", missing=missing,
                             declared=declared)

    @property
    def pending(self) -> int:
        return self._pending

    # -------------------------------------------------------- wave formation
    def _form_wave(self, now: float):
        """Pick the queue with the oldest waiting head (FIFO fairness
        across plans), drop expired requests, and coalesce a wave.  The
        wave size rounds down to a power of two while the queue holds a
        remainder, so recurring wave sizes re-hit the backend's pow2-
        bucketed compile caches; a draining wave takes everything left.
        Runs under the admission lock: formation races with concurrent
        client submits, never with the worker."""
        with self._alock:
            return self._form_wave_locked(now)

    def _form_wave_locked(self, now: float):
        while True:
            key = None
            oldest = None
            for k, q in self._queues.items():
                if q and (oldest is None or q[0].arrival_s < oldest):
                    oldest = q[0].arrival_s
                    key = k
            if key is None:
                return None
            q = self._queues[key]
            reqs: list[ServeRequest] = []
            # snapshot-homogeneous waves: one wave executes against ONE
            # pinned snapshot, so coalescing stops at the first version
            # boundary in the queue (update waves apply in queue order and
            # never split)
            span = len(q)
            if key != _WRITE_KEY:
                span = 1
                while span < len(q) and \
                        q[span].snap_version == q[0].snap_version:
                    span += 1
            size = min(span, self.max_wave)
            if self.bucket_waves and size < span:
                size = _pow2_floor(size)
            popped = 0
            while q and len(reqs) < size and popped < span:
                r = q.popleft()
                popped += 1
                self._pending -= 1
                if r.deadline_s is not None and now > r.deadline_s:
                    r.status = "dropped"
                    r.finish_s = now
                    self.stats.dropped += 1
                    continue
                reqs.append(r)
            if not q:
                del self._queues[key]
            if reqs:
                return key, reqs
            # the whole wave expired: re-form from the remaining queues

    # -------------------------------------------------------------- execution
    def _run_wave(self, key, reqs: list[ServeRequest]):
        """Execute one wave (single worker thread under overlap: every
        backend call for every wave runs here, serialized)."""
        if key == _WRITE_KEY:
            self._run_write_wave(reqs)
            return
        pq = reqs[0].prepared
        ops = pq.spec.operators(self.gopt.store)
        # wave-scoped ledgers: no bleed across waves, bounded growth
        ops.reset_ledgers()
        start = time.perf_counter()
        for r in reqs:
            r.start_s = start
        self.stats.deduped += \
            len(reqs) - len({_freeze(r.params or {}) for r in reqs})
        exec_kw = dict(self.exec_kw)
        if reqs[0].snapshot is not None:
            # the wave is snapshot-homogeneous by formation; execute the
            # whole batch against the wave's pinned snapshot
            exec_kw["snapshot"] = reqs[0].snapshot
        self._samples[key] = reqs[0].params
        if not self.containment:
            # uncontained (legacy) path: one failure kills the whole wave
            # and escapes to the caller — the perf baseline
            self._exec_group(pq, reqs, exec_kw, 0)
        else:
            level, probe = self._breaker_pick(key)
            outcome = {"level_failures": 0, "escalated_to": None}
            self._contained_exec(key, pq, reqs, exec_kw, level,
                                 self.max_retries, outcome)
            self._breaker_report(key, level, probe, outcome)
        self.stats.record_wave(key, reqs, _pow2(len(reqs)),
                               time.perf_counter() - start,
                               ops.kernel_stats.summary())
        self._update_hotness(key, len(reqs))

    def _level_kw(self, exec_kw: dict, level: int) -> dict:
        """Execution kwargs for one degradation-ladder rung: 0 = native
        (fused chains and all), 1 = per-hop loop (``chain_dispatch=False``),
        2 = the ``fallback_spec`` backend (same physical plan; chain nodes
        run on its per-hop loop)."""
        kw = dict(exec_kw)
        if level >= 1:
            kw["chain_dispatch"] = False
        if level >= 2:
            kw["backend"] = self.fallback_spec
        return kw

    def _exec_group(self, pq, reqs: list[ServeRequest], exec_kw: dict,
                    level: int):
        """Execute a (sub)wave at one ladder rung, with duplicate
        suppression and pow2 padding; marks every request done on success.
        Any failure raises to the containment layer.  When every request
        carries a deadline, their max plumbs down as the engine's
        cooperative mid-execution deadline (the wave is abandoned only once
        *all* its deadlines have expired)."""
        exec_kw = self._level_kw(exec_kw, level)
        deadlines = [r.deadline_s for r in reqs]
        if all(d is not None for d in deadlines):
            exec_kw["deadline_s"] = max(deadlines)
        # duplicate suppression: identical bindings in one wave execute
        # once and fan the result out (hot-key traffic makes these common);
        # duplicate requests share the execution's Table and ExecStats
        uniq: dict = {}
        bindings: list = []
        slot = []
        for r in reqs:
            k = _freeze(r.params or {})
            if k not in uniq:
                uniq[k] = len(bindings)
                bindings.append(r.params)
            slot.append(uniq[k])
        if len(bindings) == 1:
            results = [pq.execute(bindings[0], **exec_kw)]
        else:
            # on compiling backends, pad the executed binding list up to
            # its pow2 bucket with a duplicate binding: the union pattern
            # pass is unchanged (duplicate predicate values collapse), and
            # every wave presents the stacked tail with one of a handful
            # of stable batch shapes instead of a fresh trace per size
            pad = (self.pad_waves if self.pad_waves is not None
                   else pq.spec.operators(self.gopt.store).compiled)
            if pad and self.bucket_waves:
                bindings = bindings + \
                    [bindings[0]] * (_pow2(len(bindings)) - len(bindings))
            results = pq.execute_many(bindings, batch=True, **exec_kw)
        finish = time.perf_counter()
        for r, j in zip(reqs, slot):
            r.table, r.stats = results[j]
            r.status = "done"
            r.finish_s = finish

    def _contained_exec(self, key, pq, reqs: list[ServeRequest],
                        exec_kw: dict, level: int, retries_left: int,
                        outcome: dict):
        """The wave containment boundary (DESIGN.md §13.2): execute a
        (sub)group, retrying transients with capped exponential backoff,
        bisecting multi-request groups to isolate poison bindings, and
        walking single failures up the degradation ladder before declaring
        them failed.  Every request leaves with a terminal status."""
        try:
            self._exec_group(pq, reqs, exec_kw, level)
            return
        except DeadlineExceeded:
            # deadline_s was max() over the group: every deadline expired
            self._mark_deadline(reqs)
            return
        except Exception as exc:
            if classify_error(exc) == "transient" and retries_left > 0:
                self.stats.retries += 1
                time.sleep(self.retry_backoff_s *
                           (2 ** (self.max_retries - retries_left)))
                return self._contained_exec(key, pq, reqs, exec_kw, level,
                                            retries_left - 1, outcome)
            outcome["level_failures"] += 1
            if len(reqs) > 1:
                # bisect: isolate the poison binding so healthy co-batched
                # requests still succeed
                self.stats.bisections += 1
                mid = len(reqs) // 2
                self._contained_exec(key, pq, reqs[:mid], exec_kw, level,
                                     self.max_retries, outcome)
                self._contained_exec(key, pq, reqs[mid:], exec_kw, level,
                                     self.max_retries, outcome)
                return
            # single request: walk the remaining ladder rungs — a failure
            # that clears at a higher rung is a backend fault (the breaker
            # trips there); one that survives the last rung is poison
            for rung in range(level + 1, 3):
                try:
                    self._exec_group(pq, reqs, exec_kw, rung)
                    prev = outcome["escalated_to"]
                    outcome["escalated_to"] = (rung if prev is None
                                               else max(prev, rung))
                    return
                except DeadlineExceeded:
                    self._mark_deadline(reqs)
                    return
                except Exception as exc2:
                    exc = exc2
            self._mark_failed(key, reqs[0], exc)

    def _mark_deadline(self, reqs: list[ServeRequest]):
        """Terminal accounting for a cooperative mid-execution deadline
        abort: the whole (sub)group's deadlines expired."""
        now = time.perf_counter()
        for r in reqs:
            r.status = "dropped"
            r.finish_s = now
        self.stats.dropped += len(reqs)
        self.stats.deadline_aborts += len(reqs)

    def _mark_failed(self, key, req: ServeRequest, exc: BaseException,
                     offender: bool = True):
        """Terminal accounting for one failed request: structured
        ``ExecError`` with plan context, ``status="failed"``, offender
        bookkeeping for quarantine (skipped for worker crashes, which are
        not binding-attributable)."""
        if isinstance(exc, ExecError):
            err = exc
            if err.plan is None:
                err.plan = key
        else:
            err = ExecError(str(exc) or type(exc).__name__,
                            kind=classify_error(exc), plan=key, cause=exc)
        req.error = err
        req.status = "failed"
        req.finish_s = time.perf_counter()
        self.stats.record_failure(key)
        if offender and err.kind != "transient":
            fk = (key, _freeze(req.params or {}))
            self._offenders[fk] = self._offenders.get(fk, 0) + 1

    # ------------------------------------------------------- circuit breaker
    def _breaker(self, key) -> dict:
        b = self._breakers.get(key)
        if b is None:
            b = self._breakers[key] = {
                "level": 0, "fail_streak": 0, "ok_streak": 0,
                "trips": 0, "recoveries": 0, "probes": 0}
        return b

    def _breaker_pick(self, key) -> tuple[int, bool]:
        """The ladder rung this wave executes at.  A degraded plan that has
        been clean for ``probe_after`` waves half-opens: the next wave
        probes one rung up — success recovers, failure stays degraded."""
        b = self._breaker(key)
        if b["level"] > 0 and b["ok_streak"] >= self.probe_after:
            b["probes"] += 1
            b["ok_streak"] = 0
            self.stats.breaker_probes += 1
            return b["level"] - 1, True
        return b["level"], False

    def _breaker_report(self, key, level_used: int, probe: bool,
                        outcome: dict):
        """Feed one wave's containment outcome into the plan's breaker."""
        b = self._breaker(key)
        esc = outcome["escalated_to"]
        if esc is not None and esc > b["level"]:
            # evidence-based trip: a request failed at this rung but
            # succeeded higher up — the rung itself is faulty for this plan
            b["level"] = esc
            b["trips"] += 1
            b["fail_streak"] = 0
            b["ok_streak"] = 0
            self.stats.breaker_trips += 1
            return
        if outcome["level_failures"] == 0:
            if probe:
                b["level"] = level_used          # half-open probe succeeded
                b["recoveries"] += 1
                b["ok_streak"] = 0
                self.stats.breaker_recoveries += 1
            else:
                b["ok_streak"] += 1
                b["fail_streak"] = 0
            return
        if probe:
            b["ok_streak"] = 0                   # failed probe: stay degraded
            return
        b["fail_streak"] += 1
        b["ok_streak"] = 0
        if b["fail_streak"] >= self.breaker_threshold and b["level"] < 2:
            # streak-based trip: persistent failures with no higher-rung
            # success signal (e.g. exhausted transients) step down one rung
            b["level"] += 1
            b["trips"] += 1
            b["fail_streak"] = 0
            self.stats.breaker_trips += 1

    def _run_write_wave(self, reqs: list[ServeRequest]):
        """Apply one update wave in queue order on the worker thread (the
        single writer under overlap; admitted readers hold their own
        immutable snapshots, so writers never block readers).  Mutations
        are contained per request — one bad mutation fails alone."""
        store = self.gopt.store
        start = time.perf_counter()
        applied = 0
        for r in reqs:
            r.start_s = start
            kind, args, kw = r.update
            try:
                r.result = getattr(store, kind)(*args, **kw)
                r.status = "done"
                applied += 1
            except Exception as exc:
                self._mark_failed(_WRITE_KEY, r, exc, offender=False)
        finish = time.perf_counter()
        for r in reqs:
            if r.status == "done":
                r.finish_s = finish
        self.stats.writes += applied
        self.stats.record_wave(_WRITE_KEY, reqs, len(reqs),
                               finish - start, None)

    # --------------------------------------------------------------- hotness
    def _update_hotness(self, key, hits: int):
        """Decayed per-plan hit counts drive two protections for the
        hottest ``hot_plans`` plans: their plan-cache entries stay at the
        LRU head, and their fused chains' compiled programs are pinned
        against backend cache eviction."""
        self._hot[key] = self._hot.get(key, 0) + hits
        with self._lock:
            self.gopt.touch_plan(key)
        hot = set(sorted(self._hot, key=self._hot.get,
                         reverse=True)[:self.hot_plans])
        for k in list(self._pinned - hot):
            if self._set_pinned(k, False):
                self._pinned.discard(k)
        for k in hot - self._pinned:
            if self._set_pinned(k, True):
                self._pinned.add(k)

    def _set_pinned(self, key, pinned: bool) -> bool:
        pq = self._plans.get(key)
        if pq is None:
            return False
        ops = self.gopt.store.__dict__.get(
            "_physical_ops_cache", {}).get(pq.spec.name)
        if ops is None:
            return False
        any_pin = False
        for spec in self._chain_specs(pq, ops):
            any_pin = ops.pin_chain(spec, pinned) or any_pin
        # claim the slot even when the plan has no (executed) chains, so
        # the hot set is stable across waves
        return True

    def _chain_specs(self, pq, ops):
        """Chain specs the engine memoized on this plan's chain nodes for
        the current (store, backend) — the handles worth pinning."""
        from repro.core.physical import ExpandChainNode, plan_children
        store = self.gopt.store
        want = (id(store), getattr(store, "compaction_epoch", 0), ops.name)
        specs = []

        def walk(n):
            if n is None:
                return
            if isinstance(n, ExpandChainNode):
                cached = n.__dict__.get("_chain_spec")
                if cached is not None and cached[0] == want \
                        and cached[1] is not None:
                    specs.append(cached[1])
            for c in plan_children(n):
                walk(c)

        walk(pq.physical)
        return specs

    # ------------------------------------------------------------ scheduling
    def step(self) -> list[ServeRequest]:
        """Form and dispatch ONE wave.  Under overlap the new wave starts
        on the worker while this thread returns the *previous* wave's
        completed requests (admission of the next wave overlaps device
        execution of the current one); without overlap the wave runs
        inline.  Returns ``[]`` when nothing completed this step."""
        wave = self._form_wave(time.perf_counter())
        if wave is None:
            return self.flush()
        key, reqs = wave
        if self._pool is None:
            try:
                self._run_wave(key, reqs)
            except Exception as exc:
                # containment bug or uncontained mode: no request may be
                # left in limbo — fail whatever is still pending
                self._fail_crashed(key, reqs, exc)
                if not self.containment:
                    raise
            return reqs
        prev = self._inflight
        self._inflight = (self._pool.submit(self._run_wave, key, reqs),
                          key, reqs)
        if prev is None:
            return []
        return self._join_wave(prev)

    def flush(self) -> list[ServeRequest]:
        """Join the in-flight wave (if any) and return its requests."""
        if self._inflight is None:
            return []
        prev = self._inflight
        self._inflight = None
        return self._join_wave(prev)

    def _join_wave(self, inflight) -> list[ServeRequest]:
        """Join one dispatched wave, supervising the overlap worker.  An
        exception escaping ``_run_wave`` is a worker crash: the pool is
        respawned and the crashed wave's still-pending requests re-formed
        exactly once on the new worker (a second crash fails them)."""
        fut, key, reqs = inflight
        try:
            fut.result()
            return reqs
        except Exception as exc:
            self.stats.worker_respawns += 1
            old, self._pool = self._pool, None
            # drain the old pool BEFORE spawning its replacement: the next
            # wave may already be queued on it, and the single-worker
            # serialization contract (one backend call stream) must hold
            old.shutdown(wait=True)
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="serve-wave")
            live = [r for r in reqs if r.status == "pending"]
            if not live:
                return reqs
            if any(r.respawned for r in live):
                # already re-formed once — a repeat crash is terminal
                self._fail_crashed(key, live, exc)
                return reqs
            for r in live:
                r.respawned = True
            retry = self._pool.submit(self._run_wave, key, live)
            try:
                retry.result()
            except Exception as exc2:
                self._fail_crashed(key, live, exc2)
            return reqs

    def _fail_crashed(self, key, reqs: list[ServeRequest],
                      exc: BaseException):
        """Terminal accounting for a wave whose worker crashed: every
        still-pending request fails with the crash as cause (crashes are
        not binding-attributable, so no offender bookkeeping)."""
        for r in reqs:
            if r.status == "pending":
                self._mark_failed(key, r, exc, offender=False)

    def drain(self, max_waves: int | None = None) -> list[ServeRequest]:
        """Serve until every queued request completed (or ``max_waves``
        waves dispatched); returns the completed requests in completion
        order."""
        done: list[ServeRequest] = []
        waves = 0
        while self._queues and (max_waves is None or waves < max_waves):
            done.extend(self.step())
            waves += 1
        done.extend(self.flush())
        return done

    # ------------------------------------------------------------ compaction
    def compact(self, warm: bool = True) -> dict:
        """Quiesce, merge the delta overlay into a rebuilt base CSR, and
        bump the stats epoch (``GOpt.compact`` — every cached plan re-costs
        against post-compaction statistics on its next prepare).  With
        ``warm=True`` the hottest plans are re-prepared, warmed once against
        the rebuilt CSR (paying their chain compiles here, not in a serving
        wave), and their fused chains re-pinned — so a warmed server records
        zero chain compiles in post-compaction waves."""
        self.drain()
        event = dict(self.gopt.compact())
        self._pinned.clear()              # old-epoch chain specs are stale
        repinned = 0
        warm_skips = 0
        if warm:
            hot = sorted(self._hot, key=self._hot.get,
                         reverse=True)[:self.hot_plans]
            for key in hot:
                old = self._plans.get(key)
                if old is None or old.source is None:
                    continue
                with self._lock:
                    pq = self.gopt.prepare(old.source, backend=self.backend,
                                           **old.opts)
                self._plans[pq.cache_key] = pq
                try:
                    pq.execute(self._samples.get(key), **self.exec_kw)
                except ParamError:
                    # the remembered sample doesn't bind this plan (e.g.
                    # params cleared): skip the warm, count it, don't pin —
                    # anything else is a real failure and must surface
                    warm_skips += 1
                    continue
                if self._set_pinned(pq.cache_key, True):
                    self._pinned.add(pq.cache_key)
                    repinned += 1
        event["repinned_plans"] = repinned
        event["warm_skips"] = warm_skips
        return event

    # --------------------------------------------------------------- explain
    def explain(self, query, params: dict | None = None,
                analyze: bool = False, **kw):
        """EXPLAIN/PROFILE through the server: the standard
        ``ExplainReport`` with this plan's serving ledger attached
        (``report.serve``, rendered as a ``-- serve --`` section)."""
        with self._lock:
            pq = self.gopt.prepare(query, backend=self.backend)
        report = pq.explain(params=params, analyze=analyze, **kw)
        report.serve = self.stats.plan_summary(pq.cache_key)
        b = self._breakers.get(pq.cache_key)
        if b is not None:
            report.serve["breaker"] = dict(b)
        return report

    # ------------------------------------------------------------- lifecycle
    def close(self):
        """Join the in-flight wave, cancel everything still queued (each
        with ``status="cancelled"``), and shut the worker down.  After
        close, no admitted request is in limbo."""
        self.flush()
        now = time.perf_counter()
        with self._alock:
            for q in self._queues.values():
                for r in q:
                    r.status = "cancelled"
                    r.finish_s = now
                    self.stats.cancelled += 1
                    self._pending -= 1
            self._queues.clear()
        if self._pool is not None:
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "QueryServer":
        return self

    def __exit__(self, *exc):
        self.close()
