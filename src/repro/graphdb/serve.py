"""QueryServer — continuous batching for prepared graph queries (DESIGN.md §9).

The graph twin of the LM slot scheduler in ``repro.serve.engine``: where the
LM engine coalesces decode steps of whatever requests currently occupy its
slot pool, the QueryServer coalesces *queries that share a cached plan* into
``Engine.run_batch`` waves.  Requests are admitted into per-plan queues
keyed by the canonical plan-cache key (``PreparedQuery.cache_key``); wave
formation takes the queue with the oldest waiting request (FIFO fairness
across plans) and coalesces up to ``max_wave`` requests, rounding the wave
size down to a power of two while the queue still has a remainder — so a
warmed server's recurring wave sizes land on the same pow2 capacity buckets
the backend's compiled programs (fused chains, bucketed tail kernels) are
keyed by, re-hitting the compile cache instead of thrashing it.

Scheduling/latency mechanics:

- **admission control** — the total pending queue is bounded
  (``max_pending``); ``submit`` raises ``ServeOverload`` when full
  (backpressure, counted in ``ServeStats.rejected``).  Parameter bindings
  are validated at admission (host-side), so a malformed request is
  rejected before it ever occupies a wave slot.
- **deadline drop** — a request carrying ``deadline_s`` that expires before
  its wave forms is dropped at formation time (``ServeStats.dropped``),
  never dispatched.
- **overlap** — with ``overlap=True`` waves execute on a single worker
  thread: while wave *k* runs its device program, the main thread admits,
  validates, and forms wave *k+1* (every backend/array call stays on the
  one worker thread; host-side bookkeeping stays on the caller's thread).
- **duplicate suppression** — identical bindings within a wave execute
  once and fan the result out (hot-key traffic makes these common), so a
  wave's device cost scales with its *distinct* bindings.
- **hotness LRU** — per-plan hit counts keep the ``hot_plans`` hottest
  plans pinned: their plan-cache entries are LRU-touched and their fused
  chains' compiled programs are protected from backend cache eviction
  (``OperatorSet.pin_chain``), so a burst of cold plans cannot evict a hot
  plan's warmed programs.
- **ledger scoping** — both backend instrumentation ledgers
  (``TransferStats`` / ``KernelStats``) are reset at each wave start
  (``OperatorSet.reset_ledgers``): one request's PROFILE window can never
  report a neighboring wave's dispatches or transfers, and the ledgers
  stay bounded under sustained traffic.

``ServeStats`` is the serving ledger — wave sizes, batch occupancy, queue
delay vs execution time, fallback-to-loop counts, per-wave compile counts —
and surfaces through the existing EXPLAIN/PROFILE reporting:
``QueryServer.explain(query)`` attaches the plan's serving summary to the
``ExplainReport`` (rendered as a ``-- serve --`` section).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import ThreadPoolExecutor

from repro.core.errors import ParamError
from repro.core.gopt import _freeze


def _pow2(n: int, floor: int = 1) -> int:
    return max(floor, 1 << max(int(n) - 1, 0).bit_length())


def _pow2_floor(n: int) -> int:
    return 1 << (max(int(n), 1).bit_length() - 1)


def _percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) without numpy."""
    if not values:
        return 0.0
    s = sorted(values)
    idx = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return float(s[idx])


class ServeOverload(RuntimeError):
    """Admission rejected: the bounded pending queue is full."""


# the update stream's queue key: writes ride the same admission path and
# FIFO-fair wave formation as reads, on a dedicated queue
_WRITE_KEY = ("__update__",)
_WRITE_KINDS = ("insert_vertex", "insert_edge",
                "delete_vertex", "delete_edge")


@dataclasses.dataclass
class ServeRequest:
    """One admitted query request and its lifecycle record."""
    rid: int
    prepared: object                 # PreparedQuery (None for updates)
    params: dict | None
    arrival_s: float                 # perf_counter-domain arrival time
    deadline_s: float | None = None  # absolute; expired requests are dropped
    status: str = "pending"          # pending | done | dropped
    table: object | None = None
    stats: object | None = None      # ExecStats of this request's execution
    start_s: float = 0.0             # wave execution start
    finish_s: float = 0.0
    kind: str = "query"              # query | update
    update: tuple | None = None      # (mutation name, args, kwargs)
    result: object | None = None     # mutation return value (updates)
    # MVCC-lite: the store snapshot pinned at admission — this request
    # answers as-of its admission version no matter when its wave runs
    snapshot: object | None = None
    snap_version: int = -1

    @property
    def queue_delay_s(self) -> float:
        return max(0.0, self.start_s - self.arrival_s)

    @property
    def latency_s(self) -> float:
        return max(0.0, self.finish_s - self.arrival_s)


class ServeStats:
    """The serving ledger: wave shapes, latency decomposition, drops."""

    def __init__(self):
        self.submitted = 0
        self.completed = 0
        self.rejected = 0          # backpressure (ServeOverload)
        self.dropped = 0           # deadline drops at wave formation
        self.deduped = 0           # duplicate bindings suppressed in waves
        self.writes = 0            # applied mutations (update stream)
        self.waves = 0
        self.wave_sizes: list[int] = []
        # wave size / its pow2 capacity bucket — 1.0 means the wave exactly
        # fills the bucket its compiled programs are keyed by
        self.occupancy: list[float] = []
        self.queue_delay_s: list[float] = []   # per completed request
        self.exec_s: list[float] = []          # per wave
        self.latency_s: list[float] = []       # per completed request
        self.fallbacks: dict[str, int] = {}    # engine fallback counters
        # per-wave compile-event counts from the (wave-scoped) KernelStats
        # window — a warmed server holds these flat at zero
        self.wave_compiles: list[int] = []
        self.wave_chain_compiles: list[int] = []
        self.per_plan: dict = {}               # cache_key -> summary dict

    # ------------------------------------------------------------ recording
    def record_wave(self, key, reqs, bucket: int, exec_s: float,
                    kernels: dict | None):
        self.waves += 1
        self.wave_sizes.append(len(reqs))
        self.occupancy.append(len(reqs) / max(bucket, 1))
        self.exec_s.append(exec_s)
        kernels = kernels or {}
        compiles = sum(v for k, v in kernels.items()
                       if k.startswith("compile:"))
        self.wave_compiles.append(compiles)
        self.wave_chain_compiles.append(kernels.get("compile:fused_chain", 0))
        plan = self.per_plan.setdefault(key, {
            "waves": 0, "requests": 0, "queue_delay_s": [], "exec_s": [],
            "fallbacks": {}, "compiles": 0})
        plan["waves"] += 1
        plan["exec_s"].append(exec_s)
        plan["compiles"] += compiles
        for r in reqs:
            self.completed += 1
            self.queue_delay_s.append(r.queue_delay_s)
            self.latency_s.append(r.latency_s)
            plan["requests"] += 1
            plan["queue_delay_s"].append(r.queue_delay_s)
            for reason, n in (getattr(r.stats, "fallbacks", None) or {}).items():
                self.fallbacks[reason] = self.fallbacks.get(reason, 0) + n
                pf = plan["fallbacks"]
                pf[reason] = pf.get(reason, 0) + n

    # ------------------------------------------------------------- summaries
    def summary(self) -> dict:
        n_w = max(self.waves, 1)
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "dropped": self.dropped,
            "deduped": self.deduped,
            "writes": self.writes,
            "waves": self.waves,
            "mean_wave_size": sum(self.wave_sizes) / n_w,
            "mean_occupancy": sum(self.occupancy) / n_w,
            "queue_delay_p50_ms": _percentile(self.queue_delay_s, 50) * 1e3,
            "queue_delay_p99_ms": _percentile(self.queue_delay_s, 99) * 1e3,
            "exec_p50_ms": _percentile(self.exec_s, 50) * 1e3,
            "latency_p50_ms": _percentile(self.latency_s, 50) * 1e3,
            "latency_p99_ms": _percentile(self.latency_s, 99) * 1e3,
            "fallbacks": dict(self.fallbacks),
            "compiles_per_wave": list(self.wave_compiles),
        }

    def plan_summary(self, key) -> dict:
        """Per-plan serving section for ``ExplainReport.serve``."""
        plan = self.per_plan.get(key)
        if plan is None:
            return {"waves": 0, "requests": 0}
        n_w = max(plan["waves"], 1)
        return {
            "waves": plan["waves"],
            "requests": plan["requests"],
            "mean_wave_size": round(plan["requests"] / n_w, 2),
            "queue_delay_p50_ms":
                round(_percentile(plan["queue_delay_s"], 50) * 1e3, 3),
            "queue_delay_p99_ms":
                round(_percentile(plan["queue_delay_s"], 99) * 1e3, 3),
            "exec_p50_ms": round(_percentile(plan["exec_s"], 50) * 1e3, 3),
            "fallbacks": dict(plan["fallbacks"]),
            "compiles": plan["compiles"],
        }

    def render(self) -> str:
        s = self.summary()
        lines = [
            f"ServeStats: {s['completed']}/{s['submitted']} completed over "
            f"{s['waves']} waves "
            f"(rejected={s['rejected']}, dropped={s['dropped']}, "
            f"deduped={s['deduped']})",
            f"  wave size mean={s['mean_wave_size']:.1f} "
            f"occupancy={s['mean_occupancy']:.2f}",
            f"  queue delay p50={s['queue_delay_p50_ms']:.2f}ms "
            f"p99={s['queue_delay_p99_ms']:.2f}ms | "
            f"exec p50={s['exec_p50_ms']:.2f}ms",
            f"  latency p50={s['latency_p50_ms']:.2f}ms "
            f"p99={s['latency_p99_ms']:.2f}ms",
            f"  fallbacks={s['fallbacks'] or '{}'} "
            f"compiles/wave={s['compiles_per_wave']}",
        ]
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()


class QueryServer:
    """Continuous-batching service over a ``GOpt`` (DESIGN.md §9).

    >>> srv = gopt.serve(max_wave=32)
    >>> reqs = [srv.submit(Q, {"pid": p}) for p in people]
    >>> srv.drain()
    >>> reqs[0].table, reqs[0].stats
    """

    def __init__(self, gopt, backend=None, max_pending: int = 1024,
                 max_wave: int = 64, hot_plans: int = 4,
                 overlap: bool = True, bucket_waves: bool = True,
                 pad_waves: bool | None = None, **exec_kw):
        self.gopt = gopt
        self.backend = backend
        self.max_pending = max_pending
        self.max_wave = max_wave
        self.hot_plans = hot_plans
        self.bucket_waves = bucket_waves
        # None = auto: pad executed batches to pow2 on compiling backends
        self.pad_waves = pad_waves
        self.exec_kw = exec_kw
        self.stats = ServeStats()
        self._queues: "OrderedDict[tuple, deque[ServeRequest]]" = OrderedDict()
        self._plans: dict = {}            # cache_key -> PreparedQuery
        self._hot: dict = {}              # cache_key -> hit count
        self._samples: dict = {}          # cache_key -> a recent binding
        self._pinned: set = set()         # cache_keys currently pinned
        self._pending = 0
        self._rid = 0
        self._inflight = None             # (future, key, reqs) under overlap
        self._lock = threading.Lock()     # guards the gopt plan-cache LRU
        self._pool = (ThreadPoolExecutor(max_workers=1,
                                         thread_name_prefix="serve-wave")
                      if overlap else None)

    # ------------------------------------------------------------- admission
    def submit(self, query, params: dict | None = None,
               deadline_s: float | None = None,
               arrival_s: float | None = None) -> ServeRequest:
        """Admit one request: resolve the plan through the prepared-plan
        cache, validate its bindings host-side, and enqueue it on its
        plan's queue.  ``deadline_s`` is an absolute ``perf_counter``-domain
        deadline; ``arrival_s`` backdates the arrival (open-loop benchmark
        drivers use the scheduled arrival time so queueing delay is
        measured against the arrival process, not the submit call).
        Raises ``ServeOverload`` when the bounded queue is full and
        ``ParamError`` on a malformed binding."""
        if self._pending >= self.max_pending:
            self.stats.rejected += 1
            raise ServeOverload(
                f"pending queue full ({self._pending}/{self.max_pending})")
        if hasattr(query, "cache_key") and hasattr(query, "execute_many"):
            pq = query
        else:
            with self._lock:
                pq = self.gopt.prepare(query, backend=self.backend)
        self._validate(pq, params)
        now = time.perf_counter() if arrival_s is None else arrival_s
        self._rid += 1
        req = ServeRequest(self._rid, pq, params, now, deadline_s)
        # MVCC-lite: pin the store snapshot *at admission* — the request
        # answers as-of this version even when writes land before its wave
        snap = self.gopt.snapshot()
        if snap is not None:
            req.snapshot = snap
            req.snap_version = snap.version
        key = pq.cache_key
        self._plans[key] = pq
        self._queues.setdefault(key, deque()).append(req)
        self._pending += 1
        self.stats.submitted += 1
        return req

    def submit_update(self, kind: str, *args,
                      deadline_s: float | None = None,
                      arrival_s: float | None = None, **kw) -> ServeRequest:
        """Admit one mutation (``insert_vertex``/``insert_edge``/
        ``delete_vertex``/``delete_edge``) through the same admission path
        as queries: bounded queue, FIFO-fair wave formation.  Updates ride
        a dedicated queue and apply on the worker thread in wave order;
        the mutation's return value lands in ``req.result``.  Reads pinned
        their snapshot at admission, so an update wave never disturbs an
        already-admitted read."""
        if kind not in _WRITE_KINDS:
            raise ValueError(f"unknown update kind {kind!r}; "
                             f"expected one of {_WRITE_KINDS}")
        if not callable(getattr(self.gopt.store, kind, None)):
            raise TypeError("store is frozen; serve mutations require a "
                            "repro.graphdb.delta.MutableGraphStore")
        if self._pending >= self.max_pending:
            self.stats.rejected += 1
            raise ServeOverload(
                f"pending queue full ({self._pending}/{self.max_pending})")
        now = time.perf_counter() if arrival_s is None else arrival_s
        self._rid += 1
        req = ServeRequest(self._rid, None, None, now, deadline_s,
                           kind="update", update=(kind, args, kw))
        self._queues.setdefault(_WRITE_KEY, deque()).append(req)
        self._pending += 1
        self.stats.submitted += 1
        return req

    @staticmethod
    def _validate(pq, params: dict | None):
        """Host-side admission validation (mirrors ``Engine.bind_params``'s
        strict checks) so malformed requests never occupy a wave slot."""
        referenced = pq.logical.referenced_params()
        declared = referenced | set(pq.logical.params)
        provided = set(params or {})
        extra = provided - declared
        if extra:
            raise ParamError("binding names no declared parameter",
                             extra=extra, declared=declared)
        missing = referenced - set(pq.logical.params) - provided
        if missing:
            raise ParamError("unbound parameter(s)", missing=missing,
                             declared=declared)

    @property
    def pending(self) -> int:
        return self._pending

    # -------------------------------------------------------- wave formation
    def _form_wave(self, now: float):
        """Pick the queue with the oldest waiting head (FIFO fairness
        across plans), drop expired requests, and coalesce a wave.  The
        wave size rounds down to a power of two while the queue holds a
        remainder, so recurring wave sizes re-hit the backend's pow2-
        bucketed compile caches; a draining wave takes everything left."""
        while True:
            key = None
            oldest = None
            for k, q in self._queues.items():
                if q and (oldest is None or q[0].arrival_s < oldest):
                    oldest = q[0].arrival_s
                    key = k
            if key is None:
                return None
            q = self._queues[key]
            reqs: list[ServeRequest] = []
            # snapshot-homogeneous waves: one wave executes against ONE
            # pinned snapshot, so coalescing stops at the first version
            # boundary in the queue (update waves apply in queue order and
            # never split)
            span = len(q)
            if key != _WRITE_KEY:
                span = 1
                while span < len(q) and \
                        q[span].snap_version == q[0].snap_version:
                    span += 1
            size = min(span, self.max_wave)
            if self.bucket_waves and size < span:
                size = _pow2_floor(size)
            popped = 0
            while q and len(reqs) < size and popped < span:
                r = q.popleft()
                popped += 1
                self._pending -= 1
                if r.deadline_s is not None and now > r.deadline_s:
                    r.status = "dropped"
                    r.finish_s = now
                    self.stats.dropped += 1
                    continue
                reqs.append(r)
            if not q:
                del self._queues[key]
            if reqs:
                return key, reqs
            # the whole wave expired: re-form from the remaining queues

    # -------------------------------------------------------------- execution
    def _run_wave(self, key, reqs: list[ServeRequest]):
        """Execute one wave (single worker thread under overlap: every
        backend call for every wave runs here, serialized)."""
        if key == _WRITE_KEY:
            self._run_write_wave(reqs)
            return
        pq = reqs[0].prepared
        ops = pq.spec.operators(self.gopt.store)
        # wave-scoped ledgers: no bleed across waves, bounded growth
        ops.reset_ledgers()
        start = time.perf_counter()
        for r in reqs:
            r.start_s = start
        # duplicate suppression: identical bindings in one wave execute
        # once and fan the result out (hot-key traffic makes these common);
        # duplicate requests share the execution's Table and ExecStats
        uniq: dict = {}
        bindings: list = []
        slot = []
        for r in reqs:
            k = _freeze(r.params or {})
            if k not in uniq:
                uniq[k] = len(bindings)
                bindings.append(r.params)
            slot.append(uniq[k])
        self.stats.deduped += len(reqs) - len(bindings)
        exec_kw = dict(self.exec_kw)
        if reqs[0].snapshot is not None:
            # the wave is snapshot-homogeneous by formation; execute the
            # whole batch against the wave's pinned snapshot
            exec_kw["snapshot"] = reqs[0].snapshot
        self._samples[key] = bindings[0]
        if len(bindings) == 1:
            results = [pq.execute(bindings[0], **exec_kw)]
        else:
            # on compiling backends, pad the executed binding list up to
            # its pow2 bucket with a duplicate binding: the union pattern
            # pass is unchanged (duplicate predicate values collapse), and
            # every wave presents the stacked tail with one of a handful
            # of stable batch shapes instead of a fresh trace per size
            pad = (self.pad_waves if self.pad_waves is not None
                   else ops.compiled)
            if pad and self.bucket_waves:
                bindings = bindings + \
                    [bindings[0]] * (_pow2(len(bindings)) - len(bindings))
            results = pq.execute_many(bindings, batch=True, **exec_kw)
        finish = time.perf_counter()
        for r, j in zip(reqs, slot):
            r.table, r.stats = results[j]
            r.status = "done"
            r.finish_s = finish
        self.stats.record_wave(key, reqs, _pow2(len(reqs)), finish - start,
                               ops.kernel_stats.summary())
        self._update_hotness(key, len(reqs))

    def _run_write_wave(self, reqs: list[ServeRequest]):
        """Apply one update wave in queue order on the worker thread (the
        single writer under overlap; admitted readers hold their own
        immutable snapshots, so writers never block readers)."""
        store = self.gopt.store
        start = time.perf_counter()
        for r in reqs:
            r.start_s = start
            kind, args, kw = r.update
            r.result = getattr(store, kind)(*args, **kw)
            r.status = "done"
        finish = time.perf_counter()
        for r in reqs:
            r.finish_s = finish
        self.stats.writes += len(reqs)
        self.stats.record_wave(_WRITE_KEY, reqs, len(reqs),
                               finish - start, None)

    # --------------------------------------------------------------- hotness
    def _update_hotness(self, key, hits: int):
        """Decayed per-plan hit counts drive two protections for the
        hottest ``hot_plans`` plans: their plan-cache entries stay at the
        LRU head, and their fused chains' compiled programs are pinned
        against backend cache eviction."""
        self._hot[key] = self._hot.get(key, 0) + hits
        with self._lock:
            self.gopt.touch_plan(key)
        hot = set(sorted(self._hot, key=self._hot.get,
                         reverse=True)[:self.hot_plans])
        for k in list(self._pinned - hot):
            if self._set_pinned(k, False):
                self._pinned.discard(k)
        for k in hot - self._pinned:
            if self._set_pinned(k, True):
                self._pinned.add(k)

    def _set_pinned(self, key, pinned: bool) -> bool:
        pq = self._plans.get(key)
        if pq is None:
            return False
        ops = self.gopt.store.__dict__.get(
            "_physical_ops_cache", {}).get(pq.spec.name)
        if ops is None:
            return False
        any_pin = False
        for spec in self._chain_specs(pq, ops):
            any_pin = ops.pin_chain(spec, pinned) or any_pin
        # claim the slot even when the plan has no (executed) chains, so
        # the hot set is stable across waves
        return True

    def _chain_specs(self, pq, ops):
        """Chain specs the engine memoized on this plan's chain nodes for
        the current (store, backend) — the handles worth pinning."""
        from repro.core.physical import ExpandChainNode, plan_children
        store = self.gopt.store
        want = (id(store), getattr(store, "compaction_epoch", 0), ops.name)
        specs = []

        def walk(n):
            if n is None:
                return
            if isinstance(n, ExpandChainNode):
                cached = n.__dict__.get("_chain_spec")
                if cached is not None and cached[0] == want \
                        and cached[1] is not None:
                    specs.append(cached[1])
            for c in plan_children(n):
                walk(c)

        walk(pq.physical)
        return specs

    # ------------------------------------------------------------ scheduling
    def step(self) -> list[ServeRequest]:
        """Form and dispatch ONE wave.  Under overlap the new wave starts
        on the worker while this thread returns the *previous* wave's
        completed requests (admission of the next wave overlaps device
        execution of the current one); without overlap the wave runs
        inline.  Returns ``[]`` when nothing completed this step."""
        wave = self._form_wave(time.perf_counter())
        if wave is None:
            return self.flush()
        key, reqs = wave
        if self._pool is None:
            self._run_wave(key, reqs)
            return reqs
        prev = self._inflight
        self._inflight = (self._pool.submit(self._run_wave, key, reqs),
                          key, reqs)
        if prev is None:
            return []
        prev[0].result()
        return prev[2]

    def flush(self) -> list[ServeRequest]:
        """Join the in-flight wave (if any) and return its requests."""
        if self._inflight is None:
            return []
        fut, _key, reqs = self._inflight
        self._inflight = None
        fut.result()
        return reqs

    def drain(self, max_waves: int | None = None) -> list[ServeRequest]:
        """Serve until every queued request completed (or ``max_waves``
        waves dispatched); returns the completed requests in completion
        order."""
        done: list[ServeRequest] = []
        waves = 0
        while self._queues and (max_waves is None or waves < max_waves):
            done.extend(self.step())
            waves += 1
        done.extend(self.flush())
        return done

    # ------------------------------------------------------------ compaction
    def compact(self, warm: bool = True) -> dict:
        """Quiesce, merge the delta overlay into a rebuilt base CSR, and
        bump the stats epoch (``GOpt.compact`` — every cached plan re-costs
        against post-compaction statistics on its next prepare).  With
        ``warm=True`` the hottest plans are re-prepared, warmed once against
        the rebuilt CSR (paying their chain compiles here, not in a serving
        wave), and their fused chains re-pinned — so a warmed server records
        zero chain compiles in post-compaction waves."""
        self.drain()
        event = dict(self.gopt.compact())
        self._pinned.clear()              # old-epoch chain specs are stale
        repinned = 0
        if warm:
            hot = sorted(self._hot, key=self._hot.get,
                         reverse=True)[:self.hot_plans]
            for key in hot:
                old = self._plans.get(key)
                if old is None or old.source is None:
                    continue
                with self._lock:
                    pq = self.gopt.prepare(old.source, backend=self.backend,
                                           **old.opts)
                self._plans[pq.cache_key] = pq
                try:
                    pq.execute(self._samples.get(key), **self.exec_kw)
                except Exception:
                    continue              # no warmable binding for this plan
                if self._set_pinned(pq.cache_key, True):
                    self._pinned.add(pq.cache_key)
                    repinned += 1
        event["repinned_plans"] = repinned
        return event

    # --------------------------------------------------------------- explain
    def explain(self, query, params: dict | None = None,
                analyze: bool = False, **kw):
        """EXPLAIN/PROFILE through the server: the standard
        ``ExplainReport`` with this plan's serving ledger attached
        (``report.serve``, rendered as a ``-- serve --`` section)."""
        with self._lock:
            pq = self.gopt.prepare(query, backend=self.backend)
        report = pq.explain(params=params, analyze=analyze, **kw)
        report.serve = self.stats.plan_summary(pq.cache_key)
        return report

    # ------------------------------------------------------------- lifecycle
    def close(self):
        self.flush()
        if self._pool is not None:
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "QueryServer":
        return self

    def __exit__(self, *exc):
        self.close()
