"""Binding-table execution engine — the backend-agnostic executor core.

Executes a physical pattern plan (Scan/Expand/ExpandIntersect/Join) followed
by the relational tail of the unified-IR plan.  Intermediate pattern
matchings are dense integer tables whose columns are **backend-native
arrays** (OperatorSet v2, DESIGN.md §7): ``Table`` is a thin wrapper over
backend-owned columns, and every data-parallel step — scan, CSR expansion,
WCOJ membership probes, equi joins, selections, grouped reductions, sorts,
property gathers — goes through the ``OperatorSet`` of the active
``PhysicalSpec`` backend, chosen via ``Engine(store,
backend="numpy"|"jax"|spec)``.  On the jax backend columns are
device-resident ``jax.Array``s across *all* plan steps; the engine converts
to host exactly once, with ``ops.to_host(table)`` at result delivery, and
tags the backend's ``transfer_stats`` with the current phase
(``pattern`` / ``tail`` / ``deliver``) so the residency invariant — zero
device->host transfers outside delivery — is testable.

The engine also meters the paper's cost-model quantities: rows produced per
operator (communication-cost analogue) and per-operator wall time
(``ExecStats.op_rows`` / ``op_times``; on asynchronously-dispatching
backends the per-operator times are dispatch times — the final sync is
absorbed by delivery).

Modes (used by the RBO ablation benchmarks):
- ``fuse_expand``   — ExpandGetVFusionRule on/off: fused neighbor expansion vs
  EXPAND_EDGE materializing edges then a separate GET_VERTEX gather.
- ``trim_fields``   — FieldTrimRule on/off: lazy property gathers (trimmed) vs
  eagerly materializing every property column of every bound alias at each
  step (what an untrimmed distributed plan ships between workers).
- filters inside pattern vertices/edges (FilterIntoMatchRule) are honored
  during expansion when present.

``run_batch`` executes one plan for many parameter bindings in a single
pattern pass: parameter-dependent predicates are relaxed to the union of
the per-binding masks during the pattern phase (a multi-binding scan
filter), then re-applied exactly per binding before each binding's
relational tail — row-identical to looping ``run`` per binding, but the
expansion/join work is shared.
"""
from __future__ import annotations

import dataclasses
import operator as _op
import time

import numpy as np

from repro.core import ir
from repro.core.errors import DeadlineExceeded, ExecError, ParamError
from repro.core.pattern import Pattern, PatternEdge
from repro.core.physical import (ExpandChainNode, ExpandNode, JoinNode,
                                 PlanNode, ScanNode)
from repro.core.physical_spec import OperatorSet, PhysicalSpec, get_spec
from repro.graphdb.chain import (ChainFallback, build_chain_spec,
                                 orientations)
from repro.graphdb.delta import StaleSnapshotError
from repro.graphdb.storage import GraphStore

INT_MIN = np.iinfo(np.int64).min

_CMP = {"=": _op.eq, "<>": _op.ne, "<": _op.lt, ">": _op.gt,
        "<=": _op.le, ">=": _op.ge}


@dataclasses.dataclass
class Table:
    """Binding table: a dict of equally-long backend-native columns.

    ``ops`` is the owning ``OperatorSet``; all row movement (gather, filter,
    concatenation) delegates to it so columns never leave the backend's
    array type.  ``ops=None`` (e.g. ``Table.empty()``) means host numpy
    semantics."""
    cols: dict[str, object]
    nrows: int
    ops: OperatorSet | None = None

    @staticmethod
    def empty() -> "Table":
        return Table({}, 0)

    def take(self, idx) -> "Table":
        if self.ops is None:
            return Table({k: v[idx] for k, v in self.cols.items()},
                         int(idx.shape[0]))
        return Table({k: self.ops.take(v, idx) for k, v in self.cols.items()},
                     int(idx.shape[0]), self.ops)

    def mask(self, m) -> "Table":
        if self.ops is None:
            return Table({k: v[m] for k, v in self.cols.items()},
                         int(m.sum()))
        return self.take(self.ops.nonzero(m))

    def head(self, n: int) -> "Table":
        n = min(int(n), self.nrows)
        return Table({k: v[:n] for k, v in self.cols.items()}, n, self.ops)

    def with_cols(self, new: dict) -> "Table":
        cols = dict(self.cols)
        cols.update(new)
        return Table(cols, self.nrows, self.ops)

    @staticmethod
    def concat(tables: list["Table"]) -> "Table":
        tables = [t for t in tables if t.nrows > 0]
        if not tables:
            return Table.empty()
        if len(tables) == 1:
            return tables[0]
        ops = tables[0].ops
        keys = tables[0].cols.keys()
        if ops is None:
            cols = {k: np.concatenate([t.cols[k] for t in tables])
                    for k in keys}
        else:
            cols = {k: ops.concat([t.cols[k] for t in tables]) for k in keys}
        return Table(cols, sum(t.nrows for t in tables), ops)


@dataclasses.dataclass
class ExecStats:
    rows_produced: int = 0          # paper's intermediate-result cost
    op_rows: list = dataclasses.field(default_factory=list)
    # (opname, seconds) aligned 1:1 with op_rows; on async backends these
    # are dispatch times (the final device sync lands in delivery/wall_s)
    # unless the engine ran with sync_per_op=True (PROFILE SYNC)
    op_times: list = dataclasses.field(default_factory=list)
    wall_s: float = 0.0
    # host<->device movement summary for this run ({"phase:kind": {...}}),
    # from the backend's TransferStats ledger
    transfers: dict | None = None
    # compiled-program launch/compile summary ({"kind:label": n}) from the
    # backend's KernelStats ledger — e.g. {"dispatch:fused_chain": 1}
    kernels: dict | None = None
    # device-to-device collective summary ({"kind:label": {...}}) from the
    # backend's ExchangeStats ledger — e.g. {"psum:expand_frontier": ...};
    # None on single-device backends, which never exchange
    exchanges: dict | None = None
    # degraded-path counters ({reason: n}): which fast path this execution
    # fell off and why — e.g. {"stacked_tail_error": 1} when the segmented
    # batch tail fell back to the per-binding loop, {"chain_param": 1} when
    # a fused chain declined a slot value.  Empty on a fully fast-path run.
    fallbacks: dict = dataclasses.field(default_factory=dict)
    # injected-fault summary ({"kind:op": n}) from the backend's FaultStats
    # ledger (graphdb/faults.py); None when no wrapper injected anything
    faults: dict | None = None

    def log(self, opname: str, rows: int, secs: float = 0.0):
        self.rows_produced += rows
        self.op_rows.append((opname, rows))
        self.op_times.append((opname, secs))

    def fallback(self, reason: str, n: int = 1):
        self.fallbacks[reason] = self.fallbacks.get(reason, 0) + n


class Engine:
    def __init__(self, store: GraphStore, fuse_expand: bool = True,
                 trim_fields: bool = True, max_rows: int = 100_000_000,
                 backend: str | PhysicalSpec | OperatorSet = "numpy",
                 chain_dispatch: bool = True, sync_per_op: bool = False,
                 snapshot=None, deadline_s: float | None = None):
        self.store = store
        self.fuse_expand = fuse_expand
        self.trim_fields = trim_fields
        self.max_rows = max_rows
        # absolute time.perf_counter() budget: checked cooperatively
        # *between* operators (DESIGN.md §13.4) so an expired request
        # aborts the tail with DeadlineExceeded instead of completing
        # uselessly; None disables the checks
        self.deadline_s = deadline_s
        # chain_dispatch=False keeps ExpandChainNodes on the per-hop loop
        # (the fused path's parity oracle); sync_per_op=True blocks on the
        # device after every operator so op_times are true device times
        # (the PROFILE SYNC mode) instead of dispatch times
        self.chain_dispatch = chain_dispatch
        self.sync_per_op = sync_per_op
        self._params: dict = {}          # execution-time parameter bindings
        self._batch: list[dict] | None = None    # run_batch binding set
        self._deferred: list = []        # union-relaxed predicates to re-apply
        self._tindex = store.triple_index()
        # MVCC-lite: pin a snapshot on mutable stores (delta.py) so this
        # execution sees base ∪ inserts − tombstones as of construction,
        # regardless of concurrent writers
        if snapshot is None:
            snap_fn = getattr(store, "snapshot", None)
            if callable(snap_fn):
                snapshot = snap_fn()
        self.snapshot = snapshot
        self._delta = snapshot is not None and not snapshot.is_empty
        if self._delta and not fuse_expand:
            raise ValueError(
                "fuse_expand=False (the GET_VERTEX ablation) re-resolves "
                "vertex types from base id ranges and is not supported with "
                "a non-empty delta overlay")
        if isinstance(backend, OperatorSet):
            self.ops = backend
        else:
            self.ops = get_spec(backend).operators(store)

    def _table(self, cols: dict, nrows: int) -> Table:
        return Table(cols, nrows, self.ops)

    def _tick(self, tbl: Table | None, t0: float) -> float:
        """Per-operator elapsed time; under sync_per_op the device finishes
        the operator's work before the clock is read."""
        if self.sync_per_op and tbl is not None and tbl.cols:
            self.ops.block_ready(tbl.cols)
        return time.perf_counter() - t0

    def _offer_bindings(self, bound: list[dict]):
        """Present this execution's parameter bindings to the operator set
        before any work starts.  Plain backends ignore it; fault-injecting
        wrappers (graphdb.faults) use it as the ``bind`` boundary — the one
        place a *binding value* is visible below the engine, which is what
        makes deterministic per-binding poison (and its bisection by the
        serving layer) possible."""
        hook = getattr(self.ops, "binding_boundary", None)
        if hook is not None:
            for b in bound:
                hook(b)

    def _check_deadline(self, label: str):
        """Cooperative deadline check, called between operators — never
        inside one, so compiled dispatches finish atomically."""
        if (self.deadline_s is not None
                and time.perf_counter() > self.deadline_s):
            raise DeadlineExceeded(
                f"deadline_s expired before {label}", operator=label,
                phase=self.ops.transfer_stats.phase or None)

    # ================================================================ pattern
    def _check(self, n, label: str):
        if n > self.max_rows:
            raise RuntimeError(f"intermediate blow-up: {n} rows > cap "
                               f"{self.max_rows} in {label}")

    @staticmethod
    def _annotate_blowup(exc: RuntimeError, label: str):
        if isinstance(exc, ExecError):
            raise exc        # structured failures keep their classification
        raise RuntimeError(f"{exc} in {label}") from None

    def _scan(self, pattern: Pattern, alias: str, stats: ExecStats) -> Table:
        t0 = time.perf_counter()
        v = pattern.vertices[alias]
        parts = []
        for t in sorted(v.types):
            lo, hi = self.store.type_range(t)
            ids = self.ops.scan(lo, hi)
            if self._delta:
                # snapshot view: drop tombstoned ids, append extension ids
                # (new vertices live above the base id space, per type)
                dead = self.snapshot.dead_for(t)
                if dead is not None:
                    keep = ~self.ops.isin(ids, list(dead))
                    ids = self.ops.take(ids, self.ops.nonzero(keep))
                ext = self.snapshot.ext.get(t)
                if ext is not None:
                    parts.append(ids)
                    parts.append(self.ops.asarray(ext))
                    continue
            parts.append(ids)
        ids = self.ops.concat(parts)
        tbl = self._table({alias: ids}, int(ids.shape[0]))
        tbl = self._apply_fused_predicates(tbl, v.predicates, stats)
        stats.log(f"SCAN({alias})", tbl.nrows, self._tick(tbl, t0))
        self._materialize(tbl, alias, pattern)
        return tbl

    @staticmethod
    def _orientations(e: PatternEdge, from_alias: str):
        """(csr_kind, triple) pairs for expanding ``e`` from ``from_alias``
        — shared with the fused-chain spec builder (``chain.orientations``)
        so both execution paths concatenate identically."""
        return orientations(e, from_alias)

    def _expand_edge(self, tbl: Table, pattern: Pattern, e: PatternEdge,
                     from_alias: str, new_alias: str, stats: ExecStats) -> Table:
        """Primary expansion: bind new_alias (+ edge alias) from from_alias."""
        st = self.store
        label = f"EXPAND(+{new_alias}) via edge '{e.alias}' from '{from_alias}'"
        if tbl.nrows == 0:
            return Table.empty()
        src_ids = tbl.cols[from_alias]
        # the column invariant (scan builds from v.types; expansion only
        # binds type-checked neighbors) lets the type-range membership test
        # resolve *statically* from pattern metadata: a src row is in the
        # keyed type's id range iff its vertex type IS the keyed type —
        # no device mask work unless the alias is genuinely mixed-type
        src_types = pattern.vertices[from_alias].types
        new_types = pattern.vertices[new_alias].types
        snap = self.snapshot if self._delta else None
        outs = []
        for kind, t in self._orientations(e, from_alias):
            keyed_type = t.src if kind == "out" else t.dst
            value_type = t.dst if kind == "out" else t.src
            if value_type not in new_types or keyed_type not in src_types:
                continue
            lo, hi = st.type_range(keyed_type)
            ins_v = dels_v = dead = None
            if snap is not None:
                ins_v = snap.ins.get((t, kind))
                dels_v = snap.dels.get((t, kind))
                dead = snap.dead_for(value_type)
            # extension ids sit above every base type range, so the
            # single-type fast path (whole column assumed in range) is only
            # safe when the snapshot has no extension vertices of this type
            force_mask = snap is not None and keyed_type in snap.ext
            base_ok = True
            local = rows = None
            if len(src_types) == 1 and not force_mask:
                local = src_ids - lo           # fast path: table in range
            else:
                m = (src_ids >= lo) & (src_ids < hi)
                rows = self.ops.nonzero(m)
                if int(rows.shape[0]) == 0:
                    base_ok = False
                else:
                    local = self.ops.take(src_ids, rows) - lo
            if base_ok:
                csr = (st.out_csr if kind == "out" else st.in_csr)[t]
                try:
                    ridx, nbr, epos = self.ops.expand(csr, local,
                                                      max_out=self.max_rows)
                except RuntimeError as exc:
                    self._annotate_blowup(exc, label)
                if (dels_v is not None or dead is not None) \
                        and int(ridx.shape[0]):
                    keep = None
                    if dels_v is not None:
                        # probe the tombstone view: (src, nbr) deleted as of
                        # the snapshot?  Row-key mapping via searchsorted;
                        # misses fail the key-equality check
                        gsrc = self.ops.take(
                            src_ids if rows is None
                            else self.ops.take(src_ids, rows), ridx)
                        kd = self.ops.asarray(dels_v.keys)
                        r = self.ops.searchsorted(kd, gsrc)
                        okr = self.ops.take(kd, r) == gsrc
                        df, _ = self.ops.intersect(dels_v.csr, r, nbr)
                        keep = ~(df & okr)
                    if dead is not None:
                        dm = ~self.ops.isin(nbr, list(dead))
                        keep = dm if keep is None else keep & dm
                    sel = self.ops.nonzero(keep)
                    ridx = self.ops.take(ridx, sel)
                    nbr = self.ops.take(nbr, sel)
                    epos = self.ops.take(epos, sel)
                n_out = int(ridx.shape[0])
                gather = ridx if rows is None else self.ops.take(rows, ridx)
                part = tbl.take(gather).with_cols({
                    new_alias: nbr,
                    f"{e.alias}#t": self.ops.full(n_out, self._tindex[t]),
                    f"{e.alias}#p": epos,
                })
                outs.append(part)
            if ins_v is not None:
                # overlay insert part: map global src ids onto the view's
                # compact rows (keys hold only this triple's keyed type, so
                # the full column probes safely — mismatches compact out)
                ik = self.ops.asarray(ins_v.keys)
                r = self.ops.searchsorted(ik, src_ids)
                okm = self.ops.take(ik, r) == src_ids
                sel = self.ops.nonzero(okm)
                if int(sel.shape[0]):
                    crows = self.ops.take(r, sel)
                    try:
                        ridx2, nbr2, epos2 = self.ops.expand(
                            ins_v.csr, crows, max_out=self.max_rows)
                    except RuntimeError as exc:
                        self._annotate_blowup(exc, label)
                    if dead is not None and int(ridx2.shape[0]):
                        keep2 = self.ops.nonzero(
                            ~self.ops.isin(nbr2, list(dead)))
                        ridx2 = self.ops.take(ridx2, keep2)
                        nbr2 = self.ops.take(nbr2, keep2)
                        epos2 = self.ops.take(epos2, keep2)
                    n2 = int(ridx2.shape[0])
                    part = tbl.take(
                        self.ops.take(sel, ridx2)).with_cols({
                            new_alias: nbr2,
                            f"{e.alias}#t": self.ops.full(
                                n2, self._tindex[t]),
                            f"{e.alias}#p": epos2,
                        })
                    outs.append(part)
        out = Table.concat(outs)
        self._check(out.nrows, label)
        return out

    def _intersect_edge(self, tbl: Table, pattern: Pattern, e: PatternEdge,
                        from_alias: str, cand_alias: str) -> Table:
        """Membership probe: keep rows where edge (from_alias, cand) exists;
        bind the edge. Worst-case-optimal intersection step."""
        st = self.store
        label = (f"INTERSECT({from_alias}-[{e.alias}]-{cand_alias})")
        if tbl.nrows == 0:
            return tbl
        outs = []
        src_ids = tbl.cols[from_alias]
        cand = tbl.cols[cand_alias]
        src_types = pattern.vertices[from_alias].types
        cand_types = pattern.vertices[cand_alias].types
        snap = self.snapshot if self._delta else None
        for kind, t in self._orientations(e, from_alias):
            keyed_type = t.src if kind == "out" else t.dst
            value_type = t.dst if kind == "out" else t.src
            if keyed_type not in src_types or value_type not in cand_types:
                continue
            klo, khi = st.type_range(keyed_type)
            vlo, vhi = st.type_range(value_type)
            ins_v = dels_v = None
            force_mask = False
            if snap is not None:
                ins_v = snap.ins.get((t, kind))
                dels_v = snap.dels.get((t, kind))
                force_mask = keyed_type in snap.ext
            csr = (st.out_csr if kind == "out" else st.in_csr)[t]
            if ins_v is not None or dels_v is not None or force_mask:
                # delta path: probe over the full table — base rows out of
                # range clamp to row 0 and mask out, overlay rows (incl.
                # extension srcs) probe the insert view by global key
                inr = ((src_ids >= klo) & (src_ids < khi) &
                       (cand >= vlo) & (cand < vhi))
                local = (src_ids - klo) * inr
                found, epos = self.ops.intersect(csr, local, cand)
                found = found & inr
                if dels_v is not None:
                    kd = self.ops.asarray(dels_v.keys)
                    r = self.ops.searchsorted(kd, src_ids)
                    okr = self.ops.take(kd, r) == src_ids
                    df, _ = self.ops.intersect(dels_v.csr, r, cand)
                    found = found & ~(df & okr)
                if ins_v is not None:
                    ik = self.ops.asarray(ins_v.keys)
                    r2 = self.ops.searchsorted(ik, src_ids)
                    ok2 = self.ops.take(ik, r2) == src_ids
                    f2, p2 = self.ops.intersect(ins_v.csr, r2, cand)
                    f2 = f2 & ok2
                    # mutation-time edge uniqueness means base and overlay
                    # never both match, so the select is exact
                    found = found | f2
                    epos = self.ops.where(f2, p2, epos)
                hit = self.ops.nonzero(found)
                if int(hit.shape[0]) == 0:
                    continue
                part = tbl.take(hit).with_cols({
                    f"{e.alias}#t": self.ops.full(int(hit.shape[0]),
                                                  self._tindex[t]),
                    f"{e.alias}#p": self.ops.take(epos, hit),
                })
                outs.append(part)
                continue
            if len(src_types) == 1 and len(cand_types) == 1:
                rows = None           # statically in range (see _expand_edge)
                local = src_ids - klo
                tgt = cand
            else:
                m = ((src_ids >= klo) & (src_ids < khi) &
                     (cand >= vlo) & (cand < vhi))
                rows = self.ops.nonzero(m)
                if int(rows.shape[0]) == 0:
                    continue
                local = self.ops.take(src_ids, rows) - klo
                tgt = self.ops.take(cand, rows)
            found, epos = self.ops.intersect(csr, local, tgt)
            hit = self.ops.nonzero(found)
            if int(hit.shape[0]) == 0:
                continue
            gather = hit if rows is None else self.ops.take(rows, hit)
            part = tbl.take(gather).with_cols({
                f"{e.alias}#t": self.ops.full(int(hit.shape[0]),
                                              self._tindex[t]),
                f"{e.alias}#p": self.ops.take(epos, hit),
            })
            outs.append(part)
        out = Table.concat(outs)
        self._check(out.nrows, label)
        return out

    def _materialize(self, tbl: Table, alias: str, pattern: Pattern):
        """Untrimmed mode: eagerly attach every property column of ``alias``
        (FieldTrimRule ablation; the shipped-bytes cost the rule removes)."""
        if self.trim_fields or tbl.nrows == 0:
            return
        v = pattern.vertices.get(alias)
        if v is None:
            return
        props = set()
        for t in v.types:
            props |= set(self.store.v_props.get(t, {}))
        for p in sorted(props):
            tbl.cols[f"__mat.{alias}.{p}"] = self.ops.vertex_prop(
                tbl.cols[alias], p)

    def _apply_fused_predicates(self, tbl: Table, preds: list,
                                stats: ExecStats) -> Table:
        for p in preds or []:
            if tbl.nrows == 0:
                break
            if self._batch is not None and ir.expr_params(p):
                # batched execution: relax to the union of the per-binding
                # masks (a stacked multi-binding filter); the exact
                # per-binding predicate re-applies before each tail
                self._deferred.append(p)
                m = self._union_mask(tbl, p)
            else:
                m = self._eval(tbl, p).astype(bool)
            tbl = tbl.mask(m)
        return tbl

    def _union_mask(self, tbl: Table, pred):
        saved = self._params
        m = None
        try:
            for b in self._batch:
                self._params = b
                mb = self._eval(tbl, pred).astype(bool)
                m = mb if m is None else (m | mb)
        finally:
            self._params = saved
        return m

    def exec_pattern(self, pattern: Pattern, node: PlanNode,
                     stats: ExecStats) -> Table:
        self._check_deadline(type(node).__name__)
        if isinstance(node, ScanNode):
            return self._scan(pattern, node.alias, stats)
        if isinstance(node, ExpandNode):
            tbl = self.exec_pattern(pattern, node.child, stats)
            t0 = time.perf_counter()
            edges = list(node.edges)
            # primary expansion via the first edge
            e0 = edges[0]
            frm = e0.other(node.new_alias)
            if self.fuse_expand:
                tbl = self._expand_edge(tbl, pattern, e0, frm,
                                        node.new_alias, stats)
            else:
                # EXPAND_EDGE then a separate GET_VERTEX pass: endpoint ids
                # are re-resolved from the edge bindings and re-type-checked
                # (the work ExpandGetVFusionRule eliminates)
                tbl = self._expand_edge(tbl, pattern, e0, frm,
                                        node.new_alias, stats)
                if tbl.nrows:
                    nbr = tbl.cols[node.new_alias]
                    types = self.store._sorted_types()
                    bounds = np.array(
                        [self.store.v_offset[t] for t in types]
                        + [self.store.n_vertices], dtype=np.int64)
                    tidx = self.ops.searchsorted(          # extra pass
                        self.ops.asarray(bounds), nbr, side="right") - 1
                    allowed = np.zeros(len(types), dtype=bool)
                    for i, t in enumerate(types):
                        allowed[i] = t in pattern.vertices[
                            node.new_alias].types
                    tbl = tbl.mask(self.ops.take(self.ops.asarray(allowed),
                                                 tidx))
                stats.log(f"GET_VERTEX({node.new_alias})", tbl.nrows,
                          self._tick(tbl, t0))
            # intersect the remaining edges (WCOJ step)
            for e in edges[1:]:
                frm = e.other(node.new_alias)
                tbl = self._intersect_edge(tbl, pattern, e, frm,
                                           node.new_alias)
            v = pattern.vertices[node.new_alias]
            tbl = self._apply_fused_predicates(tbl, v.predicates, stats)
            for e in edges:
                tbl = self._apply_fused_predicates(tbl, e.predicates, stats)
            stats.log(f"EXPAND(+{node.new_alias}|{len(edges)}e)", tbl.nrows,
                      self._tick(tbl, t0))
            self._materialize(tbl, node.new_alias, pattern)
            return tbl
        if isinstance(node, ExpandChainNode):
            if not self.fuse_expand:
                # ExpandGetVFusion ablation: run the pre-fusion plan
                return self.exec_pattern(pattern, node.unfused(), stats)
            tbl = self.exec_pattern(pattern, node.child, stats)
            return self._exec_chain(pattern, node, tbl, stats)
        if isinstance(node, JoinNode):
            lt = self.exec_pattern(pattern, node.left, stats)
            rt = self.exec_pattern(pattern, node.right, stats)
            return self._exec_join(pattern, node, lt, rt, stats)
        raise TypeError(node)

    # ================================================================= chains
    def _chain_spec(self, node: ExpandChainNode, pattern: Pattern):
        """ChainSpec for the fused dispatch, memoized on the plan node per
        (store, backend) — plans are shared through the prepared-plan cache,
        so one compiled chain serves every engine over the same store."""
        key = (id(self.store), getattr(self.store, "compaction_epoch", 0),
               self.ops.name)
        cached = node.__dict__.get("_chain_spec")
        if cached is None or cached[0] != key:
            spec = build_chain_spec(self.store, self._tindex, pattern, node)
            node.__dict__["_chain_spec"] = cached = (key, spec)
        return cached[1]

    def _chain_slot_values(self, spec):
        """Evaluate the spec's runtime slots against the current parameter
        bindings.  Raises ``ChainFallback`` for values the int32-staged
        fused program cannot honor (non-integers, out-of-envelope scalars);
        the per-hop loop then executes with full host semantics."""
        i32lo, i32hi = np.iinfo(np.int32).min, np.iinfo(np.int32).max
        scalars, value_lists = [], []
        for kind, lhs, rhs in spec.slots:
            if kind == "scalar":
                v = (self._param_value(rhs.name) if isinstance(rhs, ir.Param)
                     else rhs.value)
                v = self._encode_scalar(lhs, v)
                if (isinstance(v, bool) or not isinstance(v, (int, np.integer))
                        or not i32lo < int(v) <= i32hi):
                    raise ChainFallback(repr(v))
                scalars.append(int(v))
            else:
                values = (self._param_value(rhs.name)
                          if isinstance(rhs, ir.Param) else rhs)
                enc = []
                for x in values:
                    xv = self._encode_scalar(lhs, x)
                    if isinstance(xv, bool) or not isinstance(
                            xv, (int, np.integer)):
                        raise ChainFallback(repr(xv))
                    if i32lo < int(xv) <= i32hi:   # out-of-envelope: no match
                        enc.append(int(xv))
                value_lists.append(enc)
        return scalars, value_lists

    def _exec_chain(self, pattern: Pattern, node: ExpandChainNode,
                    tbl: Table, stats: ExecStats) -> Table:
        """Fused chain execution: ONE backend dispatch through
        ``ops.chain_program`` when the backend advertises it and the shape
        is in the fusable envelope; otherwise (and on the first, measuring
        execution of a shape) the thin-frontier per-hop loop — the parity
        oracle the fused program is held to."""
        t0 = time.perf_counter()
        first = node.steps[0].from_alias
        hops = "".join(f"+{s.alias}" for s in node.steps)
        label = f"EXPANDCHAIN({hops})"
        prog = None
        delta_decline = False
        if self._delta:
            triples = [t for s in node.steps for ee in s.all_edges()
                       for t in ee.triples]
            delta_decline = self.snapshot.affects_chain(triples)
        if (self.chain_dispatch and tbl.nrows and delta_decline
                and getattr(self.ops, "supports_chains", False)):
            stats.fallback("chain_delta")
        if (self.chain_dispatch and tbl.nrows and not delta_decline
                and getattr(self.ops, "supports_chains", False)):
            spec = self._chain_spec(node, pattern)
            # batched runs relax parameter predicates to per-binding unions;
            # the fused program bakes exact slot values, so those chains
            # stay on the loop (which defers them correctly)
            if spec is not None and not (self._batch is not None
                                         and spec.has_params):
                prog = self.ops.chain_program(spec)
        if prog is not None and prog.ready():
            try:
                res = prog.run(tbl.cols[first], tbl.nrows,
                               *self._chain_slot_values(spec), self.max_rows)
                if res is None:
                    stats.fallback("chain_capacity")
            except ChainFallback:
                stats.fallback("chain_param")
                res = None
            except RuntimeError as exc:
                self._annotate_blowup(exc, label)
            if res is not None:
                rows, cols, n = res
                out = tbl.take(rows).with_cols(cols) if n else Table.empty()
                stats.log(label, out.nrows, self._tick(out, t0))
                for s in node.steps:
                    self._materialize(out, s.alias, pattern)
                return out
        # per-hop loop: thin frontier (source column, hop columns, a
        # provenance row index), full table gathered once at the end
        cur = self._table({first: tbl.cols[first],
                           "__chain_row": self.ops.arange(tbl.nrows)},
                          tbl.nrows)
        sizes = []
        for s in node.steps:
            self._check_deadline(f"hop(+{s.alias})")
            if cur.nrows == 0:
                sizes.append(0)
                continue
            cur = self._expand_edge(cur, pattern, s.edge, s.from_alias,
                                    s.alias, stats)
            sizes.append(cur.nrows)     # pre-filter total = fused capacity
            for e in s.intersect_edges:
                cur = self._intersect_edge(cur, pattern, e,
                                           e.other(s.alias), s.alias)
            v = pattern.vertices[s.alias]
            cur = self._apply_fused_predicates(cur, v.predicates, stats)
            for e in s.all_edges():
                cur = self._apply_fused_predicates(cur, e.predicates, stats)
        if prog is not None:
            prog.observe(sizes)         # fix/regrow the capacity schedule
        if cur.nrows == 0:
            stats.log(label, 0, self._tick(None, t0))
            return Table.empty()
        rows = cur.cols.pop("__chain_row")
        del cur.cols[first]          # tbl carries the original column
        out = tbl.take(rows).with_cols(cur.cols)
        stats.log(label, out.nrows, self._tick(out, t0))
        for s in node.steps:
            self._materialize(out, s.alias, pattern)
        return out

    def _exec_join(self, pattern: Pattern, node: JoinNode, lt: Table,
                   rt: Table, stats: ExecStats) -> Table:
        t0 = time.perf_counter()
        # join on the shared vertex aliases plus any other column both
        # sides bound (shared edges must bind identically on both sides)
        keys = sorted(set(node.keys) |
                      (set(lt.cols) & set(rt.cols) - {"__pad"}))
        keys = [k for k in keys if not k.startswith("__mat.")]
        label = f"JOIN({'/'.join(keys) or 'cross'})"
        lkey, rkey = self._pack_join_keys(lt, rt, keys)
        try:
            lidx, ridx = self.ops.join(lkey, rkey, max_out=self.max_rows)
        except RuntimeError as exc:
            self._annotate_blowup(exc, label)
        self._check(int(lidx.shape[0]), label)
        cols = {k: self.ops.take(v, lidx) for k, v in lt.cols.items()}
        for k, v in rt.cols.items():
            if k not in cols:
                cols[k] = self.ops.take(v, ridx)
        out = self._table(cols, int(lidx.shape[0]))
        stats.log(f"JOIN({'/'.join(keys)})", out.nrows, self._tick(out, t0))
        return out

    def _pack_join_keys(self, lt: Table, rt: Table, keys: list[str]):
        """Pack the join columns of both sides into one comparable key
        column each.  The columns are factorized *jointly* (over the
        concatenation) so equal tuples get equal keys across the two
        tables; ``ops.combine_keys`` guarantees ascending key order is the
        tuples' lexicographic order, which fixes the sort-merge output
        order identically on every backend."""
        if not keys:
            return (self.ops.full(lt.nrows, 0), self.ops.full(rt.nrows, 0))
        both = self.ops.combine_keys(
            [self.ops.concat([lt.cols[k], rt.cols[k]]) for k in keys])
        return both[:lt.nrows], both[lt.nrows:]

    # ============================================================ expressions
    def _param_value(self, name: str):
        try:
            return self._params[name]
        except KeyError:
            raise ParamError("unbound parameter at evaluation", missing=[name],
                             declared=self._params) from None

    def _full(self, n: int, value):
        if isinstance(value, str):      # host-only fallback (string literals)
            return np.full(n, value)
        return self.ops.full(n, value)

    def _eval(self, tbl: Table, e):
        st = self.store
        if isinstance(e, ir.Lit):
            return self._full(tbl.nrows, e.value)
        if isinstance(e, ir.Param):
            return self._full(tbl.nrows, self._param_value(e.name))
        if isinstance(e, ir.Var):
            return tbl.cols[e.alias]
        if isinstance(e, ir.Prop):
            mat = tbl.cols.get(f"__mat.{e.alias}.{e.name}")
            if mat is not None:
                return mat
            if f"{e.alias}#t" in tbl.cols:   # edge alias
                return self.ops.edge_prop(tbl.cols[f"{e.alias}#t"],
                                          tbl.cols[f"{e.alias}#p"], e.name)
            return self.ops.vertex_prop(tbl.cols[e.alias], e.name)
        if isinstance(e, ir.Cmp):
            lhs, rhs = e.lhs, e.rhs
            l = self._eval(tbl, lhs)
            r = self._encode_rhs(lhs, rhs, tbl)
            return _CMP[e.op](l, r)
        if isinstance(e, ir.InSet):
            item = self._eval(tbl, e.item)
            values = (self._param_value(e.values.name)
                      if isinstance(e.values, ir.Param) else e.values)
            vals = [self._encode_scalar(e.item, v) for v in values]
            return self.ops.isin(item, vals)
        if isinstance(e, ir.BoolOp):
            if e.op == "NOT":
                return ~self._eval(tbl, e.args[0]).astype(bool)
            acc = self._eval(tbl, e.args[0]).astype(bool)
            for a in e.args[1:]:
                if e.op == "AND":
                    acc = acc & self._eval(tbl, a).astype(bool)
                else:
                    acc = acc | self._eval(tbl, a).astype(bool)
            return acc
        raise TypeError(f"cannot evaluate {e!r}")

    def _encode_scalar(self, lhs, value):
        if isinstance(value, str):
            if isinstance(lhs, ir.Prop):
                return self.store.encode_str(lhs.name, value)
            return -1
        return value

    def _encode_rhs(self, lhs, rhs, tbl):
        if isinstance(rhs, ir.Lit):
            return self._encode_scalar(lhs, rhs.value)
        if isinstance(rhs, ir.Param):
            return self._encode_scalar(lhs, self._param_value(rhs.name))
        return self._eval(tbl, rhs)

    # ============================================================= relational
    def bind_params(self, plan: ir.LogicalPlan,
                    params: dict | None = None) -> dict:
        """Resolve execution-time bindings against the plan's declared
        parameter set.  Build-time bindings (``plan.params``) act as
        defaults; ``params`` overrides them.  Raises ``ParamError`` on a
        binding that names no declared parameter, or on a referenced
        parameter left unbound."""
        referenced = plan.referenced_params()
        declared = referenced | set(plan.params)
        provided = dict(params or {})
        extra = set(provided) - declared
        if extra:
            raise ParamError("binding names no declared parameter",
                             extra=extra, declared=declared)
        # structural params (hop counts baked into the pattern shape, as
        # recorded by GraphIrBuilder) cannot be rebound: silently accepting
        # a different value would lie about what executes.  Other build-time
        # bindings that no expression references are simply unused and may
        # be re-supplied freely (shared bindings dicts across queries).
        structural = plan.hints.get("structural_params") or {}
        rebound = {k for k, v in provided.items()
                   if k in structural and structural[k] != v}
        if rebound:
            raise ParamError(
                "structural parameter(s) were bound at build time and "
                "cannot be rebound at execution — re-prepare instead",
                extra=rebound, declared=declared)
        effective = {**plan.params, **provided}
        missing = referenced - set(effective)
        if missing:
            raise ParamError("unbound parameter(s)", missing=missing,
                             declared=declared)
        return effective

    def _plan_head(self, plan: ir.LogicalPlan, pattern_plan):
        from repro.core.physical import default_left_deep_plan
        if self.snapshot is not None and getattr(self.snapshot, "retired",
                                                 False):
            raise StaleSnapshotError(
                f"snapshot v{self.snapshot.version} was retired by "
                "compaction; pin a fresh snapshot")
        ops = list(plan.ops)
        if not isinstance(ops[0], ir.MatchPattern):
            raise ValueError("plan must start with MATCH_PATTERN")
        pattern = ops[0].pattern
        return ops, pattern, pattern_plan or default_left_deep_plan(pattern)

    def run(self, plan: ir.LogicalPlan, pattern_plan: PlanNode | None = None,
            params: dict | None = None):
        """Execute a logical plan; returns (result Table, ExecStats).
        ``params`` binds the plan's late-bound ``ir.Param`` nodes.  The
        returned table is host-resident: the engine converts the
        backend-native binding table with ``ops.to_host`` exactly once,
        here at delivery — never between plan steps."""
        self._params = self.bind_params(plan, params)
        self._offer_bindings([self._params])
        stats = ExecStats()
        t0 = time.perf_counter()
        ops, pattern, node = self._plan_head(plan, pattern_plan)
        ts = self.ops.transfer_stats
        ks = self.ops.kernel_stats
        es = self.ops.exchange_stats
        fs = self.ops.fault_stats
        mark = ts.mark()
        kmark = ks.mark()
        emark = es.mark()
        fmark = fs.mark()
        ts.set_phase("pattern")
        try:
            tbl = self.exec_pattern(pattern, node, stats)
            ts.set_phase("tail")
            for op in ops[1:]:
                tbl = self._run_relational(tbl, op, stats)
            ts.set_phase("deliver")
            tbl = self.ops.to_host(tbl)
        finally:
            ts.set_phase("")
        stats.wall_s = time.perf_counter() - t0
        stats.transfers = ts.summary(mark)
        stats.kernels = ks.summary(kmark)
        stats.exchanges = es.summary(emark) or None
        stats.faults = fs.summary(fmark) or None
        return tbl, stats

    def run_batch(self, plan: ir.LogicalPlan,
                  pattern_plan: PlanNode | None = None,
                  bindings: list[dict | None] = ()):
        """One pattern pass, many parameter bindings (the vectorized
        ``PreparedQuery.execute_many`` path).  Parameter-dependent pattern
        predicates execute as the union of the per-binding filters, the
        exact predicate re-applies per binding, and the relational tails
        run **stacked**: a ``__seg`` binding-id column turns the per-binding
        group/order/limit/distinct loops into one segmented pass (falling
        back to the per-binding loop on any RuntimeError or when a tail
        operator is outside the segmented envelope) — results are
        row-identical to looping ``run``.  Returns
        ``[(host Table, ExecStats), ...]``."""
        bound = [self.bind_params(plan, b) for b in bindings]
        if not bound:
            return []
        self._offer_bindings(bound)
        ops, pattern, node = self._plan_head(plan, pattern_plan)
        ts = self.ops.transfer_stats
        mark = ts.mark()
        kmark = self.ops.kernel_stats.mark()
        emark = self.ops.exchange_stats.mark()
        fmark = self.ops.fault_stats.mark()
        shared = ExecStats()
        t0 = time.perf_counter()
        self._batch = bound
        self._deferred = []
        self._params = {}
        ts.set_phase("pattern")
        try:
            tbl = self.exec_pattern(pattern, node, shared)
        finally:
            self._batch = None
            ts.set_phase("")
        pattern_s = time.perf_counter() - t0
        # the shared pattern phase's transfers belong to every binding; the
        # per-binding window starts fresh so binding i never reads binding
        # i-1's tail/deliver events
        pattern_transfers = ts.summary(mark)
        pattern_kernels = self.ops.kernel_stats.summary(kmark)
        pattern_exchanges = self.ops.exchange_stats.summary(emark)
        deferred, self._deferred = self._deferred, []
        env = (ops, tbl, bound, deferred, shared, pattern_s,
               pattern_transfers, pattern_kernels, pattern_exchanges)
        reason = None
        results = None
        if len(bound) > 1:
            if self._tail_stackable(ops[1:]):
                try:
                    results = self._run_tails_stacked(*env)
                except ExecError:
                    # structured failures (deadline aborts, injected faults)
                    # belong to the containment layer, not the loop fallback
                    raise
                except RuntimeError:
                    # fall back to the binding loop
                    reason = "stacked_tail_error"
            else:
                reason = "tail_unstackable"
        if results is None:
            results = self._run_tails_loop(*env, reason=reason)
        # the batch shares one execution, so any injected-fault window
        # describes the batch and is attributed to every binding (like the
        # shared pattern phase's kernels/transfers)
        fsum = self.ops.fault_stats.summary(fmark)
        if fsum:
            for _, st in results:
                st.faults = dict(fsum)
        return results

    @staticmethod
    def _tail_stackable(rel_ops) -> bool:
        """Tail operators the segmented (``__seg``-stacked) pass supports:
        parameter-free expressions only (parameters would need per-segment
        values), no string-literal outputs (host-only columns cannot ride
        the backend's segment ops), and no global aggregate downstream of a
        row-reducing operator (its empty-input COUNT()=0 fix-up is
        per-binding)."""
        exprs: list = []
        reducing = False
        for op in rel_ops:
            if isinstance(op, ir.Select):
                exprs.append(op.predicate)
                reducing = True
            elif isinstance(op, ir.Project):
                exprs.extend(e for e, _ in op.items)
            elif isinstance(op, ir.GroupBy):
                if not op.keys and reducing:
                    return False
                exprs.extend(e for e, _ in op.keys)
                exprs.extend(a.arg for a, _ in op.aggs if a.arg is not None)
            elif isinstance(op, ir.OrderBy):
                exprs.extend(e for e, _ in op.items)
                reducing = reducing or op.limit is not None
            elif isinstance(op, ir.Limit):
                reducing = True
            else:
                return False
        return not any(ir.expr_params(e)
                       or (isinstance(e, ir.Lit) and isinstance(e.value, str))
                       for e in exprs)

    def _refilter(self, tbl: Table, deferred, b: dict) -> Table:
        """Exact per-binding re-application of the union-relaxed pattern
        predicates."""
        self._params = b
        if not deferred or tbl.nrows == 0:
            return tbl
        m = None
        for p in deferred:
            mp = self._eval(tbl, p).astype(bool)
            m = mp if m is None else (m & mp)
        return tbl.mask(m)

    def _run_tails_loop(self, ops, tbl, bound, deferred, shared, pattern_s,
                        pattern_transfers, pattern_kernels,
                        pattern_exchanges, reason=None):
        """The per-binding tail loop — the stacked path's fallback and
        parity oracle.  ``reason`` (when the stacked pass was skipped or
        failed) is recorded in each binding's ``ExecStats.fallbacks``."""
        ts = self.ops.transfer_stats
        ks = self.ops.kernel_stats
        es = self.ops.exchange_stats
        results = []
        for b in bound:
            bind_mark = ts.mark()
            kbind = ks.mark()
            ebind = es.mark()
            tb0 = time.perf_counter()
            st = ExecStats(rows_produced=shared.rows_produced,
                           op_rows=list(shared.op_rows),
                           op_times=list(shared.op_times),
                           fallbacks=dict(shared.fallbacks))
            if reason is not None:
                st.fallback(reason)
            ts.set_phase("tail")
            try:
                t = self._refilter(tbl, deferred, b)
                st.log("BATCH_BIND", t.nrows, time.perf_counter() - tb0)
                for op in ops[1:]:
                    t = self._run_relational(t, op, st)
                ts.set_phase("deliver")
                t = self.ops.to_host(t)
            finally:
                ts.set_phase("")
            st.wall_s = pattern_s + (time.perf_counter() - tb0)
            st.transfers = {k: dict(v) for k, v in pattern_transfers.items()}
            for k, v in ts.summary(bind_mark).items():
                ent = st.transfers.setdefault(k, {"calls": 0, "elems": 0})
                ent["calls"] += v["calls"]
                ent["elems"] += v["elems"]
            st.kernels = dict(pattern_kernels)
            for k, v in ks.summary(kbind).items():
                st.kernels[k] = st.kernels.get(k, 0) + v
            exch = {k: dict(v) for k, v in pattern_exchanges.items()}
            for k, v in es.summary(ebind).items():
                ent = exch.setdefault(k, {"calls": 0, "elems": 0})
                ent["calls"] += v["calls"]
                ent["elems"] += v["elems"]
            st.exchanges = exch or None
            results.append((t, st))
        return results

    def _run_tails_stacked(self, ops, tbl, bound, deferred, shared,
                           pattern_s, pattern_transfers, pattern_kernels,
                           pattern_exchanges):
        """One segmented tail for the whole binding batch: per-binding rows
        are stacked with a ``__seg`` binding-id column, every relational
        operator runs once over the stack (grouping keys on (seg, key);
        order/limit per segment), and the stack crosses to the host in ONE
        delivery before splitting per binding.  Like the shared pattern
        phase, the stacked tail's wall time / op rows / kernel and transfer
        windows are shared work and attributed to every binding's
        ``ExecStats`` — they describe the batch, not one binding's slice."""
        ts = self.ops.transfer_stats
        ks = self.ops.kernel_stats
        es = self.ops.exchange_stats
        bind_mark = ts.mark()
        kbind = ks.mark()
        ebind = es.mark()
        tb0 = time.perf_counter()
        st = ExecStats(rows_produced=shared.rows_produced,
                       op_rows=list(shared.op_rows),
                       op_times=list(shared.op_times),
                       fallbacks=dict(shared.fallbacks))
        ts.set_phase("tail")
        try:
            parts, counts = [], []
            for i, b in enumerate(bound):
                t = self._refilter(tbl, deferred, b)
                counts.append(t.nrows)
                if t.nrows:
                    parts.append(t.with_cols(
                        {"__seg": self.ops.full(t.nrows, i)}))
            if not parts:
                raise RuntimeError("stacked tail: all bindings empty")
            self._params = {}
            stacked = Table.concat(parts)
            st.log("BATCH_BIND", stacked.nrows, time.perf_counter() - tb0)
            for op in ops[1:]:
                stacked = self._run_relational_seg(stacked, op, len(bound),
                                                   st)
            ts.set_phase("deliver")
            host = self.ops.to_host(stacked)
        finally:
            ts.set_phase("")
        tail_s = time.perf_counter() - tb0
        seg = np.asarray(host.cols.pop("__seg"))
        window = ts.summary(bind_mark)
        kwindow = ks.summary(kbind)
        ewindow = es.summary(ebind)
        results = []
        for i, c in enumerate(counts):
            if c == 0:
                # empty bindings keep the loop path's host-side semantics
                # (e.g. the COUNT()-over-empty fix-up) at zero device cost
                t = Table.empty()
                bst = ExecStats(rows_produced=shared.rows_produced,
                                op_rows=list(shared.op_rows),
                                op_times=list(shared.op_times),
                                fallbacks=dict(shared.fallbacks))
                bst.log("BATCH_BIND", 0, 0.0)
                for op in ops[1:]:
                    t = self._run_relational(t, op, bst)
                if t.ops is not None:
                    t = self.ops.to_host(t)
            else:
                m = seg == i
                t = Table({k: v[m] for k, v in host.cols.items()},
                          int(m.sum()))
                bst = ExecStats(rows_produced=st.rows_produced,
                                op_rows=list(st.op_rows),
                                op_times=list(st.op_times),
                                fallbacks=dict(st.fallbacks))
            bst.wall_s = pattern_s + tail_s
            bst.transfers = {k: dict(v) for k, v in
                             pattern_transfers.items()}
            for k, v in window.items():
                ent = bst.transfers.setdefault(k, {"calls": 0, "elems": 0})
                ent["calls"] += v["calls"]
                ent["elems"] += v["elems"]
            bst.kernels = dict(pattern_kernels)
            for k, v in kwindow.items():
                bst.kernels[k] = bst.kernels.get(k, 0) + v
            exch = {k: dict(v) for k, v in pattern_exchanges.items()}
            for k, v in ewindow.items():
                ent = exch.setdefault(k, {"calls": 0, "elems": 0})
                ent["calls"] += v["calls"]
                ent["elems"] += v["elems"]
            bst.exchanges = exch or None
            results.append((t, bst))
        return results

    def _seg_head_mask(self, seg, nrows: int, k: int, limit: int):
        """Boolean mask keeping each segment's first ``limit`` rows of a
        segment-major table."""
        starts = self.ops.searchsorted(seg, self.ops.arange(k))
        pos = self.ops.arange(nrows) - self.ops.take(starts, seg)
        return pos < limit

    def _run_relational_seg(self, tbl: Table, op, k: int,
                            stats: ExecStats) -> Table:
        """Segment-aware twin of ``_run_relational``: one pass over the
        ``__seg``-stacked batch table, row-identical per segment to running
        the plain operator on that segment alone.  The stack is segment-
        major throughout (every operator preserves or re-establishes it)."""
        self._check_deadline(type(op).__name__)
        t0 = time.perf_counter()
        seg = tbl.cols["__seg"]
        if isinstance(op, ir.Select):
            if tbl.nrows:
                tbl = tbl.mask(self._eval(tbl, op.predicate).astype(bool))
            stats.log("SELECT", tbl.nrows, self._tick(tbl, t0))
            return tbl
        if isinstance(op, ir.Project):
            cols = {name: (self._eval(tbl, e) if tbl.nrows
                           else self.ops.full(0, 0))
                    for e, name in op.items}
            cols["__seg"] = seg
            out = self._table(cols, tbl.nrows)
            if op.distinct and out.nrows:
                key = self.ops.combine_keys(list(out.cols.values()))
                out = out.take(self.ops.distinct_indices(key))
            stats.log("PROJECT", out.nrows, self._tick(out, t0))
            return out
        if isinstance(op, ir.GroupBy):
            if tbl.nrows == 0:   # empty-input fix-ups are per-binding
                raise RuntimeError("stacked tail: stack emptied")
            kcols = [self._eval(tbl, e) for e, _ in op.keys]
            key = self.ops.combine_keys([seg] + kcols)
            vals = {}
            for a, name in op.aggs:
                col = (self._eval(tbl, a.arg) if a.arg is not None
                       else self.ops.full(tbl.nrows, 0))
                vals[name] = (a.fn, col)
            first, aggd = self.ops.group_reduce(key, vals)
            cols = {name: self.ops.take(kc, first)
                    for (e, name), kc in zip(op.keys, kcols)}
            cols.update(aggd)
            cols["__seg"] = self.ops.take(seg, first)
            out = self._table(cols, int(first.shape[0]))
            stats.log("GROUP", out.nrows, self._tick(out, t0))
            return out
        if isinstance(op, ir.OrderBy):
            if tbl.nrows == 0:
                return tbl
            sort_cols = []
            for e, asc in reversed(op.items):
                name = None
                if isinstance(e, ir.Var) and e.alias in tbl.cols:
                    name = e.alias
                col = tbl.cols[name] if name else self._eval_output(tbl, e)
                sort_cols.append(col if asc else -col)
            sort_cols.append(seg)            # last column = primary key
            order = self.ops.lexsort(sort_cols)
            out = tbl.take(order)
            if op.limit is not None:
                out = out.mask(self._seg_head_mask(out.cols["__seg"],
                                                   out.nrows, k, op.limit))
            return out
        if isinstance(op, ir.Limit):
            if tbl.nrows == 0:
                return tbl
            return tbl.mask(self._seg_head_mask(seg, tbl.nrows, k, op.n))
        raise RuntimeError(f"stacked tail: unsupported operator {op!r}")

    def _run_relational(self, tbl: Table, op, stats: ExecStats) -> Table:
        self._check_deadline(type(op).__name__)
        t0 = time.perf_counter()
        if isinstance(op, ir.Select):
            if tbl.nrows:
                tbl = tbl.mask(self._eval(tbl, op.predicate).astype(bool))
            stats.log("SELECT", tbl.nrows, self._tick(tbl, t0))
            return tbl
        if isinstance(op, ir.Project):
            cols = {name: (self._eval(tbl, e) if tbl.nrows
                           else self.ops.full(0, 0))
                    for e, name in op.items}
            out = self._table(cols, tbl.nrows)
            if op.distinct and out.nrows:
                key = self.ops.combine_keys(list(out.cols.values()))
                out = out.take(self.ops.distinct_indices(key))
            stats.log("PROJECT", out.nrows, self._tick(out, t0))
            return out
        if isinstance(op, ir.GroupBy):
            if tbl.nrows == 0:
                cols = {n: np.zeros(0, np.int64) for _, n in op.keys}
                for a, n in op.aggs:
                    # global aggregate over empty input: COUNT()==0
                    if not op.keys and a.fn == "COUNT":
                        return Table({n: np.array([0], np.int64)}, 1)
                    cols[n] = np.zeros(0, np.int64)
                return Table(cols, 0)
            kcols = [self._eval(tbl, e) for e, _ in op.keys]
            key = (self.ops.combine_keys(kcols) if kcols
                   else self.ops.full(tbl.nrows, 0))
            vals = {}
            for a, name in op.aggs:
                col = (self._eval(tbl, a.arg) if a.arg is not None
                       else self.ops.full(tbl.nrows, 0))
                vals[name] = (a.fn, col)
            first, aggd = self.ops.group_reduce(key, vals)
            cols = {name: self.ops.take(kc, first)
                    for (e, name), kc in zip(op.keys, kcols)}
            cols.update(aggd)
            out = self._table(cols, int(first.shape[0]))
            stats.log("GROUP", out.nrows, self._tick(out, t0))
            return out
        if isinstance(op, ir.OrderBy):
            if tbl.nrows == 0:
                return tbl
            sort_cols = []
            for e, asc in reversed(op.items):
                name = None
                if isinstance(e, ir.Var) and e.alias in tbl.cols:
                    name = e.alias
                col = tbl.cols[name] if name else self._eval_output(tbl, e)
                sort_cols.append(col if asc else -col)
            order = self.ops.lexsort(sort_cols)
            if op.limit is not None:
                order = order[:op.limit]
            return tbl.take(order)
        if isinstance(op, ir.Limit):
            return tbl.head(op.n)
        raise TypeError(op)

    def _eval_output(self, tbl: Table, e):
        """Evaluate an ORDER BY expression against output column names first
        (aggregate outputs), else as a normal expression."""
        name = repr(e)
        if name in tbl.cols:
            return tbl.cols[name]
        if isinstance(e, ir.Agg):
            raise ValueError(f"ORDER BY references aggregate {name} "
                             "not present in RETURN")
        return self._eval(tbl, e)
