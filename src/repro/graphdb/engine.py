"""Binding-table execution engine — the backend-agnostic executor core.

Executes a physical pattern plan (Scan/Expand/ExpandIntersect/Join) followed by
the relational tail of the unified-IR plan. Intermediate pattern matchings are
dense integer tables. All data-parallel work (scan, CSR expansion, WCOJ
membership probes, equi joins, grouped reductions) is delegated to the
``OperatorSet`` of the active ``PhysicalSpec`` backend (DESIGN.md §2), chosen
via ``Engine(store, backend="numpy"|"jax"|spec)``. The engine also meters the
paper's cost-model quantities: rows produced per operator (communication cost
analogue) and per-operator wall time.

Modes (used by the RBO ablation benchmarks):
- ``fuse_expand``   — ExpandGetVFusionRule on/off: fused neighbor expansion vs
  EXPAND_EDGE materializing edges then a separate GET_VERTEX gather.
- ``trim_fields``   — FieldTrimRule on/off: lazy property gathers (trimmed) vs
  eagerly materializing every property column of every bound alias at each
  step (what an untrimmed distributed plan ships between workers).
- filters inside pattern vertices/edges (FilterIntoMatchRule) are honored
  during expansion when present.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import ir
from repro.core.errors import ParamError
from repro.core.pattern import BOTH, IN, OUT, Pattern, PatternEdge
from repro.core.physical import (ExpandChainNode, ExpandNode, JoinNode,
                                 PlanNode, ScanNode)
from repro.core.physical_spec import OperatorSet, PhysicalSpec, get_spec
from repro.graphdb.storage import GraphStore

INT_MIN = np.iinfo(np.int64).min


@dataclasses.dataclass
class Table:
    cols: dict[str, np.ndarray]
    nrows: int

    @staticmethod
    def empty() -> "Table":
        return Table({}, 0)

    def take(self, idx: np.ndarray) -> "Table":
        return Table({k: v[idx] for k, v in self.cols.items()}, int(idx.shape[0]))

    def mask(self, m: np.ndarray) -> "Table":
        return Table({k: v[m] for k, v in self.cols.items()}, int(m.sum()))

    def with_cols(self, new: dict[str, np.ndarray]) -> "Table":
        cols = dict(self.cols)
        cols.update(new)
        return Table(cols, self.nrows)

    @staticmethod
    def concat(tables: list["Table"]) -> "Table":
        tables = [t for t in tables if t.nrows > 0]
        if not tables:
            return Table.empty()
        keys = tables[0].cols.keys()
        return Table({k: np.concatenate([t.cols[k] for t in tables])
                      for k in keys}, sum(t.nrows for t in tables))


@dataclasses.dataclass
class ExecStats:
    rows_produced: int = 0          # paper's intermediate-result cost
    op_rows: list = dataclasses.field(default_factory=list)
    wall_s: float = 0.0

    def log(self, opname: str, rows: int):
        self.rows_produced += rows
        self.op_rows.append((opname, rows))


class Engine:
    def __init__(self, store: GraphStore, fuse_expand: bool = True,
                 trim_fields: bool = True, max_rows: int = 100_000_000,
                 backend: str | PhysicalSpec | OperatorSet = "numpy"):
        self.store = store
        self.fuse_expand = fuse_expand
        self.trim_fields = trim_fields
        self.max_rows = max_rows
        self._params: dict = {}          # execution-time parameter bindings
        self._tindex = store.triple_index()
        if isinstance(backend, OperatorSet):
            self.ops = backend
        else:
            self.ops = get_spec(backend).operators(store)

    # ================================================================ pattern
    def _check(self, n):
        if n > self.max_rows:
            raise RuntimeError(f"intermediate blow-up: {n} rows > cap")

    def _scan(self, pattern: Pattern, alias: str, stats: ExecStats) -> Table:
        v = pattern.vertices[alias]
        parts = []
        for t in sorted(v.types):
            lo, hi = self.store.type_range(t)
            parts.append(self.ops.scan(lo, hi))
        ids = np.concatenate(parts) if parts else np.zeros(0, np.int64)
        tbl = Table({alias: ids}, ids.shape[0])
        tbl = self._apply_fused_predicates(tbl, v.predicates, stats)
        stats.log(f"SCAN({alias})", tbl.nrows)
        self._materialize(tbl, alias, pattern)
        return tbl

    def _orientations(self, e: PatternEdge, from_alias: str):
        """Yield (csr_kind, triple) pairs for expanding edge ``e`` from
        ``from_alias``. csr_kind 'out' keys the CSR by the data-edge source."""
        dirs = [OUT, IN] if e.direction == BOTH else [e.direction]
        for d in dirs:
            data_src, data_dst = (e.src, e.dst) if d == OUT else (e.dst, e.src)
            use_out = from_alias == data_src
            for t in sorted(e.triples, key=repr):
                yield ("out" if use_out else "in"), t

    def _expand_edge(self, tbl: Table, pattern: Pattern, e: PatternEdge,
                     from_alias: str, new_alias: str, stats: ExecStats) -> Table:
        """Primary expansion: bind new_alias (+ edge alias) from from_alias."""
        st = self.store
        src_ids = tbl.cols[from_alias]
        new_types = pattern.vertices[new_alias].types
        outs = []
        for kind, t in self._orientations(e, from_alias):
            keyed_type = t.src if kind == "out" else t.dst
            value_type = t.dst if kind == "out" else t.src
            if value_type not in new_types:
                continue
            lo, hi = st.type_range(keyed_type)
            m = (src_ids >= lo) & (src_ids < hi)
            if not m.any():
                continue
            rows = np.nonzero(m)[0]
            csr = (st.out_csr if kind == "out" else st.in_csr)[t]
            ridx, nbr, epos = self.ops.expand(
                csr, src_ids[rows] - lo, max_out=self.max_rows)
            part = tbl.take(rows[ridx]).with_cols({
                new_alias: nbr,
                f"{e.alias}#t": np.full(nbr.shape, self._tindex[t], np.int64),
                f"{e.alias}#p": epos,
            })
            outs.append(part)
        out = Table.concat(outs)
        self._check(out.nrows)
        return out

    def _intersect_edge(self, tbl: Table, e: PatternEdge, from_alias: str,
                        cand_alias: str) -> Table:
        """Membership probe: keep rows where edge (from_alias, cand) exists;
        bind the edge. Worst-case-optimal intersection step."""
        st = self.store
        outs = []
        src_ids = tbl.cols[from_alias]
        cand = tbl.cols[cand_alias]
        for kind, t in self._orientations(e, from_alias):
            keyed_type = t.src if kind == "out" else t.dst
            value_type = t.dst if kind == "out" else t.src
            klo, khi = st.type_range(keyed_type)
            vlo, vhi = st.type_range(value_type)
            m = ((src_ids >= klo) & (src_ids < khi) &
                 (cand >= vlo) & (cand < vhi))
            if not m.any():
                continue
            rows = np.nonzero(m)[0]
            csr = (st.out_csr if kind == "out" else st.in_csr)[t]
            local = src_ids[rows] - klo
            found, epos = self.ops.intersect(csr, local, cand[rows])
            hit = rows[found]
            if hit.size == 0:
                continue
            part = tbl.take(hit).with_cols({
                f"{e.alias}#t": np.full(hit.shape, self._tindex[t], np.int64),
                f"{e.alias}#p": epos[found],
            })
            outs.append(part)
        out = Table.concat(outs)
        self._check(out.nrows)
        return out

    def _materialize(self, tbl: Table, alias: str, pattern: Pattern):
        """Untrimmed mode: eagerly attach every property column of ``alias``
        (FieldTrimRule ablation; the shipped-bytes cost the rule removes)."""
        if self.trim_fields or tbl.nrows == 0:
            return
        v = pattern.vertices.get(alias)
        if v is None:
            return
        props = set()
        for t in v.types:
            props |= set(self.store.v_props.get(t, {}))
        for p in sorted(props):
            tbl.cols[f"__mat.{alias}.{p}"] = self.store.vertex_prop(
                tbl.cols[alias], p)

    def _apply_fused_predicates(self, tbl: Table, preds: list,
                                stats: ExecStats) -> Table:
        for p in preds or []:
            if tbl.nrows == 0:
                break
            m = self._eval(tbl, p).astype(bool)
            tbl = tbl.mask(m)
        return tbl

    def exec_pattern(self, pattern: Pattern, node: PlanNode,
                     stats: ExecStats) -> Table:
        if isinstance(node, ScanNode):
            return self._scan(pattern, node.alias, stats)
        if isinstance(node, ExpandNode):
            tbl = self.exec_pattern(pattern, node.child, stats)
            edges = list(node.edges)
            # primary expansion via the first edge
            e0 = edges[0]
            frm = e0.other(node.new_alias)
            if self.fuse_expand:
                tbl = self._expand_edge(tbl, pattern, e0, frm,
                                        node.new_alias, stats)
            else:
                # EXPAND_EDGE then a separate GET_VERTEX pass: endpoint ids
                # are re-resolved from the edge bindings and re-type-checked
                # (the work ExpandGetVFusionRule eliminates)
                tbl = self._expand_edge(tbl, pattern, e0, frm,
                                        node.new_alias, stats)
                if tbl.nrows:
                    nbr = tbl.cols[node.new_alias]
                    tidx = self.store.type_of_ids(nbr)          # extra pass
                    types = sorted(self.store._sorted_types())
                    allowed = np.zeros(len(types), dtype=bool)
                    for i, t in enumerate(self.store._sorted_types()):
                        allowed[i] = t in pattern.vertices[
                            node.new_alias].types
                    tbl = tbl.mask(allowed[tidx])
                stats.log(f"GET_VERTEX({node.new_alias})", tbl.nrows)
            # intersect the remaining edges (WCOJ step)
            for e in edges[1:]:
                frm = e.other(node.new_alias)
                tbl = self._intersect_edge(tbl, e, frm, node.new_alias)
            v = pattern.vertices[node.new_alias]
            tbl = self._apply_fused_predicates(tbl, v.predicates, stats)
            for e in edges:
                tbl = self._apply_fused_predicates(tbl, e.predicates, stats)
            stats.log(f"EXPAND(+{node.new_alias}|{len(edges)}e)", tbl.nrows)
            self._materialize(tbl, node.new_alias, pattern)
            return tbl
        if isinstance(node, ExpandChainNode):
            # fused predicate-free expand run (backend physical rewrite):
            # expand a *thin* frontier table hop-by-hop — the source column,
            # per-hop alias/edge columns and a provenance row index — and
            # gather the full binding table once at the end, instead of
            # taking every bound column through the host at every hop
            if not self.fuse_expand:
                # ExpandGetVFusion ablation: run the pre-fusion plan
                return self.exec_pattern(pattern, node.unfused(), stats)
            tbl = self.exec_pattern(pattern, node.child, stats)
            first = node.steps[0].from_alias
            cur = Table({first: tbl.cols[first],
                         "__chain_row": np.arange(tbl.nrows,
                                                  dtype=np.int64)},
                        tbl.nrows)
            for s in node.steps:
                if cur.nrows == 0:
                    break
                cur = self._expand_edge(cur, pattern, s.edge, s.from_alias,
                                        s.alias, stats)
            hops = "".join(f"+{s.alias}" for s in node.steps)
            if cur.nrows == 0:
                stats.log(f"EXPANDCHAIN({hops})", 0)
                return Table.empty()
            rows = cur.cols.pop("__chain_row")
            del cur.cols[first]          # tbl carries the original column
            out = tbl.take(rows).with_cols(cur.cols)
            stats.log(f"EXPANDCHAIN({hops})", out.nrows)
            for s in node.steps:
                self._materialize(out, s.alias, pattern)
            return out
        if isinstance(node, JoinNode):
            lt = self.exec_pattern(pattern, node.left, stats)
            rt = self.exec_pattern(pattern, node.right, stats)
            # join on the shared vertex aliases plus any other column both
            # sides bound (shared edges must bind identically on both sides)
            keys = sorted(set(node.keys) |
                          (set(lt.cols) & set(rt.cols) - {"__pad"}))
            keys = [k for k in keys if not k.startswith("__mat.")]
            lkey = self._pack_join_keys(lt, rt, keys)
            lidx, ridx = self.ops.join(lkey[0], lkey[1],
                                       max_out=self.max_rows)
            self._check(lidx.shape[0])
            cols = {k: v[lidx] for k, v in lt.cols.items()}
            for k, v in rt.cols.items():
                if k not in cols:
                    cols[k] = v[ridx]
            out = Table(cols, int(lidx.shape[0]))
            stats.log(f"JOIN({'/'.join(keys)})", out.nrows)
            return out
        raise TypeError(node)

    @staticmethod
    def _pack_join_keys(lt: Table, rt: Table, keys: list[str]):
        lcols = [lt.cols[k] for k in keys]
        rcols = [rt.cols[k] for k in keys]
        lkey = np.zeros(lt.nrows, dtype=np.int64)
        rkey = np.zeros(rt.nrows, dtype=np.int64)
        for lc, rc in zip(lcols, rcols):
            both = np.concatenate([lc, rc])
            _, inv = np.unique(both, return_inverse=True)
            card = int(inv.max()) + 1 if inv.size else 1
            lkey = lkey * card + inv[:lt.nrows]
            rkey = rkey * card + inv[lt.nrows:]
        return lkey, rkey

    # ============================================================ expressions
    def _param_value(self, name: str):
        try:
            return self._params[name]
        except KeyError:
            raise ParamError("unbound parameter at evaluation", missing=[name],
                             declared=self._params) from None

    def _eval(self, tbl: Table, e) -> np.ndarray:
        st = self.store
        if isinstance(e, ir.Lit):
            return np.full(tbl.nrows, e.value)
        if isinstance(e, ir.Param):
            return np.full(tbl.nrows, self._param_value(e.name))
        if isinstance(e, ir.Var):
            return tbl.cols[e.alias]
        if isinstance(e, ir.Prop):
            mat = tbl.cols.get(f"__mat.{e.alias}.{e.name}")
            if mat is not None:
                return mat
            if f"{e.alias}#t" in tbl.cols:   # edge alias
                return st.edge_prop(tbl.cols[f"{e.alias}#t"],
                                    tbl.cols[f"{e.alias}#p"], e.name)
            return st.vertex_prop(tbl.cols[e.alias], e.name)
        if isinstance(e, ir.Cmp):
            lhs, rhs = e.lhs, e.rhs
            l = self._eval(tbl, lhs)
            r = self._encode_rhs(lhs, rhs, tbl)
            ops = {"=": np.equal, "<>": np.not_equal, "<": np.less,
                   ">": np.greater, "<=": np.less_equal,
                   ">=": np.greater_equal}
            return ops[e.op](l, r)
        if isinstance(e, ir.InSet):
            item = self._eval(tbl, e.item)
            values = (self._param_value(e.values.name)
                      if isinstance(e.values, ir.Param) else e.values)
            vals = [self._encode_scalar(e.item, v) for v in values]
            return np.isin(item, np.asarray(vals, dtype=np.int64))
        if isinstance(e, ir.BoolOp):
            if e.op == "NOT":
                return ~self._eval(tbl, e.args[0]).astype(bool)
            acc = self._eval(tbl, e.args[0]).astype(bool)
            for a in e.args[1:]:
                if e.op == "AND":
                    acc = acc & self._eval(tbl, a).astype(bool)
                else:
                    acc = acc | self._eval(tbl, a).astype(bool)
            return acc
        raise TypeError(f"cannot evaluate {e!r}")

    def _encode_scalar(self, lhs, value):
        if isinstance(value, str):
            if isinstance(lhs, ir.Prop):
                return self.store.encode_str(lhs.name, value)
            return -1
        return value

    def _encode_rhs(self, lhs, rhs, tbl):
        if isinstance(rhs, ir.Lit):
            return self._encode_scalar(lhs, rhs.value)
        if isinstance(rhs, ir.Param):
            return self._encode_scalar(lhs, self._param_value(rhs.name))
        return self._eval(tbl, rhs)

    # ============================================================= relational
    def bind_params(self, plan: ir.LogicalPlan,
                    params: dict | None = None) -> dict:
        """Resolve execution-time bindings against the plan's declared
        parameter set.  Build-time bindings (``plan.params``) act as
        defaults; ``params`` overrides them.  Raises ``ParamError`` on a
        binding that names no declared parameter, or on a referenced
        parameter left unbound."""
        referenced = plan.referenced_params()
        declared = referenced | set(plan.params)
        provided = dict(params or {})
        extra = set(provided) - declared
        if extra:
            raise ParamError("binding names no declared parameter",
                             extra=extra, declared=declared)
        # structural params (hop counts baked into the pattern shape, as
        # recorded by GraphIrBuilder) cannot be rebound: silently accepting
        # a different value would lie about what executes.  Other build-time
        # bindings that no expression references are simply unused and may
        # be re-supplied freely (shared bindings dicts across queries).
        structural = plan.hints.get("structural_params") or {}
        rebound = {k for k, v in provided.items()
                   if k in structural and structural[k] != v}
        if rebound:
            raise ParamError(
                "structural parameter(s) were bound at build time and "
                "cannot be rebound at execution — re-prepare instead",
                extra=rebound, declared=declared)
        effective = {**plan.params, **provided}
        missing = referenced - set(effective)
        if missing:
            raise ParamError("unbound parameter(s)", missing=missing,
                             declared=declared)
        return effective

    def run(self, plan: ir.LogicalPlan, pattern_plan: PlanNode | None = None,
            params: dict | None = None):
        """Execute a logical plan; returns (result Table, ExecStats).
        ``params`` binds the plan's late-bound ``ir.Param`` nodes."""
        from repro.core.physical import default_left_deep_plan
        self._params = self.bind_params(plan, params)
        stats = ExecStats()
        t0 = time.perf_counter()
        ops = list(plan.ops)
        if not isinstance(ops[0], ir.MatchPattern):
            raise ValueError("plan must start with MATCH_PATTERN")
        pattern = ops[0].pattern
        node = pattern_plan or default_left_deep_plan(pattern)
        tbl = self.exec_pattern(pattern, node, stats)
        for op in ops[1:]:
            tbl = self._run_relational(tbl, op, stats)
        stats.wall_s = time.perf_counter() - t0
        return tbl, stats

    def _run_relational(self, tbl: Table, op, stats: ExecStats) -> Table:
        if isinstance(op, ir.Select):
            if tbl.nrows:
                tbl = tbl.mask(self._eval(tbl, op.predicate).astype(bool))
            stats.log("SELECT", tbl.nrows)
            return tbl
        if isinstance(op, ir.Project):
            cols = {name: (self._eval(tbl, e) if tbl.nrows
                           else np.zeros(0, np.int64))
                    for e, name in op.items}
            out = Table(cols, tbl.nrows)
            if op.distinct and out.nrows:
                key = self.ops.combine_keys(list(out.cols.values()))
                _, first = np.unique(key, return_index=True)
                out = out.take(np.sort(first))
            stats.log("PROJECT", out.nrows)
            return out
        if isinstance(op, ir.GroupBy):
            if tbl.nrows == 0:
                cols = {n: np.zeros(0, np.int64) for _, n in op.keys}
                for a, n in op.aggs:
                    # global aggregate over empty input: COUNT()==0
                    if not op.keys and a.fn == "COUNT":
                        return Table({n: np.array([0], np.int64)}, 1)
                    cols[n] = np.zeros(0, np.int64)
                return Table(cols, 0)
            kcols = [self._eval(tbl, e) for e, _ in op.keys]
            key = (self.ops.combine_keys(kcols) if kcols
                   else np.zeros(tbl.nrows, dtype=np.int64))
            vals = {}
            for a, name in op.aggs:
                col = (self._eval(tbl, a.arg) if a.arg is not None
                       else np.zeros(tbl.nrows, np.int64))
                vals[name] = (a.fn, col)
            first, aggd = self.ops.group_reduce(key, vals)
            cols = {name: kc[first] for (e, name), kc in zip(op.keys, kcols)}
            cols.update(aggd)
            out = Table(cols, first.shape[0])
            stats.log("GROUP", out.nrows)
            return out
        if isinstance(op, ir.OrderBy):
            if tbl.nrows == 0:
                return tbl
            sort_cols = []
            for e, asc in reversed(op.items):
                name = None
                if isinstance(e, ir.Var) and e.alias in tbl.cols:
                    name = e.alias
                col = tbl.cols[name] if name else self._eval_output(tbl, e)
                sort_cols.append(col if asc else -col)
            order = np.lexsort(sort_cols)
            if op.limit is not None:
                order = order[:op.limit]
            return tbl.take(order)
        if isinstance(op, ir.Limit):
            idx = np.arange(min(op.n, tbl.nrows))
            return tbl.take(idx)
        raise TypeError(op)

    def _eval_output(self, tbl: Table, e):
        """Evaluate an ORDER BY expression against output column names first
        (aggregate outputs), else as a normal expression."""
        name = repr(e)
        if name in tbl.cols:
            return tbl.cols[name]
        if isinstance(e, ir.Agg):
            raise ValueError(f"ORDER BY references aggregate {name} "
                             "not present in RETURN")
        return self._eval(tbl, e)
