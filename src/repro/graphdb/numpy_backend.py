"""Numpy backend — the host path of the binding-table engine.

Registers the ``"numpy"`` PhysicalSpec: every core operator is the
corresponding ``repro.graphdb.vecops`` primitive (flat gathers, sorted
binary search, sort-merge join, segmented reductions), and the v2 array
primitives (``take``/``mask``/``concat``/...) are the host-numpy defaults
inherited from ``OperatorSet`` — for this backend ``to_host`` is the
identity and ``transfer_stats`` stays empty.  This is the seed engine's
original execution path, declared through the registry (DESIGN.md §2/§7).
"""
from __future__ import annotations

import numpy as np

from repro.core.physical_spec import (CostParams, OperatorSet, PhysicalSpec,
                                      register_spec)
from repro.graphdb import vecops


class NumpyOperators(OperatorSet):
    name = "numpy"

    def scan(self, lo: int, hi: int) -> np.ndarray:
        return np.arange(lo, hi, dtype=np.int64)

    def expand(self, csr, rows_local, max_out=None):
        return vecops.expand_csr(csr.indptr, csr.indices, rows_local,
                                 csr.pos, max_out=max_out)

    def intersect(self, csr, rows_local, targets):
        found, pos = vecops.bounded_binary_search(
            csr.indices, csr.indptr[rows_local],
            csr.indptr[rows_local + 1], targets)
        epos = np.zeros(pos.shape, dtype=np.int64)
        if found.any():
            fpos = pos[found]
            epos[found] = csr.pos[fpos] if csr.pos is not None else fpos
        return found, epos

    def join(self, lkeys, rkeys, max_out=None):
        return vecops.equi_join(lkeys, rkeys, max_out=max_out)

    def combine_keys(self, cols):
        return vecops.combine_keys(cols)

    def group_reduce(self, keys, values):
        return vecops.group_reduce(keys, values)


NUMPY_SPEC = register_spec(PhysicalSpec(
    name="numpy",
    make_operators=NumpyOperators,
    cost=CostParams(),
    description="host numpy vecops path (sorted-CSR binary search WCOJ)",
))
