"""Columnar property-graph storage.

Vertices get global ids range-partitioned by type (type t owns
``[v_offset[t], v_offset[t]+v_count[t])``), so SCAN is an iota and the type of
an id is a ``searchsorted``. Each edge triple (src_type, label, dst_type) is
stored as a *sorted-CSR pair* (out of src, in of dst) — sorted adjacency is
what enables the worst-case-optimal intersection step (and the Pallas
``wcoj_intersect`` kernel) on TPU.

On a production mesh this structure shards by vertex over the ``data`` axis —
indptr/indices are plain arrays with no pointers, exactly the layout pjit
partitions. Here it lives in host numpy with jnp views for the jit paths.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.schema import EdgeTriple, GraphSchema


@dataclasses.dataclass
class CSR:
    """One direction of one edge triple. indices are *global* vertex ids,
    sorted within each row. ``pos``: for the IN direction, position of each
    entry in the OUT direction's indices (edge identity for properties)."""
    indptr: np.ndarray      # int64[n_rows+1] over local ids of the keyed type
    indices: np.ndarray     # int64[nnz] global neighbor ids (sorted per row)
    pos: np.ndarray | None = None   # int64[nnz] edge position in OUT order

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)


@dataclasses.dataclass
class GraphStore:
    schema: GraphSchema
    v_offset: dict[str, int]            # type -> first global id
    v_count: dict[str, int]
    out_csr: dict[EdgeTriple, CSR]
    in_csr: dict[EdgeTriple, CSR]
    # vertex properties: type -> prop -> int64 column (strings dict-encoded)
    v_props: dict[str, dict[str, np.ndarray]]
    # edge properties: triple -> prop -> int64 column aligned with OUT order
    e_props: dict[EdgeTriple, dict[str, np.ndarray]]
    str_vocab: dict[str, dict[str, int]]  # prop name -> string -> code

    # ------------------------------------------------------------------ meta
    @property
    def n_vertices(self) -> int:
        return sum(self.v_count.values())

    @property
    def n_edges(self) -> int:
        return sum(c.nnz for c in self.out_csr.values())

    def type_range(self, vtype: str) -> tuple[int, int]:
        o = self.v_offset[vtype]
        return o, o + self.v_count[vtype]

    def _sorted_types(self):
        return sorted(self.v_offset, key=lambda t: self.v_offset[t])

    def type_of_ids(self, ids: np.ndarray) -> np.ndarray:
        """Type *index* (into sorted_types order) for each global id."""
        types = self._sorted_types()
        bounds = np.array([self.v_offset[t] for t in types] +
                          [self.n_vertices], dtype=np.int64)
        return np.searchsorted(bounds, ids, side="right") - 1

    def encode_str(self, prop: str, value: str) -> int:
        return self.str_vocab.get(prop, {}).get(value, -1)

    # -------------------------------------------------------------- property
    def vertex_prop(self, ids: np.ndarray, prop: str) -> np.ndarray:
        """Gather property values for global ids (possibly of mixed type).
        Missing (type has no such prop) -> INT64_MIN sentinel."""
        out = np.full(ids.shape, np.iinfo(np.int64).min, dtype=np.int64)
        types = self._sorted_types()
        tidx = self.type_of_ids(ids)
        for i, t in enumerate(types):
            col = self.v_props.get(t, {}).get(prop)
            if col is None:
                continue
            m = tidx == i
            if not m.any():
                continue
            out[m] = col[ids[m] - self.v_offset[t]]
        return out

    def edge_prop(self, triple_ids: np.ndarray, pos: np.ndarray,
                  prop: str) -> np.ndarray:
        out = np.full(pos.shape, np.iinfo(np.int64).min, dtype=np.int64)
        triples = sorted(self.out_csr, key=repr)
        for i, t in enumerate(triples):
            col = self.e_props.get(t, {}).get(prop)
            if col is None:
                continue
            m = triple_ids == i
            if not m.any():
                continue
            out[m] = col[pos[m]]
        return out

    def triple_index(self) -> dict[EdgeTriple, int]:
        return {t: i for i, t in enumerate(sorted(self.out_csr, key=repr))}


def build_store(schema: GraphSchema,
                v_count: dict[str, int],
                edges: dict[EdgeTriple, tuple[np.ndarray, np.ndarray]],
                v_props: dict[str, dict[str, np.ndarray]] | None = None,
                e_props: dict[EdgeTriple, dict[str, np.ndarray]] | None = None,
                str_vocab: dict[str, dict[str, int]] | None = None,
                ) -> GraphStore:
    """Assemble a GraphStore from per-triple (src_local, dst_local) edge lists.

    ``edges[t] = (src_local_ids, dst_local_ids)`` with local ids in
    ``[0, v_count[type])``. Duplicate edges are removed.
    """
    v_offset, off = {}, 0
    for t in schema.vertex_types:
        v_offset[t] = off
        off += int(v_count.get(t, 0))

    out_csr: dict[EdgeTriple, CSR] = {}
    in_csr: dict[EdgeTriple, CSR] = {}
    e_props = dict(e_props or {})
    for triple, (src, dst) in edges.items():
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        ns, nd = v_count[triple.src], v_count[triple.dst]
        if src.size:
            if src.max() >= ns or dst.max() >= nd:
                raise ValueError(f"edge endpoints out of range for {triple}")
        # dedupe
        key = src * nd + dst
        key, uniq_idx = np.unique(key, return_index=True)
        src, dst = key // nd, key % nd
        gsrc = src + v_offset[triple.src]
        gdst = dst + v_offset[triple.dst]
        # out CSR (rows = src local, sorted by (src, gdst) — unique already is)
        indptr = np.zeros(ns + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        out_csr[triple] = CSR(indptr, gdst.copy())
        # edge props follow the dedupe/sort order
        if triple in e_props:
            e_props[triple] = {k: np.asarray(v)[uniq_idx]
                               for k, v in e_props[triple].items()}
        # in CSR: sort by (dst, gsrc); remember out-order position
        order = np.lexsort((gsrc, dst))
        indptr_in = np.zeros(nd + 1, dtype=np.int64)
        np.add.at(indptr_in, dst + 1, 1)
        indptr_in = np.cumsum(indptr_in)
        in_csr[triple] = CSR(indptr_in, gsrc[order], pos=order.astype(np.int64))

    return GraphStore(schema=schema, v_offset=v_offset,
                      v_count={t: int(v_count.get(t, 0))
                               for t in schema.vertex_types},
                      out_csr=out_csr, in_csr=in_csr,
                      v_props=v_props or {}, e_props=e_props,
                      str_vocab=str_vocab or {})


def encode_strings(values: list[str], vocab: dict[str, int]) -> np.ndarray:
    out = np.empty(len(values), dtype=np.int64)
    for i, v in enumerate(values):
        if v not in vocab:
            vocab[v] = len(vocab)
        out[i] = vocab[v]
    return out
