from repro.kernels.wcoj_intersect.ops import wcoj_intersect  # noqa: F401
