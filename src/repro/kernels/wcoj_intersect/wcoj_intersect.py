"""Pallas TPU kernel: worst-case-optimal-join membership probe.

The expand-and-intersect step of GOpt's WCOJ plans: for every binding-table
row, test whether candidate vertex ``target[i]`` occurs in the sorted
adjacency row ``adj[i, :deg[i]]`` (padded ELL layout, -1 padding).

TPU adaptation (DESIGN.md): a GPU WCOJ uses per-thread binary search; on the
TPU VPU a *vectorized compare-scan* over the VMEM-resident adjacency tile
beats serialized log-step gathers for the degree ranges the engine feeds
(D_max <= 1024) — 8x128 vector lanes compare an entire row block per cycle.
The engine splits higher-degree rows before calling.

Layout: adj [R, D_max] int32 (rows sorted ascending, -1 padded), target [R]
int32. Grid tiles rows; each tile loads [TR, D_max] into VMEM, broadcasts the
target lane, reduces equality masks. Outputs: found [R] int32 (0/1) and
pos [R] int32 (index within the row, or -1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(adj_ref, tgt_ref, found_ref, pos_ref):
    adj = adj_ref[...]                       # [TR, D]
    tgt = tgt_ref[...]                       # [TR]
    eq = adj == tgt[:, None]                 # [TR, D] vectorized compare
    found = jnp.any(eq, axis=1)
    # position of the hit (rows are sorted & unique -> at most one hit)
    idx = jnp.argmax(eq, axis=1).astype(jnp.int32)
    found_ref[...] = found.astype(jnp.int32)
    pos_ref[...] = jnp.where(found, idx, -1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wcoj_intersect_pallas(adj: jax.Array, target: jax.Array,
                          block_rows: int = 256,
                          interpret: bool = True):
    """adj [R, D] int32 sorted rows (-1 pad); target [R] int32.
    Returns (found [R] int32, pos [R] int32)."""
    R, D = adj.shape
    pad = (-R) % block_rows
    if pad:
        adj = jnp.pad(adj, ((0, pad), (0, 0)), constant_values=-1)
        target = jnp.pad(target, (0, pad), constant_values=-2)
    Rp = adj.shape[0]
    grid = (Rp // block_rows,)
    found, pos = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Rp,), jnp.int32),
            jax.ShapeDtypeStruct((Rp,), jnp.int32),
        ],
        interpret=interpret,
    )(adj, target)
    return found[:R], pos[:R]
