"""jit'd wrapper: picks the Pallas kernel (interpret on CPU, compiled on
TPU) and handles the CSR -> padded-ELL row materialization."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wcoj_intersect.wcoj_intersect import wcoj_intersect_pallas


def wcoj_intersect(adj: jax.Array, target: jax.Array,
                   block_rows: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return wcoj_intersect_pallas(adj, target, block_rows=block_rows,
                                 interpret=interpret)


def gather_rows(indices: jax.Array, indptr: jax.Array, rows: jax.Array,
                d_max: int) -> jax.Array:
    """CSR rows -> padded ELL [R, d_max] (host-side prep for the kernel)."""
    start = indptr[rows]
    deg = indptr[rows + 1] - start
    offs = jnp.arange(d_max)[None, :]
    valid = offs < deg[:, None]
    flat = jnp.clip(start[:, None] + offs, 0, indices.shape[0] - 1)
    return jnp.where(valid, indices[flat], -1)
