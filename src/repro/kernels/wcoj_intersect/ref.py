"""Pure-jnp oracle for the WCOJ membership probe."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wcoj_intersect_ref(adj: jax.Array, target: jax.Array):
    eq = adj == target[:, None]
    found = jnp.any(eq, axis=1)
    pos = jnp.where(found, jnp.argmax(eq, axis=1).astype(jnp.int32), -1)
    return found.astype(jnp.int32), pos
