"""Pallas TPU kernel: FlashAttention forward (causal / sliding-window /
logit-softcap), the LM hot spot.

Grid: (batch*heads, n_q_blocks, n_kv_blocks), kv innermost so the online
softmax accumulators (m, l, acc) live in VMEM scratch across kv steps. Block
shapes keep the working set (q tile, kv tile, p tile, acc) inside ~16MB VMEM
with MXU-aligned dims (q_block x head_dim and kv_block x head_dim tiles,
head_dim padded to 128 by the wrapper when needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, bq, bkv, n_kv):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # [bq, d]
    k = k_ref[0].astype(jnp.float32)          # [bkv, d]
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T) * scale               # [bq, bkv] (MXU)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    kv_pos = kv_i * bkv + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                        # [bq, 1]
    m_new = jnp.maximum(m_prev[:, 0], s.max(axis=1))[:, None]
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)             # [bq, 1]
    l_new = l_scr[...] * corr + p.sum(axis=1)[:, None]
    acc_new = acc_scr[...] * corr + jnp.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(kv_i == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                              "block_kv", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           softcap=None, block_q=128, block_kv=128,
                           interpret=True):
    """q [B, H, Sq, d]; k, v [B, H, Skv, d] (pre-broadcast GQA groups).
    Returns [B, H, Sq, d]."""
    B, H, Sq, d = q.shape
    Skv = k.shape[2]
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, "wrapper pads to block multiples"
    qr = q.reshape(B * H, Sq, d)
    kr = k.reshape(B * H, Skv, d)
    vr = v.reshape(B * H, Skv, d)
    n_q, n_kv = Sq // bq, Skv // bkv
    grid = (B * H, n_q, n_kv)
    kernel = functools.partial(
        _kernel, scale=1.0 / (d ** 0.5), causal=causal, window=window,
        softcap=softcap, bq=bq, bkv=bkv, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, d)
