"""jit'd wrapper: pads sequence/head dims to block multiples, broadcasts GQA
groups, and dispatches to the Pallas kernel (interpret on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    block_q=128, block_kv=128, interpret=None):
    """q [B,H,Sq,d]; k/v [B,Hkv,Skv,d] with H % Hkv == 0."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, Sq, d = q.shape
    Hkv = k.shape[1]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    Skv = k.shape[2]
    bq = min(block_q, max(Sq, 8))
    bkv = min(block_kv, max(Skv, 8))
    pq = (-Sq) % bq
    pkv = (-Skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        # padded kv positions must never win the softmax: causal masking
        # already excludes them for decode; for bidirectional use window
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=softcap, block_q=bq, block_kv=bkv,
                                 interpret=interpret)
    return out[:, :, :Sq]
