"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None):
    """q [B,H,Sq,d], k/v [B,H,Skv,d] -> [B,H,Sq,d] (fp32 math)."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    Sq, Skv = q.shape[2], k.shape[2]
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
