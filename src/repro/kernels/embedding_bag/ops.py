"""jit'd wrapper with batch/vocab padding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_pallas


def embedding_bag(ids: jax.Array, table: jax.Array, block_b: int = 128,
                  block_v: int = 512, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, L = ids.shape
    V, D = table.shape
    bb, bv = min(block_b, B), min(block_v, V)
    pb, pv = (-B) % bb, (-V) % bv
    if pb:
        ids = jnp.pad(ids, ((0, pb), (0, 0)), constant_values=-1)
    if pv:
        table = jnp.pad(table, ((0, pv), (0, 0)))
    out = embedding_bag_pallas(ids, table, block_b=bb, block_v=bv,
                               interpret=interpret)
    return out[:B]
