"""Pallas TPU kernel: embedding-bag (multi-hot lookup + bag-sum).

TPU adaptation (DESIGN.md): GPUs do random-access row gathers; the TPU has no
fast HBM gather, so the classic MXU formulation tiles the table over the grid
and turns lookups into one-hot matmuls: for each (batch tile, table tile),
``onehot(ids in tile) @ table_tile`` accumulates into the output rows.
Production TPU serving offloads this to SparseCore; this kernel is the
TensorCore fallback and the oracle-checked stand-in.

ids [B, L] int32 (-1 padding; already offset into the concatenated table),
table [V, D] -> out [B, D] (sum over the L bag slots).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, tab_ref, o_ref, acc_scr, *, bv, n_v):
    v_i = pl.program_id(1)

    @pl.when(v_i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ids = ids_ref[...]                       # [bb, L]
    tab = tab_ref[...]                       # [bv, D]
    lo = v_i * bv
    local = ids - lo                          # [bb, L]
    in_tile = (local >= 0) & (local < bv) & (ids >= 0)
    # one-hot [bb, bv] summed over bag slots -> counts matrix, then MXU
    iot = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], ids.shape[1],
                                               bv), 2)
    onehot = (iot == local[..., None]) & in_tile[..., None]
    counts = onehot.sum(axis=1).astype(jnp.float32)   # [bb, bv]
    acc_scr[...] += jnp.dot(counts, tab.astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(v_i == n_v - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_v",
                                             "interpret"))
def embedding_bag_pallas(ids: jax.Array, table: jax.Array,
                         block_b: int = 128, block_v: int = 512,
                         interpret: bool = True) -> jax.Array:
    B, L = ids.shape
    V, D = table.shape
    bb, bv = min(block_b, B), min(block_v, V)
    assert B % bb == 0 and V % bv == 0, "wrapper pads"
    grid = (B // bb, V // bv)
    kernel = functools.partial(_kernel, bv=bv, n_v=V // bv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, L), lambda b, v: (b, 0)),
            pl.BlockSpec((bv, D), lambda b, v: (v, 0)),
        ],
        out_specs=pl.BlockSpec((bb, D), lambda b, v: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        scratch_shapes=[pltpu.VMEM((bb, D), jnp.float32)],
        interpret=interpret,
    )(ids, table)
