"""Pure-jnp oracle: gather + masked bag-sum (the engine's formulation)."""
import jax.numpy as jnp


def embedding_bag_ref(ids, table):
    mask = ids >= 0
    emb = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    return (emb * mask[..., None].astype(table.dtype)).sum(axis=1)
