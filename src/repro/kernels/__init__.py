# Pallas TPU kernels for the compute hot-spots (validated in interpret mode):
#   wcoj_intersect  — GOpt's worst-case-optimal-join membership probe
#   flash_attention — LM train/prefill attention (online softmax)
#   grouped_matmul  — MoE expert FFN / eSCN SO(2) grouped GEMM
#   embedding_bag   — recsys multi-hot lookup-reduce (one-hot MXU trick)
