"""jit'd wrapper with padding to MXU-aligned block multiples."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.grouped_matmul.grouped_matmul import grouped_matmul_pallas


def grouped_matmul(x: jax.Array, w: jax.Array, block_m: int = 128,
                   block_n: int = 128, block_k: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    G, M, K = x.shape
    _, _, N = w.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, 0), (0, pk), (0, pn)))
    out = grouped_matmul_pallas(x, w, block_m=bm, block_n=bn, block_k=bk,
                                interpret=interpret)
    return out[:, :M, :N]
