"""Pallas TPU kernel: grouped GEMM — x [G, M, K] @ w [G, K, N] -> [G, M, N].

The expert-FFN hot spot of the MoE architectures (olmoe / moonshot dispatch
buffers [E, C, D] x [E, D, F]) and the eSCN SO(2) mixings of EquiformerV2.
Grid (G, M/bm, N/bn, K/bk) with K innermost; partial products accumulate in a
fp32 VMEM scratch tile and flush to the output on the last K step — the
canonical MXU blocking (bm x bk and bk x bn tiles, 128-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_scr, *, n_k):
    k_i = pl.program_id(3)

    @pl.when(k_i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]                                   # [bm, bk]
    w = w_ref[0]                                   # [bk, bn]
    acc_scr[...] += jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k_i == n_k - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def grouped_matmul_pallas(x: jax.Array, w: jax.Array, block_m: int = 128,
                          block_n: int = 128, block_k: int = 128,
                          interpret: bool = True) -> jax.Array:
    G, M, K = x.shape
    _, _, N = w.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        "wrapper pads to block multiples"
    grid = (G, M // bm, N // bn, K // bk)
    kernel = functools.partial(_kernel, n_k=K // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
