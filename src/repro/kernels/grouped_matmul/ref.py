"""Pure-jnp oracle for grouped matmul."""
import jax.numpy as jnp


def grouped_matmul_ref(x, w):
    return jnp.einsum("gmk,gkn->gmn", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)
