import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell: build the production
mesh, lower the step with full shardings, ``.compile()``, and record
memory_analysis / cost_analysis / scan-aware roofline terms.

The XLA_FLAGS line above MUST stay the first statement — jax locks the device
count at first init. Do not import this module from tests.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import get_bundle, list_archs          # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.launch.roofline import analyze_hlo, summarize  # noqa: E402
from repro.models.sharding import hint_context            # noqa: E402


def run_cell(arch: str, shape: str, multi_pod: bool,
             with_roofline: bool = True) -> dict:
    t0 = time.time()
    bundle = get_bundle(arch)
    spec = bundle.shapes[shape]
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": spec.kind}
    if spec.skip:
        rec["status"] = "SKIPPED"
        rec["reason"] = spec.skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    step = bundle.make_step(shape)
    args = bundle.input_specs(shape)
    in_sh, out_sh, hints = bundle.shardings(mesh, shape)
    try:
        with hint_context(hints):
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        rec.update({
            "status": "OK",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "bytes_per_device": {
                "arguments": int(ma.argument_size_in_bytes),
                "outputs": int(ma.output_size_in_bytes),
                "temps": int(ma.temp_size_in_bytes),
                "total_gb": round((ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes) / 2**30, 3),
            },
            "xla_cost_analysis": {
                "flops_body_once": float(ca.get("flops", 0.0)),
                "bytes_body_once": float(ca.get("bytes accessed", 0.0)),
            },
        })
        if with_roofline:
            terms = analyze_hlo(compiled.as_text())
            chips = mesh.devices.size
            mf = bundle.model_flops(shape)
            rec["roofline"] = summarize(terms, mf / chips if mf else 0.0)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-roofline", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results = []
    for arch in archs:
        bundle = get_bundle(arch)
        shapes = ([args.shape] if args.shape else bundle.shape_names())
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp,
                               with_roofline=not args.no_roofline)
                results.append(rec)
                status = rec["status"]
                extra = ""
                if status == "OK":
                    extra = (f"mem={rec['bytes_per_device']['total_gb']}GB "
                             f"compile={rec['compile_s']}s")
                    if "roofline" in rec:
                        r = rec["roofline"]
                        extra += (f" dom={r['dominant']}"
                                  f" Tc={r['t_compute_s']:.3g}"
                                  f" Tm={r['t_memory_s']:.3g}"
                                  f" Tx={r['t_collective_s']:.3g}")
                elif status == "FAIL":
                    extra = rec["error"][:200]
                else:
                    extra = rec["reason"][:80]
                print(f"[{status:7s}] {arch:22s} {shape:14s} "
                      f"{rec['mesh']:8s} {extra}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"{len(results)} cells, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
