"""Roofline-term extraction from compiled HLO (CPU container, TPU target).

``compiled.cost_analysis()`` on XLA:CPU is per-device AND counts while-loop
(lax.scan) bodies exactly once — verified by calibration (see tests). This
module therefore parses the optimized HLO text itself:

- splits the module into computations and builds the call graph
  (fusion ``calls=``, ``to_apply=``, while ``condition=/body=``, conditional
  branches);
- recovers while trip counts from the loop-condition constants;
- counts dot FLOPs (2 * |out| * K) per computation, multiplied by the
  product of enclosing trip counts;
- models memory traffic at fusion boundaries (operands + outputs of every
  non-fused op);
- sums collective bytes per collective kind, with the same multipliers.

Terms (TPU v5e-like):
    T_compute    = flops_per_chip / 197e12
    T_memory     = bytes_per_chip / 819e9
    T_collective = collective_bytes_per_chip / 50e9
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-chip effective)

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast",
                "ragged-all-to-all")


def shape_bytes(type_str: str) -> float:
    """Total bytes of every dtype[dims] occurrence in a type string."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpLine:
    name: str
    opcode: str
    out_type: str
    operands: list[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[OpLine]
    is_fused: bool
    op_types: dict[str, str]    # op name -> output type string


_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|\S+))\s+([\w\-]+)\(")
_REF_RE = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and stripped.endswith("{"):
            m = _COMP_HEAD.match(stripped)
            if m:
                name = m.group(1)
                cur = Computation(name, [], "fused_computation" in name, {})
                comps[name] = cur
            else:
                cur = None
            continue
        if cur is None:
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        opname, out_type, opcode = m.group(1), m.group(2), m.group(3)
        # operand names: references inside the parens, before metadata
        paren = line[line.find(opcode + "(") + len(opcode):]
        refs = _REF_RE.findall(paren)
        cur.ops.append(OpLine(opname, opcode, out_type, refs, line))
        cur.op_types[opname] = out_type
    return comps


def _called_comps(op: OpLine) -> list[str]:
    out = []
    for kw in ("calls=", "to_apply=", "condition=", "body="):
        i = op.raw.find(kw)
        if i >= 0:
            m = _REF_RE.match(op.raw[i + len(kw):].lstrip())
            if m:
                out.append(m.group(1))
    i = op.raw.find("branch_computations={")
    if i >= 0:
        seg = op.raw[i:op.raw.find("}", i)]
        out.extend(_REF_RE.findall(seg))
    return out


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition — scan trip count."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.raw)
            if m:
                best = max(best, int(m.group(1)))
    return best


def compute_multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    """Execution-count multiplier per computation (ENTRY = 1)."""
    mult: dict[str, float] = defaultdict(float)
    # root computations: never called by others (ENTRY et al.)
    called = set()
    for c in comps.values():
        for op in c.ops:
            for t in _called_comps(op):
                called.add(t)
    roots = [n for n in comps if n not in called]
    for r in roots:
        mult[r] = max(mult[r], 1.0)
    # propagate in topological-ish order via worklist
    work = list(roots)
    while work:
        name = work.pop()
        c = comps.get(name)
        if c is None:
            continue
        m = mult[name]
        for op in c.ops:
            targets = _called_comps(op)
            if not targets:
                continue
            if op.opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.raw)
                cm = re.search(r"condition=%?([\w.\-]+)", op.raw)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trip = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    if mult[body] < m * trip:
                        mult[body] = m * trip
                        work.append(body)
                if cond:
                    if mult[cond] < m * (trip + 1):
                        mult[cond] = m * (trip + 1)
                        work.append(cond)
                continue
            for t in targets:
                if mult[t] < m:
                    mult[t] = m
                    work.append(t)
    return dict(mult)


def _dot_flops(op: OpLine, comp: Computation) -> float:
    out_elems = 1.0
    m = _SHAPE_RE.search(op.out_type)
    if m and m.group(2):
        for d in m.group(2).split(","):
            out_elems *= int(d)
    # contraction size from lhs shape + lhs_contracting_dims
    lhs_name = op.operands[0] if op.operands else None
    lhs_type = comp.op_types.get(lhs_name, "")
    lm = _SHAPE_RE.search(lhs_type)
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.raw)
    k = 1.0
    if lm and cdims and lm.group(2):
        dims = [int(x) for x in lm.group(2).split(",")]
        for ci in cdims.group(1).split(","):
            if ci:
                k *= dims[int(ci)]
    return 2.0 * out_elems * k


@dataclasses.dataclass
class RooflineTerms:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(default_factory=dict)
    n_collectives: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant(),
            "collectives": self.collective_breakdown,
        }


def analyze_hlo(text: str) -> RooflineTerms:
    comps = parse_hlo(text)
    mult = compute_multipliers(comps)
    out = RooflineTerms()
    for name, c in comps.items():
        m = mult.get(name, 1.0)
        if c.is_fused:
            continue  # accounted at the fusion call site
        for op in c.ops:
            if op.opcode == "dot":
                out.flops += m * _dot_flops(op, c)
            # Memory-traffic model: count bytes only at boundaries a TPU
            # compiler cannot fuse away — matmuls, fusions, reductions,
            # scatter/gather/sort, dynamic (update-)slices, collectives.
            # Standalone elementwise/layout ops on the XLA:CPU dump are
            # assumed fused into neighbors on the TPU target (documented in
            # EXPERIMENTS.md §Roofline-method).
            if op.opcode in ("fusion", "dot", "convolution", "reduce",
                             "scatter", "gather", "sort",
                             "dynamic-slice", "dynamic-update-slice",
                             "reduce-window",
                             "custom-call") or op.opcode in _COLLECTIVES:
                b = shape_bytes(op.out_type)
                for operand in op.operands:
                    t = c.op_types.get(operand)
                    if t:
                        b += shape_bytes(t)
                out.bytes += m * b
            if op.opcode in _COLLECTIVES:
                cb = max(shape_bytes(op.out_type),
                         sum(shape_bytes(c.op_types.get(o, ""))
                             for o in op.operands))
                out.collective_bytes += m * cb
                key = op.opcode
                out.collective_breakdown[key] = (
                    out.collective_breakdown.get(key, 0.0) + m * cb)
                out.n_collectives += int(m)
        # fused computations: count dot flops inside at the caller multiplier
    for name, c in comps.items():
        if not c.is_fused:
            continue
        m = mult.get(name, 1.0)
        for op in c.ops:
            if op.opcode == "dot":
                out.flops += m * _dot_flops(op, c)
    return out


def summarize(terms: RooflineTerms, model_flops_per_chip: float) -> dict:
    d = terms.as_dict()
    d["model_flops_per_chip"] = model_flops_per_chip
    d["useful_flops_ratio"] = (model_flops_per_chip / terms.flops
                               if terms.flops else 0.0)
    t_bound = max(terms.t_compute, terms.t_memory, terms.t_collective)
    d["roofline_fraction"] = (
        (model_flops_per_chip / PEAK_FLOPS) / t_bound if t_bound else 0.0)
    return d
