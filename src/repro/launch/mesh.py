"""Production mesh construction.

Must stay a FUNCTION (importing this module never touches jax device state).
Single pod: 16x16 = 256 chips ("data", "model"); multi-pod: 2x16x16 = 512
("pod", "data", "model") — the pod axis is pure data parallelism whose
all-reduce crosses DCN.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist in newer releases."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / smoke runs)."""
    n = len(jax.devices())
    return _make_mesh((n, 1), ("data", "model"))
