"""End-to-end training driver.

Composes the substrate: config -> data pipeline -> jit'd train step ->
fault-tolerant loop with async checkpointing. On the production mesh this is
invoked per-host by the cluster launcher (one process per host, jax
distributed init); on CPU it runs the same code single-process.

    PYTHONPATH=src python -m repro.launch.train --preset lm100m --steps 300
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, batch_at
from repro.train.loop import LoopConfig, run_loop

PRESETS = {
    # ~109M params: the deliverable-b "train a ~100M model" driver
    "lm100m": tfm.TransformerConfig(
        name="lm100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=32768, block_q=128, block_kv=128,
        dtype=jnp.float32),
    "lm10m": tfm.TransformerConfig(
        name="lm10m", n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
        d_ff=1024, vocab_size=8192, block_q=64, block_kv=64,
        dtype=jnp.float32),
    "lm-moe": tfm.TransformerConfig(
        name="lm-moe", n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
        d_ff=512, vocab_size=8192, moe=True, n_experts=8, top_k=2,
        block_q=64, block_kv=64, dtype=jnp.float32),
}


def train(preset: str = "lm10m", steps: int = 100, batch: int = 4,
          seq: int = 128, ckpt_dir: str = "/tmp/repro_ckpt",
          lr: float = 3e-4, compress_grads: bool = False,
          log_fn=print, should_preempt=lambda: False):
    cfg = PRESETS[preset]
    acfg = opt_mod.AdamWConfig(lr=lr, warmup_steps=min(50, steps // 10 + 1),
                               total_steps=steps,
                               compress_grads=compress_grads)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt_mod.init(acfg, params)
    raw_step = tfm.make_train_step(cfg, acfg)
    jstep = jax.jit(raw_step, donate_argnums=(0, 1))

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = jstep(params, opt_state, batch)
        return (params, opt_state), metrics

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in batch_at(dcfg, step).items()}

    ckpt = CheckpointManager(ckpt_dir, keep=2)
    loop_cfg = LoopConfig(total_steps=steps,
                          ckpt_every=max(steps // 4, 10), log_every=10)
    result = run_loop(step_fn, (params, opt_state), batch_fn, ckpt, loop_cfg,
                      should_preempt=should_preempt, log_fn=log_fn)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="lm10m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress-grads", action="store_true")
    a = ap.parse_args()
    result = train(a.preset, a.steps, a.batch, a.seq, a.ckpt_dir, a.lr,
                   a.compress_grads)
    print(f"done: step={result.final_step} retries={result.retries} "
          f"stragglers={result.straggler_steps}")
    if result.metrics_history:
        first = result.metrics_history[0][1]["loss"]
        last = result.metrics_history[-1][1]["loss"]
        print(f"loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
