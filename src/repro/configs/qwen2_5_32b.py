"""Qwen2.5-32B [hf:Qwen/Qwen2.5-32B]: 64L d=5120 40H (GQA kv=8) d_ff=27648
vocab 152064, QKV bias."""
from repro.configs.lm_common import LMBundle
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2.5-32b", n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab_size=152064, qkv_bias=True, rope_theta=1_000_000.0)

SMOKE = TransformerConfig(
    name="qwen-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab_size=256, qkv_bias=True, block_q=32, block_kv=32)


def bundle(smoke: bool = False) -> LMBundle:
    return LMBundle(SMOKE if smoke else CONFIG, smoke=smoke,
                    supports_long=False)
