"""Shared ArchBundle implementation for the GNN family.

Every GNN arch must serve all four assigned shapes; citation-style shapes
(full_graph_sm / minibatch_lg / ogb_products) are node classification over
dense features, ``molecule`` is batched per-graph energy regression. The
geometric models (SchNet/NequIP/EquiformerV2) additionally take positions on
every shape (documented adaptation, DESIGN.md §4). ``minibatch_lg`` lowers the
train step on the *sampled* subgraph produced by graphdb.sampler (fanout
15-10 from 1024 seeds); the sampler itself is exercised in tests/examples.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchBundle, ShapeSpec, dp_axes, ns, sds
from repro.train import optimizer as opt_mod

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7}),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train",
        {"n_nodes": 169984, "n_edges": 168960, "d_feat": 602,
         "n_classes": 41, "note": "sampled subgraph of reddit-scale graph "
                                  "(232965 nodes), fanout 15-10 x 1024 seeds"}),
    "ogb_products": ShapeSpec(
        "ogb_products", "train",
        {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
         "n_classes": 47}),
    "molecule": ShapeSpec(
        "molecule", "train",
        {"n_nodes": 30, "n_edges": 64, "batch": 128}),
}

SMOKE_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        {"n_nodes": 64, "n_edges": 256, "d_feat": 24, "n_classes": 5}),
    "molecule": ShapeSpec(
        "molecule", "train", {"n_nodes": 8, "n_edges": 16, "batch": 4}),
}


class GNNBundle(ArchBundle):
    family = "gnn"

    def __init__(self, arch_id: str, module, make_cfg: Callable,
                 smoke: bool = False, flops_fn: Callable | None = None):
        """make_cfg(shape_spec, geometric_inputs) -> model config."""
        self.arch_id = arch_id
        self.module = module
        self.make_cfg = make_cfg
        self.smoke = smoke
        self.shapes = dict(SMOKE_SHAPES if smoke else GNN_SHAPES)
        self._flops_fn = flops_fn

    # ----------------------------------------------------------------- cfg
    def model_cfg(self, shape: str):
        return self.make_cfg(self.shapes[shape])

    def init_params_abstract(self, shape: str = None):
        cfg = self.model_cfg(shape)
        return jax.eval_shape(lambda r: self.module.init_params(cfg, r),
                              jax.random.PRNGKey(0))

    def adam_cfg(self):
        return opt_mod.AdamWConfig(lr=1e-3, total_steps=10000,
                                   weight_decay=0.0)

    def make_step(self, shape: str):
        return self.module.make_train_step(self.model_cfg(shape),
                                           self.adam_cfg())

    # -------------------------------------------------------------- inputs
    def needs_positions(self) -> bool:
        return self.arch_id != "gat-cora"

    @staticmethod
    def _pad512(n: int) -> int:
        """Input shardings need divisibility by the dp axes (<=32); pad all
        node/edge dims to multiples of 512 (padding encoded as -1 edges /
        -1 labels / 0 masks, which every model already handles)."""
        return ((n + 511) // 512) * 512

    def _batch_specs(self, shape: str):
        d = self.shapes[shape].dims
        if shape == "molecule":
            N = self._pad512(d["n_nodes"] * d["batch"])
            E = self._pad512(d["n_edges"] * d["batch"])
            batch = {
                "atom_type": sds((N,), jnp.int32),
                "positions": sds((N, 3), jnp.float32),
                "edges": sds((2, E), jnp.int32),
                "graph_ids": sds((N,), jnp.int32),
                "energy": sds((d["batch"],), jnp.float32),
            }
            if self.arch_id == "gat-cora":
                batch.pop("positions")
                batch["labels"] = sds((N,), jnp.int32)
                batch.pop("energy")
            return batch
        N, E = self._pad512(d["n_nodes"]), self._pad512(d["n_edges"])
        batch = {
            "node_feat": sds((N, d["d_feat"]), jnp.float32),
            "edges": sds((2, E), jnp.int32),
            "labels": sds((N,), jnp.int32),
            "train_mask": sds((N,), jnp.float32),
        }
        if self.needs_positions():
            batch["positions"] = sds((N, 3), jnp.float32)
        return batch

    def input_specs(self, shape: str):
        params = self.init_params_abstract(shape)
        ost = self.abstract_adam_state(params)
        return (params, ost, self._batch_specs(shape))

    # ------------------------------------------------------------ shardings
    def _param_pspec(self, path, leaf):
        name = "/".join(path)
        nd = len(leaf.shape)
        if "so2" in name and nd == 2:       # EquiformerV2 SO(2) mixings
            return P(None, "model")
        if "ffn1" in name and nd == 2:
            return P(None, "model")
        return P(*([None] * nd))

    def shardings(self, mesh, shape: str):
        dp = dp_axes(mesh)
        params = self.init_params_abstract(shape)
        from repro.configs.base import params_spec_like
        pshard = params_spec_like(
            params, lambda path, leaf: ns(mesh, *self._param_pspec(path, leaf)))
        ost = self.abstract_adam_state(params)
        oshard = opt_mod.AdamState(
            step=ns(mesh), mu=pshard, nu=pshard,
            ef_error=jax.tree.map(lambda _: ns(mesh), ost.ef_error))

        bspec = {}
        for k, v in self._batch_specs(shape).items():
            if k == "edges":
                bspec[k] = ns(mesh, None, dp)
            elif k == "energy":
                bspec[k] = ns(mesh, dp)
            else:
                bspec[k] = ns(mesh, dp, *([None] * (len(v.shape) - 1)))
        hints = {
            "edge_msg": ns(mesh, dp),
            "node_hidden": ns(mesh, dp),
        }
        in_sh = (pshard, oshard, bspec)
        out_sh = (pshard, oshard, None)
        return in_sh, out_sh, hints

    # ------------------------------------------------------------- concrete
    def make_concrete(self, shape: str, seed: int = 0):
        rng = np.random.default_rng(seed)
        cfg = self.model_cfg(shape)
        params = self.module.init_params(cfg, jax.random.PRNGKey(seed))
        ost = opt_mod.init(self.adam_cfg(), params)
        specs = self._batch_specs(shape)
        d = self.shapes[shape].dims
        n_real = d["n_nodes"] * d.get("batch", 1) if shape == "molecule" \
            else d["n_nodes"]
        e_real = d["n_edges"] * d.get("batch", 1) if shape == "molecule" \
            else d["n_edges"]
        batch = {}
        for k, v in specs.items():
            if k == "edges":
                arr = np.full(v.shape, -1, np.int32)
                if shape == "molecule":
                    g = np.repeat(np.arange(d["batch"]), d["n_edges"])
                    vals = (rng.integers(0, d["n_nodes"], size=(2, e_real))
                            + g[None] * d["n_nodes"])
                else:
                    vals = rng.integers(0, n_real, size=(2, e_real))
                arr[:, :e_real] = vals
                batch[k] = jnp.asarray(arr)
            elif k == "graph_ids":
                arr = np.full(v.shape, -1, np.int32)
                arr[:n_real] = np.repeat(np.arange(d["batch"]), d["n_nodes"])
                batch[k] = jnp.asarray(arr)
            elif k == "labels":
                arr = np.full(v.shape, -1, np.int32)
                arr[:n_real] = rng.integers(0, max(d.get("n_classes", 16), 2),
                                            size=n_real)
                batch[k] = jnp.asarray(arr)
            elif k == "atom_type":
                batch[k] = jnp.asarray(rng.integers(
                    0, 10, size=v.shape).astype(np.int32))
            elif k == "train_mask":
                arr = np.zeros(v.shape, np.float32)
                arr[:n_real] = (rng.random(n_real) < 0.5)
                batch[k] = jnp.asarray(arr)
            else:
                batch[k] = jnp.asarray(
                    rng.normal(size=v.shape).astype(np.float32))
        return (params, ost, batch)

    def model_flops(self, shape: str) -> float:
        if self._flops_fn is None:
            return 0.0
        return self._flops_fn(self.model_cfg(shape), self.shapes[shape])
