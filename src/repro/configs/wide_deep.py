"""wide-deep [arXiv:1606.07792]: 40 sparse fields, embed_dim 32,
MLP 1024-512-256, concat interaction."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ArchBundle, ShapeSpec, dp_axes, ns,
                                params_spec_like, sds)
from repro.models import recsys
from repro.train import optimizer as opt_mod

SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                {"batch": 1, "n_candidates": 1_000_000}),
}

SMOKE_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 64}),
    "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 16}),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                {"batch": 1, "n_candidates": 512}),
}

CONFIG = recsys.WideDeepConfig()
SMOKE = recsys.WideDeepConfig(name="wide-deep-smoke",
                              vocab_sizes=tuple([512] * 40),
                              wide_vocab=1024, n_items=512, item_dim=32,
                              mlp=(64, 32, 16))


class RecsysBundle(ArchBundle):
    family = "recsys"
    arch_id = "wide-deep"

    def __init__(self, smoke: bool = False):
        self.smoke = smoke
        self.cfg = SMOKE if smoke else CONFIG
        self.shapes = dict(SMOKE_SHAPES if smoke else SHAPES)

    def init_params_abstract(self):
        return jax.eval_shape(lambda r: recsys.init_params(self.cfg, r),
                              jax.random.PRNGKey(0))

    def adam_cfg(self):
        return opt_mod.AdamWConfig(lr=1e-3, total_steps=100000,
                                   weight_decay=0.0)

    def make_step(self, shape: str):
        kind = self.shapes[shape].kind
        cfg = self.cfg
        if kind == "train":
            return recsys.make_train_step(cfg, self.adam_cfg())
        if kind == "serve":
            return lambda params, batch: recsys.forward(params, batch, cfg)
        return lambda params, batch: recsys.retrieval_scores(params, batch,
                                                             cfg)

    def _batch_specs(self, shape: str):
        d = self.shapes[shape].dims
        B = d["batch"]
        cfg = self.cfg
        base = {
            "sparse_ids": sds((B, cfg.n_sparse, cfg.max_bag), jnp.int32),
            "dense": sds((B, cfg.n_dense), jnp.float32),
        }
        kind = self.shapes[shape].kind
        if kind == "retrieval":
            base["candidate_ids"] = sds((d["n_candidates"],), jnp.int32)
            return base
        base["wide_ids"] = sds((B, cfg.n_wide), jnp.int32)
        if kind == "train":
            base["labels"] = sds((B,), jnp.float32)
        return base

    def input_specs(self, shape: str):
        params = self.init_params_abstract()
        kind = self.shapes[shape].kind
        if kind == "train":
            return (params, self.abstract_adam_state(params),
                    self._batch_specs(shape))
        return (params, self._batch_specs(shape))

    def _param_pspec(self, path, leaf):
        name = "/".join(path)
        nd = len(leaf.shape)
        if "table" in name or "items" in name:
            return P("model", None)
        if name.endswith("('wide',)") or "wide'" in name:
            return P("model") if nd == 1 else P(*([None] * nd))
        return P(*([None] * nd))

    def shardings(self, mesh, shape: str):
        dp = dp_axes(mesh)
        params = self.init_params_abstract()
        pshard = params_spec_like(
            params, lambda p, l: ns(mesh, *self._param_pspec(p, l)))
        kind = self.shapes[shape].kind
        bspec = {}
        B = self.shapes[shape].dims["batch"]
        for k, v in self._batch_specs(shape).items():
            if k == "candidate_ids":
                bspec[k] = ns(mesh, dp)
            elif B == 1:       # retrieval: a single query is replicated
                bspec[k] = ns(mesh, *([None] * len(v.shape)))
            else:
                bspec[k] = ns(mesh, dp, *([None] * (len(v.shape) - 1)))
        hints = {"bag_emb": ns(mesh, dp),
                 "mlp_hidden": ns(mesh, dp),
                 "cand_emb": ns(mesh, dp, None)}
        if kind == "train":
            ost = self.abstract_adam_state(params)
            oshard = opt_mod.AdamState(
                step=ns(mesh), mu=pshard, nu=pshard,
                ef_error=jax.tree.map(lambda _: ns(mesh), ost.ef_error))
            return ((pshard, oshard, bspec), (pshard, oshard, None), hints)
        if kind == "retrieval":
            return ((pshard, bspec), ns(mesh, dp), hints)
        return ((pshard, bspec), ns(mesh, dp), hints)

    def make_concrete(self, shape: str, seed: int = 0):
        cfg = self.cfg
        d = self.shapes[shape].dims
        params = recsys.init_params(cfg, jax.random.PRNGKey(seed))
        kind = self.shapes[shape].kind
        batch = {k: jnp.asarray(v) for k, v in recsys.synthetic_batch(
            cfg, d["batch"], seed=seed,
            with_labels=(kind == "train")).items()}
        if kind == "retrieval":
            batch.pop("wide_ids")
            rng = np.random.default_rng(seed)
            batch["candidate_ids"] = jnp.asarray(rng.integers(
                0, cfg.n_items, size=d["n_candidates"]).astype(np.int32))
            return (params, batch)
        if kind == "train":
            return (params, opt_mod.init(self.adam_cfg(), params), batch)
        return (params, batch)

    def model_flops(self, shape: str) -> float:
        cfg = self.cfg
        d = self.shapes[shape].dims
        B = d["batch"]
        deep_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
        mlp = 0
        prev = deep_in
        for h in cfg.mlp:
            mlp += 2 * prev * h
            prev = h
        bag = cfg.n_sparse * cfg.max_bag * cfg.embed_dim
        fwd = B * (mlp + bag)
        kind = self.shapes[shape].kind
        if kind == "train":
            return 3.0 * fwd
        if kind == "retrieval":
            return fwd + 2.0 * d["n_candidates"] * cfg.item_dim
        return float(fwd)


def bundle(smoke: bool = False) -> RecsysBundle:
    return RecsysBundle(smoke=smoke)
