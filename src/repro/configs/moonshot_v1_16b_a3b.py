"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: 48L d=2048 16H
(GQA kv=16) d_ff=1408 per expert, vocab 163840, MoE 64 experts top-6."""
from repro.configs.lm_common import LMBundle
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab_size=163840, moe=True, n_experts=64,
    top_k=6, rope_theta=50000.0)

SMOKE = TransformerConfig(
    name="moonshot-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=48, vocab_size=256, moe=True, n_experts=8, top_k=2,
    block_q=32, block_kv=32)


def bundle(smoke: bool = False) -> LMBundle:
    return LMBundle(SMOKE if smoke else CONFIG, smoke=smoke,
                    supports_long=False)
