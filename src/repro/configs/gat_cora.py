"""gat-cora [arXiv:1710.10903]: 2 layers, d_hidden=8, 8 heads, attention
aggregation."""
from repro.configs.gnn_common import GNNBundle
from repro.models.gnn import gat


def _make_cfg(spec):
    d = spec.dims
    if spec.name == "molecule":
        return gat.GATConfig(name="gat-cora", n_layers=2, d_hidden=8,
                             n_heads=8, d_feat=0, n_atom_types=100,
                             n_classes=16)
    return gat.GATConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
                         d_feat=d["d_feat"], n_classes=d["n_classes"])


def _flops(cfg, spec):
    d = spec.dims
    N = d.get("n_nodes", 0) * d.get("batch", 1)
    E = d.get("n_edges", 0) * d.get("batch", 1)
    per_layer = 2 * N * cfg.d_feat * cfg.n_heads * cfg.d_hidden \
        + 6 * E * cfg.n_heads * cfg.d_hidden
    return 3.0 * cfg.n_layers * per_layer     # fwd+bwd ~ 3x fwd


def bundle(smoke: bool = False) -> GNNBundle:
    return GNNBundle("gat-cora", gat, _make_cfg, smoke=smoke,
                     flops_fn=_flops)
