"""Architecture registry: ``get_bundle(arch_id)`` -> ArchBundle."""
from __future__ import annotations

from repro.configs.base import ArchBundle

_ARCHS = (
    "olmoe-1b-7b", "moonshot-v1-16b-a3b", "qwen2.5-32b", "phi3-medium-14b",
    "gemma2-27b",
    "gat-cora", "equiformer-v2", "schnet", "nequip",
    "wide-deep",
)


def list_archs() -> tuple[str, ...]:
    return _ARCHS


def get_bundle(arch_id: str, smoke: bool = False) -> ArchBundle:
    key = arch_id.replace(".", "_").replace("-", "_")
    import importlib
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.bundle(smoke=smoke)
