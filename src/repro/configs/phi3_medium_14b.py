"""Phi-3-medium-14B [arXiv:2404.14219]: 40L d=5120 40H (GQA kv=10)
d_ff=17920 vocab 100352, RoPE SwiGLU GQA."""
from repro.configs.lm_common import LMBundle
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="phi3-medium-14b", n_layers=40, d_model=5120, n_heads=40,
    n_kv_heads=10, d_ff=17920, vocab_size=100352, rope_theta=10000.0)

SMOKE = TransformerConfig(
    name="phi3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab_size=256, block_q=32, block_kv=32)


def bundle(smoke: bool = False) -> LMBundle:
    return LMBundle(SMOKE if smoke else CONFIG, smoke=smoke,
                    supports_long=False)
