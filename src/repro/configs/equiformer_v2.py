"""equiformer-v2 [arXiv:2306.12059]: 12 layers, 128 channels, l_max=6,
m_max=2, 8 heads, SO(2)-eSCN convolutions."""
from repro.configs.gnn_common import GNNBundle
from repro.models.gnn import equiformer_v2 as eq2


def _perf_knob(key: str) -> int:
    """Perf knobs (§Perf): REPRO_GNN_PERF=chunk:<n_edges>|nodechunk:<n>."""
    import os
    for part in os.environ.get("REPRO_GNN_PERF", "").split(","):
        if part.startswith(key + ":"):
            return int(part.split(":")[1])
    return 0


def _make_cfg(spec):
    import os
    import jax.numpy as jnp
    d = spec.dims
    kw = {"edge_chunk": _perf_knob("chunk"),
          "node_chunks": _perf_knob("nodechunk")}
    if "bf16" in os.environ.get("REPRO_GNN_PERF", ""):
        kw["dtype"] = jnp.bfloat16
    if spec.name == "molecule":
        return eq2.EquiformerV2Config(name="equiformer-v2", n_layers=12,
                                      d_hidden=128, l_max=6, m_max=2,
                                      n_heads=8, task="energy",
                                      n_graphs=d["batch"], **kw)
    return eq2.EquiformerV2Config(name="equiformer-v2", n_layers=12,
                                  d_hidden=128, l_max=6, m_max=2, n_heads=8,
                                  d_feat=d["d_feat"], task="node_class",
                                  n_classes=d["n_classes"], **kw)


def _flops(cfg, spec):
    d = spec.dims
    N = d.get("n_nodes", 0) * d.get("batch", 1)
    E = d.get("n_edges", 0) * d.get("batch", 1)
    C = cfg.d_hidden
    so2 = 0
    for m, (pos, neg) in enumerate(cfg.m_indices()):
        nl = len(pos)
        so2 += (1 if m == 0 else 4) * 2 * (nl * C) ** 2
    wig = 2 * sum((2 * l + 1) ** 2 for l in range(cfg.l_max + 1)) * C * 2
    per = E * (so2 + wig) + 4 * N * C * C * cfg.dim
    return 3.0 * cfg.n_layers * per


def bundle(smoke: bool = False) -> GNNBundle:
    b = GNNBundle("equiformer-v2", eq2, _make_cfg, smoke=smoke,
                  flops_fn=_flops)
    if smoke:
        # shrink the model for CPU smoke runs (full l_max=6 is heavy)
        orig = b.make_cfg

        def small(spec):
            import dataclasses
            c = orig(spec)
            return dataclasses.replace(c, n_layers=2, d_hidden=16, l_max=2,
                                       n_heads=4, n_rbf=16)
        b.make_cfg = small
    return b
