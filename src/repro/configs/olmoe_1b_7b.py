"""OLMoE-1B-7B [arXiv:2409.02060; hf]: 16L d=2048 16H (GQA kv=16) d_ff=1024
per expert, vocab 50304, MoE 64 experts top-8."""
import dataclasses

from repro.configs.lm_common import LMBundle
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, moe=True, n_experts=64, top_k=8,
    rope_theta=10000.0)

SMOKE = TransformerConfig(
    name="olmoe-1b-7b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab_size=256, moe=True, n_experts=8, top_k=2,
    block_q=32, block_kv=32)


def bundle(smoke: bool = False) -> LMBundle:
    return LMBundle(SMOKE if smoke else CONFIG, smoke=smoke,
                    supports_long=False)
