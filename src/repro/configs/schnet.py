"""schnet [arXiv:1706.08566]: 3 interactions, d_hidden=64, 300 RBF,
cutoff 10."""
from repro.configs.gnn_common import GNNBundle
from repro.models.gnn import schnet


def _make_cfg(spec):
    d = spec.dims
    if spec.name == "molecule":
        return schnet.SchNetConfig(name="schnet", n_interactions=3,
                                   d_hidden=64, n_rbf=300, cutoff=10.0,
                                   task="energy", n_graphs=d["batch"])
    return schnet.SchNetConfig(name="schnet", n_interactions=3, d_hidden=64,
                               n_rbf=300, cutoff=10.0, d_feat=d["d_feat"],
                               task="node_class", n_classes=d["n_classes"])


def _flops(cfg, spec):
    d = spec.dims
    N = d.get("n_nodes", 0) * d.get("batch", 1)
    E = d.get("n_edges", 0) * d.get("batch", 1)
    D, R = cfg.d_hidden, cfg.n_rbf
    per = 2 * E * (R * D + D * D + D) + 2 * N * (3 * D * D)
    return 3.0 * cfg.n_interactions * per


def bundle(smoke: bool = False) -> GNNBundle:
    return GNNBundle("schnet", schnet, _make_cfg, smoke=smoke,
                     flops_fn=_flops)
