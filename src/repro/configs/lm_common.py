"""Shared ArchBundle implementation for the LM transformer family."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ArchBundle, ShapeSpec, dp_axes, map_sds, ns,
                                params_spec_like, sds, zero1)
from repro.models import transformer as tfm
from repro.models.sharding import hint_context
from repro.train import optimizer as opt_mod

LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             {"seq_len": 32768, "global_batch": 32}),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            {"seq_len": 32768, "global_batch": 128}),
    "long_500k": ShapeSpec("long_500k", "decode",
                           {"seq_len": 524288, "global_batch": 1}),
}


def _apply_perf_env(cfg: tfm.TransformerConfig) -> tfm.TransformerConfig:
    """Perf-iteration knobs via REPRO_LM_PERF=skip,remat,pbf16 (§Perf)."""
    import os
    flags = set(filter(None, os.environ.get("REPRO_LM_PERF", "").split(",")))
    kw = {}
    if "skip" in flags:
        kw["causal_block_skip"] = True
    if "remat" in flags:
        kw["attn_remat"] = True
    if "pbf16" in flags:
        kw["attn_p_bf16"] = True
    return dataclasses.replace(cfg, **kw) if kw else cfg


class LMBundle(ArchBundle):
    family = "lm"

    def __init__(self, cfg: tfm.TransformerConfig, smoke: bool = False,
                 supports_long: bool = False):
        self.cfg = _apply_perf_env(cfg)
        self.arch_id = cfg.name
        self.smoke = smoke
        self.shapes = dict(LM_SHAPES)
        if not supports_long:
            self.shapes["long_500k"] = dataclasses.replace(
                self.shapes["long_500k"],
                skip=("pure full-attention arch: 524k dense global KV "
                      "out of published scope (DESIGN.md §4)"))
        if smoke:
            self.shapes = {
                "train_4k": ShapeSpec("train_4k", "train",
                                      {"seq_len": 64, "global_batch": 2}),
                "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                                         {"seq_len": 64, "global_batch": 2}),
                "decode_32k": ShapeSpec("decode_32k", "decode",
                                        {"seq_len": 64, "global_batch": 2}),
            }

    # ------------------------------------------------------------- abstract
    def init_params_abstract(self):
        return jax.eval_shape(
            lambda r: tfm.init_params(self.cfg, r), jax.random.PRNGKey(0))

    def _cache_abstract(self, batch, max_len):
        return jax.eval_shape(
            lambda: tfm.init_kv_cache(self.cfg, batch, max_len))

    def adam_cfg(self):
        return opt_mod.AdamWConfig(total_steps=10000)

    # ----------------------------------------------------------------- step
    def make_step(self, shape: str):
        spec = self.shapes[shape]
        cfg, acfg = self.cfg, self.adam_cfg()
        if spec.kind == "train":
            return tfm.make_train_step(cfg, acfg)
        if spec.kind == "prefill":
            return functools.partial(_prefill_step, cfg=cfg)
        return functools.partial(_decode_step, cfg=cfg)

    def input_specs(self, shape: str):
        spec = self.shapes[shape]
        B = spec.dims["global_batch"]
        S = spec.dims["seq_len"]
        params = self.init_params_abstract()
        if spec.kind == "train":
            ost = self.abstract_adam_state(params)
            batch = {"tokens": sds((B, S), jnp.int32)}
            return (params, ost, batch)
        caches = self._cache_abstract(B, S)
        if spec.kind == "prefill":
            # chunked prefill: the engine feeds prompt chunks; lower a
            # representative full-prompt call
            tokens = sds((B, S), jnp.int32)
            return (params, tokens, caches)
        tokens = sds((B, 1), jnp.int32)
        return (params, tokens, caches, sds((), jnp.int32))

    # ------------------------------------------------------------ shardings
    def _param_pspec(self, path, leaf):
        name = "/".join(path)
        nd = len(leaf.shape)
        if "embed" in name:
            return P("model", None)
        if "head" in name:
            return P(None, "model")
        if "router" in name:
            return P(None, None, None)
        if "mlp" in name and nd == 4:        # MoE experts [L, E, D, F]
            return P(None, "model", None, None)
        if any(k in name for k in ("wq", "wk", "wv", "w1", "w3")) and nd == 3:
            return P(None, None, "model")
        if any(k in name for k in ("wo", "w2")) and nd == 3:
            return P(None, "model", None)
        if any(k in name for k in ("bq", "bk", "bv")):
            return P(None, "model")
        return P(*([None] * nd))

    def param_shardings(self, mesh):
        params = self.init_params_abstract()
        return params_spec_like(
            params, lambda path, leaf: ns(mesh, *self._param_pspec(path, leaf)))

    def opt_shardings(self, mesh, params_sds, ost_sds):
        dsize = mesh.shape["data"]

        def spec_of(path, leaf):
            base = self._param_pspec(path, leaf)
            return ns(mesh, *zero1(base, leaf.shape, dsize, mesh))

        mu = params_spec_like(ost_sds.mu, spec_of)
        nu = params_spec_like(ost_sds.nu, spec_of)
        ef = jax.tree.map(lambda _: ns(mesh), ost_sds.ef_error)
        return opt_mod.AdamState(step=ns(mesh), mu=mu, nu=nu, ef_error=ef)

    def _kv_divisible(self, mesh) -> bool:
        return self.cfg.n_kv_heads % mesh.shape["model"] == 0

    def _cache_spec(self, mesh, B):
        dp = dp_axes(mesh)
        if self._kv_divisible(mesh):
            if B == 1:   # long-context: shard the sequence axis over data
                return ns(mesh, None, None, dp, "model", None)
            return ns(mesh, None, dp, None, "model", None)
        # kv heads don't divide the model axis: shard the sequence instead
        # (ring-decode style psum over sequence shards)
        if B == 1:
            return ns(mesh, None, None, dp, None, None)
        return ns(mesh, None, dp, "model", None, None)

    def hints(self, mesh, kind: str = "train"):
        dp = dp_axes(mesh)
        h = {
            # Megatron sequence parallelism: the residual stream (and the
            # remat-saved per-layer carries) shard over (dp, model)
            "act_resid": (ns(mesh, dp, "model", None) if kind != "decode"
                          else ns(mesh, dp, None, None)),
            "act_ff": ns(mesh, dp, None, "model"),
            "logits": ns(mesh, dp, None, "model"),
            "moe_buf": ns(mesh, "model", None, None),
            "moe_ff": ns(mesh, "model", None, None),
            "moe_rows": ns(mesh, dp, None),
            "moe_eout": ns(mesh, "model", None),
        }
        if self._kv_divisible(mesh):
            h["act_q"] = ns(mesh, dp, None, "model", None, None)
            h["act_kv"] = ns(mesh, dp, None, "model", None)
        return h

    def shardings(self, mesh, shape: str):
        spec = self.shapes[shape]
        dp = dp_axes(mesh)
        B = spec.dims["global_batch"]
        pshard = self.param_shardings(mesh)
        if spec.kind == "train":
            params_sds = self.init_params_abstract()
            ost_sds = self.abstract_adam_state(params_sds)
            oshard = self.opt_shardings(mesh, params_sds, ost_sds)
            batch_shard = {"tokens": ns(mesh, dp, None)}
            in_sh = (pshard, oshard, batch_shard)
            out_sh = (pshard, oshard, None)   # metrics: let XLA choose
            return in_sh, out_sh, self.hints(mesh, 'train')
        cshard = {"k": self._cache_spec(mesh, B),
                  "v": self._cache_spec(mesh, B)}
        if spec.kind == "prefill":
            tok = ns(mesh, dp, None) if B > 1 else ns(mesh, None, dp)
            in_sh = (pshard, tok, cshard)
            out_sh = (ns(mesh, dp, "model") if B > 1
                      else ns(mesh, None, "model"), cshard)
            return in_sh, out_sh, self.hints(mesh, "prefill")
        tok = ns(mesh, dp, None) if B > 1 else ns(mesh, None, None)
        in_sh = (pshard, tok, cshard, ns(mesh))
        out_sh = (ns(mesh, dp, "model") if B > 1 else ns(mesh, None, "model"),
                  cshard)
        return in_sh, out_sh, self.hints(mesh, "decode")

    # ------------------------------------------------------------- concrete
    def make_concrete(self, shape: str, seed: int = 0):
        assert self.smoke, "concrete inputs only for smoke bundles"
        rng = np.random.default_rng(seed)
        spec = self.shapes[shape]
        B, S = spec.dims["global_batch"], spec.dims["seq_len"]
        params = tfm.init_params(self.cfg, jax.random.PRNGKey(seed))
        if spec.kind == "train":
            ost = opt_mod.init(self.adam_cfg(), params)
            batch = {"tokens": jnp.asarray(
                rng.integers(0, self.cfg.vocab_size, (B, S)), jnp.int32)}
            return (params, ost, batch)
        caches = tfm.init_kv_cache(self.cfg, B, S)
        if spec.kind == "prefill":
            toks = jnp.asarray(rng.integers(0, self.cfg.vocab_size, (B, S)),
                               jnp.int32)
            return (params, toks, caches)
        toks = jnp.asarray(rng.integers(0, self.cfg.vocab_size, (B, 1)),
                           jnp.int32)
        return (params, toks, caches, jnp.int32(S // 2))

    # ------------------------------------------------------------ analytics
    def model_flops(self, shape: str) -> float:
        spec = self.shapes[shape]
        B, S = spec.dims["global_batch"], spec.dims["seq_len"]
        if spec.kind == "train":
            return self.cfg.train_flops(B, S)
        if spec.kind == "prefill":
            return self.cfg.train_flops(B, S) / 3.0   # forward only
        return self.cfg.decode_flops(B, S)


def _prefill_step(params, tokens, caches, cfg):
    return tfm.prefill(params, tokens, cfg, caches)


def _decode_step(params, tokens, caches, t, cfg):
    return tfm.decode_step(params, tokens, cfg, caches, t)
