"""ArchBundle: uniform interface every assigned architecture implements.

A bundle knows, per input shape:
- ``input_specs(shape)``      — ShapeDtypeStruct stand-ins for every input of
  the lowered step (weak-type-correct, shardable, no allocation);
- ``abstract_state(shape)``   — SDS pytrees for params / optimizer / caches;
- ``make_step(shape)``        — the jit-able step callable;
- ``shardings(mesh, shape)``  — (in_shardings, out_shardings, hint table)
  NamedSharding pytrees for the production mesh;
- ``make_concrete(shape)``    — real (small) arrays for smoke tests.

launch/dryrun.py composes these into lower().compile() for every
(arch x shape x mesh) cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.train import optimizer as opt_mod


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                     # train | prefill | decode | serve | retrieval
    dims: dict
    skip: str | None = None       # reason string when cell is skipped


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def ns(mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def map_sds(tree):
    return jax.tree.map(lambda x: sds(x.shape, x.dtype), tree)


class ArchBundle:
    arch_id: str = ""
    family: str = ""              # lm | gnn | recsys
    shapes: dict[str, ShapeSpec] = {}

    # ---- to implement ----------------------------------------------------
    def init_params_abstract(self):
        raise NotImplementedError

    def make_step(self, shape: str) -> Callable:
        raise NotImplementedError

    def input_specs(self, shape: str):
        """Full argument tuple (SDS pytrees) for make_step(shape)."""
        raise NotImplementedError

    def shardings(self, mesh, shape: str):
        """(in_shardings, out_shardings, hints) for make_step(shape)."""
        raise NotImplementedError

    def make_concrete(self, shape: str, seed: int = 0):
        """Real small arrays for smoke testing (only for smoke bundles)."""
        raise NotImplementedError

    # ---- common ----------------------------------------------------------
    def adam_cfg(self) -> opt_mod.AdamWConfig:
        return opt_mod.AdamWConfig()

    def abstract_adam_state(self, params_sds):
        return jax.eval_shape(lambda p: opt_mod.init(self.adam_cfg(), p),
                              params_sds)

    def model_flops(self, shape: str) -> float:
        """Analytic MODEL_FLOPS for the §Roofline table (global, per step)."""
        return 0.0

    def shape_names(self) -> list[str]:
        return list(self.shapes)


def params_spec_like(tree, fn) -> Any:
    """Build a sharding pytree by mapping fn(path_tuple, leaf_sds)->P."""
    # jax.tree.flatten_with_path only exists in newer jax releases
    flatten_with_path = getattr(
        jax.tree, "flatten_with_path",
        jax.tree_util.tree_flatten_with_path)
    flat, treedef = flatten_with_path(tree)
    specs = [fn(tuple(str(k) for k in path), leaf) for path, leaf in flat]
    return jax.tree.unflatten(treedef, specs)


def zero1(spec: P, shape, data_size: int, mesh) -> P:
    """ZeRO-1: add 'data' sharding to an optimizer-state leaf on the first
    axis that is unsharded and divisible by the data-axis size."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    if "data" in [p for p in parts if p]:
        return P(*parts)
    for i, (p, d) in enumerate(zip(parts, shape)):
        if p is None and d % data_size == 0 and d >= data_size:
            parts[i] = "data"
            return P(*parts)
    return P(*parts)


def replicate_tree(mesh, tree):
    return jax.tree.map(lambda _: ns(mesh), tree)


def metrics_sharding(mesh, metrics_sds):
    return jax.tree.map(lambda _: ns(mesh), metrics_sds)


def to_jnp(tree):
    return jax.tree.map(jnp.asarray, tree)


def rand_tokens(rng: np.random.Generator, shape, vocab: int):
    return rng.integers(0, vocab, size=shape).astype(np.int32)
