"""nequip [arXiv:2101.03164]: 5 layers, 32 channels, l_max=2, 8 Bessel RBF,
cutoff 5, E(3) tensor products."""
from repro.configs.gnn_common import GNNBundle
from repro.models.gnn import nequip


def _make_cfg(spec):
    d = spec.dims
    if spec.name == "molecule":
        return nequip.NequIPConfig(name="nequip", n_layers=5, d_hidden=32,
                                   l_max=2, n_rbf=8, cutoff=5.0,
                                   task="energy", n_graphs=d["batch"])
    return nequip.NequIPConfig(name="nequip", n_layers=5, d_hidden=32,
                               l_max=2, n_rbf=8, cutoff=5.0,
                               d_feat=d["d_feat"], task="node_class",
                               n_classes=d["n_classes"])


def _flops(cfg, spec):
    d = spec.dims
    N = d.get("n_nodes", 0) * d.get("batch", 1)
    E = d.get("n_edges", 0) * d.get("batch", 1)
    C = cfg.d_hidden
    cg = sum((2 * l3 + 1) * (2 * l1 + 1) * (2 * l2 + 1)
             for l1, l2, l3 in cfg.paths())
    per = 2 * E * C * cg + 4 * N * C * C * cfg.dim
    return 3.0 * cfg.n_layers * per


def bundle(smoke: bool = False) -> GNNBundle:
    return GNNBundle("nequip", nequip, _make_cfg, smoke=smoke,
                     flops_fn=_flops)
