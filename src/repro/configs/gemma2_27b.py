"""Gemma2-27B [arXiv:2408.00118]: 46L d=4608 32H (GQA kv=16) d_ff=36864
vocab 256000; local(4096)+global alternating, attn softcap 50, final softcap
30, pre+post zero-centered RMSNorm, head_dim 128.

Runs ``long_500k``: local layers bound attention to the 4096 window, global
layers attend over the (sequence-sharded) full cache.
"""
from repro.configs.lm_common import LMBundle
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma2-27b", n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
    d_ff=36864, vocab_size=256000, head_dim=128,
    layer_pattern="local_global", window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    zero_centered_norm=True, rope_theta=10000.0)

SMOKE = TransformerConfig(
    name="gemma2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab_size=256, head_dim=16, layer_pattern="local_global",
    window=16, attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    zero_centered_norm=True, block_q=32, block_kv=32)


def bundle(smoke: bool = False) -> LMBundle:
    return LMBundle(SMOKE if smoke else CONFIG, smoke=smoke,
                    supports_long=True)
