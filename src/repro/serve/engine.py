"""Batched LM serving engine: continuous-batching-lite over a fixed slot pool.

A fixed number of slots share one KV cache ([L, slots, S_max, K, hd] — the
decode_32k dry-run shape). Requests occupy free slots, prefill writes their
prompt into the slot's cache region, and one fused decode step advances every
active slot per tick. Finished slots (EOS or max_tokens) free immediately and
are refilled from the queue — the vLLM-style scheduling loop adapted to fixed
TPU shapes (no paging: slot-granular allocation; paged-KV is noted as the
production extension in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # int32 [prompt_len]
    max_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: tfm.TransformerConfig, params, n_slots: int = 4,
                 max_len: int = 512, eos_id: int = 0, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos = eos_id
        self.caches = tfm.init_kv_cache(cfg, n_slots, max_len)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, dtype=np.int32)
        self.queue: deque[Request] = deque()
        self.ticks = 0

        # one-slot prefill writes into the shared cache at slot `slot`
        def _prefill(params, caches, tokens, slot):
            logits, new_caches, _ = tfm.forward(
                params, tokens, self.cfg,
                kv_caches=jax.tree.map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, 1),
                    caches),
                cache_index=jnp.int32(0))
            caches = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_slice_in_dim(
                    c, n.astype(c.dtype), slot, 1), caches, new_caches)
            return logits[:, -1], caches

        def _decode(params, tokens, caches, pos):
            return tfm.decode_step_multi(params, tokens, self.cfg, caches,
                                         pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    # --------------------------------------------------------------- public
    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_ticks: int = 1000) -> list[Request]:
        finished = []
        while (self.queue or any(self.slot_req)) and self.ticks < max_ticks:
            self._admit()
            self._step(finished)
            self.ticks += 1
        return finished

    # -------------------------------------------------------------- private
    def _admit(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                toks = jnp.asarray(req.prompt[None, :], jnp.int32)
                logits, self.caches = self._prefill(
                    self.params, self.caches, toks, jnp.int32(s))
                first = int(jnp.argmax(logits[0]))
                req.out_tokens.append(first)
                self.slot_req[s] = req
                self.slot_pos[s] = len(req.prompt)

    def _step(self, finished: list):
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return
        tokens = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slot_req[s].out_tokens[-1]
        pos = jnp.asarray(self.slot_pos)
        logits, self.caches = self._decode(self.params, jnp.asarray(tokens),
                                           self.caches, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in active:
            req = self.slot_req[s]
            tok = int(nxt[s])
            req.out_tokens.append(tok)
            self.slot_pos[s] += 1
            if (tok == self.eos or len(req.out_tokens) >= req.max_tokens
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                finished.append(req)
                self.slot_req[s] = None
