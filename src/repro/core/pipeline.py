"""OptimizerPipeline — the registrable pass/rule API of the optimizer
(paper §3: "extensive heuristic rules ... automatic type inference ... and
cost-based optimization" composed as interchangeable pieces; DESIGN.md §6).

PR 1 made the backends pluggable (PhysicalSpec) and PR 2 the frontends
(GraphIrBuilder); this module makes the layer between them pluggable too.
``GOpt.optimize`` is now a thin driver over an ``OptimizerPipeline``: an
ordered sequence of registered ``Pass`` objects grouped into phases

    pre -> type_inference -> rbo (fixpoint group) -> cbo -> post_physical

Each pass sees a ``PassContext`` (the logical plan, metadata providers, the
active backend spec, and the optimizer flags) and records a ``PassTrace``
(wall time, changed flag, rule hit counts, plan-snapshot diffs).  The
``rbo`` phase is special: its passes are run together to a fixpoint, like
the old HepPlanner driver, so heuristic rules registered by users interleave
with the built-ins.  Backends participate through the
``PhysicalSpec.physical_rules`` hook: post-CBO rewrites of the physical
plan, run in the ``post_physical`` phase (e.g. the jax backend's
expand-chain fusion).

On top of the per-pass traces sits the EXPLAIN/PROFILE surface: a
structured ``ExplainReport`` (per-pass traces and diffs, per-operator
estimated cost/cardinality, actual row counts under ``analyze=True``) with
a text renderer, exposed as ``GOpt.explain`` / ``PreparedQuery.explain``
and the ``EXPLAIN`` / ``PROFILE`` query prefixes in the Cypher parser.
"""
from __future__ import annotations

import collections
import dataclasses
import difflib
import time
from typing import Any

from repro.core import ir
from repro.core.cardinality import CardEstimator, Statistics
from repro.core.cbo import GraphOptimizer, annotate_estimates
from repro.core.errors import PipelineError, PlanInvariantError
from repro.core.glogue import GLogue
from repro.core.pattern import expand_path_edges
from repro.core.physical import (ExpandChainNode, ExpandNode, JoinNode,
                                 PlanNode, ScanNode,
                                 default_left_deep_plan, describe_node,
                                 plan_children, plan_operators,
                                 plan_signature)
from repro.core.physical_spec import PhysicalSpec
from repro.core.rules import DEFAULT_RULES, EXTENDED_RULES, Rule
from repro.core.schema import GraphSchema
from repro.core.type_inference import INVALID, infer_types
from repro.core.verify import PlanVerifier, VerifyReport

PHASES = ("pre", "type_inference", "rbo", "cbo", "post_physical")

# static-verification modes (DESIGN.md §12): "cached" verifies the pipeline
# output once per canonical plan form; "always" re-verifies after EVERY
# registered pass so an invalid rewrite raises PlanInvariantError naming it
VERIFY_MODES = ("off", "cached", "always")

# message rendered for a query type inference proved unsatisfiable
UNSAT_MESSAGE = "empty result (type inference proved pattern unsatisfiable)"


# --------------------------------------------------------------------------
# Context and traces
# --------------------------------------------------------------------------


@dataclasses.dataclass
class PassContext:
    """Everything a pass may read or rewrite.

    Passes mutate ``plan`` (logical) and ``physical`` in place / by
    replacement; ``invalid=True`` short-circuits the remaining phases (the
    query provably returns no rows).  ``estimator`` is published by the CBO
    pass so later passes (and EXPLAIN) share its memoized cardinalities."""
    plan: ir.LogicalPlan
    schema: GraphSchema
    stats: Statistics
    glogue: GLogue | None
    spec: PhysicalSpec
    flags: dict
    counters: Any                        # collections.Counter
    physical: PlanNode | None = None
    invalid: bool = False
    estimator: CardEstimator | None = None

    def pattern(self):
        return self.plan.pattern()


@dataclasses.dataclass
class PassTrace:
    """What one registered pass did during one ``optimize`` run."""
    name: str
    phase: str
    wall_s: float = 0.0
    changed: bool = False
    hits: int = 0                        # fixpoint iterations that changed
    skipped: str | None = None           # reason, when the pass did not run
    diff: list[str] = dataclasses.field(default_factory=list)

    def render(self) -> str:
        if self.skipped:
            return (f"[{self.phase:<13}] {self.name:<24} "
                    f"skipped ({self.skipped})")
        state = f"hits={self.hits}" if self.changed else "no-op"
        return (f"[{self.phase:<13}] {self.name:<24} "
                f"{self.wall_s * 1e3:7.2f}ms  {state}")


@dataclasses.dataclass
class PipelineTrace:
    passes: list[PassTrace]
    wall_s: float = 0.0
    invalid: bool = False
    # PlanVerifier report of the pipeline output (verify="cached"/"always";
    # None when verification was off) — EXPLAIN's "-- verify --" section
    verify: VerifyReport | None = None

    def by_name(self, name: str) -> PassTrace | None:
        for t in self.passes:
            if t.name == name:
                return t
        return None

    def render_lines(self, diffs: bool = False) -> list[str]:
        lines = [t.render() for t in self.passes]
        if diffs:
            for t in self.passes:
                if t.diff:
                    lines.append(f"-- {t.name} plan diff --")
                    lines.extend("  " + d for d in t.diff)
        return lines


def _snapshot(ctx: PassContext) -> list[str]:
    lines = ctx.plan.snapshot()
    if ctx.physical is not None:
        lines.append("PHYSICAL[" + plan_signature(ctx.physical) + "]")
    return lines


def _diff(before: list[str], after: list[str]) -> list[str]:
    if before == after:
        return []
    out = difflib.unified_diff(before, after, lineterm="", n=0)
    return [l for l in out if l[:1] in "+-" and l[:3] not in ("+++", "---")]


# --------------------------------------------------------------------------
# The Pass protocol and the pipeline driver
# --------------------------------------------------------------------------


class Pass:
    """One registered unit of optimizer work.

    Subclasses set ``name``/``phase`` and implement ``run(ctx) -> bool``
    (the changed flag).  ``skip(ctx)`` may return a human-readable reason
    to leave the pass out of a run (flag gating); the trace records it."""

    name = "pass"
    phase = "pre"

    def skip(self, ctx: PassContext) -> str | None:
        return None

    def run(self, ctx: PassContext) -> bool:
        raise NotImplementedError


class OptimizerPipeline:
    """Ordered, phase-grouped pass registry + driver.

    Registration keeps passes sorted by phase (the order of ``PHASES``);
    within a phase, insertion order — or ``before=``/``after=`` an existing
    pass name.  ``run`` executes phases in order, running the ``rbo`` phase
    as a fixpoint group, and returns one ``PassTrace`` per pass."""

    MAX_RBO_ITERS = 10
    # memoized clean VerifyReports, keyed by canonical plan form (+ backend
    # + physical signature): verify="cached" pays the checker once per
    # distinct plan shape, like the prepared-plan cache pays the optimizer
    VERIFY_MEMO_SIZE = 512

    def __init__(self, passes: tuple[Pass, ...] = (),
                 capture_diffs: bool = True, verify: str = "off"):
        self._passes: list[Pass] = []
        # before/after canonical-form snapshots feed the PassTrace diffs
        # that EXPLAIN renders; measured at a few percent of compile time
        # (CBO dominates), but compile-latency-critical embedders can turn
        # them off — traces then carry timings/hits only
        self.capture_diffs = capture_diffs
        if verify not in VERIFY_MODES:
            raise PipelineError(f"unknown verify mode {verify!r}; "
                                f"modes are {VERIFY_MODES}")
        self.verify = verify
        self._verified: collections.OrderedDict = collections.OrderedDict()
        for p in passes:
            self.register(p)

    # ---------------------------------------------------------- registration
    def register(self, p: Pass, *, before: str | None = None,
                 after: str | None = None) -> "OptimizerPipeline":
        if p.phase not in PHASES:
            raise PipelineError(
                f"pass {p.name!r} declares unknown phase {p.phase!r}; "
                f"phases are {PHASES}")
        if any(q.name == p.name for q in self._passes):
            raise PipelineError(f"pass {p.name!r} is already registered")
        if before is not None and after is not None:
            raise PipelineError("give at most one of before=/after=")
        anchor = before or after
        if anchor is not None:
            idx = next((i for i, q in enumerate(self._passes)
                        if q.name == anchor), None)
            if idx is None:
                raise PipelineError(f"no registered pass named {anchor!r}")
            if self._passes[idx].phase != p.phase:
                raise PipelineError(
                    f"{anchor!r} is in phase {self._passes[idx].phase!r}, "
                    f"cannot anchor a {p.phase!r} pass on it")
            self._passes.insert(idx if before else idx + 1, p)
        else:
            # append at the end of this pass's phase block
            order = {ph: i for i, ph in enumerate(PHASES)}
            idx = len(self._passes)
            for i, q in enumerate(self._passes):
                if order[q.phase] > order[p.phase]:
                    idx = i
                    break
            self._passes.insert(idx, p)
        return self

    def register_rule(self, rule: Rule) -> "OptimizerPipeline":
        """Sugar: wrap a heuristic ``Rule`` as an rbo-phase pass."""
        return self.register(RulePass(rule))

    def remove(self, name: str) -> Pass:
        for i, p in enumerate(self._passes):
            if p.name == name:
                return self._passes.pop(i)
        raise PipelineError(f"no registered pass named {name!r}")

    def passes(self, phase: str | None = None) -> list[Pass]:
        if phase is None:
            return list(self._passes)
        return [p for p in self._passes if p.phase == phase]

    def signature(self) -> tuple[str, ...]:
        """Stable identity of the registered sequence — part of the
        prepared-plan cache key, so registering a pass never serves plans
        compiled by a differently-shaped pipeline."""
        return tuple(f"{p.phase}:{p.name}" for p in self._passes)

    # ----------------------------------------------------------------- drive
    def run(self, ctx: PassContext) -> PipelineTrace:
        t0 = time.perf_counter()
        mode = ctx.flags.get("verify") or self.verify
        if mode not in VERIFY_MODES:
            raise PipelineError(f"unknown verify mode {mode!r}; "
                                f"modes are {VERIFY_MODES}")
        # expect_sat flips once the type_inference pass has *proven* the
        # pattern satisfiable: from then on, a pass whose output is
        # unsatisfiable broke a valid plan (violation) rather than
        # discovered an empty result (clean verified-empty short-circuit)
        state = {"expect_sat": False}
        check = self._make_checker(ctx, state) if mode == "always" else None
        traces: list[PassTrace] = []
        for phase in PHASES:
            group = [p for p in self._passes if p.phase == phase]
            if group:
                if phase == "rbo":
                    traces.extend(self._run_fixpoint(group, ctx, check))
                else:
                    for p in group:
                        traces.append(self._run_one(p, ctx, check))
                        if ctx.invalid:
                            break
            if (phase == "type_inference" and not ctx.invalid
                    and any(t.name == "type_inference" and not t.skipped
                            for t in traces)):
                state["expect_sat"] = True
            if ctx.invalid:
                break
        report = self._verify_final(ctx, state) if mode != "off" else None
        return PipelineTrace(traces, wall_s=time.perf_counter() - t0,
                             invalid=ctx.invalid, verify=report)

    # ---------------------------------------------------------- verification
    def _verifier(self, ctx: PassContext) -> PlanVerifier:
        return PlanVerifier(ctx.schema, spec=ctx.spec,
                            store=getattr(ctx.stats, "store", None))

    def _make_checker(self, ctx: PassContext, state: dict):
        verifier = self._verifier(ctx)

        def check(p: Pass, tr: PassTrace) -> None:
            report = verifier.verify(ctx.plan, ctx.physical,
                                     invalid=ctx.invalid,
                                     expect_satisfiable=state["expect_sat"])
            if not report.ok:
                raise PlanInvariantError(report.violations, pass_name=p.name,
                                         phase=p.phase, trace=tr)
        return check

    def _verify_final(self, ctx: PassContext, state: dict) -> VerifyReport:
        key = (ir.canonical_form(ctx.plan), ctx.spec.name,
               plan_signature(ctx.physical) if ctx.physical is not None
               else None, ctx.invalid)
        hit = self._verified.get(key)
        if hit is not None:
            self._verified.move_to_end(key)
            return dataclasses.replace(hit, cached=True)
        report = self._verifier(ctx).verify(
            ctx.plan, ctx.physical, invalid=ctx.invalid,
            expect_satisfiable=state["expect_sat"])
        if not report.ok:
            # no offending pass to name: the defect was only detected on
            # the final output (use verify="always" to bisect)
            raise PlanInvariantError(report.violations)
        self._verified[key] = report
        if len(self._verified) > self.VERIFY_MEMO_SIZE:
            self._verified.popitem(last=False)
        return report

    def _run_one(self, p: Pass, ctx: PassContext, check=None) -> PassTrace:
        reason = p.skip(ctx)
        if reason is not None:
            return PassTrace(p.name, p.phase, skipped=reason)
        before = _snapshot(ctx) if self.capture_diffs else []
        t0 = time.perf_counter()
        changed = bool(p.run(ctx))
        dt = time.perf_counter() - t0
        after = (_snapshot(ctx) if changed and self.capture_diffs
                 else before)
        tr = PassTrace(p.name, p.phase, wall_s=dt, changed=changed,
                       hits=int(changed), diff=_diff(before, after))
        if check is not None:
            check(p, tr)
        return tr

    def _run_fixpoint(self, group: list[Pass], ctx: PassContext,
                      check=None) -> list[PassTrace]:
        """HepPlanner-style driver: apply every eligible rbo pass repeatedly
        until none reports a change (or MAX_RBO_ITERS)."""
        traces = {p.name: PassTrace(p.name, p.phase) for p in group}
        eligible = []
        for p in group:
            reason = p.skip(ctx)
            if reason is not None:
                traces[p.name].skipped = reason
            else:
                eligible.append(p)
        if eligible:
            ctx.counters["rbo"] += 1
        for _ in range(self.MAX_RBO_ITERS):
            any_changed = False
            for p in eligible:
                tr = traces[p.name]
                before = _snapshot(ctx) if self.capture_diffs else []
                t0 = time.perf_counter()
                changed = bool(p.run(ctx))
                tr.wall_s += time.perf_counter() - t0
                if changed:
                    tr.changed = True
                    tr.hits += 1
                    if self.capture_diffs:
                        tr.diff.extend(_diff(before, _snapshot(ctx)))
                if check is not None:
                    check(p, tr)
                any_changed |= changed
                if ctx.invalid:     # short-circuit, like the phase driver
                    return [traces[p.name] for p in group]
            if not any_changed:
                break
        return [traces[p.name] for p in group]


# --------------------------------------------------------------------------
# Built-in passes (the old GOpt.optimize if-ladder, as registrable pieces)
# --------------------------------------------------------------------------


class ExpandPathsPass(Pass):
    """Unfold hops>1 EXPAND_PATH edges into 1-hop chains (§4.1)."""

    name = "expand_paths"
    phase = "pre"

    def run(self, ctx: PassContext) -> bool:
        pattern = ctx.pattern()
        had_paths = any(e.hops > 1 for e in pattern.edges)
        ctx.plan.replace_pattern(expand_path_edges(pattern, ctx.schema))
        return had_paths


class TypeInferencePass(Pass):
    """Algorithm 1; flags ``invalid`` when the pattern is unsatisfiable."""

    name = "type_inference"
    phase = "type_inference"

    def skip(self, ctx):
        if not ctx.flags.get("type_inference", True):
            return "disabled (type_inference=False)"
        return None

    def run(self, ctx: PassContext) -> bool:
        ctx.counters["type_inference"] += 1
        pattern = ctx.pattern()
        inferred = infer_types(pattern, ctx.schema)
        if inferred == INVALID:
            ctx.invalid = True
            return True
        changed = inferred.canonical_key() != pattern.canonical_key()
        ctx.plan.replace_pattern(inferred)
        return changed


class RulePass(Pass):
    """Adapter: any heuristic ``rules.Rule`` as an rbo fixpoint-group pass."""

    phase = "rbo"

    def __init__(self, rule: Rule):
        self.rule = rule
        self.name = rule.name

    def skip(self, ctx):
        if not ctx.flags.get("rbo", True):
            return "disabled (rbo=False)"
        return None

    def run(self, ctx: PassContext) -> bool:
        return self.rule.apply(ctx.plan)


class CboPass(Pass):
    """Algorithm 2 (or the left-deep fallback) over the optimized pattern.

    Publishes ``ctx.estimator`` and always annotates the chosen plan with
    per-operator frequency/cost estimates so EXPLAIN has numbers even for
    non-CBO plans."""

    name = "cbo"
    phase = "cbo"

    def run(self, ctx: PassContext) -> bool:
        pattern = ctx.pattern()
        est = CardEstimator(
            ctx.stats,
            ctx.glogue if ctx.flags.get("use_glogue", True) else None,
            use_selectivity=ctx.flags.get("use_selectivity", True),
            params=ctx.plan.params)
        ctx.estimator = est
        if ctx.flags.get("cbo", True) and pattern.is_connected():
            ctx.counters["cbo"] += 1
            ctx.physical = GraphOptimizer(est, spec=ctx.spec).optimize(pattern)
        else:
            # disconnected patterns: cross-product plan (Algorithm 2
            # searches connected sub-patterns only)
            ctx.physical = default_left_deep_plan(pattern)
        annotate_estimates(ctx.physical, pattern, est, ctx.spec.cost)
        return True


class PhysicalRulesPass(Pass):
    """Backend seam: apply the active spec's registered post-CBO physical
    rewrites (``PhysicalSpec.physical_rules``) to the physical plan."""

    name = "physical_rules"
    phase = "post_physical"

    def skip(self, ctx):
        if not ctx.flags.get("physical_rules", True):
            return "disabled (physical_rules=False)"
        if not ctx.spec.physical_rules:
            return f"no physical rules registered by {ctx.spec.name!r}"
        return None

    def run(self, ctx: PassContext) -> bool:
        if ctx.physical is None:
            return False
        changed = False
        for rule in ctx.spec.physical_rules:
            out = rule(ctx.physical, ctx)
            if out is not None and out is not ctx.physical:
                ctx.physical = out
                changed = True
        return changed


class IntersectToJoinPass(Pass):
    """Registrable post-CBO decomposition of expand-and-intersect into a
    binary join (DESIGN.md §6.2): a multi-edge ``ExpandNode`` — expand
    along its first edge, WCOJ-probe the rest — rewrites to
    ``Join(Expand(child, e1), Expand(Scan(other(e_i)), e_i))`` on the
    extra edges, joining on the shared ``(other_endpoint, new_alias)``
    keys.  Until now this alternative existed only inside Algorithm 2's
    search (steered by ``alpha_intersect`` vs ``alpha_join``); registering
    this pass applies it to *any* physical plan, including the left-deep
    fallback and ablation plans the CBO never searched.

    ``force=True`` decomposes every multi-edge expand; the default
    consults the backend's ``CostParams`` (including the distributed
    backends' ``alpha_exchange`` term) and rewrites only where the join
    side estimates cheaper.  Register it *before* ``physical_rules`` on
    fusing backends — chain fusion may otherwise swallow the multi-edge
    expand into a fused WCOJ tail first."""

    name = "intersect_to_join"
    phase = "post_physical"

    def __init__(self, force: bool = False):
        self.force = force

    def skip(self, ctx):
        if ctx.physical is None:
            return "no physical plan"
        return None

    def run(self, ctx: PassContext) -> bool:
        pattern = ctx.pattern()
        est, cost = ctx.estimator, ctx.spec.cost
        changed = False

        def decompose(n):
            nonlocal changed
            e1, rest = n.edges[0], n.edges[1:]
            f_left = (est.pattern_freq(
                pattern, n.child.bound_aliases() | {n.new_alias})
                if est is not None else n.est_frequency)
            node = ExpandNode(n.child, n.new_alias, [e1],
                              est_frequency=f_left,
                              est_cost=n.child.est_cost + f_left)
            for e in rest:
                b = e.other(n.new_alias)
                fb = est.vertex_freq(pattern, b) if est is not None else 0.0
                scan = ScanNode(b, est_frequency=fb,
                                est_cost=cost.alpha_scan * fb)
                fr = (fb * est.expand_sigma(pattern, e, n.new_alias)
                      if est is not None else 0.0)
                right = ExpandNode(scan, n.new_alias, [e],
                                   est_frequency=fr,
                                   est_cost=scan.est_cost + fr)
                keys = tuple(sorted({b, n.new_alias}))
                node = JoinNode(node, right, keys,
                                est_frequency=n.est_frequency,
                                est_cost=(node.est_cost + right.est_cost
                                          + n.est_frequency
                                          + (cost.alpha_join
                                             + cost.alpha_exchange)
                                          * (node.est_frequency + fr)))
            changed = True
            return node

        def join_cheaper(n) -> bool:
            if self.force:
                return True
            if est is None:
                return False
            f_src = n.child.est_frequency
            probe = f_src * sum(
                cost.alpha_intersect * est.expand_sigma(pattern, e, None)
                for e in n.edges[1:])
            join_c = 0.0
            for e in n.edges[1:]:
                b = e.other(n.new_alias)
                fb = est.vertex_freq(pattern, b)
                fr = fb * est.expand_sigma(pattern, e, n.new_alias)
                join_c += (cost.alpha_scan * fb + fr
                           + (cost.alpha_join + cost.alpha_exchange)
                           * (f_src + fr))
            return join_c < probe

        def rec(n):
            if isinstance(n, ExpandNode):
                n.child = rec(n.child)
                if len(n.edges) > 1 and join_cheaper(n):
                    return decompose(n)
            elif isinstance(n, JoinNode):
                n.left, n.right = rec(n.left), rec(n.right)
            elif isinstance(n, ExpandChainNode):
                # fused chains are a backend rewrite downstream of this
                # one; their WCOJ tails stay fused
                n.child = rec(n.child)
            return n

        ctx.physical = rec(ctx.physical)
        return changed


def default_pipeline() -> OptimizerPipeline:
    """The standard pass sequence: path unfolding, type inference, the
    heuristic-rule fixpoint group (paper rules + the extended registrable
    rules), CBO, then backend physical rewrites."""
    pl = OptimizerPipeline()
    pl.register(ExpandPathsPass())
    pl.register(TypeInferencePass())
    for r in DEFAULT_RULES:
        pl.register_rule(r)
    for r in EXTENDED_RULES:
        pl.register_rule(r)
    pl.register(CboPass())
    pl.register(PhysicalRulesPass())
    return pl


# --------------------------------------------------------------------------
# EXPLAIN / PROFILE
# --------------------------------------------------------------------------

# engine ExecStats.op_rows entries that correspond 1:1 (in post-order) with
# the physical pattern-plan operators; GET_VERTEX lines are the unfused
# ablation's extra pass and belong to their EXPAND
_PATTERN_LOG_PREFIXES = ("SCAN(", "EXPAND(", "EXPANDCHAIN(", "JOIN(")


@dataclasses.dataclass
class OpReport:
    """One physical operator's estimated-vs-actual numbers."""
    op: str
    depth: int
    est_rows: float
    est_cost: float
    actual_rows: int | None = None
    # measured wall time under analyze=True (dispatch time on async
    # backends; the final device sync is absorbed by delivery)
    actual_time_s: float | None = None


@dataclasses.dataclass
class ExplainReport:
    """Structured EXPLAIN/PROFILE output (DESIGN.md §6.3).

    ``operators`` lists the physical pattern operators in tree order (root
    first, children indented by ``depth``); ``tail`` holds the relational
    operators' actual ``(name, rows, wall_s)`` under ``analyze=True``.
    ``invalid`` marks a query type inference proved unsatisfiable — no
    physical plan exists and execution returns zero rows."""
    source: str | None
    backend: str
    analyze: bool
    invalid: bool
    compile_s: float
    trace: PipelineTrace | None
    physical: PlanNode | None
    operators: list[OpReport]
    tail: list[tuple[str, int, float]]
    result_rows: int | None = None
    exec_wall_s: float | None = None
    # PROFILE SYNC mode: the engine blocked on the device after every
    # operator, so actual_time_s are true device times, not dispatch times
    sync: bool = False
    # serving-ledger section (QueryServer.explain attaches the plan's
    # ServeStats summary dict here): wave sizes/occupancy, queue delay vs
    # execution time, fallback counts — rendered as "-- serve --"
    serve: dict | None = None
    # device-to-device collective summary from ExecStats.exchanges
    # ({"kind:label": {"calls": n, "elems": m}}), PROFILE on the sharded
    # backend only — rendered as "-- exchanges --"
    exchanges: dict | None = None
    # delta-overlay ledger (``MutableGraphStore.delta_info()``): overlay
    # occupancy, snapshot spread, compaction events — rendered as
    # "-- delta --" when the store is mutable
    delta: dict | None = None

    def render(self, diffs: bool = False) -> str:
        head = ("PROFILE SYNC" if self.analyze and self.sync
                else "PROFILE" if self.analyze else "EXPLAIN")
        lines = [f"{head} (backend={self.backend}, "
                 f"compile={self.compile_s * 1e3:.2f}ms)"]
        if self.source:
            lines.append(f"query: {self.source}")
        if self.trace is not None:
            lines.append("-- pipeline --")
            lines.extend("  " + l for l in self.trace.render_lines(diffs))
        vr = self.verify
        if vr is not None:
            lines.append("-- verify --")
            lines.append(f"  status={vr['status']} checks={vr['checks']} "
                         f"wall={vr['wall_ms']:.3f}ms"
                         + (" (cached)" if vr["cached"] else ""))
            lines.extend(f"  violation: {v}" for v in vr["violations"])
        if self.invalid:
            lines.append(UNSAT_MESSAGE)
        else:
            lines.append("-- physical plan --")
            for op in self.operators:
                act = (f" act={op.actual_rows}"
                       if op.actual_rows is not None else "")
                if op.actual_time_s is not None:
                    act += f" time={op.actual_time_s * 1e3:.2f}ms"
                lines.append(f"  {'  ' * op.depth}{op.op} "
                             f"[est={op.est_rows:.3g} "
                             f"cost={op.est_cost:.3g}{act}]")
            if self.tail:
                lines.append("-- relational tail --")
                lines.extend(f"  {name} rows={rows} "
                             f"time={secs * 1e3:.2f}ms"
                             for name, rows, secs in self.tail)
        if self.exchanges:
            lines.append("-- exchanges --")
            lines.extend(f"  {k}: calls={v['calls']} elems={v['elems']}"
                         for k, v in self.exchanges.items())
        if self.serve:
            lines.append("-- serve --")
            lines.extend(f"  {k}: {v}" for k, v in self.serve.items())
        if self.delta:
            lines.append("-- delta --")
            lines.extend(f"  {k}: {v}" for k, v in self.delta.items())
        if self.result_rows is not None:
            wall = (f" in {self.exec_wall_s * 1e3:.2f}ms"
                    if self.exec_wall_s is not None else "")
            lines.append(f"result: {self.result_rows} rows{wall}")
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()

    # convenience accessors used by tests / tooling
    @property
    def verify(self) -> dict | None:
        """``VerifyReport.summary()`` of the pipeline's static verification
        (None when ``verify="off"`` or the report predates verification)."""
        rep = getattr(self.trace, "verify", None) if self.trace else None
        return rep.summary() if rep is not None else None

    def pass_names(self) -> list[str]:
        return [t.name for t in self.trace.passes] if self.trace else []

    def estimated_vs_actual(self) -> list[tuple[str, float, int | None]]:
        return [(o.op, o.est_rows, o.actual_rows) for o in self.operators]


def _tree_order(node: PlanNode) -> list[tuple[PlanNode, int]]:
    """Root-first render order with depths (children below their parent)."""
    out: list[tuple[PlanNode, int]] = []

    def rec(n: PlanNode, depth: int):
        out.append((n, depth))
        for c in plan_children(n):
            rec(c, depth + 1)

    rec(node, 0)
    return out


def build_explain_report(opt, spec: PhysicalSpec, source: str | None = None,
                         analyze: bool = False, table=None,
                         stats=None, sync: bool = False,
                         delta: dict | None = None) -> ExplainReport:
    """Assemble an ``ExplainReport`` from an ``OptimizedQuery`` (and, under
    ``analyze=True``, the execution's result table + ``ExecStats``).

    Handles the type-inference-INVALID case (``opt.physical is None``)
    by reporting the provably-empty result instead of crashing."""
    trace = getattr(opt, "trace", None)
    if opt.invalid or opt.physical is None:
        return ExplainReport(
            source=source, backend=spec.name, analyze=analyze, invalid=True,
            compile_s=opt.compile_s, trace=trace, physical=None,
            operators=[], tail=[],
            result_rows=0 if analyze else None,
            exec_wall_s=stats.wall_s if stats is not None else None,
            sync=sync, delta=delta)

    post = plan_operators(opt.physical)          # execution (post-)order
    actual_by_node: dict[int, int] = {}
    time_by_node: dict[int, float] = {}
    tail: list[tuple[str, int, float]] = []
    if stats is not None:
        # op_times entries are logged 1:1 with op_rows (same call); zip them
        # back together, defensively zero-filling foreign ExecStats
        times = (stats.op_times if len(getattr(stats, "op_times", ()))
                 == len(stats.op_rows)
                 else [(n, 0.0) for n, _ in stats.op_rows])
        logs = [(name, r, secs) for (name, r), (_, secs)
                in zip(stats.op_rows, times)]
        pat_logs = [l for l in logs
                    if l[0].startswith(_PATTERN_LOG_PREFIXES)]
        i = 0
        for n in post:
            if i >= len(pat_logs):
                break
            name, rows, secs = pat_logs[i]
            if (isinstance(n, ExpandChainNode)
                    and not name.startswith("EXPANDCHAIN(")):
                # the fuse_expand=False ablation executed the unfused plan:
                # one EXPAND log line per hop — the chain's output is the
                # last hop's, its time the hops' sum
                last = min(i + len(n.steps), len(pat_logs))
                rows = pat_logs[last - 1][1]
                secs = sum(l[2] for l in pat_logs[i:last])
                i = last
            else:
                i += 1
            actual_by_node[id(n)] = rows
            time_by_node[id(n)] = secs
        tail = [l for l in logs
                if not l[0].startswith(_PATTERN_LOG_PREFIXES)
                and not l[0].startswith("GET_VERTEX")]
    operators = [
        OpReport(describe_node(n), depth, n.est_frequency, n.est_cost,
                 actual_by_node.get(id(n)), time_by_node.get(id(n)))
        for n, depth in _tree_order(opt.physical)]
    return ExplainReport(
        source=source, backend=spec.name, analyze=analyze, invalid=False,
        compile_s=opt.compile_s, trace=trace, physical=opt.physical,
        operators=operators, tail=tail,
        result_rows=table.nrows if table is not None else None,
        exec_wall_s=stats.wall_s if stats is not None else None,
        sync=sync,
        exchanges=getattr(stats, "exchanges", None)
        if stats is not None else None,
        delta=delta)
