"""PhysicalSpec — the pluggable backend layer (paper §5.3, DESIGN.md §2/§7).

The paper's modularity claim at the physical level: a graph system plugs into
GOpt by *registering* (a) implementations of the physical operators the CBO
emits (scan, expand, expand-and-intersect/WCOJ, pattern join, and the
relational tail primitives) and (b) the cost-model parameters the optimizer
uses to weigh those operators. The optimizer and the binding-table executor
core are backend-agnostic; everything data-parallel goes through an
``OperatorSet`` resolved from the registry.

OperatorSet v2 (DESIGN.md §7): operators take and return **backend-native
arrays**.  The engine's binding ``Table`` is a thin wrapper over
backend-owned columns; the only sanctioned device->host conversion is
``ops.to_host(...)``, which the engine calls exactly once per query — at
result delivery, never between plan steps.  Besides the six core operators
(``REQUIRED_OPERATORS``) a backend inherits host-numpy defaults for the
generic array primitives (``ARRAY_PRIMITIVES``); a device backend overrides
them so binding tables stay resident.  ``TransferStats`` is the
instrumentation hook proving residency: backends record every host<->device
data movement, tagged with the engine's current execution phase.

Three backends ship in-tree (lazily imported on first ``get_spec``):

- ``numpy``   — the host path over ``repro.graphdb.vecops``;
- ``jax``     — device-resident columns, jit'd padded-block primitives, the
  ``wcoj_intersect`` Pallas kernel for membership probes, and a
  segment-reduce / sort-merge relational tail;
- ``sharded`` — the jax operators re-based on a device mesh: vertex-cut
  partitioned CSR shards, collective (``shard_map``) expansion/probing,
  and an ``ExchangeStats`` ledger recording every cross-device collective
  (DESIGN.md §10).

Adding a third backend: subclass ``OperatorSet``, build a ``PhysicalSpec``
with a ``make_operators`` factory and a ``CostParams``, call
``register_spec``, and hold the operator set to
``validate_operator_set(ops, conformance=True)`` — the v2 conformance
suite checks semantics *and* the row-order contract against tiny oracles.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

import numpy as np

# operator names every backend must implement itself (callable attributes on
# the OperatorSet it returns from make_operators, not inherited from the base)
REQUIRED_OPERATORS = ("scan", "expand", "intersect", "join",
                      "combine_keys", "group_reduce")

# v2 array primitives: host-numpy defaults on the base class; a backend with
# its own array type overrides all of them (plus vertex_prop/edge_prop) so
# binding-table columns never leave the device between plan steps
ARRAY_PRIMITIVES = ("asarray", "to_host", "take", "mask", "concat", "nonzero",
                    "full", "arange", "isin", "searchsorted", "lexsort",
                    "distinct_indices", "where")

@dataclasses.dataclass(frozen=True)
class CostParams:
    """Per-operator cost weights consumed by ``GraphOptimizer`` (Eq. 2/3).

    ``alpha_scan`` scales the Scan leaf cost F(v); ``alpha_expand`` the
    first-edge expansion term F(p_s)*sigma; ``alpha_intersect`` the extra
    WCOJ membership probes of an expand-and-intersect; ``alpha_join`` the
    binary pattern-join term F(p_s1)+F(p_s2).  ``alpha_exchange`` is the
    distributed backends' per-hop communication term: every expansion /
    probe moves its frontier across the device mesh before any local work,
    so its cost gains ``alpha_exchange * F(p_s)`` (and a join pays it on
    both input sides) — a CBO on a sharded backend thereby trades
    communication volume against intersection work.  Single-device
    backends leave it 0.0."""
    alpha_scan: float = 1.0
    alpha_expand: float = 1.0
    alpha_intersect: float = 1.0
    alpha_join: float = 1.0
    alpha_exchange: float = 0.0


class TransferStats:
    """Host<->device data-movement ledger of one ``OperatorSet``.

    Backends call ``record("d2h"|"h2d", n_elems)`` on every array that
    crosses the boundary; the engine tags the current execution phase
    (``"pattern"`` / ``"tail"`` / ``"deliver"``) so tests and benchmarks can
    assert the residency invariant: zero ``d2h`` outside ``deliver``.
    Scalar control-plane syncs (row counts, blow-up guards) are *not*
    transfers and are not recorded."""

    def __init__(self):
        self.phase = ""
        self.events: list[tuple[str, str, int]] = []   # (phase, kind, elems)

    def record(self, kind: str, elems: int):
        self.events.append((self.phase, kind, int(elems)))

    def set_phase(self, phase: str):
        self.phase = phase

    def reset(self):
        self.phase = ""
        self.events.clear()

    def mark(self) -> int:
        return len(self.events)

    def count(self, kind: str, phase: str | None = None,
              since: int = 0) -> int:
        return sum(1 for ph, k, _ in self.events[since:]
                   if k == kind and (phase is None or ph == phase))

    def elems(self, kind: str, phase: str | None = None,
              since: int = 0) -> int:
        return sum(n for ph, k, n in self.events[since:]
                   if k == kind and (phase is None or ph == phase))

    def summary(self, since: int = 0) -> dict[str, dict[str, int]]:
        """``{"phase:kind": {"calls": n, "elems": m}}`` over events recorded
        after the ``mark()`` value ``since``."""
        out: dict[str, dict[str, int]] = {}
        for ph, k, n in self.events[since:]:
            ent = out.setdefault(f"{ph or 'unphased'}:{k}",
                                 {"calls": 0, "elems": 0})
            ent["calls"] += 1
            ent["elems"] += n
        return out

    @staticmethod
    def mid_plan_d2h(transfers: dict | None) -> int:
        """Device->host transfer calls outside the delivery phase, from a
        ``summary()`` dict (``ExecStats.transfers``) — THE residency
        invariant: zero for a conforming device-resident execution.  Lives
        here because this class owns the summary key format."""
        return sum(v["calls"] for k, v in (transfers or {}).items()
                   if k.endswith(":d2h") and not k.startswith("deliver:"))


class KernelStats:
    """Compiled-program launch/compile ledger — ``TransferStats``' sibling.

    Backends record one ``dispatch`` event per *compiled program launch*
    (jit'd compound primitives, Pallas kernels, fused chain programs) and
    one ``compile`` event per program they newly build; cheap eager glue
    (takes, masks, pads, slices) is deliberately not recorded.  The engine
    snapshots the ledger into ``ExecStats.kernels`` per run, so tests and
    benchmarks can assert dispatch counts — e.g. that a fused 3-hop chain
    executes as exactly one ``fused_chain`` dispatch (DESIGN.md §8)."""

    def __init__(self):
        self.events: list[tuple[str, str, int]] = []   # (kind, label, n)

    def record(self, kind: str, label: str, n: int = 1):
        self.events.append((kind, label, int(n)))

    def reset(self):
        self.events.clear()

    def mark(self) -> int:
        return len(self.events)

    def count(self, kind: str, label: str | None = None,
              since: int = 0) -> int:
        return sum(n for k, lb, n in self.events[since:]
                   if k == kind and (label is None or lb == label))

    def summary(self, since: int = 0) -> dict[str, int]:
        out: dict[str, int] = {}
        for k, lb, n in self.events[since:]:
            out[f"{k}:{lb}"] = out.get(f"{k}:{lb}", 0) + n
        return out


class ExchangeStats:
    """Cross-device collective ledger — the third sibling of
    ``TransferStats`` / ``KernelStats``, owned by distributed backends.

    A sharded backend records one event per collective it dispatches
    (``kind`` in ``all_gather`` / ``psum`` / ``psum_scatter`` /
    ``ppermute`` / ``all_to_all``) with the operator label and the number
    of elements moved per device.  Collectives are *device-to-device* —
    they never appear in ``TransferStats`` — so the pair of ledgers proves
    the distributed residency contract: frontiers are exchanged across the
    mesh on device (``ExchangeStats`` non-empty) while host transfers stay
    confined to the delivery gather (``TransferStats.mid_plan_d2h == 0``).
    The engine snapshots the ledger into ``ExecStats.exchanges`` per run;
    single-device backends simply never record and the summary stays
    empty."""

    def __init__(self):
        self.events: list[tuple[str, str, int]] = []   # (kind, label, elems)

    def record(self, kind: str, label: str, elems: int):
        self.events.append((kind, label, int(elems)))

    def reset(self):
        self.events.clear()

    def mark(self) -> int:
        return len(self.events)

    def count(self, kind: str | None = None, label: str | None = None,
              since: int = 0) -> int:
        return sum(1 for k, lb, _ in self.events[since:]
                   if (kind is None or k == kind)
                   and (label is None or lb == label))

    def elems(self, kind: str | None = None, label: str | None = None,
              since: int = 0) -> int:
        return sum(n for k, lb, n in self.events[since:]
                   if (kind is None or k == kind)
                   and (label is None or lb == label))

    def summary(self, since: int = 0) -> dict[str, dict[str, int]]:
        """``{"kind:label": {"calls": n, "elems": m}}`` over events recorded
        after the ``mark()`` value ``since``."""
        out: dict[str, dict[str, int]] = {}
        for k, lb, n in self.events[since:]:
            ent = out.setdefault(f"{k}:{lb}", {"calls": 0, "elems": 0})
            ent["calls"] += 1
            ent["elems"] += n
        return out


class FaultStats:
    """Injected-fault ledger — the fourth sibling of ``TransferStats`` /
    ``KernelStats`` / ``ExchangeStats``, owned by fault-wrapped operator
    sets (``graphdb/faults.py``, DESIGN.md §13).

    A ``FaultPlan`` wrapper records one event per injection it performs
    (``kind`` in ``transient`` / ``permanent`` / ``capacity`` /
    ``latency``) with the operator boundary it fired at.  Clean backends
    never record and the summary stays empty, so the serving layer's
    failure accounting can always read the ledger unconditionally."""

    def __init__(self):
        self.events: list[tuple[str, str, int]] = []   # (kind, op, n)

    def record(self, kind: str, op: str, n: int = 1):
        self.events.append((kind, op, int(n)))

    def reset(self):
        self.events.clear()

    def mark(self) -> int:
        return len(self.events)

    def count(self, kind: str | None = None, op: str | None = None,
              since: int = 0) -> int:
        return sum(n for k, o, n in self.events[since:]
                   if (kind is None or k == kind) and (op is None or o == op))

    def summary(self, since: int = 0) -> dict[str, int]:
        out: dict[str, int] = {}
        for k, o, n in self.events[since:]:
            out[f"{k}:{o}"] = out.get(f"{k}:{o}", 0) + n
        return out


class OperatorSet:
    """Physical operator implementations bound to one ``GraphStore``.

    v2 contract: every array argument and result is **backend-native** —
    whatever array type the backend keeps its binding-table columns in.
    ``asarray`` brings host data in, ``to_host`` (the only sanctioned
    device->host conversion) brings results out.  The base class ships
    working host-numpy implementations of the generic array primitives and
    the property gathers, so a host backend only implements
    ``REQUIRED_OPERATORS``; a device backend overrides the primitives too.

    Output **row order is part of the contract** (DESIGN.md §2.2): operators
    are order-preserving (row-major over inputs; joins emit pairs in
    sort-merge order; groups in ascending key order) so any two conforming
    backends produce row-for-row identical binding tables for one plan.
    ``validate_operator_set(ops, conformance=True)`` checks both semantics
    and order against tiny oracles.
    """

    name = "abstract"
    # True on backends that implement chain_program (fused whole-chain
    # execution, DESIGN.md §8); the engine checks this before building specs
    supports_chains = False
    # True on backends that trace/compile programs keyed by input shapes —
    # consumers that can stabilize shapes (e.g. the QueryServer padding a
    # wave's binding list to its pow2 bucket) should do so only here
    compiled = False

    def __init__(self, store):
        self.store = store
        self.transfer_stats = TransferStats()
        self.kernel_stats = KernelStats()
        self.exchange_stats = ExchangeStats()
        self.fault_stats = FaultStats()

    def reset_ledgers(self):
        """Clear the instrumentation ledgers.  Operator sets are shared
        per (store, backend), so the event lists grow without bound under
        sustained traffic and a consumer that forgets its ``mark()`` reads
        a neighbor's events; the QueryServer scopes the ledgers to one
        wave by resetting here between waves (DESIGN.md §9)."""
        self.transfer_stats.reset()
        self.kernel_stats.reset()
        self.exchange_stats.reset()
        self.fault_stats.reset()

    # ------------------------------------------------- array primitives (v2)
    def asarray(self, values):
        """Host values -> backend array (records ``h2d`` on device sets)."""
        return np.asarray(values)

    def to_host(self, x):
        """Backend array (or a binding ``Table`` of them) -> host numpy.

        The engine calls this exactly once per query, at result delivery;
        device backends record the ``d2h`` transfer."""
        if hasattr(x, "cols") and hasattr(x, "nrows"):      # binding Table
            return type(x)({k: self._array_to_host(v)
                            for k, v in x.cols.items()}, x.nrows)
        return self._array_to_host(x)

    def _array_to_host(self, a) -> np.ndarray:
        return np.asarray(a)

    def take(self, a, idx):
        return a[idx]

    def mask(self, a, m):
        return a[m]

    def concat(self, parts: list):
        if not parts:
            return np.zeros(0, dtype=np.int64)
        if len(parts) == 1:
            return parts[0]
        return np.concatenate(parts)

    def nonzero(self, m):
        return np.nonzero(m)[0]

    def full(self, n: int, value):
        return np.full(n, value)

    def arange(self, n: int):
        return np.arange(n, dtype=np.int64)

    def isin(self, a, values) -> np.ndarray:
        return np.isin(a, np.asarray(list(values), dtype=np.int64))

    def searchsorted(self, sorted_arr, values, side: str = "left"):
        return np.searchsorted(sorted_arr, values, side=side)

    def lexsort(self, cols: list):
        """Indices sorting rows by ``cols`` (last col primary, stable)."""
        return np.lexsort(tuple(cols))

    def distinct_indices(self, key):
        """First-occurrence row index per distinct key value, ascending —
        ``take``-ing them preserves the original order of first sightings."""
        _, first = np.unique(key, return_index=True)
        return np.sort(first)

    def where(self, cond, a, b):
        """Elementwise select: ``a`` where ``cond`` else ``b`` (the delta
        overlay's epos merge between base and overlay probe results)."""
        return np.where(cond, a, b)

    # ------------------------------------------------------ property gathers
    def vertex_prop(self, ids, prop: str):
        """Property column gather for (possibly mixed-type) vertex ids;
        missing -> the backend's integer-min sentinel."""
        return self.store.vertex_prop(ids, prop)

    def edge_prop(self, triple_ids, pos, prop: str):
        return self.store.edge_prop(triple_ids, pos, prop)

    # ------------------------------------------------------------- pattern
    def scan(self, lo: int, hi: int):
        """All vertex ids of one type range ``[lo, hi)`` (SCAN leaf)."""
        raise NotImplementedError

    def expand(self, csr, rows_local, max_out: int | None = None):
        """Expand each row's vertex (local id into ``csr``) to all neighbors.

        Returns ``(row_idx, neighbor_global_id, edge_pos)`` in row-major
        order: originating binding-table row, neighbor id, and the edge's
        identity position (``csr.pos``-mapped when present)."""
        raise NotImplementedError

    def intersect(self, csr, rows_local, targets):
        """WCOJ membership probe: is ``targets[i]`` in row ``rows_local[i]``?

        Returns ``(found: bool[n], edge_pos: int[n])`` — ``edge_pos`` is
        the edge identity position, valid only where ``found``."""
        raise NotImplementedError

    def join(self, lkeys, rkeys, max_out: int | None = None):
        """Equi join of two key columns -> (lidx, ridx) row pairs in
        sort-merge order (stable by left sorted position, then right)."""
        raise NotImplementedError

    # ---------------------------------------------------- relational tail
    def combine_keys(self, cols: list):
        """Pack multiple key columns into one comparable key column whose
        ascending order is the lexicographic order of the tuples
        (``cols[0]`` most significant)."""
        raise NotImplementedError

    def group_reduce(self, keys, values: dict):
        """Group by key; groups ascend by key value.  Returns
        ``(first_row_index_per_group, {name: aggregated})``."""
        raise NotImplementedError

    # ------------------------------------------------- optional capabilities
    def chain_program(self, spec):
        """Fused whole-chain execution (DESIGN.md §8): given a
        ``graphdb.chain.ChainSpec``, return a program handle with
        ``ready() -> bool``, ``observe(hop_sizes)`` (capacity feedback from
        a per-hop measuring run) and ``run(src_col, nrows, scalars,
        value_lists, max_rows) -> (rows, cols, n) | None`` — ``None`` means
        "fall back to the per-hop loop for this execution" (capacity
        overflow; the handle regrows its buckets).  ``run`` must be
        row-identical to the per-hop loop.  The base returns ``None``: no
        fused-chain capability."""
        return None

    def pin_chain(self, spec, pinned: bool = True) -> bool:
        """Protect (or release) the compiled program handle of one chain
        shape from backend-side cache eviction — the QueryServer pins the
        chains of its hottest plans so a burst of cold plans cannot evict
        a hot plan's warmed programs.  Returns True when a handle was
        (un)pinned; the base has no program cache and returns False."""
        return False

    def block_ready(self, arrays):
        """Synchronization barrier for the sync-per-op PROFILE mode: block
        until every array in ``arrays`` (any pytree) is computed.  Host
        backends are synchronous — the default is a no-op."""
        return arrays


@dataclasses.dataclass(frozen=True)
class PhysicalSpec:
    """One backend's registration: operator factory + cost model + optional
    post-CBO physical rewrites.

    ``physical_rules`` is the backend's hook into the optimizer pipeline
    (DESIGN.md §6.2): each entry is a callable ``(plan_node, ctx) ->
    PlanNode | None`` run by the ``post_physical`` pipeline phase after the
    CBO has fixed the join/expansion order.  A rule returns a rewritten
    plan (or None / the input to decline).  Rewrites must be
    semantics-preserving — they repackage the plan for the backend (e.g.
    the jax backend's expand-chain fusion), never change its results."""
    name: str
    make_operators: Callable[..., OperatorSet]   # GraphStore -> OperatorSet
    cost: CostParams = CostParams()
    description: str = ""
    physical_rules: tuple = ()

    def operators(self, store) -> OperatorSet:
        """Operator set for ``store``, cached on the store so device-array
        uploads survive across per-query ``Engine`` instances."""
        cache = store.__dict__.setdefault("_physical_ops_cache", {})
        ops = cache.get(self.name)
        if ops is None:
            ops = self.make_operators(store)
            validate_operator_set(ops)
            cache[self.name] = ops
        return ops


_REGISTRY: dict[str, PhysicalSpec] = {}

# built-in backends, imported on first lookup (registration is a module
# side effect) so importing the engine never drags in jax
_LAZY_BACKENDS = {
    "numpy": "repro.graphdb.numpy_backend",
    "jax": "repro.graphdb.jax_backend",
    "sharded": "repro.graphdb.sharded_backend",
}


def register_spec(spec: PhysicalSpec, overwrite: bool = False) -> PhysicalSpec:
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_spec(backend: str | PhysicalSpec) -> PhysicalSpec:
    """Resolve a backend name (or pass a spec through)."""
    if isinstance(backend, PhysicalSpec):
        return backend
    if backend not in _REGISTRY and backend in _LAZY_BACKENDS:
        importlib.import_module(_LAZY_BACKENDS[backend])
    if backend not in _REGISTRY:
        raise KeyError(f"unknown physical backend {backend!r}; "
                       f"available: {available_backends()}")
    return _REGISTRY[backend]


def available_backends() -> list[str]:
    return sorted(set(_REGISTRY) | set(_LAZY_BACKENDS))


def validate_operator_set(ops: OperatorSet,
                          conformance: bool = False) -> OperatorSet:
    """Interface check (always) + the OperatorSet-v2 conformance suite
    (``conformance=True``): run every operator against tiny oracles,
    checking values *and* the row-order contract.  Raises ``TypeError``
    with the full failure list, so a third backend gets every broken
    operator in one shot."""
    missing = [n for n in REQUIRED_OPERATORS
               if not callable(getattr(ops, n, None))
               or getattr(type(ops), n, None) is getattr(OperatorSet, n)]
    if missing:
        raise TypeError(f"operator set {type(ops).__name__} does not "
                        f"implement required operators: {missing}")
    absent = [n for n in ARRAY_PRIMITIVES
              if not callable(getattr(ops, n, None))]
    if absent:
        raise TypeError(f"operator set {type(ops).__name__} lost array "
                        f"primitives: {absent}")
    if conformance:
        failures = run_operator_conformance(ops)
        if failures:
            raise TypeError(
                f"operator set {type(ops).__name__} failed OperatorSet-v2 "
                f"conformance ({len(failures)}):\n  " + "\n  ".join(failures))
    return ops


# --------------------------------------------------------------------------
# OperatorSet v2 conformance suite
# --------------------------------------------------------------------------

def _conf_csr():
    """Tiny sorted-CSR fixture: 4 rows -> [10,12] / [3,7,9] / [] / [12]."""
    from repro.graphdb.storage import CSR
    return CSR(indptr=np.array([0, 2, 5, 5, 6], dtype=np.int64),
               indices=np.array([10, 12, 3, 7, 9, 12], dtype=np.int64))


def _conf_csr2():
    """Second-hop fixture keyed over ids 0..12 (the value range of
    ``_conf_csr``): 3->[5], 7->[2,4], 10->[1], 12->[0,8], rest empty."""
    from repro.graphdb.storage import CSR
    return CSR(indptr=np.array([0, 0, 0, 0, 1, 1, 1, 1, 3, 3, 3, 4, 4, 6],
                               dtype=np.int64),
               indices=np.array([5, 2, 4, 1, 0, 8], dtype=np.int64))


def _conformance_chain(ops, fails: list[str]):
    """Fused-chain contract: a 2-hop chain over the tiny fixtures must be
    row-identical to the hand-computed per-hop expansion — provenance rows,
    bound aliases, and edge identity columns alike."""
    from repro.graphdb.chain import ChainSpec, HopSpec, OrientSpec
    spec = ChainSpec("a", [
        HopSpec("a", "b", "e1", [OrientSpec("out", _conf_csr(), 0, 4, 0)],
                [], None),
        HopSpec("b", "c", "e2", [OrientSpec("out", _conf_csr2(), 0, 13, 1)],
                [], None),
    ], [])
    prog = ops.chain_program(spec)
    if prog is None:
        fails.append("chain_program: supports_chains backend returned None")
        return
    prog.observe([6, 8])
    res = prog.run(ops.asarray(np.array([1, 0, 3], dtype=np.int64)), 3,
                   [], [], max_rows=1 << 20)
    if res is None:
        fails.append("chain_program.run: refused after observe()")
        return
    rows, cols, n = res
    H = ops.to_host
    oracle = {
        "rows": [0, 0, 0, 1, 1, 1, 2, 2],
        "b": [3, 7, 7, 10, 12, 12, 12, 12],
        "c": [5, 2, 4, 1, 0, 8, 0, 8],
        "e2#p": [0, 1, 2, 3, 4, 5, 4, 5],
        "e1#p": [2, 3, 3, 0, 1, 1, 5, 5],
        "e1#t": [0] * 8, "e2#t": [1] * 8,
    }
    got = {"rows": np.asarray(H(rows))[:n]}
    for k in ("b", "c", "e1#t", "e1#p", "e2#t", "e2#p"):
        if k not in cols:
            fails.append(f"chain_program: missing output column {k!r}")
            return
        # device-side dtype pin: compiled backends stage id/identity columns
        # as int32; checking after to_host would be blind (it widens to
        # int64 by design)
        if getattr(ops, "compiled", False):
            dt = getattr(cols[k], "dtype", None)
            if dt != np.int32:
                fails.append(f"chain_program.{k}: device column dtype "
                             f"{dt}, want int32 (staging contract)")
        got[k] = np.asarray(H(cols[k]))[:n]
    if n != 8:
        fails.append(f"chain_program: got {n} rows, want 8")
        return
    for k, want in oracle.items():
        if not np.array_equal(got[k].astype(np.int64), np.asarray(want)):
            fails.append(f"chain_program.{k}: got {got[k].tolist()!r}, "
                         f"want {want!r}")


def dtype_contract_failures(ops: OperatorSet) -> list[str]:
    """Dtype contract at operator boundaries (DESIGN.md §12), checked on
    the *backend-native* output arrays — ``to_host`` deliberately widens
    int32 to int64 and would mask a staging-dtype mixup.

    Every backend: ``isin`` and ``intersect.found`` emit a real bool mask
    (callers compose masks with ``~``/``&``; bitwise-not on an int 0/1
    column corrupts silently — the PR-8 regression), and id/position
    columns out of ``scan``/``arange``/``expand``/``intersect``/``nonzero``
    are integer-kind.  Compiled (device) backends additionally pin those
    columns to the int32 staging envelope."""
    fails: list[str] = []
    compiled = bool(getattr(ops, "compiled", False))

    def kind(a):
        return getattr(getattr(a, "dtype", None), "kind", "?")

    def want_mask(name, a):
        if getattr(a, "dtype", None) != np.bool_:
            fails.append(f"{name}: mask dtype {getattr(a, 'dtype', None)}, "
                         f"want bool")

    def want_int(name, a):
        if kind(a) not in ("i", "u"):
            fails.append(f"{name}: dtype {getattr(a, 'dtype', None)}, "
                         f"want integer kind")
        elif compiled and a.dtype != np.int32:
            fails.append(f"{name}: device dtype {a.dtype}, want int32 "
                         f"(staging contract)")

    try:
        A = ops.asarray
        want_mask("isin", ops.isin(A(np.array([5, 1, 3], np.int64)), [1, 5]))
        want_int("scan", ops.scan(0, 4))
        want_int("arange", ops.arange(4))
        want_int("nonzero",
                 ops.nonzero(A(np.array([False, True, True]))))
        csr = _conf_csr()
        # device backends cache uploaded CSR twins by id(csr): keep the
        # fixture alive on the ops instance so its id is never recycled by
        # a real CSR that would then alias the stale cache entry
        ops.__dict__.setdefault("_conf_fixtures", []).append(csr)
        ridx, nbr, epos = ops.expand(csr, A(np.array([0, 1], np.int64)))
        want_int("expand.row_idx", ridx)
        want_int("expand.nbr", nbr)
        want_int("expand.edge_pos", epos)
        found, ipos = ops.intersect(csr, A(np.array([0, 1], np.int64)),
                                    A(np.array([12, 8], np.int64)))
        want_mask("intersect.found", found)
        want_int("intersect.edge_pos", ipos)
    except Exception as exc:                           # noqa: BLE001
        fails.append(f"dtype contract aborted: {type(exc).__name__}: {exc}")
    return fails


def run_operator_conformance(ops: OperatorSet) -> list[str]:
    """Exercise every v2 operator against hand-computed oracles; returns a
    list of human-readable failures (empty = conformant).  Uses only
    synthetic arrays + a tiny CSR, so any backend can run it without a
    populated ``GraphStore``."""
    fails: list[str] = []
    H = ops.to_host
    A = ops.asarray

    def check(name, got, want, order_matters=True):
        got = np.asarray(H(got))
        want = np.asarray(want)
        if not order_matters:
            got, want = np.sort(got), np.sort(want)
        if got.shape != want.shape or not np.array_equal(
                got.astype(np.float64), want.astype(np.float64)):
            fails.append(f"{name}: got {got.tolist()!r}, "
                         f"want {want.tolist()!r}")

    def expect_raise(name, fn):
        try:
            fn()
            fails.append(f"{name}: expected RuntimeError (blow-up guard)")
        except RuntimeError:
            pass
        except Exception as exc:                       # noqa: BLE001
            fails.append(f"{name}: wrong exception {type(exc).__name__}")

    try:
        ids = A(np.array([5, 1, 3, 1, 0], dtype=np.int64))
        check("asarray/to_host roundtrip", ids, [5, 1, 3, 1, 0])
        check("take", ops.take(ids, A(np.array([2, 0], np.int64))), [3, 5])
        check("mask", ops.mask(ids, A(np.array([True, False, True, False,
                                                False]))), [5, 3])
        check("concat", ops.concat([ids, A(np.array([9], np.int64))]),
              [5, 1, 3, 1, 0, 9])
        check("nonzero", ops.nonzero(A(np.array([False, True, False, True]))),
              [1, 3])
        check("full", ops.full(3, 7), [7, 7, 7])
        check("arange", ops.arange(4), [0, 1, 2, 3])
        check("isin", ops.isin(ids, [1, 5]),
              [True, True, False, True, False])
        check("searchsorted",
              ops.searchsorted(A(np.array([1, 3, 3, 8], np.int64)),
                               A(np.array([0, 3, 9], np.int64)), side="right"),
              [0, 3, 4])
        # lexsort: last col primary, stable within ties
        c0 = A(np.array([1, 0, 1, 0], np.int64))
        c1 = A(np.array([2, 2, 1, 1], np.int64))
        check("lexsort", ops.lexsort([c0, c1]), [3, 2, 1, 0])
        check("distinct_indices",
              ops.distinct_indices(A(np.array([3, 1, 3, 7, 1], np.int64))),
              [0, 1, 3])
        check("where",
              ops.where(A(np.array([True, False, True])),
                        A(np.array([1, 2, 3], np.int64)),
                        A(np.array([7, 8, 9], np.int64))),
              [1, 8, 3])

        check("scan", ops.scan(3, 7), [3, 4, 5, 6])

        csr = _conf_csr()
        rows = A(np.array([1, 0, 2, 3], np.int64))
        ridx, nbr, epos = ops.expand(csr, rows)
        check("expand.row_idx", ridx, [0, 0, 0, 1, 1, 3])
        check("expand.nbr", nbr, [3, 7, 9, 10, 12, 12])
        check("expand.edge_pos", epos, [2, 3, 4, 0, 1, 5])
        expect_raise("expand.max_out", lambda: ops.expand(csr, rows,
                                                          max_out=2))

        found, ipos = ops.intersect(csr, A(np.array([0, 1, 1, 3], np.int64)),
                                    A(np.array([12, 8, 9, 12], np.int64)))
        check("intersect.found", found, [True, False, True, True])
        # dtype is part of the contract: callers compose the found mask with
        # ~/& and bitwise-not on an int 0/1 column corrupts silently
        if np.asarray(H(found)).dtype != np.bool_:
            fails.append("intersect.found: mask dtype "
                         f"{np.asarray(H(found)).dtype}, want bool")
        fh = np.asarray(H(found)).astype(bool)
        check("intersect.edge_pos", np.asarray(H(ipos))[fh], [1, 4, 5])

        lidx, ridx2 = ops.join(A(np.array([2, 1, 2, 5], np.int64)),
                               A(np.array([2, 2, 7, 1], np.int64)))
        check("join.lidx (sort-merge order)", lidx, [1, 0, 0, 2, 2])
        check("join.ridx (sort-merge order)", ridx2, [3, 0, 1, 0, 1])
        expect_raise("join.max_out",
                     lambda: ops.join(A(np.array([2, 1, 2, 5], np.int64)),
                                      A(np.array([2, 2, 7, 1], np.int64)),
                                      max_out=2))

        # combine_keys: grouping identity + lexicographic order
        key = H(ops.combine_keys([A(np.array([1, 1, 2, 2], np.int64)),
                                  A(np.array([1, 2, 1, 1], np.int64))]))
        key = np.asarray(key)
        if not (key[2] == key[3] and key[0] < key[1] < key[2]
                and key[0] != key[1]):
            fails.append(f"combine_keys: packed order/identity broken: "
                         f"{key.tolist()!r}")

        keys = A(np.array([3, 1, 3, 1, 7], np.int64))
        col = A(np.array([1, 2, 3, 4, 5], np.int64))
        first, aggs = ops.group_reduce(
            keys, {"c": ("COUNT", col), "s": ("SUM", col),
                   "lo": ("MIN", col), "hi": ("MAX", col),
                   "av": ("AVG", col)})
        check("group_reduce.first", first, [1, 0, 4])
        check("group_reduce.COUNT", aggs["c"], [2, 2, 1])
        check("group_reduce.SUM", aggs["s"], [6, 4, 5])
        check("group_reduce.MIN", aggs["lo"], [2, 1, 5])
        check("group_reduce.MAX", aggs["hi"], [4, 3, 5])
        check("group_reduce.AVG", aggs["av"], [3.0, 2.0, 5.0])

        if getattr(ops, "supports_chains", False):
            _conformance_chain(ops, fails)

        # operator-boundary dtype contract, pinned on every backend (not
        # just the jax intersect exit): bool masks, integer id columns,
        # int32 device staging on compiled backends
        fails.extend(dtype_contract_failures(ops))
    except Exception as exc:                           # noqa: BLE001
        fails.append(f"conformance aborted: {type(exc).__name__}: {exc}")
    return fails
