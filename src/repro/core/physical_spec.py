"""PhysicalSpec — the pluggable backend layer (paper §5.3, DESIGN.md §2).

The paper's modularity claim at the physical level: a graph system plugs into
GOpt by *registering* (a) implementations of the physical operators the CBO
emits (scan, expand, expand-and-intersect/WCOJ, pattern join, and the
relational tail primitives) and (b) the cost-model parameters the optimizer
uses to weigh those operators. The optimizer and the binding-table executor
core are backend-agnostic; everything data-parallel goes through an
``OperatorSet`` resolved from the registry.

Two backends ship in-tree (lazily imported on first ``get_spec``):

- ``numpy`` — the host path over ``repro.graphdb.vecops``;
- ``jax``   — jit'd padded-block primitives (``repro.graphdb.jaxops``) with
  the ``wcoj_intersect`` Pallas kernel for the expand-and-intersect membership
  probe (interpret mode on CPU, compiled on TPU).

Adding a third backend: subclass ``OperatorSet``, build a ``PhysicalSpec``
with a ``make_operators`` factory and a ``CostParams``, and call
``register_spec``. See DESIGN.md for the full contract.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

import numpy as np

# operator names every backend must provide (callable attributes on the
# OperatorSet it returns from make_operators)
REQUIRED_OPERATORS = ("scan", "expand", "intersect", "join",
                      "combine_keys", "group_reduce")


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Per-operator cost weights consumed by ``GraphOptimizer`` (Eq. 2/3).

    ``alpha_scan`` scales the Scan leaf cost F(v); ``alpha_expand`` the
    first-edge expansion term F(p_s)*sigma; ``alpha_intersect`` the extra
    WCOJ membership probes of an expand-and-intersect; ``alpha_join`` the
    binary pattern-join term F(p_s1)+F(p_s2)."""
    alpha_scan: float = 1.0
    alpha_expand: float = 1.0
    alpha_intersect: float = 1.0
    alpha_join: float = 1.0


class OperatorSet:
    """Physical operator implementations bound to one ``GraphStore``.

    All array arguments and results are host numpy (int64 binding-table
    columns); a backend is free to stage through device arrays internally —
    padded-block / validity-mask layouts stay hidden behind this interface.
    """

    name = "abstract"

    def __init__(self, store):
        self.store = store

    # ------------------------------------------------------------- pattern
    def scan(self, lo: int, hi: int) -> np.ndarray:
        """All vertex ids of one type range ``[lo, hi)`` (SCAN leaf)."""
        raise NotImplementedError

    def expand(self, csr, rows_local: np.ndarray,
               max_out: int | None = None):
        """Expand each row's vertex (local id into ``csr``) to all neighbors.

        Returns ``(row_idx, neighbor_global_id, edge_pos)`` in row-major
        order: originating binding-table row, neighbor id, and the edge's
        identity position (``csr.pos``-mapped when present)."""
        raise NotImplementedError

    def intersect(self, csr, rows_local: np.ndarray, targets: np.ndarray):
        """WCOJ membership probe: is ``targets[i]`` in row ``rows_local[i]``?

        Returns ``(found: bool[n], edge_pos: int64[n])`` — ``edge_pos`` is
        the edge identity position, valid only where ``found``."""
        raise NotImplementedError

    def join(self, lkeys: np.ndarray, rkeys: np.ndarray,
             max_out: int | None = None):
        """Equi join of two int64 key columns -> (lidx, ridx) row pairs."""
        raise NotImplementedError

    # ---------------------------------------------------- relational tail
    def combine_keys(self, cols: list[np.ndarray]) -> np.ndarray:
        raise NotImplementedError

    def group_reduce(self, keys: np.ndarray, values: dict):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PhysicalSpec:
    """One backend's registration: operator factory + cost model + optional
    post-CBO physical rewrites.

    ``physical_rules`` is the backend's hook into the optimizer pipeline
    (DESIGN.md §6.2): each entry is a callable ``(plan_node, ctx) ->
    PlanNode | None`` run by the ``post_physical`` pipeline phase after the
    CBO has fixed the join/expansion order.  A rule returns a rewritten
    plan (or None / the input to decline).  Rewrites must be
    semantics-preserving — they repackage the plan for the backend (e.g.
    the jax backend's expand-chain fusion), never change its results."""
    name: str
    make_operators: Callable[..., OperatorSet]   # GraphStore -> OperatorSet
    cost: CostParams = CostParams()
    description: str = ""
    physical_rules: tuple = ()

    def operators(self, store) -> OperatorSet:
        """Operator set for ``store``, cached on the store so device-array
        uploads survive across per-query ``Engine`` instances."""
        cache = store.__dict__.setdefault("_physical_ops_cache", {})
        ops = cache.get(self.name)
        if ops is None:
            ops = self.make_operators(store)
            validate_operator_set(ops)
            cache[self.name] = ops
        return ops


_REGISTRY: dict[str, PhysicalSpec] = {}

# built-in backends, imported on first lookup (registration is a module
# side effect) so importing the engine never drags in jax
_LAZY_BACKENDS = {
    "numpy": "repro.graphdb.numpy_backend",
    "jax": "repro.graphdb.jax_backend",
}


def register_spec(spec: PhysicalSpec, overwrite: bool = False) -> PhysicalSpec:
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_spec(backend: str | PhysicalSpec) -> PhysicalSpec:
    """Resolve a backend name (or pass a spec through)."""
    if isinstance(backend, PhysicalSpec):
        return backend
    if backend not in _REGISTRY and backend in _LAZY_BACKENDS:
        importlib.import_module(_LAZY_BACKENDS[backend])
    if backend not in _REGISTRY:
        raise KeyError(f"unknown physical backend {backend!r}; "
                       f"available: {available_backends()}")
    return _REGISTRY[backend]


def available_backends() -> list[str]:
    return sorted(set(_REGISTRY) | set(_LAZY_BACKENDS))


def validate_operator_set(ops: OperatorSet) -> OperatorSet:
    missing = [n for n in REQUIRED_OPERATORS
               if not callable(getattr(ops, n, None))
               or getattr(type(ops), n, None) is getattr(OperatorSet, n)]
    if missing:
        raise TypeError(f"operator set {type(ops).__name__} does not "
                        f"implement required operators: {missing}")
    return ops
