"""Rule-based optimization (paper §5.2, §6).

Hep-style driver: each rule is (condition, action) over the LogicalPlan;
rules are applied repeatedly until a fixpoint. Implemented rules:

- FilterIntoMatchRule  (graph-relational interplay): single-alias conjuncts of
  SELECT move into the pattern vertex/edge predicate lists, so the engine
  filters during expansion.
- FieldTrimRule        (relational): computes which aliases/properties are
  live downstream and records them on the plan (`plan.hints['live']`); the
  engine then never materializes or ships dead columns.
- ExpandGetVFusionRule (graph): marks EXPAND_EDGE+GET_VERTEX fusable unless a
  downstream operator needs standalone edge processing
  (`plan.hints['fuse_expand']`).
- OrderLimitFuseRule   (relational): ORDER BY followed by LIMIT becomes a
  top-k OrderBy (partial sort in the engine).

``DEFAULT_RULES`` is the paper's historical rule set (frozen — the parity
baseline); ``EXTENDED_RULES`` (ConstantFoldingRule,
RedundantSelectMergeRule) ride the OptimizerPipeline registration seam
(core/pipeline.py) instead of being hand-woven into the driver.  The
``apply_rules`` fixpoint driver remains for direct/legacy use; the default
pipeline runs every rule in its rbo fixpoint group with per-rule traces.
"""
from __future__ import annotations

import operator

from repro.core import ir


class Rule:
    name = "rule"

    def apply(self, plan: ir.LogicalPlan) -> bool:
        """Mutates plan; returns True if anything changed."""
        raise NotImplementedError


class FilterIntoMatchRule(Rule):
    name = "FilterIntoMatchRule"

    def apply(self, plan: ir.LogicalPlan) -> bool:
        pattern = plan.pattern()
        if pattern is None:
            return False
        changed = False
        new_ops = []
        for op in plan.ops:
            if not isinstance(op, ir.Select):
                new_ops.append(op)
                continue
            keep = []
            for c in ir.conjuncts(op.predicate):
                aliases = ir.expr_aliases(c)
                if len(aliases) != 1:
                    keep.append(c)
                    continue
                a = next(iter(aliases))
                if a in pattern.vertices:
                    pattern.vertices[a].predicates.append(c)
                    changed = True
                    continue
                edge = next((e for e in pattern.edges if e.alias == a), None)
                if edge is not None:
                    edge.predicates.append(c)
                    changed = True
                    continue
                keep.append(c)
            pred = ir.make_and(keep)
            if pred is not None:
                new_ops.append(ir.Select(pred))
        if changed:
            plan.ops[:] = new_ops
        return changed


class FieldTrimRule(Rule):
    name = "FieldTrimRule"

    def apply(self, plan: ir.LogicalPlan) -> bool:
        pattern = plan.pattern()
        if pattern is None:
            return False
        live_aliases: set[str] = set()
        live_props: set[tuple[str, str]] = set()

        def visit(e):
            live_aliases.update(ir.expr_aliases(e))
            for p in ir.expr_props(e):
                live_props.add((p.alias, p.name))

        for op in plan.ops:
            if isinstance(op, ir.Select):
                visit(op.predicate)
            elif isinstance(op, ir.Project):
                for e, _ in op.items:
                    visit(e)
            elif isinstance(op, ir.GroupBy):
                for e, _ in op.keys:
                    visit(e)
                for a, _ in op.aggs:
                    visit(a)
            elif isinstance(op, ir.OrderBy):
                for e, _ in op.items:
                    visit(e)
        # pattern-internal predicates (already pushed) count as live too
        for v in pattern.vertices.values():
            for p in v.predicates:
                visit(p)
        for e in pattern.edges:
            for p in e.predicates:
                visit(p)
        new = {"aliases": frozenset(live_aliases),
               "props": frozenset(live_props)}
        if plan.hints.get("live") == new:
            return False
        plan.hints["live"] = new
        return True


class ExpandGetVFusionRule(Rule):
    name = "ExpandGetVFusionRule"

    def apply(self, plan: ir.LogicalPlan) -> bool:
        if "fuse_expand" in plan.hints:
            return False
        # Fusion is legal unless some downstream op needs the edge as a
        # standalone row stream; with binding tables we can always fuse.
        plan.hints["fuse_expand"] = True
        return True


class OrderLimitFuseRule(Rule):
    name = "OrderLimitFuseRule"

    def apply(self, plan: ir.LogicalPlan) -> bool:
        ops = plan.ops
        for i in range(len(ops) - 1):
            if (isinstance(ops[i], ir.OrderBy) and ops[i].limit is None
                    and isinstance(ops[i + 1], ir.Limit)):
                ops[i].limit = ops[i + 1].n
                del ops[i + 1]
                return True
        return False


class ConstantFoldingRule(Rule):
    """Fold constant sub-expressions in predicates (SELECT ops and the
    predicates already pushed into pattern vertices/edges): ``Cmp``/``InSet``
    over literals become ``Lit(True/False)``, booleans simplify (AND drops
    True / collapses on False, OR dually, NOT inverts).  A tautological
    filter disappears; a contradiction stays as ``Select(Lit(False))`` so
    the engine short-circuits to zero rows."""

    name = "ConstantFoldingRule"

    @classmethod
    def fold(cls, e):
        if isinstance(e, ir.Cmp):
            lhs, rhs = cls.fold(e.lhs), cls.fold(e.rhs)
            if isinstance(lhs, ir.Lit) and isinstance(rhs, ir.Lit):
                ops = {"=": operator.eq, "<>": operator.ne,
                       "<": operator.lt, ">": operator.gt,
                       "<=": operator.le, ">=": operator.ge}
                try:
                    return ir.Lit(bool(ops[e.op](lhs.value, rhs.value)))
                except TypeError:
                    pass                      # incomparable literals
            if lhs is e.lhs and rhs is e.rhs:
                return e
            return ir.Cmp(e.op, lhs, rhs)
        if isinstance(e, ir.InSet):
            item = cls.fold(e.item)
            if isinstance(item, ir.Lit) and not isinstance(e.values, ir.Param):
                return ir.Lit(item.value in e.values)
            if item is e.item:
                return e
            return ir.InSet(item, e.values)
        if isinstance(e, ir.BoolOp):
            args = tuple(cls.fold(a) for a in e.args)
            if e.op == "NOT":
                if isinstance(args[0], ir.Lit):
                    return ir.Lit(not args[0].value)
                return e if args[0] is e.args[0] else ir.BoolOp("NOT", args)
            dominant = e.op == "OR"           # True dominates OR, False AND
            keep = []
            for a in args:
                if isinstance(a, ir.Lit) and isinstance(a.value, bool):
                    if a.value == dominant:
                        return ir.Lit(dominant)
                    continue                  # neutral element: drop
                keep.append(a)
            if not keep:
                return ir.Lit(not dominant)
            if len(keep) == 1:
                return keep[0]
            if tuple(keep) == e.args:
                return e
            return ir.BoolOp(e.op, tuple(keep))
        return e

    def apply(self, plan: ir.LogicalPlan) -> bool:
        changed = False
        new_ops = []
        for op in plan.ops:
            if isinstance(op, ir.Select):
                folded = self.fold(op.predicate)
                # NB: check the folded *value*, not object identity — a
                # predicate that already IS Lit(True) must still be dropped
                # (and report changed, honoring the fixpoint contract)
                if isinstance(folded, ir.Lit) and folded.value is True:
                    changed = True
                    continue                  # tautology: drop the filter
                if folded is not op.predicate:
                    changed = True
                    op = ir.Select(folded)
            new_ops.append(op)
        pattern = plan.pattern()
        if pattern is not None:
            elems = list(pattern.vertices.values()) + list(pattern.edges)
            for el in elems:
                kept = []
                for p in el.predicates:
                    folded = self.fold(p)
                    if isinstance(folded, ir.Lit) and folded.value is True:
                        changed = True
                        continue
                    if folded is not p:
                        changed = True
                    kept.append(folded)
                el.predicates[:] = kept
        if changed:
            plan.ops[:] = new_ops
        return changed


class RedundantSelectMergeRule(Rule):
    """Merge consecutive SELECT ops into one and drop duplicate conjuncts
    (expressions are frozen dataclasses, so equality is structural).  Keeps
    conjunct order stable for deterministic canonical forms."""

    name = "RedundantSelectMergeRule"

    @staticmethod
    def _dedup(conjs: list) -> list:
        seen = set()
        out = []
        for c in conjs:
            if c not in seen:
                seen.add(c)
                out.append(c)
        return out

    def apply(self, plan: ir.LogicalPlan) -> bool:
        changed = False
        new_ops: list = []
        for op in plan.ops:
            if (isinstance(op, ir.Select) and new_ops
                    and isinstance(new_ops[-1], ir.Select)):
                merged = self._dedup(ir.conjuncts(new_ops[-1].predicate)
                                     + ir.conjuncts(op.predicate))
                new_ops[-1] = ir.Select(ir.make_and(merged))
                changed = True
                continue
            if isinstance(op, ir.Select):
                conjs = ir.conjuncts(op.predicate)
                deduped = self._dedup(conjs)
                if len(deduped) != len(conjs):
                    op = ir.Select(ir.make_and(deduped))
                    changed = True
            new_ops.append(op)
        if changed:
            plan.ops[:] = new_ops
        return changed


DEFAULT_RULES: tuple[Rule, ...] = (
    FilterIntoMatchRule(),
    FieldTrimRule(),
    ExpandGetVFusionRule(),
    OrderLimitFuseRule(),
)

# Rules that ride the OptimizerPipeline's registration seam rather than the
# historical frozen driver list: the default pipeline registers these after
# DEFAULT_RULES (core/pipeline.py), proving the rbo phase carries rules that
# were never hand-woven into GOpt.optimize.
EXTENDED_RULES: tuple[Rule, ...] = (
    ConstantFoldingRule(),
    RedundantSelectMergeRule(),
)


def apply_rules(plan: ir.LogicalPlan, rules=DEFAULT_RULES,
                max_iters: int = 10) -> ir.LogicalPlan:
    """HepPlanner-style fixpoint application. Mutates and returns plan."""
    for _ in range(max_iters):
        changed = False
        for r in rules:
            changed |= r.apply(plan)
        if not changed:
            break
    return plan
