"""Rule-based optimization (paper §5.2, §6).

Hep-style driver: each rule is (condition, action) over the LogicalPlan;
rules are applied repeatedly until a fixpoint. Implemented rules:

- FilterIntoMatchRule  (graph-relational interplay): single-alias conjuncts of
  SELECT move into the pattern vertex/edge predicate lists, so the engine
  filters during expansion.
- FieldTrimRule        (relational): computes which aliases/properties are
  live downstream and records them on the plan (`plan.hints['live']`); the
  engine then never materializes or ships dead columns.
- ExpandGetVFusionRule (graph): marks EXPAND_EDGE+GET_VERTEX fusable unless a
  downstream operator needs standalone edge processing
  (`plan.hints['fuse_expand']`).
- OrderLimitFuseRule   (relational): ORDER BY followed by LIMIT becomes a
  top-k OrderBy (partial sort in the engine).
"""
from __future__ import annotations

from repro.core import ir


class Rule:
    name = "rule"

    def apply(self, plan: ir.LogicalPlan) -> bool:
        """Mutates plan; returns True if anything changed."""
        raise NotImplementedError


class FilterIntoMatchRule(Rule):
    name = "FilterIntoMatchRule"

    def apply(self, plan: ir.LogicalPlan) -> bool:
        pattern = plan.pattern()
        if pattern is None:
            return False
        changed = False
        new_ops = []
        for op in plan.ops:
            if not isinstance(op, ir.Select):
                new_ops.append(op)
                continue
            keep = []
            for c in ir.conjuncts(op.predicate):
                aliases = ir.expr_aliases(c)
                if len(aliases) != 1:
                    keep.append(c)
                    continue
                a = next(iter(aliases))
                if a in pattern.vertices:
                    pattern.vertices[a].predicates.append(c)
                    changed = True
                    continue
                edge = next((e for e in pattern.edges if e.alias == a), None)
                if edge is not None:
                    edge.predicates.append(c)
                    changed = True
                    continue
                keep.append(c)
            pred = ir.make_and(keep)
            if pred is not None:
                new_ops.append(ir.Select(pred))
        if changed:
            plan.ops[:] = new_ops
        return changed


class FieldTrimRule(Rule):
    name = "FieldTrimRule"

    def apply(self, plan: ir.LogicalPlan) -> bool:
        pattern = plan.pattern()
        if pattern is None:
            return False
        live_aliases: set[str] = set()
        live_props: set[tuple[str, str]] = set()

        def visit(e):
            live_aliases.update(ir.expr_aliases(e))
            for p in ir.expr_props(e):
                live_props.add((p.alias, p.name))

        for op in plan.ops:
            if isinstance(op, ir.Select):
                visit(op.predicate)
            elif isinstance(op, ir.Project):
                for e, _ in op.items:
                    visit(e)
            elif isinstance(op, ir.GroupBy):
                for e, _ in op.keys:
                    visit(e)
                for a, _ in op.aggs:
                    visit(a)
            elif isinstance(op, ir.OrderBy):
                for e, _ in op.items:
                    visit(e)
        # pattern-internal predicates (already pushed) count as live too
        for v in pattern.vertices.values():
            for p in v.predicates:
                visit(p)
        for e in pattern.edges:
            for p in e.predicates:
                visit(p)
        new = {"aliases": frozenset(live_aliases),
               "props": frozenset(live_props)}
        if plan.hints.get("live") == new:
            return False
        plan.hints["live"] = new
        return True


class ExpandGetVFusionRule(Rule):
    name = "ExpandGetVFusionRule"

    def apply(self, plan: ir.LogicalPlan) -> bool:
        if "fuse_expand" in plan.hints:
            return False
        # Fusion is legal unless some downstream op needs the edge as a
        # standalone row stream; with binding tables we can always fuse.
        plan.hints["fuse_expand"] = True
        return True


class OrderLimitFuseRule(Rule):
    name = "OrderLimitFuseRule"

    def apply(self, plan: ir.LogicalPlan) -> bool:
        ops = plan.ops
        for i in range(len(ops) - 1):
            if (isinstance(ops[i], ir.OrderBy) and ops[i].limit is None
                    and isinstance(ops[i + 1], ir.Limit)):
                ops[i].limit = ops[i + 1].n
                del ops[i + 1]
                return True
        return False


DEFAULT_RULES: tuple[Rule, ...] = (
    FilterIntoMatchRule(),
    FieldTrimRule(),
    ExpandGetVFusionRule(),
    OrderLimitFuseRule(),
)


def apply_rules(plan: ir.LogicalPlan, rules=DEFAULT_RULES,
                max_iters: int = 10) -> ir.LogicalPlan:
    """HepPlanner-style fixpoint application. Mutates and returns plan."""
    for _ in range(max_iters):
        changed = False
        for r in rules:
            changed |= r.apply(plan)
        if not changed:
            break
    return plan
