"""Physical pattern-plan algebra (paper §5.3.1).

The CBO decomposes a PATTERN into a tree over two physical operators:

- ``Expand({p_s, +v} -> p_t)`` — vertex expansion; with one edge it's a simple
  neighbor expansion, with several it is the *expand-and-intersect* step of a
  worst-case-optimal join;
- ``Join({p_s1, p_s2} -> p_t)`` — binary pattern join on the common vertices
  (PatternJoinRule, Eq. 1).

Leaf = Scan of a single pattern vertex. Nodes carry the estimated frequency
and accumulated cost so plans are inspectable in benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.pattern import Pattern, PatternEdge


@dataclasses.dataclass
class PlanNode:
    est_frequency: float = dataclasses.field(default=0.0, kw_only=True)
    est_cost: float = dataclasses.field(default=0.0, kw_only=True)

    def bound_aliases(self) -> frozenset[str]:
        raise NotImplementedError

    def pretty(self, indent: int = 0) -> str:
        raise NotImplementedError


@dataclasses.dataclass
class ScanNode(PlanNode):
    alias: str

    def bound_aliases(self) -> frozenset[str]:
        return frozenset({self.alias})

    def pretty(self, indent=0):
        pad = "  " * indent
        return (f"{pad}Scan({self.alias}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]")


@dataclasses.dataclass
class ExpandNode(PlanNode):
    child: PlanNode
    new_alias: str
    edges: list[PatternEdge]   # all pattern edges new_alias<->bound vertices

    def bound_aliases(self) -> frozenset[str]:
        return self.child.bound_aliases() | {self.new_alias}

    def pretty(self, indent=0):
        pad = "  " * indent
        kind = "ExpandIntersect" if len(self.edges) > 1 else "Expand"
        es = ",".join(f"{e.src}->{e.dst}" for e in self.edges)
        return (f"{pad}{kind}(+{self.new_alias} via {es}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]\n"
                + self.child.pretty(indent + 1))


@dataclasses.dataclass
class JoinNode(PlanNode):
    left: PlanNode
    right: PlanNode
    keys: tuple[str, ...]

    def bound_aliases(self) -> frozenset[str]:
        return self.left.bound_aliases() | self.right.bound_aliases()

    def pretty(self, indent=0):
        pad = "  " * indent
        return (f"{pad}Join(keys={list(self.keys)}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]\n"
                + self.left.pretty(indent + 1) + "\n"
                + self.right.pretty(indent + 1))


@dataclasses.dataclass
class ChainStep:
    """One hop of an ``ExpandChainNode``: expand ``from_alias`` along
    ``edge`` to bind ``alias``.  Carries the per-hop estimates of the
    ``ExpandNode`` it was fused from, so ``unfused()`` round-trips."""
    edge: PatternEdge
    from_alias: str
    alias: str
    est_frequency: float = 0.0
    est_cost: float = 0.0


@dataclasses.dataclass
class ExpandChainNode(PlanNode):
    """A fused run of consecutive single-edge expansions (backend physical
    rewrite, DESIGN.md §6.2): the engine expands a *thin* frontier table
    (hop columns only) hop-by-hop and gathers the full binding table once
    at the end, instead of round-tripping every bound column through the
    host at every hop.  Only predicate-free hops are fusable — deferring a
    filter past a hop would change intermediate semantics."""
    child: PlanNode
    steps: list[ChainStep]

    def bound_aliases(self) -> frozenset[str]:
        return self.child.bound_aliases() | {s.alias for s in self.steps}

    def unfused(self) -> PlanNode:
        """The equivalent nested-``ExpandNode`` chain (the pre-fusion
        plan) — used by the engine's fuse ablation and by parity checks."""
        node = self.child
        for s in self.steps:
            node = ExpandNode(node, s.alias, [s.edge],
                              est_frequency=s.est_frequency,
                              est_cost=s.est_cost)
        return node

    def pretty(self, indent=0):
        pad = "  " * indent
        hops = ",".join(f"+{s.alias}" for s in self.steps)
        return (f"{pad}ExpandChain({hops}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]\n"
                + self.child.pretty(indent + 1))


def plan_signature(node: PlanNode) -> str:
    """Stable string for logging/plan comparison."""
    if isinstance(node, ScanNode):
        return f"S({node.alias})"
    if isinstance(node, ExpandNode):
        return f"E({plan_signature(node.child)},+{node.new_alias}x{len(node.edges)})"
    if isinstance(node, JoinNode):
        return (f"J({plan_signature(node.left)},{plan_signature(node.right)},"
                f"k={'/'.join(node.keys)})")
    if isinstance(node, ExpandChainNode):
        hops = "".join(f",+{s.alias}" for s in node.steps)
        return f"C({plan_signature(node.child)}{hops})"
    raise TypeError(node)


def unfuse_chains(node: PlanNode) -> PlanNode:
    """Normalize a plan by unfolding every ``ExpandChainNode`` back into
    nested expansions — chain fusion is packaging, not a different join
    order, so parity checks compare plans modulo fusion through this."""
    if isinstance(node, ExpandChainNode):
        return unfuse_chains(node.unfused())
    if isinstance(node, ExpandNode):
        return dataclasses.replace(node, child=unfuse_chains(node.child))
    if isinstance(node, JoinNode):
        return dataclasses.replace(node, left=unfuse_chains(node.left),
                                   right=unfuse_chains(node.right))
    return node


def plan_children(node: PlanNode) -> list[PlanNode]:
    if isinstance(node, ExpandNode):
        return [node.child]
    if isinstance(node, ExpandChainNode):
        return [node.child]
    if isinstance(node, JoinNode):
        return [node.left, node.right]
    return []


def plan_operators(node: PlanNode) -> list[PlanNode]:
    """All operators of a pattern plan in execution (post-)order — the
    order the engine logs their actual row counts in ``ExecStats``."""
    out: list[PlanNode] = []

    def rec(n: PlanNode):
        for c in plan_children(n):
            rec(c)
        out.append(n)

    rec(node)
    return out


def describe_node(node: PlanNode) -> str:
    """Short human-readable operator label for EXPLAIN output."""
    if isinstance(node, ScanNode):
        return f"Scan({node.alias})"
    if isinstance(node, ExpandNode):
        kind = "ExpandIntersect" if len(node.edges) > 1 else "Expand"
        return f"{kind}(+{node.new_alias}|{len(node.edges)}e)"
    if isinstance(node, JoinNode):
        return f"Join(keys={list(node.keys)})"
    if isinstance(node, ExpandChainNode):
        hops = "".join(f"+{s.alias}" for s in node.steps)
        return f"ExpandChain({hops})"
    raise TypeError(node)


def _component_left_deep(pattern: Pattern,
                         start: str) -> tuple[PlanNode, set[str]]:
    """Left-deep expansion of ``start``'s connected component."""
    node: PlanNode = ScanNode(start)
    bound = {start}
    while True:
        nxt = None
        for b in sorted(bound):
            for e in pattern.adjacent(b):
                o = e.other(b)
                if o not in bound:
                    nxt = o
                    break
            if nxt:
                break
        if nxt is None:
            return node, bound
        edges = [e for e in pattern.adjacent(nxt) if e.other(nxt) in bound]
        node = ExpandNode(node, nxt, edges)
        bound.add(nxt)


def default_left_deep_plan(pattern: Pattern,
                           start: Optional[str] = None) -> PlanNode:
    """A naive left-deep expansion plan in BFS alias order — the engine's
    fallback when no CBO plan is supplied, and the 'unoptimized' baseline.

    A disconnected pattern becomes one left-deep plan per connected
    component, combined with keyless Joins (cross products)."""
    aliases = sorted(pattern.vertices)
    if not aliases:
        raise ValueError("cannot plan an empty pattern")
    start = start or aliases[0]
    node, bound = _component_left_deep(pattern, start)
    while bound != set(aliases):
        nxt = next(a for a in aliases if a not in bound)
        right, rbound = _component_left_deep(pattern, nxt)
        node = JoinNode(node, right, ())
        bound |= rbound
    return node
