"""Physical pattern-plan algebra (paper §5.3.1).

The CBO decomposes a PATTERN into a tree over two physical operators:

- ``Expand({p_s, +v} -> p_t)`` — vertex expansion; with one edge it's a simple
  neighbor expansion, with several it is the *expand-and-intersect* step of a
  worst-case-optimal join;
- ``Join({p_s1, p_s2} -> p_t)`` — binary pattern join on the common vertices
  (PatternJoinRule, Eq. 1).

Leaf = Scan of a single pattern vertex. Nodes carry the estimated frequency
and accumulated cost so plans are inspectable in benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.pattern import Pattern, PatternEdge


@dataclasses.dataclass
class PlanNode:
    est_frequency: float = dataclasses.field(default=0.0, kw_only=True)
    est_cost: float = dataclasses.field(default=0.0, kw_only=True)

    def bound_aliases(self) -> frozenset[str]:
        raise NotImplementedError

    def pretty(self, indent: int = 0) -> str:
        raise NotImplementedError


@dataclasses.dataclass
class ScanNode(PlanNode):
    alias: str

    def bound_aliases(self) -> frozenset[str]:
        return frozenset({self.alias})

    def pretty(self, indent=0):
        pad = "  " * indent
        return (f"{pad}Scan({self.alias}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]")


@dataclasses.dataclass
class ExpandNode(PlanNode):
    child: PlanNode
    new_alias: str
    edges: list[PatternEdge]   # all pattern edges new_alias<->bound vertices

    def bound_aliases(self) -> frozenset[str]:
        return self.child.bound_aliases() | {self.new_alias}

    def pretty(self, indent=0):
        pad = "  " * indent
        kind = "ExpandIntersect" if len(self.edges) > 1 else "Expand"
        es = ",".join(f"{e.src}->{e.dst}" for e in self.edges)
        return (f"{pad}{kind}(+{self.new_alias} via {es}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]\n"
                + self.child.pretty(indent + 1))


@dataclasses.dataclass
class JoinNode(PlanNode):
    left: PlanNode
    right: PlanNode
    keys: tuple[str, ...]

    def bound_aliases(self) -> frozenset[str]:
        return self.left.bound_aliases() | self.right.bound_aliases()

    def pretty(self, indent=0):
        pad = "  " * indent
        return (f"{pad}Join(keys={list(self.keys)}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]\n"
                + self.left.pretty(indent + 1) + "\n"
                + self.right.pretty(indent + 1))


def plan_signature(node: PlanNode) -> str:
    """Stable string for logging/plan comparison."""
    if isinstance(node, ScanNode):
        return f"S({node.alias})"
    if isinstance(node, ExpandNode):
        return f"E({plan_signature(node.child)},+{node.new_alias}x{len(node.edges)})"
    if isinstance(node, JoinNode):
        return (f"J({plan_signature(node.left)},{plan_signature(node.right)},"
                f"k={'/'.join(node.keys)})")
    raise TypeError(node)


def _component_left_deep(pattern: Pattern,
                         start: str) -> tuple[PlanNode, set[str]]:
    """Left-deep expansion of ``start``'s connected component."""
    node: PlanNode = ScanNode(start)
    bound = {start}
    while True:
        nxt = None
        for b in sorted(bound):
            for e in pattern.adjacent(b):
                o = e.other(b)
                if o not in bound:
                    nxt = o
                    break
            if nxt:
                break
        if nxt is None:
            return node, bound
        edges = [e for e in pattern.adjacent(nxt) if e.other(nxt) in bound]
        node = ExpandNode(node, nxt, edges)
        bound.add(nxt)


def default_left_deep_plan(pattern: Pattern,
                           start: Optional[str] = None) -> PlanNode:
    """A naive left-deep expansion plan in BFS alias order — the engine's
    fallback when no CBO plan is supplied, and the 'unoptimized' baseline.

    A disconnected pattern becomes one left-deep plan per connected
    component, combined with keyless Joins (cross products)."""
    aliases = sorted(pattern.vertices)
    if not aliases:
        raise ValueError("cannot plan an empty pattern")
    start = start or aliases[0]
    node, bound = _component_left_deep(pattern, start)
    while bound != set(aliases):
        nxt = next(a for a in aliases if a not in bound)
        right, rbound = _component_left_deep(pattern, nxt)
        node = JoinNode(node, right, ())
        bound |= rbound
    return node
