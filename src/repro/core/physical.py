"""Physical pattern-plan algebra (paper §5.3.1).

The CBO decomposes a PATTERN into a tree over two physical operators:

- ``Expand({p_s, +v} -> p_t)`` — vertex expansion; with one edge it's a simple
  neighbor expansion, with several it is the *expand-and-intersect* step of a
  worst-case-optimal join;
- ``Join({p_s1, p_s2} -> p_t)`` — binary pattern join on the common vertices
  (PatternJoinRule, Eq. 1).

Leaf = Scan of a single pattern vertex. Nodes carry the estimated frequency
and accumulated cost so plans are inspectable in benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import ir
from repro.core.pattern import Pattern, PatternEdge


@dataclasses.dataclass
class PlanNode:
    est_frequency: float = dataclasses.field(default=0.0, kw_only=True)
    est_cost: float = dataclasses.field(default=0.0, kw_only=True)

    def bound_aliases(self) -> frozenset[str]:
        raise NotImplementedError

    def pretty(self, indent: int = 0) -> str:
        raise NotImplementedError


@dataclasses.dataclass
class ScanNode(PlanNode):
    alias: str

    def bound_aliases(self) -> frozenset[str]:
        return frozenset({self.alias})

    def pretty(self, indent=0):
        pad = "  " * indent
        return (f"{pad}Scan({self.alias}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]")


@dataclasses.dataclass
class ExpandNode(PlanNode):
    child: PlanNode
    new_alias: str
    edges: list[PatternEdge]   # all pattern edges new_alias<->bound vertices

    def bound_aliases(self) -> frozenset[str]:
        return self.child.bound_aliases() | {self.new_alias}

    def pretty(self, indent=0):
        pad = "  " * indent
        kind = "ExpandIntersect" if len(self.edges) > 1 else "Expand"
        es = ",".join(f"{e.src}->{e.dst}" for e in self.edges)
        return (f"{pad}{kind}(+{self.new_alias} via {es}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]\n"
                + self.child.pretty(indent + 1))


@dataclasses.dataclass
class JoinNode(PlanNode):
    left: PlanNode
    right: PlanNode
    keys: tuple[str, ...]

    def bound_aliases(self) -> frozenset[str]:
        return self.left.bound_aliases() | self.right.bound_aliases()

    def pretty(self, indent=0):
        pad = "  " * indent
        return (f"{pad}Join(keys={list(self.keys)}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]\n"
                + self.left.pretty(indent + 1) + "\n"
                + self.right.pretty(indent + 1))


@dataclasses.dataclass
class ChainStep:
    """One hop of an ``ExpandChainNode``: expand ``from_alias`` along
    ``edge`` to bind ``alias``.  Carries the per-hop estimates of the
    ``ExpandNode`` it was fused from, so ``unfused()`` round-trips.

    ``intersect_edges`` (only ever non-empty on a chain's *last* step) are
    the extra edges of a fused expand-and-intersect: after the expansion
    the step probes each of them as a WCOJ membership filter, exactly like
    a multi-edge ``ExpandNode`` — the chain then ends in a wcoj step."""
    edge: PatternEdge
    from_alias: str
    alias: str
    est_frequency: float = 0.0
    est_cost: float = 0.0
    intersect_edges: tuple = ()

    def all_edges(self) -> list[PatternEdge]:
        return [self.edge, *self.intersect_edges]


@dataclasses.dataclass
class ExpandChainNode(PlanNode):
    """A fused run of consecutive single-edge expansions (backend physical
    rewrite, DESIGN.md §6.2): the engine expands a *thin* frontier table
    (hop columns only) hop-by-hop and gathers the full binding table once
    at the end, instead of round-tripping every bound column through the
    host at every hop.  Only predicate-free hops are fusable — deferring a
    filter past a hop would change intermediate semantics."""
    child: PlanNode
    steps: list[ChainStep]

    def bound_aliases(self) -> frozenset[str]:
        return self.child.bound_aliases() | {s.alias for s in self.steps}

    def unfused(self) -> PlanNode:
        """The equivalent nested-``ExpandNode`` chain (the pre-fusion
        plan) — used by the engine's fuse ablation and by parity checks."""
        node = self.child
        for s in self.steps:
            node = ExpandNode(node, s.alias, s.all_edges(),
                              est_frequency=s.est_frequency,
                              est_cost=s.est_cost)
        return node

    def pretty(self, indent=0):
        pad = "  " * indent
        hops = ",".join(f"+{s.alias}" + (f"x{1 + len(s.intersect_edges)}"
                                         if s.intersect_edges else "")
                        for s in self.steps)
        return (f"{pad}ExpandChain({hops}) "
                f"[F={self.est_frequency:.3g} C={self.est_cost:.3g}]\n"
                + self.child.pretty(indent + 1))


def plan_signature(node: PlanNode) -> str:
    """Stable string for logging/plan comparison."""
    if isinstance(node, ScanNode):
        return f"S({node.alias})"
    if isinstance(node, ExpandNode):
        return f"E({plan_signature(node.child)},+{node.new_alias}x{len(node.edges)})"
    if isinstance(node, JoinNode):
        return (f"J({plan_signature(node.left)},{plan_signature(node.right)},"
                f"k={'/'.join(node.keys)})")
    if isinstance(node, ExpandChainNode):
        hops = "".join(f",+{s.alias}x{1 + len(s.intersect_edges)}"
                       if s.intersect_edges else f",+{s.alias}"
                       for s in node.steps)
        return f"C({plan_signature(node.child)}{hops})"
    raise TypeError(node)


def unfuse_chains(node: PlanNode) -> PlanNode:
    """Normalize a plan by unfolding every ``ExpandChainNode`` back into
    nested expansions — chain fusion is packaging, not a different join
    order, so parity checks compare plans modulo fusion through this."""
    if isinstance(node, ExpandChainNode):
        return unfuse_chains(node.unfused())
    if isinstance(node, ExpandNode):
        return dataclasses.replace(node, child=unfuse_chains(node.child))
    if isinstance(node, JoinNode):
        return dataclasses.replace(node, left=unfuse_chains(node.left),
                                   right=unfuse_chains(node.right))
    return node


def plan_children(node: PlanNode) -> list[PlanNode]:
    if isinstance(node, ExpandNode):
        return [node.child]
    if isinstance(node, ExpandChainNode):
        return [node.child]
    if isinstance(node, JoinNode):
        return [node.left, node.right]
    return []


def plan_operators(node: PlanNode) -> list[PlanNode]:
    """All operators of a pattern plan in execution (post-)order — the
    order the engine logs their actual row counts in ``ExecStats``."""
    out: list[PlanNode] = []

    def rec(n: PlanNode):
        for c in plan_children(n):
            rec(c)
        out.append(n)

    rec(node)
    return out


def describe_node(node: PlanNode) -> str:
    """Short human-readable operator label for EXPLAIN output."""
    if isinstance(node, ScanNode):
        return f"Scan({node.alias})"
    if isinstance(node, ExpandNode):
        kind = "ExpandIntersect" if len(node.edges) > 1 else "Expand"
        return f"{kind}(+{node.new_alias}|{len(node.edges)}e)"
    if isinstance(node, JoinNode):
        return f"Join(keys={list(node.keys)})"
    if isinstance(node, ExpandChainNode):
        hops = "".join(f"+{s.alias}" for s in node.steps)
        return f"ExpandChain({hops})"
    raise TypeError(node)


# --------------------------------------------------------------------------
# Chain-fusable predicates (DESIGN.md §8)
# --------------------------------------------------------------------------
# A hop predicate can fold into a fused ExpandChainNode program when it is a
# boolean combination of comparisons / IN-set probes whose column side reads
# an alias the thin chain frontier carries and whose value side is a literal
# or a late-bound parameter.  ``compile_chain_predicate`` turns such a
# predicate into (a) a hashable *static* signature — part of the fused
# program's compile-cache key, shared across literal/parameter values — and
# (b) runtime *slot* descriptors the engine evaluates per execution (value
# encoding, parameter resolution), so rebinding a parameter never recompiles.

_I32_LO, _I32_HI = -(1 << 31), (1 << 31) - 1


def _chain_value_ok(v) -> bool:
    """Literal values the int32-staged fused program can honor: in-envelope
    integers, or strings (encoded to ints at slot evaluation).  Anything
    else is rejected *statically* so the hop stays on the plain path
    instead of fusing and then falling back on every execution."""
    if isinstance(v, str):
        return True
    return (not isinstance(v, bool) and isinstance(v, int)
            and _I32_LO < v <= _I32_HI)


def _chain_col_ref(e, vertex_aliases, edge_aliases):
    if isinstance(e, ir.Var) and e.alias in vertex_aliases:
        return ("col", e.alias)
    if isinstance(e, ir.Prop):
        if e.alias in vertex_aliases:
            return ("vprop", e.alias, e.name)
        if e.alias in edge_aliases:
            return ("eprop", e.alias, e.name)
    return None


def compile_chain_predicate(expr, vertex_aliases, edge_aliases, slots):
    """Compile one pattern predicate into its chain-fusable form.

    Returns the static signature (appending runtime slot descriptors —
    ``("scalar", lhs_expr, rhs_expr)`` or ``("values", item_expr, values)``
    — to ``slots``), or ``None`` when the predicate falls outside the
    fusable subset; the caller then leaves the hop to the per-hop loop."""
    if isinstance(expr, ir.Cmp):
        ref = _chain_col_ref(expr.lhs, vertex_aliases, edge_aliases)
        if ref is None or not isinstance(expr.rhs, (ir.Lit, ir.Param)):
            return None
        if isinstance(expr.rhs, ir.Lit) and not _chain_value_ok(
                expr.rhs.value):
            return None
        slots.append(("scalar", expr.lhs, expr.rhs))
        return ("cmp", expr.op, ref, len(slots) - 1)
    if isinstance(expr, ir.InSet):
        ref = _chain_col_ref(expr.item, vertex_aliases, edge_aliases)
        if ref is None:
            return None
        if not isinstance(expr.values, ir.Param) and not all(
                _chain_value_ok(v) for v in expr.values):
            return None
        slots.append(("values", expr.item, expr.values))
        return ("in", ref, len(slots) - 1)
    if isinstance(expr, ir.BoolOp):
        subs = tuple(compile_chain_predicate(a, vertex_aliases, edge_aliases,
                                             slots)
                     for a in expr.args)
        if any(s is None for s in subs):
            return None
        return (expr.op.lower(), subs)
    return None


def chain_fusable_predicates(preds, vertex_aliases, edge_aliases) -> bool:
    """True when every predicate in ``preds`` compiles to chain-fusable
    form — the fusion rule's gate for folding a predicated hop."""
    scratch: list = []
    return all(
        compile_chain_predicate(p, vertex_aliases, edge_aliases, scratch)
        is not None for p in preds or [])


def _component_left_deep(pattern: Pattern,
                         start: str) -> tuple[PlanNode, set[str]]:
    """Left-deep expansion of ``start``'s connected component."""
    node: PlanNode = ScanNode(start)
    bound = {start}
    while True:
        nxt = None
        for b in sorted(bound):
            for e in pattern.adjacent(b):
                o = e.other(b)
                if o not in bound:
                    nxt = o
                    break
            if nxt:
                break
        if nxt is None:
            return node, bound
        edges = [e for e in pattern.adjacent(nxt) if e.other(nxt) in bound]
        node = ExpandNode(node, nxt, edges)
        bound.add(nxt)


def default_left_deep_plan(pattern: Pattern,
                           start: Optional[str] = None) -> PlanNode:
    """A naive left-deep expansion plan in BFS alias order — the engine's
    fallback when no CBO plan is supplied, and the 'unoptimized' baseline.

    A disconnected pattern becomes one left-deep plan per connected
    component, combined with keyless Joins (cross products)."""
    aliases = sorted(pattern.vertices)
    if not aliases:
        raise ValueError("cannot plan an empty pattern")
    start = start or aliases[0]
    node, bound = _component_left_deep(pattern, start)
    while bound != set(aliases):
        nxt = next(a for a in aliases if a not in bound)
        right, rbound = _component_left_deep(pattern, nxt)
        node = JoinNode(node, right, ())
        bound |= rbound
    return node
