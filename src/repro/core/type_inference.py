"""Type inference and validation (paper §4.3, Algorithm 1).

Iteratively refines the type constraints of every pattern vertex/edge against
the graph schema until a fixpoint, or returns INVALID when some element admits
no type. Edge constraints are kept as schema *triples*, so direction-sensitive
refinement (paper lines 13-22) is a set intersection.
"""
from __future__ import annotations

import heapq
import itertools

from repro.core.pattern import BOTH, IN, OUT, Pattern
from repro.core.schema import GraphSchema

INVALID = "INVALID"


def _edge_triples_consistent(edge, src_types, dst_types):
    """Triples of ``edge`` consistent with current endpoint constraints,
    honouring direction (BOTH admits either orientation)."""
    keep = set()
    for t in edge.triples:
        fwd = t.src in src_types and t.dst in dst_types
        rev = t.src in dst_types and t.dst in src_types
        if edge.direction == OUT and fwd:
            keep.add(t)
        elif edge.direction == IN and rev:
            keep.add(t)
        elif edge.direction == BOTH and (fwd or rev):
            keep.add(t)
    return frozenset(keep)


def _endpoint_candidates(edge, v_alias, vertices):
    """Vertex types ``v_alias`` may take per edge triples, orientation-aware:
    a triple only contributes a candidate for the orientation whose *other*
    endpoint type is currently feasible (found by a hypothesis property
    test: BOTH edges must not leak the wrong-orientation endpoint type)."""
    src_types = vertices[edge.src].types
    dst_types = vertices[edge.dst].types
    cand = set()
    for t in edge.triples:
        if edge.direction in (OUT, BOTH):      # forward: src->dst
            if v_alias == edge.dst and t.src in src_types:
                cand.add(t.dst)
            if v_alias == edge.src and t.dst in dst_types:
                cand.add(t.src)
        if edge.direction in (IN, BOTH):       # reverse: dst->src
            if v_alias == edge.src and t.src in dst_types:
                cand.add(t.dst)
            if v_alias == edge.dst and t.dst in src_types:
                cand.add(t.src)
    return frozenset(cand)


def infer_types(pattern: Pattern, schema: GraphSchema):
    """Algorithm 1. Returns a *new* Pattern with validated constraints, or the
    string INVALID. The input pattern is not mutated."""
    p = pattern.copy()

    # Drop vertex types with no support in the schema at all.
    for v in p.vertices.values():
        v.types = v.types & schema.all_vertex_types()
        if not v.types:
            return INVALID

    # Line 1: priority queue of vertices, ascending |tau(v)|.
    counter = itertools.count()
    q: list = []
    in_q: set[str] = set()

    def push(alias):
        if alias not in in_q:
            heapq.heappush(q, (len(p.vertices[alias].types), next(counter), alias))
            in_q.add(alias)

    for a in p.vertices:
        push(a)

    while q:                                            # line 2
        _, _, u = heapq.heappop(q)                      # line 3
        in_q.discard(u)
        uv = p.vertices[u]

        # (1) Type refinement for u itself (lines 5-12): a basic type of u is
        # viable only if, for every adjacent pattern edge, the schema offers a
        # triple in that edge's constraint set touching u with the right
        # orientation.
        viable = set()
        for tb in uv.types:
            ok = True
            for e in p.adjacent(u):
                u_is_src = e.src == u
                found = False
                for t in e.triples:
                    if e.direction == OUT:
                        found |= (t.src == tb) if u_is_src else (t.dst == tb)
                    elif e.direction == IN:
                        found |= (t.dst == tb) if u_is_src else (t.src == tb)
                    else:
                        found |= t.src == tb or t.dst == tb
                    if found:
                        break
                if not found:
                    ok = False
                    break
            if ok:
                viable.add(tb)
        if not viable:
            return INVALID
        if viable != uv.types:
            uv.types = frozenset(viable)

        # (2) Refinement for adjacencies (lines 13-22).
        for e in p.adjacent(u):
            v_alias = e.other(u)
            vv = p.vertices[v_alias]
            new_triples = _edge_triples_consistent(
                e, p.vertices[e.src].types, p.vertices[e.dst].types)
            if not new_triples:                          # line 16-18
                return INVALID
            e.triples = new_triples
            cand_v = _endpoint_candidates(e, v_alias, p.vertices)
            new_types = vv.types & cand_v
            if not new_types:
                return INVALID
            if new_types != vv.types:                    # lines 19-21
                vv.types = new_types
                push(v_alias)
            # u itself may also have shrunk via the edge; requeue if so.
            cand_u = _endpoint_candidates(e, u, p.vertices)
            new_u = uv.types & cand_u
            if not new_u:
                return INVALID
            if new_u != uv.types:
                uv.types = new_u
                push(u)
    return p


def enumerate_basic_assignments(pattern: Pattern, schema: GraphSchema,
                                limit: int | None = None):
    """The naive unfold of §4.3 (for testing & GLogue): all BasicType
    assignments of ``pattern`` consistent with the schema. Exponential — only
    used on small patterns and as the oracle for property tests."""
    names = sorted(pattern.vertices)
    domains = [sorted(pattern.vertices[a].types) for a in names]
    out = []
    for combo in itertools.product(*domains):
        assign = dict(zip(names, combo))
        ok = True
        for e in pattern.edges:
            s, d = assign[e.src], assign[e.dst]
            match = False
            for t in e.triples:
                if e.direction == OUT:
                    match |= t.src == s and t.dst == d
                elif e.direction == IN:
                    match |= t.src == d and t.dst == s
                else:
                    match |= (t.src == s and t.dst == d) or (
                        t.src == d and t.dst == s)
                if match:
                    break
            if not match:
                ok = False
                break
        if ok:
            out.append(assign)
            if limit is not None and len(out) >= limit:
                break
    return out
