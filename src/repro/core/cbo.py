"""Cost-based graph optimizer (paper §5.3.4, Algorithm 2).

Top-down recursive search over connected induced sub-patterns with
branch-and-bound pruning, seeded by a greedy initial plan. Physical algebra:
vertex Expand (simple / expand-and-intersect == WCOJ) and binary pattern Join
(PatternJoinRule). Cost model Eq. 2/3 plus the intermediate-result term
(communication cost):

    cost'(Expand) = cost(p_s) + F(p_t) + F(p_s) * sum(sigma_e)     (Eq. 3)
    cost'(Join)   = cost(p_s1) + cost(p_s2) + F(p_t) + F(p_s1) + F(p_s2)

Also provides the paper's experimental foils: random valid plans and a
"low-order" baseline optimizer (Neo4j-style: independence assumption, no
GLogue, no WCOJ intersections — greedy single-edge expansions only).
"""
from __future__ import annotations

import dataclasses
import itertools
import random

from repro.core.cardinality import CardEstimator
from repro.core.pattern import Pattern
from repro.core.physical import (ExpandChainNode, ExpandNode, JoinNode,
                                 PlanNode, ScanNode, plan_signature)
from repro.core.physical_spec import CostParams, PhysicalSpec, get_spec


@dataclasses.dataclass
class _Best:
    plan: PlanNode | None
    cost: float


class GraphOptimizer:
    """Algorithm 2 over the alias-subset lattice of a pattern."""

    def __init__(self, est: CardEstimator, enable_join: bool = True,
                 enable_intersect: bool = True,
                 alpha_expand: float | None = None,
                 alpha_join: float | None = None,
                 alpha_intersect: float | None = None,
                 alpha_scan: float | None = None,
                 alpha_exchange: float | None = None,
                 spec: str | PhysicalSpec | None = None):
        """Cost weights default to the active backend's ``CostParams``
        (``spec``, a PhysicalSpec or backend name); explicit ``alpha_*``
        keyword arguments override the spec values."""
        self.est = est
        self.enable_join = enable_join
        self.enable_intersect = enable_intersect
        cost = get_spec(spec).cost if spec is not None else CostParams()
        self.alpha_scan = cost.alpha_scan if alpha_scan is None else alpha_scan
        self.alpha_expand = (cost.alpha_expand if alpha_expand is None
                             else alpha_expand)
        self.alpha_intersect = (cost.alpha_intersect if alpha_intersect is None
                                else alpha_intersect)
        self.alpha_join = cost.alpha_join if alpha_join is None else alpha_join
        self.alpha_exchange = (cost.alpha_exchange if alpha_exchange is None
                               else alpha_exchange)
        self.stats = {"explored": 0, "pruned": 0}

    # ------------------------------------------------------------- interface
    def optimize(self, pattern: Pattern) -> PlanNode:
        full = frozenset(pattern.vertices)
        init = self.greedy_initial(pattern)
        self._bound = init.est_cost          # cost* from GreedyInitial
        self._plan_map: dict[frozenset[str], _Best] = {}
        # seed PlanMap with single vertices (precomputed sizes 1 & 2 — size-2
        # plans emerge from a Scan+Expand, so seeding scans suffices)
        for a in pattern.vertices:
            f = self.est.vertex_freq(pattern, a)
            c = self.alpha_scan * f
            self._plan_map[frozenset({a})] = _Best(
                ScanNode(a, est_frequency=f, est_cost=c), c)
        self._search(pattern, full)
        out = self._plan_map[full].plan
        if out is None or init.est_cost < self._plan_map[full].cost:
            return init
        return out

    # --------------------------------------------------------------- greedy
    def greedy_initial(self, pattern: Pattern) -> PlanNode:
        """GreedyInitial: cheapest-next-extension from the cheapest vertex.

        A disconnected pattern (no expandable candidate left) attaches the
        next component via a keyless cross-product Join and keeps going."""
        aliases = set(pattern.vertices)
        start = min(aliases, key=lambda a: self.est.vertex_freq(pattern, a))
        f = self.est.vertex_freq(pattern, start)
        node: PlanNode = ScanNode(start, est_frequency=f,
                                  est_cost=self.alpha_scan * f)
        bound = {start}
        while bound != aliases:
            best_alias, best_cost = None, None
            for cand in sorted(aliases - bound):
                edges = [e for e in pattern.adjacent(cand)
                         if e.other(cand) in bound]
                if not edges:
                    continue
                step_cost, f_new = self._expand_cost(
                    pattern, frozenset(bound), node.est_frequency, cand, edges)
                if best_cost is None or step_cost + f_new < best_cost:
                    best_alias, best_cost = cand, step_cost + f_new
                    best_edges, best_f, best_step = edges, f_new, step_cost
            if best_alias is None:   # next connected component
                nxt = min(aliases - bound,
                          key=lambda a: self.est.vertex_freq(pattern, a))
                fs = self.est.vertex_freq(pattern, nxt)
                scan = ScanNode(nxt, est_frequency=fs,
                                est_cost=self.alpha_scan * fs)
                fx = node.est_frequency * fs   # cross product is exact
                node = JoinNode(
                    node, scan, (), est_frequency=fx,
                    est_cost=(node.est_cost + scan.est_cost + fx +
                              (self.alpha_join + self.alpha_exchange)
                              * (node.est_frequency + fs)))
                bound.add(nxt)
                continue
            node = ExpandNode(node, best_alias, best_edges,
                              est_frequency=best_f,
                              est_cost=node.est_cost + best_step + best_f)
            bound.add(best_alias)
        return node

    def _expand_cost(self, pattern, bound: frozenset[str], f_src: float,
                     new_alias: str, edges) -> tuple[float, float]:
        """(operator cost Eq.3, F(p_t) via Eq.6/GLogue)."""
        if not self.enable_intersect:
            edges = edges[:1]
        # first edge is the primary expansion; the rest are WCOJ membership
        # probes — each weighted by its backend's cost parameter
        weighted = 0.0
        first = True
        for e in edges:
            sigma = self.est.expand_sigma(pattern, e,
                                          new_alias if first else None)
            weighted += (self.alpha_expand if first
                         else self.alpha_intersect) * sigma
            first = False
        # Eq.3 + the distributed backends' per-hop communication term:
        # every frontier row is exchanged once per hop (degree resolution /
        # probe routing), so communication scales with F(p_s), not sigma
        op_cost = f_src * max(weighted, 1e-12) + self.alpha_exchange * f_src
        f_new = self.est.pattern_freq(pattern, bound | {new_alias})
        return op_cost, f_new

    # ---------------------------------------------------------------- search
    def _search(self, pattern: Pattern, subset: frozenset[str]) -> _Best:
        if subset in self._plan_map:
            return self._plan_map[subset]
        self.stats["explored"] += 1
        best = _Best(None, float("inf"))
        self._plan_map[subset] = best  # placeholder (patterns are DAG-free)
        f_t = self.est.pattern_freq(pattern, subset)

        # --- Expand candidates: peel one vertex -------------------------
        for v in sorted(subset):
            rest = subset - {v}
            if not rest:
                continue
            rsub = pattern.induced(rest)
            if not rsub.is_connected():
                continue
            edges = [e for e in pattern.adjacent(v) if e.other(v) in rest]
            if not edges:
                continue
            f_s = self.est.pattern_freq(pattern, rest)
            # LowerBound pruning (lines 10-12): any plan materializing ``rest``
            # pays at least F(p_s); compare against the greedy bound cost*.
            if f_s >= self._bound:
                self.stats["pruned"] += 1
                continue
            child = self._search(pattern, rest)
            if child.plan is None:
                continue
            op_cost, _ = self._expand_cost(pattern, rest, f_s, v, edges)
            cost = child.cost + f_t + op_cost
            if cost < best.cost:
                best.plan = ExpandNode(child.plan, v, edges,
                                       est_frequency=f_t, est_cost=cost)
                best.cost = cost
                self._bound = min(self._bound, cost) if subset == frozenset(
                    pattern.vertices) else self._bound

        # --- Join candidates: split into two overlapping connected parts --
        if self.enable_join and len(subset) >= 3:
            for s1, s2 in self._join_splits(pattern, subset):
                f1 = self.est.pattern_freq(pattern, s1)
                f2 = self.est.pattern_freq(pattern, s2)
                if min(f1, f2) >= self._bound:
                    self.stats["pruned"] += 1
                    continue
                c1 = self._search(pattern, s1)
                c2 = self._search(pattern, s2)
                if c1.plan is None or c2.plan is None:
                    continue
                # both join sides' key columns are gather-exchanged on a
                # distributed backend before the merge
                op_cost = (self.alpha_join + self.alpha_exchange) * (f1 + f2)
                cost = c1.cost + c2.cost + f_t + op_cost
                if cost < best.cost:
                    best.plan = JoinNode(c1.plan, c2.plan,
                                         tuple(sorted(s1 & s2)),
                                         est_frequency=f_t, est_cost=cost)
                    best.cost = cost
        return best

    def _join_splits(self, pattern: Pattern, subset: frozenset[str]):
        """Valid PatternJoinRule splits: connected overlapping halves whose
        union covers every edge of the induced pattern."""
        sub = pattern.induced(subset)
        names = sorted(subset)
        seen = set()
        for r in range(2, len(names)):
            for combo in itertools.combinations(names, r):
                s1 = frozenset(combo)
                # s2 must contain all vertices not in s1 plus the overlap;
                # enumerate overlaps implicitly: s2 = complement + boundary.
                comp = subset - s1
                if not comp:
                    continue
                # boundary vertices of s1 touching comp must be shared
                shared = {v for v in s1
                          for e in sub.adjacent(v) if e.other(v) in comp}
                s2 = frozenset(comp | shared)
                if not shared:
                    continue
                key = (s1, s2)
                if key in seen or (s2, s1) in seen:
                    continue
                seen.add(key)
                if len(s2) >= len(subset):
                    continue
                p1, p2 = pattern.induced(s1), pattern.induced(s2)
                if not (p1.is_connected() and p2.is_connected()):
                    continue
                # every edge covered by one side?
                cov = 0
                for e in sub.edges:
                    in1 = e.src in s1 and e.dst in s1
                    in2 = e.src in s2 and e.dst in s2
                    if in1 or in2:
                        cov += 1
                if cov == len(sub.edges):
                    yield s1, s2


def annotate_estimates(node: PlanNode, pattern: Pattern, est: CardEstimator,
                       cost: CostParams | None = None) -> PlanNode:
    """Fill in ``est_frequency``/``est_cost`` (Eq. 2/3) on plan nodes that
    were built outside Algorithm 2 — the left-deep fallback for
    disconnected patterns and ablation plans carry zeros otherwise, which
    leaves EXPLAIN without per-operator numbers.  Nodes that already carry
    a nonzero frequency (CBO output) are left untouched.  Mutates and
    returns ``node``."""
    cost = cost or CostParams()

    def expand_op_cost(src_freq: float, edges, new_alias: str) -> float:
        weighted = 0.0
        first = True
        for e in edges:
            sigma = est.expand_sigma(pattern, e, new_alias if first else None)
            weighted += (cost.alpha_expand if first
                         else cost.alpha_intersect) * sigma
            first = False
        return (src_freq * max(weighted, 1e-12)
                + cost.alpha_exchange * src_freq)

    def rec(n: PlanNode) -> float:
        if isinstance(n, ScanNode):
            if n.est_frequency == 0.0:
                f = est.vertex_freq(pattern, n.alias)
                n.est_frequency = f
                n.est_cost = cost.alpha_scan * f
            return n.est_cost
        if isinstance(n, ExpandNode):
            child_cost = rec(n.child)
            if n.est_frequency == 0.0:
                bound = n.child.bound_aliases()
                f = est.pattern_freq(pattern, bound | {n.new_alias})
                n.est_frequency = f
                n.est_cost = (child_cost + f + expand_op_cost(
                    n.child.est_frequency, n.edges, n.new_alias))
            return n.est_cost
        if isinstance(n, JoinNode):
            lc, rc = rec(n.left), rec(n.right)
            if n.est_frequency == 0.0:
                s1 = n.left.bound_aliases()
                s2 = n.right.bound_aliases()
                f = est.join_freq(pattern, s1, s2)
                n.est_frequency = f
                n.est_cost = (lc + rc + f
                              + (cost.alpha_join + cost.alpha_exchange)
                              * (n.left.est_frequency
                                 + n.right.est_frequency))
            return n.est_cost
        if isinstance(n, ExpandChainNode):
            child_cost = rec(n.child)
            bound = set(n.child.bound_aliases())
            src_freq = n.child.est_frequency
            acc = child_cost
            for s in n.steps:
                bound.add(s.alias)
                if s.est_frequency == 0.0:
                    f = est.pattern_freq(pattern, frozenset(bound))
                    s.est_frequency = f
                    s.est_cost = acc + f + expand_op_cost(
                        src_freq, [s.edge], s.alias)
                src_freq = s.est_frequency
                acc = s.est_cost
            if n.est_frequency == 0.0 and n.steps:
                n.est_frequency = n.steps[-1].est_frequency
                n.est_cost = n.steps[-1].est_cost
            return n.est_cost
        raise TypeError(n)

    rec(node)
    return node


# ---------------------------------------------------------------- baselines

def random_plan(pattern: Pattern, rng: random.Random,
                est: CardEstimator | None = None) -> PlanNode:
    """A random valid left-deep expansion order (the paper's red-circle
    comparison plans)."""
    aliases = list(pattern.vertices)
    start = rng.choice(aliases)
    node: PlanNode = ScanNode(start)
    bound = {start}
    while len(bound) < len(aliases):
        frontier = sorted({e.other(b) for b in bound
                           for e in pattern.adjacent(b)
                           if e.other(b) not in bound})
        v = rng.choice(frontier)
        edges = [e for e in pattern.adjacent(v) if e.other(v) in bound]
        node = ExpandNode(node, v, edges)
        bound.add(v)
    return node


def low_order_plan(pattern: Pattern, est: CardEstimator,
                   spec: str | PhysicalSpec | None = None) -> PlanNode:
    """Neo4j-style foil: greedy order from low-order stats under the edge
    independence assumption, no GLogue, no WCOJ intersect (single-edge
    expansion; extra cycle edges become post-filters, modeled here by
    expanding on the first edge only). ``spec`` supplies backend cost
    parameters, like the full optimizer."""
    opt = GraphOptimizer(est, enable_join=False, enable_intersect=False,
                         spec=spec)
    return opt.greedy_initial(pattern)


def all_left_deep_plans(pattern: Pattern, limit: int = 10000):
    """Enumerate every left-deep expansion order (for exhaustive tests)."""
    aliases = sorted(pattern.vertices)
    plans = []

    def rec(node, bound):
        if len(plans) >= limit:
            return
        if len(bound) == len(aliases):
            plans.append(node)
            return
        for v in aliases:
            if v in bound:
                continue
            edges = [e for e in pattern.adjacent(v) if e.other(v) in bound]
            if not edges:
                continue
            rec(ExpandNode(node, v, edges), bound | {v})

    for s in aliases:
        rec(ScanNode(s), {s})
    return plans
