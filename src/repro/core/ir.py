"""Unified intermediate representation (paper §4.1).

The IR couples a data model (Vertex/Edge/Path + primitives) with graph
operators (SCAN, EXPAND_EDGE, GET_VERTEX, EXPAND_PATH, MATCH_PATTERN) and
relational operators (SELECT, PROJECT, GROUP, ORDER, LIMIT, JOIN).  A logical
plan is a DAG of these operators; for PatRelQuery it is a chain
``MATCH_PATTERN -> relational ops`` (joins appear inside the pattern part as
physical operators chosen by the CBO).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

from repro.core.pattern import Pattern

# --------------------------------------------------------------------------
# Expressions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Prop:
    """alias.prop — a property of a bound vertex/edge."""
    alias: str
    name: str

    def __repr__(self):
        return f"{self.alias}.{self.name}"


@dataclasses.dataclass(frozen=True)
class Var:
    """A bound pattern alias itself (vertex/edge id column)."""
    alias: str

    def __repr__(self):
        return self.alias


@dataclasses.dataclass(frozen=True)
class Lit:
    value: Any

    def __repr__(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True)
class Param:
    """A late-bound query parameter ``$name`` — a first-class IR node that
    survives through RBO/CBO into the physical plan and is resolved against
    the execution-time bindings (DESIGN.md §3).  ``InSet.values`` may also be
    a ``Param`` (whole-list parameter, e.g. ``x IN $S``)."""
    name: str

    def __repr__(self):
        return f"${self.name}"


@dataclasses.dataclass(frozen=True)
class Cmp:
    op: str          # = <> < > <= >=
    lhs: Any
    rhs: Any

    def __repr__(self):
        return f"({self.lhs} {self.op} {self.rhs})"


@dataclasses.dataclass(frozen=True)
class InSet:
    item: Any
    values: tuple

    def __repr__(self):
        return f"({self.item} IN {list(self.values)!r})"


@dataclasses.dataclass(frozen=True)
class BoolOp:
    op: str          # AND OR NOT
    args: tuple

    def __repr__(self):
        if self.op == "NOT":
            return f"(NOT {self.args[0]})"
        return "(" + f" {self.op} ".join(map(repr, self.args)) + ")"


@dataclasses.dataclass(frozen=True)
class Agg:
    fn: str          # COUNT SUM MIN MAX AVG
    arg: Any = None  # None == COUNT(*)

    def __repr__(self):
        return f"{self.fn}({self.arg if self.arg is not None else '*'})"


def expr_aliases(e) -> set[str]:
    """Pattern aliases referenced by an expression."""
    if isinstance(e, Prop):
        return {e.alias}
    if isinstance(e, Var):
        return {e.alias}
    if isinstance(e, Cmp):
        return expr_aliases(e.lhs) | expr_aliases(e.rhs)
    if isinstance(e, InSet):
        return expr_aliases(e.item)
    if isinstance(e, BoolOp):
        out: set[str] = set()
        for a in e.args:
            out |= expr_aliases(a)
        return out
    if isinstance(e, Agg):
        return expr_aliases(e.arg) if e.arg is not None else set()
    return set()


def expr_var_aliases(e) -> set[str]:
    """Aliases referenced as bare ``Var`` nodes (which the engine resolves
    against the binding table's id columns — unlike ``Prop`` references,
    which also resolve for edge aliases through the ``alias#t``/``alias#p``
    identity columns).  The ``PlanVerifier`` scopes the two differently."""
    if isinstance(e, Var):
        return {e.alias}
    if isinstance(e, Prop):
        return set()
    if isinstance(e, Cmp):
        return expr_var_aliases(e.lhs) | expr_var_aliases(e.rhs)
    if isinstance(e, InSet):
        return expr_var_aliases(e.item)
    if isinstance(e, BoolOp):
        out: set[str] = set()
        for a in e.args:
            out |= expr_var_aliases(a)
        return out
    if isinstance(e, Agg):
        return expr_var_aliases(e.arg) if e.arg is not None else set()
    return set()


def expr_props(e) -> set[Prop]:
    if isinstance(e, Prop):
        return {e}
    if isinstance(e, Cmp):
        return expr_props(e.lhs) | expr_props(e.rhs)
    if isinstance(e, InSet):
        return expr_props(e.item)
    if isinstance(e, BoolOp):
        out: set[Prop] = set()
        for a in e.args:
            out |= expr_props(a)
        return out
    if isinstance(e, Agg):
        return expr_props(e.arg) if e.arg is not None else set()
    return set()


def expr_params(e) -> set[str]:
    """Names of late-bound parameters referenced by an expression."""
    if isinstance(e, Param):
        return {e.name}
    if isinstance(e, Cmp):
        return expr_params(e.lhs) | expr_params(e.rhs)
    if isinstance(e, InSet):
        out = expr_params(e.item)
        if isinstance(e.values, Param):
            out |= {e.values.name}
        return out
    if isinstance(e, BoolOp):
        out: set[str] = set()
        for a in e.args:
            out |= expr_params(a)
        return out
    if isinstance(e, Agg):
        return expr_params(e.arg) if e.arg is not None else set()
    return set()


def subst_aliases(e, mapping: dict):
    """Rewrite an expression with pattern aliases renamed via ``mapping``
    (expressions are immutable; returns a new node where needed)."""
    if isinstance(e, Prop):
        return Prop(mapping.get(e.alias, e.alias), e.name)
    if isinstance(e, Var):
        return Var(mapping.get(e.alias, e.alias))
    if isinstance(e, Cmp):
        return Cmp(e.op, subst_aliases(e.lhs, mapping),
                   subst_aliases(e.rhs, mapping))
    if isinstance(e, InSet):
        return InSet(subst_aliases(e.item, mapping), e.values)
    if isinstance(e, BoolOp):
        return BoolOp(e.op, tuple(subst_aliases(a, mapping) for a in e.args))
    if isinstance(e, Agg):
        return Agg(e.fn, subst_aliases(e.arg, mapping)
                   if e.arg is not None else None)
    return e


def conjuncts(e) -> list:
    """Split a predicate into AND-conjuncts."""
    if isinstance(e, BoolOp) and e.op == "AND":
        out = []
        for a in e.args:
            out.extend(conjuncts(a))
        return out
    return [e]


def make_and(parts: Sequence) -> Any:
    parts = [p for p in parts if p is not None]
    if not parts:
        return None
    if len(parts) == 1:
        return parts[0]
    return BoolOp("AND", tuple(parts))


# --------------------------------------------------------------------------
# Logical operators
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Op:
    """Base logical operator."""


@dataclasses.dataclass
class Scan(Op):
    alias: str
    types: frozenset
    elem: str = "V"                     # V | E
    predicate: Any = None               # fused filter (FilterIntoMatchRule)
    columns: Optional[frozenset] = None  # needed props (FieldTrimRule)


@dataclasses.dataclass
class ExpandEdge(Op):
    tag: str
    alias: str
    labels: frozenset
    direction: str                      # OUT | IN | BOTH
    predicate: Any = None
    columns: Optional[frozenset] = None


@dataclasses.dataclass
class GetVertex(Op):
    tag: str
    alias: str
    types: frozenset
    endpoint: str                       # SOURCE | TARGET | OTHER
    predicate: Any = None
    columns: Optional[frozenset] = None


@dataclasses.dataclass
class ExpandFused(Op):
    """EXPAND_EDGE+GET_VERTEX fused by ExpandGetVFusionRule."""
    tag: str
    edge_alias: str
    alias: str
    labels: frozenset
    types: frozenset
    direction: str
    predicate: Any = None
    columns: Optional[frozenset] = None


@dataclasses.dataclass
class ExpandPath(Op):
    tag: str
    alias: str
    labels: frozenset
    direction: str
    hops: int


@dataclasses.dataclass
class MatchPattern(Op):
    """Composite operator MATCH_START..MATCH_END; semantically the Pattern."""
    pattern: Pattern


@dataclasses.dataclass
class Select(Op):
    predicate: Any


@dataclasses.dataclass
class Project(Op):
    items: list                          # [(expr, out_name)]
    distinct: bool = False


@dataclasses.dataclass
class GroupBy(Op):
    keys: list                           # [(expr, out_name)]
    aggs: list                           # [(Agg, out_name)]


@dataclasses.dataclass
class OrderBy(Op):
    items: list                          # [(expr, ascending)]
    limit: Optional[int] = None


@dataclasses.dataclass
class Limit(Op):
    n: int


@dataclasses.dataclass
class LogicalPlan:
    """Chain of operators (MATCH first, relational after)."""
    ops: list
    params: dict = dataclasses.field(default_factory=dict)
    hints: dict = dataclasses.field(default_factory=dict)

    def pattern(self) -> Optional[Pattern]:
        for op in self.ops:
            if isinstance(op, MatchPattern):
                return op.pattern
        return None

    def replace_pattern(self, pattern: Pattern) -> None:
        for i, op in enumerate(self.ops):
            if isinstance(op, MatchPattern):
                self.ops[i] = MatchPattern(pattern)
                return
        raise ValueError("plan has no MATCH_PATTERN")

    def copy(self) -> "LogicalPlan":
        """Deep-enough copy: pattern and op list are fresh (expressions are
        immutable and shared)."""
        ops = []
        for op in self.ops:
            if isinstance(op, MatchPattern):
                ops.append(MatchPattern(op.pattern.copy()))
            elif isinstance(op, Project):
                ops.append(Project(list(op.items), op.distinct))
            elif isinstance(op, GroupBy):
                ops.append(GroupBy(list(op.keys), list(op.aggs)))
            elif isinstance(op, OrderBy):
                ops.append(OrderBy(list(op.items), op.limit))
            else:
                ops.append(dataclasses.replace(op))
        return LogicalPlan(ops, dict(self.params), dict(self.hints))

    def referenced_params(self) -> set[str]:
        """Every ``$param`` referenced by an expression anywhere in the plan
        (relational ops and predicates pushed into the pattern)."""
        out: set[str] = set()
        for op in self.ops:
            if isinstance(op, MatchPattern):
                for v in op.pattern.vertices.values():
                    for p in v.predicates:
                        out |= expr_params(p)
                for e in op.pattern.edges:
                    for p in e.predicates:
                        out |= expr_params(p)
            elif isinstance(op, Select):
                out |= expr_params(op.predicate)
            elif isinstance(op, Project):
                for e, _ in op.items:
                    out |= expr_params(e)
            elif isinstance(op, GroupBy):
                for e, _ in op.keys:
                    out |= expr_params(e)
                for a, _ in op.aggs:
                    out |= expr_params(a)
            elif isinstance(op, OrderBy):
                for e, _ in op.items:
                    out |= expr_params(e)
        return out

    def declared_params(self) -> set[str]:
        """Referenced params plus everything bound at build time (including
        structural params consumed during parsing, e.g. hop counts)."""
        return self.referenced_params() | set(self.params)

    def snapshot(self) -> list[str]:
        """Deterministic one-line-per-op serialization (the canonical form
        split into lines) — what optimizer passes diff before/after to
        record plan changes in their ``PassTrace``."""
        return canonical_form(self).split("\n")

    def __repr__(self):
        return "LogicalPlan[\n  " + "\n  ".join(map(repr, self.ops)) + "\n]"


# --------------------------------------------------------------------------
# Canonical form (normalized GIR)
# --------------------------------------------------------------------------


def _ser_expr(e, ren) -> str:
    """Deterministic serialization of an expression with aliases renamed
    through ``ren`` and commutative boolean args sorted."""
    if isinstance(e, Prop):
        return f"{ren(e.alias)}.{e.name}"
    if isinstance(e, Var):
        return ren(e.alias)
    if isinstance(e, Lit):
        return repr(e.value)
    if isinstance(e, Param):
        return f"${e.name}"
    if isinstance(e, Cmp):
        return f"({_ser_expr(e.lhs, ren)} {e.op} {_ser_expr(e.rhs, ren)})"
    if isinstance(e, InSet):
        vals = (f"${e.values.name}" if isinstance(e.values, Param)
                else repr(list(e.values)))
        return f"({_ser_expr(e.item, ren)} IN {vals})"
    if isinstance(e, BoolOp):
        args = [_ser_expr(a, ren) for a in e.args]
        if e.op in ("AND", "OR"):
            args = sorted(args)
        return "(" + e.op + " " + " ".join(args) + ")"
    if isinstance(e, Agg):
        arg = _ser_expr(e.arg, ren) if e.arg is not None else "*"
        return f"{e.fn}({arg})"
    return repr(e)


def canonical_form(plan: LogicalPlan) -> str:
    """A normalized, hashable serialization of the GIR.

    Used (a) as the prepared-plan cache key — two queries that lower to the
    same GIR share one optimized plan — and (b) for frontend-parity checks:
    the Cypher parser and the Gremlin builder must produce identical
    canonical forms for equivalent queries.  Anonymous aliases (the
    ``_``-prefixed ones minted by ``GraphIrBuilder``) are relabeled by order
    of first structural appearance so frontends' fresh-name counters do not
    leak into the form.  Late-bound ``Param`` nodes serialize by name, so the
    form is independent of any binding values."""
    pattern = plan.pattern()
    order: list[str] = []

    def note(a: str):
        if a.startswith("_") and a not in order:
            order.append(a)

    if pattern is not None:
        for e in pattern.edges:
            note(e.src)
            note(e.dst)
            note(e.alias)
        for a in sorted(pattern.vertices):
            note(a)
    rename = {a: f"_c{i}" for i, a in enumerate(order)}

    def ren(a: str) -> str:
        return rename.get(a, a)

    parts: list[str] = []
    for op in plan.ops:
        if isinstance(op, MatchPattern):
            p = op.pattern
            vs = sorted(
                f"({ren(a)}:{'|'.join(sorted(v.types))}"
                + ("" if not v.predicates else
                   "{" + ",".join(sorted(_ser_expr(q, ren)
                                         for q in v.predicates)) + "}")
                + ")"
                for a, v in p.vertices.items())
            es = sorted(
                f"{ren(e.src)}-[{ren(e.alias)}:"
                f"{'|'.join(sorted(map(repr, e.triples)))}"
                f":{e.direction}*{e.hops}"
                + ("" if not e.predicates else
                   "{" + ",".join(sorted(_ser_expr(q, ren)
                                         for q in e.predicates)) + "}")
                + f"]-{ren(e.dst)}"
                for e in p.edges)
            parts.append("MATCH[" + ";".join(vs) + "|" + ";".join(es) + "]")
        elif isinstance(op, Select):
            cs = sorted(_ser_expr(c, ren) for c in conjuncts(op.predicate))
            parts.append("SELECT[" + " AND ".join(cs) + "]")
        elif isinstance(op, Project):
            items = ",".join(f"{_ser_expr(e, ren)} AS {n}"
                             for e, n in op.items)
            parts.append(f"PROJECT[{items}|distinct={op.distinct}]")
        elif isinstance(op, GroupBy):
            ks = ",".join(f"{_ser_expr(e, ren)} AS {n}" for e, n in op.keys)
            ags = ",".join(f"{_ser_expr(a, ren)} AS {n}" for a, n in op.aggs)
            parts.append(f"GROUP[{ks}|{ags}]")
        elif isinstance(op, OrderBy):
            items = ",".join(f"{_ser_expr(e, ren)}:{'A' if asc else 'D'}"
                             for e, asc in op.items)
            parts.append(f"ORDER[{items}|limit={op.limit}]")
        elif isinstance(op, Limit):
            parts.append(f"LIMIT[{op.n}]")
        else:
            parts.append(repr(op))
    return "\n".join(parts)
