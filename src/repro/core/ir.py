"""Unified intermediate representation (paper §4.1).

The IR couples a data model (Vertex/Edge/Path + primitives) with graph
operators (SCAN, EXPAND_EDGE, GET_VERTEX, EXPAND_PATH, MATCH_PATTERN) and
relational operators (SELECT, PROJECT, GROUP, ORDER, LIMIT, JOIN).  A logical
plan is a DAG of these operators; for PatRelQuery it is a chain
``MATCH_PATTERN -> relational ops`` (joins appear inside the pattern part as
physical operators chosen by the CBO).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

from repro.core.pattern import Pattern

# --------------------------------------------------------------------------
# Expressions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Prop:
    """alias.prop — a property of a bound vertex/edge."""
    alias: str
    name: str

    def __repr__(self):
        return f"{self.alias}.{self.name}"


@dataclasses.dataclass(frozen=True)
class Var:
    """A bound pattern alias itself (vertex/edge id column)."""
    alias: str

    def __repr__(self):
        return self.alias


@dataclasses.dataclass(frozen=True)
class Lit:
    value: Any

    def __repr__(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True)
class Cmp:
    op: str          # = <> < > <= >=
    lhs: Any
    rhs: Any

    def __repr__(self):
        return f"({self.lhs} {self.op} {self.rhs})"


@dataclasses.dataclass(frozen=True)
class InSet:
    item: Any
    values: tuple

    def __repr__(self):
        return f"({self.item} IN {list(self.values)!r})"


@dataclasses.dataclass(frozen=True)
class BoolOp:
    op: str          # AND OR NOT
    args: tuple

    def __repr__(self):
        if self.op == "NOT":
            return f"(NOT {self.args[0]})"
        return "(" + f" {self.op} ".join(map(repr, self.args)) + ")"


@dataclasses.dataclass(frozen=True)
class Agg:
    fn: str          # COUNT SUM MIN MAX AVG
    arg: Any = None  # None == COUNT(*)

    def __repr__(self):
        return f"{self.fn}({self.arg if self.arg is not None else '*'})"


def expr_aliases(e) -> set[str]:
    """Pattern aliases referenced by an expression."""
    if isinstance(e, Prop):
        return {e.alias}
    if isinstance(e, Var):
        return {e.alias}
    if isinstance(e, Cmp):
        return expr_aliases(e.lhs) | expr_aliases(e.rhs)
    if isinstance(e, InSet):
        return expr_aliases(e.item)
    if isinstance(e, BoolOp):
        out: set[str] = set()
        for a in e.args:
            out |= expr_aliases(a)
        return out
    if isinstance(e, Agg):
        return expr_aliases(e.arg) if e.arg is not None else set()
    return set()


def expr_props(e) -> set[Prop]:
    if isinstance(e, Prop):
        return {e}
    if isinstance(e, Cmp):
        return expr_props(e.lhs) | expr_props(e.rhs)
    if isinstance(e, InSet):
        return expr_props(e.item)
    if isinstance(e, BoolOp):
        out: set[Prop] = set()
        for a in e.args:
            out |= expr_props(a)
        return out
    if isinstance(e, Agg):
        return expr_props(e.arg) if e.arg is not None else set()
    return set()


def conjuncts(e) -> list:
    """Split a predicate into AND-conjuncts."""
    if isinstance(e, BoolOp) and e.op == "AND":
        out = []
        for a in e.args:
            out.extend(conjuncts(a))
        return out
    return [e]


def make_and(parts: Sequence) -> Any:
    parts = [p for p in parts if p is not None]
    if not parts:
        return None
    if len(parts) == 1:
        return parts[0]
    return BoolOp("AND", tuple(parts))


# --------------------------------------------------------------------------
# Logical operators
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Op:
    """Base logical operator."""


@dataclasses.dataclass
class Scan(Op):
    alias: str
    types: frozenset
    elem: str = "V"                     # V | E
    predicate: Any = None               # fused filter (FilterIntoMatchRule)
    columns: Optional[frozenset] = None  # needed props (FieldTrimRule)


@dataclasses.dataclass
class ExpandEdge(Op):
    tag: str
    alias: str
    labels: frozenset
    direction: str                      # OUT | IN | BOTH
    predicate: Any = None
    columns: Optional[frozenset] = None


@dataclasses.dataclass
class GetVertex(Op):
    tag: str
    alias: str
    types: frozenset
    endpoint: str                       # SOURCE | TARGET | OTHER
    predicate: Any = None
    columns: Optional[frozenset] = None


@dataclasses.dataclass
class ExpandFused(Op):
    """EXPAND_EDGE+GET_VERTEX fused by ExpandGetVFusionRule."""
    tag: str
    edge_alias: str
    alias: str
    labels: frozenset
    types: frozenset
    direction: str
    predicate: Any = None
    columns: Optional[frozenset] = None


@dataclasses.dataclass
class ExpandPath(Op):
    tag: str
    alias: str
    labels: frozenset
    direction: str
    hops: int


@dataclasses.dataclass
class MatchPattern(Op):
    """Composite operator MATCH_START..MATCH_END; semantically the Pattern."""
    pattern: Pattern


@dataclasses.dataclass
class Select(Op):
    predicate: Any


@dataclasses.dataclass
class Project(Op):
    items: list                          # [(expr, out_name)]
    distinct: bool = False


@dataclasses.dataclass
class GroupBy(Op):
    keys: list                           # [(expr, out_name)]
    aggs: list                           # [(Agg, out_name)]


@dataclasses.dataclass
class OrderBy(Op):
    items: list                          # [(expr, ascending)]
    limit: Optional[int] = None


@dataclasses.dataclass
class Limit(Op):
    n: int


@dataclasses.dataclass
class LogicalPlan:
    """Chain of operators (MATCH first, relational after)."""
    ops: list
    params: dict = dataclasses.field(default_factory=dict)
    hints: dict = dataclasses.field(default_factory=dict)

    def pattern(self) -> Optional[Pattern]:
        for op in self.ops:
            if isinstance(op, MatchPattern):
                return op.pattern
        return None

    def replace_pattern(self, pattern: Pattern) -> None:
        for i, op in enumerate(self.ops):
            if isinstance(op, MatchPattern):
                self.ops[i] = MatchPattern(pattern)
                return
        raise ValueError("plan has no MATCH_PATTERN")

    def __repr__(self):
        return "LogicalPlan[\n  " + "\n  ".join(map(repr, self.ops)) + "\n]"
