"""GOpt facade — the paper's full pipeline (Fig. 3):

    Cypher/Gremlin -> unified GIR (GraphIrBuilder) -> type inference -> RBO
    -> CBO -> physical plan -> binding-table engine execution.

``GOpt`` owns the metadata providers (schema + GLogue) and the
**OptimizerPipeline** (DESIGN.md §6): ``optimize`` is a thin driver over a
registered sequence of passes (``pre -> type_inference -> rbo fixpoint ->
cbo -> post_physical``); users register custom passes/rules via
``gopt.pipeline.register(...)`` and backends contribute post-CBO physical
rewrites through ``PhysicalSpec.physical_rules``.  The historical
``type_inference=/rbo=/cbo=`` switches are kept as deprecated shims that
gate the corresponding pipeline phases, so benchmarks can still ablate each
technique exactly like the paper's experiments.

On top of the one-shot pipeline sits the **prepared-query lifecycle**
(DESIGN.md §3): ``prepare(query)`` runs the compile pipeline once and caches
the optimized physical plan keyed by (normalized GIR canonical form,
backend, optimizer flags, pipeline signature, build-time bindings);
``PreparedQuery.execute(params)`` skips straight to the engine with fresh
parameter bindings, and ``execute_many`` runs a whole binding batch through
one vectorized engine pass over the cached plan (``Engine.run_batch``).
``run()`` is sugar over an LRU of prepared queries.
``refresh_stats()`` bumps the statistics epoch, invalidating every cached
plan (stale ``PreparedQuery`` handles keep executing their old plan).
``compile_counters`` meters the pipeline stages so tests (and benchmarks)
can assert what re-ran.

The EXPLAIN/PROFILE surface: ``gopt.explain(query, analyze=...)`` (and
``PreparedQuery.explain``) returns a structured ``ExplainReport`` — per-pass
traces with plan diffs, per-operator estimated cost/cardinality, and actual
row counts when ``analyze=True``.  ``run()`` routes queries prefixed with
``EXPLAIN`` / ``PROFILE`` to the same surface.
"""
from __future__ import annotations

import collections
import dataclasses
import re
import time

from repro.core import ir
from repro.core.cardinality import CardEstimator, Statistics
from repro.core.cbo import low_order_plan, random_plan
from repro.core.glogue import GLogue
from repro.core.parser import parse_cypher
from repro.core.pattern import Pattern
from repro.core.physical import PlanNode
from repro.core.physical_spec import PhysicalSpec, get_spec
from repro.core.pipeline import (VERIFY_MODES, ExplainReport,
                                 OptimizerPipeline, PassContext,
                                 PipelineTrace, build_explain_report,
                                 default_pipeline)
from repro.graphdb.engine import Engine, ExecStats, Table
from repro.graphdb.storage import GraphStore

_OPT_KEYS = ("type_inference", "rbo", "cbo", "use_glogue", "use_selectivity",
             "physical_rules", "verify")

_EXPLAIN_RE = re.compile(r"^\s*(EXPLAIN\b|PROFILE\b(\s+SYNC\b)?)",
                         re.IGNORECASE)


def _explain_prefix(query: str):
    """Parse an EXPLAIN / PROFILE / PROFILE SYNC prefix; returns
    (mode | None, stripped query) — mode is 'explain', 'profile', or
    'profile_sync'."""
    m = _EXPLAIN_RE.match(query)
    if not m:
        return None, query
    head = m.group(1).split()[0].lower()
    if head == "profile" and m.group(2):
        head = "profile_sync"
    return head, query[m.end():]


def _collect_value_peeks(plan: ir.LogicalPlan,
                         params: dict | None) -> tuple:
    """Record what a freshly-compiled plan *assumed* about each
    ``prop IN $param`` vertex predicate: the peeked set size when the param
    was bound at prepare time, else None (the estimator's agnostic 0.5)."""
    pattern = plan.pattern()
    if pattern is None:
        return ()
    out = []
    for v in pattern.vertices.values():
        for p in v.predicates:
            if (isinstance(p, ir.InSet) and isinstance(p.values, ir.Param)
                    and isinstance(p.item, ir.Prop)):
                bound = (params or {}).get(p.values.name)
                out.append((p.values.name, p.item.name, frozenset(v.types),
                            None if bound is None else len(bound)))
    return tuple(out)


def _freeze(v):
    """Hashable mirror of a binding value (lists/dicts/sets -> tuples)."""
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, (set, frozenset)):
        return tuple(sorted(_freeze(x) for x in v))
    return v


@dataclasses.dataclass
class OptimizedQuery:
    logical: ir.LogicalPlan
    physical: PlanNode
    compile_s: float
    invalid: bool = False
    trace: PipelineTrace | None = None


@dataclasses.dataclass
class PreparedQuery:
    """A compiled, reusable query: optimized physical plan + metadata.

    ``execute(params)`` binds late-bound ``ir.Param`` nodes and goes straight
    to the engine — no parse / type inference / RBO / CBO re-runs.  Obtained
    from ``GOpt.prepare``; instances are shared via the plan cache, so treat
    them as immutable."""
    gopt: "GOpt"
    opt: OptimizedQuery
    spec: PhysicalSpec
    cache_key: tuple
    source: str | None = None           # query text, when prepared from text
    executions: int = 0
    # build-time value-peek assumptions, one per ``prop IN $param`` vertex
    # predicate: (param name, prop, vertex types, peeked |S| or None) —
    # checked at bind time by GOpt._maybe_replan (re-optimize on skew)
    peeks: tuple = ()
    opts: dict = dataclasses.field(default_factory=dict)

    @property
    def logical(self) -> ir.LogicalPlan:
        return self.opt.logical

    @property
    def physical(self) -> PlanNode:
        return self.opt.physical

    @property
    def compile_s(self) -> float:
        return self.opt.compile_s

    def declared_params(self) -> frozenset[str]:
        return frozenset(self.opt.logical.declared_params())

    def execute(self, params: dict | None = None,
                **exec_kw) -> tuple[Table, ExecStats]:
        # binding-skew guard: a binding whose IN-set cardinality diverges
        # >10x from the build-time peek invalidates this cache entry and
        # re-plans once against the actual binding
        pq = self.gopt._maybe_replan(self, params)
        if pq is not self:
            return pq.execute(params, **exec_kw)
        self.executions += 1
        return self.gopt.execute(self.opt, params=params,
                                 backend=exec_kw.pop("backend", self.spec),
                                 **exec_kw)

    def execute_many(self, bindings: list[dict | None], batch: bool = True,
                     **exec_kw) -> list[tuple[Table, ExecStats]]:
        """Batch execution: one cached plan, many parameter bindings, one
        engine pass.

        The engine runs the pattern phase **once**: parameter-dependent
        predicates execute as the union of the per-binding filters (the
        bindings stack into a single scan filter), then each binding
        re-applies its exact predicate and runs its own relational tail —
        row-identical to looping ``execute`` per binding, with the
        expansion/join work shared.  ``batch=False`` (or a blow-up of the
        union intermediate under ``max_rows``) falls back to the loop."""
        if batch and len(bindings) > 1 and not self.opt.invalid:
            kw = dict(exec_kw)
            backend = kw.pop("backend", self.spec)
            try:
                out = self.gopt.execute_batch(self.opt, bindings,
                                              backend=backend, **kw)
                self.executions += len(bindings)
                return out
            except RuntimeError as exc:
                # only the union intermediate blowing the row cap falls
                # back to the loop; other engine/XLA failures surface
                if "intermediate blow-up" not in str(exc):
                    raise
                out = [self.execute(b, **exec_kw) for b in bindings]
                for _, st in out:
                    st.fallback("batch_blowup")
                return out
        return [self.execute(b, **exec_kw) for b in bindings]

    def explain(self, params: dict | None = None, analyze: bool = False,
                sync: bool = False, **exec_kw) -> ExplainReport:
        """Structured EXPLAIN of the cached plan (``analyze=True`` also
        executes with ``params`` and reports actual row counts;
        ``sync=True`` — the ``PROFILE SYNC`` mode — blocks on the device
        after every operator so ``OpReport.actual_time_s`` reports true
        device times instead of dispatch times on async backends).  A
        type-inference-INVALID query reports its provably-empty result
        instead of crashing on the missing physical plan."""
        tbl = stats = None
        if analyze and not self.opt.invalid:
            declared = self.declared_params()
            bound = {k: v for k, v in (params or {}).items() if k in declared}
            tbl, stats = self.execute(bound, sync_per_op=sync, **exec_kw)
        delta_fn = getattr(self.gopt.store, "delta_info", None)
        return build_explain_report(self.opt, spec=self.spec,
                                    source=self.source, analyze=analyze,
                                    table=tbl, stats=stats, sync=sync,
                                    delta=delta_fn() if callable(delta_fn)
                                    else None)


class GOpt:
    def __init__(self, store: GraphStore, glogue_k: int = 3,
                 build_glogue: bool = True,
                 backend: str | PhysicalSpec = "numpy",
                 plan_cache_size: int = 256,
                 pipeline: OptimizerPipeline | None = None,
                 devices: int | None = None,
                 verify: str | None = None):
        self.store = store
        self.schema = store.schema
        self.stats = Statistics(store)
        self.glogue = GLogue(store, k=glogue_k) if build_glogue else None
        if devices is not None:
            # shard-count pin: only meaningful on the sharded backend,
            # where each count is its own registered spec ("sharded[8]")
            # so plan caches and per-store operator caches never mix
            # shard layouts
            if backend != "sharded":
                raise ValueError("devices= requires backend='sharded'")
            from repro.graphdb.sharded_backend import sharded_spec
            self.spec = sharded_spec(devices)
        else:
            self.spec = get_spec(backend)
        # the registered pass sequence driving optimize(); per-instance, so
        # registering a custom pass/rule never leaks across GOpt instances
        self.pipeline = pipeline or default_pipeline()
        if verify is not None:
            # instance-wide default verify mode (per-call override: the
            # verify= option of optimize()/prepare())
            if verify not in VERIFY_MODES:
                raise ValueError(f"unknown verify mode {verify!r}; "
                                 f"modes are {VERIFY_MODES}")
            self.pipeline.verify = verify
        # pipeline-stage meters: how many times each compile stage ran
        self.compile_counters: collections.Counter = collections.Counter()
        self.plan_cache_size = plan_cache_size
        self._plan_cache: collections.OrderedDict = collections.OrderedDict()
        self._text_cache: collections.OrderedDict = collections.OrderedDict()
        self._stats_epoch = 0
        self._replans = 0            # binding-skew re-optimizations
        self.replan_ratio = 10.0     # skew threshold (>10x selectivity drift)

    # ----------------------------------------------------------------- parse
    def parse(self, query: str, params: dict | None = None) -> ir.LogicalPlan:
        self.compile_counters["parse"] += 1
        return parse_cypher(query, self.schema, params)

    # -------------------------------------------------------------- optimize
    def optimize(self, query: str | ir.LogicalPlan,
                 params: dict | None = None,
                 type_inference: bool = True,
                 rbo: bool = True,
                 cbo: bool = True,
                 use_glogue: bool = True,
                 use_selectivity: bool = True,
                 physical_rules: bool = True,
                 verify: str | None = None,
                 backend: str | PhysicalSpec | None = None,
                 pipeline: OptimizerPipeline | None = None) -> OptimizedQuery:
        """Thin driver over the registered ``OptimizerPipeline``.

        The boolean stage switches are deprecated shims kept for the
        paper's ablation benchmarks: they gate the corresponding pipeline
        phases (``type_inference`` the inference pass, ``rbo`` the whole
        rbo fixpoint group, ``cbo`` Algorithm 2 vs the left-deep fallback,
        ``physical_rules`` the backend's post-CBO rewrites).  Prefer
        configuring ``gopt.pipeline`` directly."""
        t0 = time.perf_counter()
        if isinstance(query, str):
            plan = self.parse(query, params)
        else:
            plan = query
            if params:
                for k, v in params.items():
                    plan.params.setdefault(k, v)
        spec = self.spec if backend is None else get_spec(backend)
        ctx = PassContext(
            plan=plan, schema=self.schema, stats=self.stats,
            glogue=self.glogue, spec=spec,
            flags={"type_inference": type_inference, "rbo": rbo, "cbo": cbo,
                   "use_glogue": use_glogue,
                   "use_selectivity": use_selectivity,
                   "physical_rules": physical_rules,
                   "verify": verify},
            counters=self.compile_counters)
        trace = (pipeline or self.pipeline).run(ctx)
        return OptimizedQuery(plan, ctx.physical, time.perf_counter() - t0,
                              invalid=ctx.invalid, trace=trace)

    # --------------------------------------------------------------- prepare
    def prepare(self, query: str | ir.LogicalPlan,
                params: dict | None = None,
                backend: str | PhysicalSpec | None = None,
                **opts) -> PreparedQuery:
        """Compile once, execute many: returns a ``PreparedQuery`` whose
        optimized physical plan is cached keyed by (normalized GIR canonical
        form, backend, optimizer flags, pipeline signature, statistics
        epoch, build-time bindings).

        ``params`` here binds *structural* parameters (hop counts) and
        provides defaults / selectivity hints for value parameters; fresh
        bindings go to ``PreparedQuery.execute(params)``.  Two different
        query strings (or a Cypher string and a Gremlin traversal) that
        lower to the same GIR share one cached plan."""
        unknown = set(opts) - set(_OPT_KEYS)
        if unknown:
            raise TypeError(f"unknown optimizer option(s): {sorted(unknown)}")
        spec = self.spec if backend is None else get_spec(backend)
        text = query if isinstance(query, str) else None
        # the pipeline shape is part of every cache key: registering a pass
        # must never serve plans compiled by a differently-shaped pipeline
        opts_key = (tuple(sorted(opts.items())), self.pipeline.signature())

        # fast path: seen this exact query text before -> skip the parse
        text_key = None
        if text is not None:
            text_key = (text, spec.name, opts_key)
            for consumed, pq in self._text_cache.get(text_key, ()):
                if all((params or {}).get(k) == v for k, v in consumed):
                    self._text_cache.move_to_end(text_key)
                    return pq

        if text is not None:
            plan = self.parse(text, params)
        else:
            plan = query.copy()      # never mutate the caller's plan
            if params:
                for k, v in params.items():
                    plan.params.setdefault(k, v)

        # value parameters stay out of the key: structural params are
        # already reflected in the pattern shape (hence in the canonical
        # form), and value bindings only steer cost estimation ("peeking"),
        # so plans are interchangeable across bindings
        key = (ir.canonical_form(plan), spec.name, opts_key)
        pq = self._plan_cache.get(key)
        if pq is None:
            pq = PreparedQuery(self, self.optimize(plan, backend=spec, **opts),
                               spec, key, source=text, opts=dict(opts))
            pq.peeks = _collect_value_peeks(pq.logical, params)
            # prepared queries are strict: drop value-param bindings so they
            # cannot silently act as execution defaults for a later caller —
            # every referenced param must be bound at execute().  Structural
            # bindings (baked into the pattern) are kept for bookkeeping.
            referenced = pq.logical.referenced_params()
            for k in [k for k in pq.logical.params if k in referenced]:
                del pq.logical.params[k]
            self._plan_cache[key] = pq
            if len(self._plan_cache) > self.plan_cache_size:
                self._plan_cache.popitem(last=False)
        else:
            self._plan_cache.move_to_end(key)

        if text_key is not None:
            # structural bindings consumed at parse time are baked into the
            # pattern; remember them so a later call with different values
            # misses this entry and re-prepares
            consumed = tuple(sorted(
                (k, _freeze(v)) for k, v in
                (pq.logical.hints.get("structural_params") or {}).items()))
            entries = self._text_cache.setdefault(text_key, [])
            entries.append((consumed, pq))
            del entries[:-16]     # cap variants per text (structural params)
            self._text_cache.move_to_end(text_key)
            if len(self._text_cache) > self.plan_cache_size:
                self._text_cache.popitem(last=False)
        return pq

    # ---------------------------------------------------- cache invalidation
    def plan_cache_info(self) -> dict:
        return {"plans": len(self._plan_cache),
                "texts": len(self._text_cache),
                "max": self.plan_cache_size,
                "epoch": self._stats_epoch,
                "replans": self._replans}

    def _maybe_replan(self, pq: PreparedQuery,
                      params: dict | None) -> PreparedQuery:
        """Re-optimize-on-binding-skew: if a binding's IN-set selectivity
        diverges more than ``replan_ratio`` from the cached plan's build-time
        value-peek assumption, invalidate the entry and re-plan once against
        the actual binding.  Returns the (possibly fresh) prepared query."""
        if not pq.peeks or not params or pq.opt.invalid:
            return pq
        skewed = False
        for name, prop, types, assumed in pq.peeks:
            vals = params.get(name)
            if vals is None:
                continue
            try:
                actual = float(len(vals))
            except TypeError:
                continue
            ndv = max(max((self.stats.ndv(t, prop) for t in types),
                          default=1.0), 1.0)
            act_sel = min(max(actual, 1.0) / ndv, 1.0)
            asm_sel = (0.5 if assumed is None
                       else min(max(float(assumed), 1.0) / ndv, 1.0))
            if max(act_sel / asm_sel, asm_sel / act_sel) > self.replan_ratio:
                skewed = True
                break
        if not skewed:
            return pq
        self._plan_cache.pop(pq.cache_key, None)
        for tk in list(self._text_cache):
            kept = [e for e in self._text_cache[tk] if e[1] is not pq]
            if kept:
                self._text_cache[tk][:] = kept
            else:
                del self._text_cache[tk]
        self._replans += 1
        source = pq.source if pq.source is not None else pq.logical
        return self.prepare(source, params=dict(params), backend=pq.spec,
                            **pq.opts)

    def touch_plan(self, key: tuple) -> bool:
        """Mark a cached plan recently-used (LRU touch) without resolving
        it — the QueryServer's hotness loop keeps hot plans' cache entries
        alive even while their requests ride stored ``PreparedQuery``
        handles that never call ``prepare``."""
        if key in self._plan_cache:
            self._plan_cache.move_to_end(key)
            return True
        return False

    def bump_stats_epoch(self) -> int:
        """Invalidate every cached prepared plan (call after the store or
        its statistics change).  Outstanding ``PreparedQuery`` handles keep
        executing their — possibly stale-cost — plan; the next
        ``prepare``/``run`` recompiles against fresh statistics."""
        self._stats_epoch += 1
        self._plan_cache.clear()
        self._text_cache.clear()
        return self._stats_epoch

    def refresh_stats(self, rebuild_glogue: bool = False) -> int:
        """Re-derive ``Statistics`` (NDV caches, counts) from the store and
        bump the epoch; optionally rebuild the GLogue catalogue too."""
        self.stats = Statistics(self.store)
        if rebuild_glogue and self.glogue is not None:
            self.glogue = GLogue(self.store, k=self.glogue.k)
        return self.bump_stats_epoch()

    # --------------------------------------------------------------- explain
    def explain(self, query: str | ir.LogicalPlan,
                params: dict | None = None, analyze: bool = False,
                sync: bool = False,
                backend: str | PhysicalSpec | None = None,
                **kw) -> ExplainReport:
        """Structured EXPLAIN/PROFILE: compile (through the prepared-plan
        cache) and report per-pass traces plus per-operator estimates;
        ``analyze=True`` (or a ``PROFILE`` prefix) also executes with
        ``params`` and reports estimated-vs-actual cardinalities.
        ``sync=True`` (or ``PROFILE SYNC``) syncs the device per operator
        for true per-operator device times."""
        opts = {k: v for k, v in kw.items() if k in _OPT_KEYS}
        exec_kw = {k: v for k, v in kw.items() if k not in _OPT_KEYS}
        if isinstance(query, str):
            mode, query = _explain_prefix(query)
            if mode is not None and mode.startswith("profile"):
                analyze = True
                if mode == "profile_sync":
                    sync = True
        pq = self.prepare(query, params, backend=backend, **opts)
        return pq.explain(params=params, analyze=analyze, sync=sync,
                          **exec_kw)

    # --------------------------------------------------------------- execute
    def execute(self, opt: OptimizedQuery,
                fuse_expand: bool | None = None,
                trim_fields: bool = True,
                max_rows: int = 100_000_000,
                backend: str | PhysicalSpec | None = None,
                params: dict | None = None,
                chain_dispatch: bool = True,
                sync_per_op: bool = False,
                snapshot=None,
                deadline_s: float | None = None
                ) -> tuple[Table, ExecStats]:
        if opt.invalid:
            return Table.empty(), ExecStats()
        fuse = (opt.logical.hints.get("fuse_expand", True)
                if fuse_expand is None else fuse_expand)
        spec = self.spec if backend is None else get_spec(backend)
        eng = Engine(self.store, fuse_expand=fuse, trim_fields=trim_fields,
                     max_rows=max_rows, backend=spec,
                     chain_dispatch=chain_dispatch, sync_per_op=sync_per_op,
                     snapshot=snapshot, deadline_s=deadline_s)
        return eng.run(opt.logical, opt.physical, params=params)

    def execute_batch(self, opt: OptimizedQuery, bindings: list[dict | None],
                      fuse_expand: bool | None = None,
                      trim_fields: bool = True,
                      max_rows: int = 100_000_000,
                      backend: str | PhysicalSpec | None = None,
                      chain_dispatch: bool = True,
                      snapshot=None,
                      deadline_s: float | None = None
                      ) -> list[tuple[Table, ExecStats]]:
        """Vectorized sibling of ``execute``: one engine pattern pass for a
        whole binding batch (``Engine.run_batch``), with the relational
        tails stacked on a binding-id segment column."""
        if opt.invalid:
            return [(Table.empty(), ExecStats()) for _ in bindings]
        fuse = (opt.logical.hints.get("fuse_expand", True)
                if fuse_expand is None else fuse_expand)
        spec = self.spec if backend is None else get_spec(backend)
        eng = Engine(self.store, fuse_expand=fuse, trim_fields=trim_fields,
                     max_rows=max_rows, backend=spec,
                     chain_dispatch=chain_dispatch, snapshot=snapshot,
                     deadline_s=deadline_s)
        return eng.run_batch(opt.logical, opt.physical, bindings)

    def run(self, query: str | ir.LogicalPlan, params: dict | None = None,
            **kw) -> tuple[Table, ExecStats] | ExplainReport:
        """Prepared-query sugar: resolve the query through the prepared-plan
        LRU, then execute with ``params``.  Repeated runs of one query text
        with fresh bindings compile exactly once.

        A query prefixed with ``EXPLAIN`` (compile only) or ``PROFILE``
        (compile + execute) returns an ``ExplainReport`` instead of a
        result table; a plan parsed from such a query (the parser records
        the prefix as ``hints['explain']``) routes the same way."""
        mode = None
        if isinstance(query, str):
            mode, query = _explain_prefix(query)
        elif isinstance(query, ir.LogicalPlan):
            mode = query.hints.get("explain")
        if mode is not None:
            return self.explain(query, params,
                                analyze=mode.startswith("profile"),
                                sync=mode == "profile_sync",
                                backend=kw.pop("backend", None), **kw)
        opts = {k: v for k, v in kw.items() if k in _OPT_KEYS}
        exec_kw = {k: v for k, v in kw.items()
                   if k not in _OPT_KEYS and k != "backend"}
        pq = self.prepare(query, params, backend=kw.get("backend"), **opts)
        # run() is shared-dict friendly: forward only the bindings this
        # query declares (whichever call populated the cache), so unused
        # keys never trip the strict extra-binding check in execute().  A
        # typo'd name still surfaces — as the real parameter left unbound.
        declared = pq.declared_params()
        bound = {k: v for k, v in (params or {}).items() if k in declared}
        return pq.execute(bound, **exec_kw)

    # -------------------------------------------------------------- mutations
    def _mutable(self):
        if not callable(getattr(self.store, "insert_edge", None)):
            raise TypeError(
                "store is frozen; wrap it in repro.graphdb.delta."
                "MutableGraphStore to accept mutations")
        return self.store

    def insert_vertex(self, vtype: str, props: dict | None = None) -> int:
        return self._mutable().insert_vertex(vtype, props)

    def delete_vertex(self, gid: int) -> bool:
        return self._mutable().delete_vertex(gid)

    def insert_edge(self, triple, src: int, dst: int,
                    props: dict | None = None) -> bool:
        return self._mutable().insert_edge(triple, src, dst, props)

    def delete_edge(self, triple, src: int, dst: int) -> bool:
        return self._mutable().delete_edge(triple, src, dst)

    def snapshot(self):
        """Pin the store's current MVCC snapshot (None on a frozen store)."""
        snap_fn = getattr(self.store, "snapshot", None)
        return snap_fn() if callable(snap_fn) else None

    def delta_info(self) -> dict | None:
        fn = getattr(self.store, "delta_info", None)
        return fn() if callable(fn) else None

    def compact(self, rebuild_glogue: bool = True) -> dict:
        """Merge the delta overlay into a rebuilt base CSR, re-derive
        statistics and bump the stats epoch (cached plans re-cost on next
        prepare).  Returns the compaction event dict."""
        event = self._mutable().compact()
        self.refresh_stats(rebuild_glogue=rebuild_glogue)
        return event

    # ----------------------------------------------------------------- serve
    def serve(self, **kw) -> "object":
        """Continuous-batching query service over this GOpt (DESIGN.md §9):
        a ``repro.graphdb.serve.QueryServer`` that coalesces submitted
        ``(query, params)`` requests into ``execute_many`` waves per cached
        plan.  Keyword arguments forward to the ``QueryServer``
        constructor (``max_pending``, ``max_wave``, ``hot_plans``, ...)."""
        from repro.graphdb.serve import QueryServer
        return QueryServer(self, **kw)

    # ------------------------------------------------------------- baselines
    def estimator(self, use_glogue: bool = True,
                  use_selectivity: bool = True,
                  params: dict | None = None) -> CardEstimator:
        return CardEstimator(self.stats, self.glogue if use_glogue else None,
                             use_selectivity=use_selectivity, params=params)

    def neo4j_style_plan(self, pattern: Pattern) -> PlanNode:
        """Low-order foil: no type inference assumed done by caller, no
        GLogue, no WCOJ, independence assumption."""
        return low_order_plan(pattern, self.estimator(use_glogue=False),
                              spec=self.spec)

    def random_plans(self, pattern: Pattern, n: int, seed: int = 0):
        import random as _r
        rng = _r.Random(seed)
        return [random_plan(pattern, rng) for _ in range(n)]
