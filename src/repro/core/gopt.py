"""GOpt facade — the paper's full pipeline (Fig. 3):

    Cypher/Gremlin -> unified IR -> type inference/validation -> RBO -> CBO
    -> physical plan -> binding-table engine execution.

``GOpt`` owns the metadata providers (schema + GLogue) and exposes
``optimize`` / ``execute`` with per-stage switches so benchmarks can ablate
each technique exactly like the paper's experiments.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import ir
from repro.core.cardinality import CardEstimator, Statistics
from repro.core.cbo import GraphOptimizer, low_order_plan, random_plan
from repro.core.glogue import GLogue
from repro.core.parser import parse_cypher
from repro.core.pattern import Pattern, expand_path_edges
from repro.core.physical import PlanNode, default_left_deep_plan
from repro.core.physical_spec import PhysicalSpec, get_spec
from repro.core.rules import DEFAULT_RULES, apply_rules
from repro.core.type_inference import INVALID, infer_types
from repro.graphdb.engine import Engine, ExecStats, Table
from repro.graphdb.storage import GraphStore


@dataclasses.dataclass
class OptimizedQuery:
    logical: ir.LogicalPlan
    physical: PlanNode
    compile_s: float
    invalid: bool = False


class GOpt:
    def __init__(self, store: GraphStore, glogue_k: int = 3,
                 build_glogue: bool = True,
                 backend: str | PhysicalSpec = "numpy"):
        self.store = store
        self.schema = store.schema
        self.stats = Statistics(store)
        self.glogue = GLogue(store, k=glogue_k) if build_glogue else None
        self.spec = get_spec(backend)

    # ----------------------------------------------------------------- parse
    def parse(self, query: str, params: dict | None = None) -> ir.LogicalPlan:
        return parse_cypher(query, self.schema, params)

    # -------------------------------------------------------------- optimize
    def optimize(self, query: str | ir.LogicalPlan,
                 params: dict | None = None,
                 type_inference: bool = True,
                 rbo: bool = True,
                 cbo: bool = True,
                 use_glogue: bool = True,
                 use_selectivity: bool = True,
                 backend: str | PhysicalSpec | None = None) -> OptimizedQuery:
        t0 = time.perf_counter()
        plan = (self.parse(query, params) if isinstance(query, str)
                else query)
        pattern = expand_path_edges(plan.pattern(), self.schema)
        plan.replace_pattern(pattern)
        if type_inference:
            inferred = infer_types(pattern, self.schema)
            if inferred == INVALID:
                return OptimizedQuery(plan, None, time.perf_counter() - t0,
                                      invalid=True)
            pattern = inferred
            plan.replace_pattern(pattern)
        if rbo:
            plan = apply_rules(plan, DEFAULT_RULES)
            pattern = plan.pattern()
        est = CardEstimator(self.stats,
                            self.glogue if use_glogue else None,
                            use_selectivity=use_selectivity)
        spec = self.spec if backend is None else get_spec(backend)
        if cbo and pattern.is_connected():
            physical = GraphOptimizer(est, spec=spec).optimize(pattern)
        else:
            # disconnected patterns: cross-product plan (Algorithm 2
            # searches connected sub-patterns only)
            physical = default_left_deep_plan(pattern)
        return OptimizedQuery(plan, physical, time.perf_counter() - t0)

    # --------------------------------------------------------------- execute
    def execute(self, opt: OptimizedQuery,
                fuse_expand: bool | None = None,
                trim_fields: bool = True,
                max_rows: int = 100_000_000,
                backend: str | PhysicalSpec | None = None
                ) -> tuple[Table, ExecStats]:
        if opt.invalid:
            return Table.empty(), ExecStats()
        fuse = (opt.logical.hints.get("fuse_expand", True)
                if fuse_expand is None else fuse_expand)
        spec = self.spec if backend is None else get_spec(backend)
        eng = Engine(self.store, fuse_expand=fuse, trim_fields=trim_fields,
                     max_rows=max_rows, backend=spec)
        return eng.run(opt.logical, opt.physical)

    def run(self, query: str, params: dict | None = None, **kw):
        backend = kw.get("backend")
        return self.execute(self.optimize(query, params, **{
            k: v for k, v in kw.items()
            if k in ("type_inference", "rbo", "cbo", "use_glogue",
                     "use_selectivity", "backend")}), backend=backend)

    # ------------------------------------------------------------- baselines
    def estimator(self, use_glogue: bool = True,
                  use_selectivity: bool = True) -> CardEstimator:
        return CardEstimator(self.stats, self.glogue if use_glogue else None,
                             use_selectivity=use_selectivity)

    def neo4j_style_plan(self, pattern: Pattern) -> PlanNode:
        """Low-order foil: no type inference assumed done by caller, no
        GLogue, no WCOJ, independence assumption."""
        return low_order_plan(pattern, self.estimator(use_glogue=False),
                              spec=self.spec)

    def random_plans(self, pattern: Pattern, n: int, seed: int = 0):
        import random as _r
        rng = _r.Random(seed)
        return [random_plan(pattern, rng) for _ in range(n)]
