"""GOpt facade — the paper's full pipeline (Fig. 3):

    Cypher/Gremlin -> unified GIR (GraphIrBuilder) -> type inference -> RBO
    -> CBO -> physical plan -> binding-table engine execution.

``GOpt`` owns the metadata providers (schema + GLogue) and exposes
``optimize`` / ``execute`` with per-stage switches so benchmarks can ablate
each technique exactly like the paper's experiments.

On top of the one-shot pipeline sits the **prepared-query lifecycle**
(DESIGN.md §3): ``prepare(query)`` runs the compile pipeline once and caches
the optimized physical plan keyed by (normalized GIR canonical form,
backend, optimizer flags, build-time bindings); ``PreparedQuery.execute(
params)`` skips straight to the engine with fresh parameter bindings.
``run()`` is sugar over an LRU of prepared queries — repeated calls with new
bindings for the same query text pay compile cost once.  ``compile_counters``
meters the pipeline stages so tests (and benchmarks) can assert what re-ran.
"""
from __future__ import annotations

import collections
import dataclasses
import time

from repro.core import ir
from repro.core.cardinality import CardEstimator, Statistics
from repro.core.cbo import GraphOptimizer, low_order_plan, random_plan
from repro.core.glogue import GLogue
from repro.core.parser import parse_cypher
from repro.core.pattern import Pattern, expand_path_edges
from repro.core.physical import PlanNode, default_left_deep_plan
from repro.core.physical_spec import PhysicalSpec, get_spec
from repro.core.rules import DEFAULT_RULES, apply_rules
from repro.core.type_inference import INVALID, infer_types
from repro.graphdb.engine import Engine, ExecStats, Table
from repro.graphdb.storage import GraphStore

_OPT_KEYS = ("type_inference", "rbo", "cbo", "use_glogue", "use_selectivity")


def _freeze(v):
    """Hashable mirror of a binding value (lists/dicts/sets -> tuples)."""
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, (set, frozenset)):
        return tuple(sorted(_freeze(x) for x in v))
    return v


@dataclasses.dataclass
class OptimizedQuery:
    logical: ir.LogicalPlan
    physical: PlanNode
    compile_s: float
    invalid: bool = False


@dataclasses.dataclass
class PreparedQuery:
    """A compiled, reusable query: optimized physical plan + metadata.

    ``execute(params)`` binds late-bound ``ir.Param`` nodes and goes straight
    to the engine — no parse / type inference / RBO / CBO re-runs.  Obtained
    from ``GOpt.prepare``; instances are shared via the plan cache, so treat
    them as immutable."""
    gopt: "GOpt"
    opt: OptimizedQuery
    spec: PhysicalSpec
    cache_key: tuple
    source: str | None = None           # query text, when prepared from text
    executions: int = 0

    @property
    def logical(self) -> ir.LogicalPlan:
        return self.opt.logical

    @property
    def physical(self) -> PlanNode:
        return self.opt.physical

    @property
    def compile_s(self) -> float:
        return self.opt.compile_s

    def declared_params(self) -> frozenset[str]:
        return frozenset(self.opt.logical.declared_params())

    def execute(self, params: dict | None = None,
                **exec_kw) -> tuple[Table, ExecStats]:
        self.executions += 1
        return self.gopt.execute(self.opt, params=params,
                                 backend=exec_kw.pop("backend", self.spec),
                                 **exec_kw)

    def explain(self) -> str:
        if self.opt.physical is None:
            return "<invalid query>"
        return self.opt.physical.pretty()


class GOpt:
    def __init__(self, store: GraphStore, glogue_k: int = 3,
                 build_glogue: bool = True,
                 backend: str | PhysicalSpec = "numpy",
                 plan_cache_size: int = 256):
        self.store = store
        self.schema = store.schema
        self.stats = Statistics(store)
        self.glogue = GLogue(store, k=glogue_k) if build_glogue else None
        self.spec = get_spec(backend)
        # pipeline-stage meters: how many times each compile stage ran
        self.compile_counters: collections.Counter = collections.Counter()
        self.plan_cache_size = plan_cache_size
        self._plan_cache: collections.OrderedDict = collections.OrderedDict()
        self._text_cache: collections.OrderedDict = collections.OrderedDict()

    # ----------------------------------------------------------------- parse
    def parse(self, query: str, params: dict | None = None) -> ir.LogicalPlan:
        self.compile_counters["parse"] += 1
        return parse_cypher(query, self.schema, params)

    # -------------------------------------------------------------- optimize
    def optimize(self, query: str | ir.LogicalPlan,
                 params: dict | None = None,
                 type_inference: bool = True,
                 rbo: bool = True,
                 cbo: bool = True,
                 use_glogue: bool = True,
                 use_selectivity: bool = True,
                 backend: str | PhysicalSpec | None = None) -> OptimizedQuery:
        t0 = time.perf_counter()
        if isinstance(query, str):
            plan = self.parse(query, params)
        else:
            plan = query
            if params:
                for k, v in params.items():
                    plan.params.setdefault(k, v)
        pattern = expand_path_edges(plan.pattern(), self.schema)
        plan.replace_pattern(pattern)
        if type_inference:
            self.compile_counters["type_inference"] += 1
            inferred = infer_types(pattern, self.schema)
            if inferred == INVALID:
                return OptimizedQuery(plan, None, time.perf_counter() - t0,
                                      invalid=True)
            pattern = inferred
            plan.replace_pattern(pattern)
        if rbo:
            self.compile_counters["rbo"] += 1
            plan = apply_rules(plan, DEFAULT_RULES)
            pattern = plan.pattern()
        est = CardEstimator(self.stats,
                            self.glogue if use_glogue else None,
                            use_selectivity=use_selectivity,
                            params=plan.params)
        spec = self.spec if backend is None else get_spec(backend)
        if cbo and pattern.is_connected():
            self.compile_counters["cbo"] += 1
            physical = GraphOptimizer(est, spec=spec).optimize(pattern)
        else:
            # disconnected patterns: cross-product plan (Algorithm 2
            # searches connected sub-patterns only)
            physical = default_left_deep_plan(pattern)
        return OptimizedQuery(plan, physical, time.perf_counter() - t0)

    # --------------------------------------------------------------- prepare
    def prepare(self, query: str | ir.LogicalPlan,
                params: dict | None = None,
                backend: str | PhysicalSpec | None = None,
                **opts) -> PreparedQuery:
        """Compile once, execute many: returns a ``PreparedQuery`` whose
        optimized physical plan is cached keyed by (normalized GIR canonical
        form, backend, optimizer flags, build-time bindings).

        ``params`` here binds *structural* parameters (hop counts) and
        provides defaults / selectivity hints for value parameters; fresh
        bindings go to ``PreparedQuery.execute(params)``.  Two different
        query strings (or a Cypher string and a Gremlin traversal) that
        lower to the same GIR share one cached plan."""
        unknown = set(opts) - set(_OPT_KEYS)
        if unknown:
            raise TypeError(f"unknown optimizer option(s): {sorted(unknown)}")
        spec = self.spec if backend is None else get_spec(backend)
        text = query if isinstance(query, str) else None
        opts_key = tuple(sorted(opts.items()))

        # fast path: seen this exact query text before -> skip the parse
        text_key = None
        if text is not None:
            text_key = (text, spec.name, opts_key)
            for consumed, pq in self._text_cache.get(text_key, ()):
                if all((params or {}).get(k) == v for k, v in consumed):
                    self._text_cache.move_to_end(text_key)
                    return pq

        if text is not None:
            plan = self.parse(text, params)
        else:
            plan = query.copy()      # never mutate the caller's plan
            if params:
                for k, v in params.items():
                    plan.params.setdefault(k, v)

        # value parameters stay out of the key: structural params are
        # already reflected in the pattern shape (hence in the canonical
        # form), and value bindings only steer cost estimation ("peeking"),
        # so plans are interchangeable across bindings
        key = (ir.canonical_form(plan), spec.name, opts_key)
        pq = self._plan_cache.get(key)
        if pq is None:
            pq = PreparedQuery(self, self.optimize(plan, backend=spec, **opts),
                               spec, key, source=text)
            # prepared queries are strict: drop value-param bindings so they
            # cannot silently act as execution defaults for a later caller —
            # every referenced param must be bound at execute().  Structural
            # bindings (baked into the pattern) are kept for bookkeeping.
            referenced = pq.logical.referenced_params()
            for k in [k for k in pq.logical.params if k in referenced]:
                del pq.logical.params[k]
            self._plan_cache[key] = pq
            if len(self._plan_cache) > self.plan_cache_size:
                self._plan_cache.popitem(last=False)
        else:
            self._plan_cache.move_to_end(key)

        if text_key is not None:
            # structural bindings consumed at parse time are baked into the
            # pattern; remember them so a later call with different values
            # misses this entry and re-prepares
            consumed = tuple(sorted(
                (k, _freeze(v)) for k, v in
                (pq.logical.hints.get("structural_params") or {}).items()))
            entries = self._text_cache.setdefault(text_key, [])
            entries.append((consumed, pq))
            del entries[:-16]     # cap variants per text (structural params)
            self._text_cache.move_to_end(text_key)
            if len(self._text_cache) > self.plan_cache_size:
                self._text_cache.popitem(last=False)
        return pq

    def plan_cache_info(self) -> dict:
        return {"plans": len(self._plan_cache),
                "texts": len(self._text_cache),
                "max": self.plan_cache_size}

    # --------------------------------------------------------------- execute
    def execute(self, opt: OptimizedQuery,
                fuse_expand: bool | None = None,
                trim_fields: bool = True,
                max_rows: int = 100_000_000,
                backend: str | PhysicalSpec | None = None,
                params: dict | None = None
                ) -> tuple[Table, ExecStats]:
        if opt.invalid:
            return Table.empty(), ExecStats()
        fuse = (opt.logical.hints.get("fuse_expand", True)
                if fuse_expand is None else fuse_expand)
        spec = self.spec if backend is None else get_spec(backend)
        eng = Engine(self.store, fuse_expand=fuse, trim_fields=trim_fields,
                     max_rows=max_rows, backend=spec)
        return eng.run(opt.logical, opt.physical, params=params)

    def run(self, query: str | ir.LogicalPlan, params: dict | None = None,
            **kw) -> tuple[Table, ExecStats]:
        """Prepared-query sugar: resolve the query through the prepared-plan
        LRU, then execute with ``params``.  Repeated runs of one query text
        with fresh bindings compile exactly once."""
        opts = {k: v for k, v in kw.items() if k in _OPT_KEYS}
        exec_kw = {k: v for k, v in kw.items()
                   if k not in _OPT_KEYS and k != "backend"}
        pq = self.prepare(query, params, backend=kw.get("backend"), **opts)
        # run() is shared-dict friendly: forward only the bindings this
        # query declares (whichever call populated the cache), so unused
        # keys never trip the strict extra-binding check in execute().  A
        # typo'd name still surfaces — as the real parameter left unbound.
        declared = pq.declared_params()
        bound = {k: v for k, v in (params or {}).items() if k in declared}
        return pq.execute(bound, **exec_kw)

    # ------------------------------------------------------------- baselines
    def estimator(self, use_glogue: bool = True,
                  use_selectivity: bool = True,
                  params: dict | None = None) -> CardEstimator:
        return CardEstimator(self.stats, self.glogue if use_glogue else None,
                             use_selectivity=use_selectivity, params=params)

    def neo4j_style_plan(self, pattern: Pattern) -> PlanNode:
        """Low-order foil: no type inference assumed done by caller, no
        GLogue, no WCOJ, independence assumption."""
        return low_order_plan(pattern, self.estimator(use_glogue=False),
                              spec=self.spec)

    def random_plans(self, pattern: Pattern, n: int, seed: int = 0):
        import random as _r
        rng = _r.Random(seed)
        return [random_plan(pattern, rng) for _ in range(n)]
