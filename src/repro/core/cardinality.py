"""Cardinality estimation for arbitrary (Union/All-typed) patterns
(paper §5.3.3, Eqs. 4-6) plus predicate selectivities.

The estimator prefers exact GLogue frequencies for BasicPatterns within the
catalogue size; everything else is derived iteratively by vertex-expansion
ratios (Eq. 5/6) and pattern joins (Eq. 4), exactly the paper's scheme for
UnionPatterns. Predicate selectivities (1/NDV for equality, |set|/NDV for IN)
scale vertex frequencies — this is what makes the money-mule case study's
join-vertex position data-dependent.
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.glogue import GLogue
from repro.core.pattern import BOTH, IN, OUT, Pattern, PatternEdge
from repro.graphdb.storage import GraphStore


class Statistics:
    """Low-order statistics + NDV cache over a store."""

    def __init__(self, store: GraphStore):
        self.store = store
        self._ndv: dict = {}

    def vertex_type_freq(self, vtype: str) -> float:
        return float(self.store.v_count[vtype])

    def triple_freq(self, triple) -> float:
        f = float(self.store.out_csr[triple].nnz)
        # delta-overlay occupancy (MutableGraphStore): net inserted-minus-
        # tombstoned edges count toward the live frequency, so cached plans
        # re-cost against real occupancy after a stats-epoch bump
        counts = getattr(self.store, "delta_edge_counts", None)
        if counts is not None:
            f += float(counts().get(triple, 0))
        return max(f, 0.0)

    def ndv(self, vtype: str, prop: str) -> float:
        key = (vtype, prop)
        if key not in self._ndv:
            col = self.store.v_props.get(vtype, {}).get(prop)
            self._ndv[key] = (float(len(np.unique(col)))
                              if col is not None and col.size else 1.0)
        return self._ndv[key]


def predicate_selectivity(stats: Statistics, types: frozenset[str],
                          preds: list, params: dict | None = None) -> float:
    """Independence-combined selectivity of a vertex's fused predicates.

    ``params`` supplies build-time bindings for late-bound ``ir.Param``
    nodes (prepared-query "value peeking"): an ``IN $S`` predicate is
    |S|/NDV when the set is bound, else an agnostic 0.5.  Equality against a
    ``Param`` is 1/NDV either way — value-independent, so the cached plan
    stays valid across bindings."""
    sel = 1.0
    for p in preds:
        if isinstance(p, ir.Cmp) and isinstance(p.lhs, ir.Prop):
            ndv = max(max((stats.ndv(t, p.lhs.name) for t in types),
                          default=1.0), 1.0)
            sel *= (1.0 / ndv) if p.op == "=" else (1.0 / 3.0)
        elif isinstance(p, ir.InSet) and isinstance(p.item, ir.Prop):
            values = p.values
            if isinstance(values, ir.Param):
                values = (params or {}).get(values.name)
            if values is None:
                sel *= 0.5
                continue
            ndv = max(max((stats.ndv(t, p.item.name) for t in types),
                          default=1.0), 1.0)
            sel *= min(len(values) / ndv, 1.0)
        else:
            sel *= 0.5
    return sel


class CardEstimator:
    def __init__(self, stats: Statistics, glogue: GLogue | None = None,
                 use_selectivity: bool = True, params: dict | None = None):
        self.stats = stats
        self.glogue = glogue
        self.use_selectivity = use_selectivity
        self.params = dict(params or {})   # build-time bindings for Params
        self._memo: dict = {}

    # ----------------------------------------------------------- primitives
    def vertex_freq(self, pattern: Pattern, alias: str,
                    with_preds: bool = True) -> float:
        v = pattern.vertices[alias]
        f = sum(self.stats.vertex_type_freq(t) for t in v.types)
        if with_preds and self.use_selectivity and v.predicates:
            f *= predicate_selectivity(self.stats, v.types, v.predicates,
                                       self.params)
        return max(f, 1e-9)

    def edge_freq(self, edge: PatternEdge) -> float:
        f = sum(self.stats.triple_freq(t) for t in edge.triples)
        if edge.direction == BOTH:
            f *= 2.0
        return max(f, 1e-9)

    def selectivity(self, pattern: Pattern, alias: str) -> float:
        v = pattern.vertices[alias]
        if not (self.use_selectivity and v.predicates):
            return 1.0
        return predicate_selectivity(self.stats, v.types, v.predicates,
                                     self.params)

    def expand_sigma(self, pattern: Pattern, edge: PatternEdge,
                     new_alias: str | None) -> float:
        """Eq. 5. ``new_alias``: the vertex being introduced by this edge, or
        None when the edge closes a cycle (both endpoints already bound)."""
        f_e = self.edge_freq(edge)
        if new_alias is not None:
            anchor = edge.other(new_alias)
            f_anchor = self.vertex_freq(pattern, anchor, with_preds=False)
            sigma = f_e / f_anchor
            sigma *= self.selectivity(pattern, new_alias)
        else:
            f_src = self.vertex_freq(pattern, edge.src, with_preds=False)
            f_dst = self.vertex_freq(pattern, edge.dst, with_preds=False)
            sigma = f_e / (f_src * f_dst)
        return sigma

    # --------------------------------------------------------- pattern freq
    def pattern_freq(self, pattern: Pattern,
                     aliases: frozenset[str] | None = None) -> float:
        """Frequency estimate of (the induced sub-pattern on) ``aliases``.
        Exact via GLogue for catalogued BasicPatterns without predicates;
        otherwise iterative Eq. 6 from a canonical greedy order (paper:
        'Eq. 4 and Eq. 6 can be applied iteratively ... until the source
        pattern is a BasicPattern that can be queried from GLogue directly,
        or a single vertex or single edge')."""
        sub = pattern if aliases is None else pattern.induced(aliases)
        key = sub.canonical_key()
        if key in self._memo:
            return self._memo[key]
        f = self._freq_impl(sub)
        self._memo[key] = f
        return f

    def _glogue_lookup(self, sub: Pattern) -> float | None:
        if self.glogue is None or sub.n_vertices() > self.glogue.k:
            return None
        if any(e.hops > 1 for e in sub.edges):
            return None
        stripped = sub.copy()
        for v in stripped.vertices.values():
            v.predicates = []
        f = self.glogue.get_freq(stripped)
        if f is None:
            return None
        # fold predicate selectivities back in
        for a, v in sub.vertices.items():
            f *= self.selectivity(sub, a)
        return max(f, 1e-9)

    def _freq_impl(self, sub: Pattern) -> float:
        n = sub.n_vertices()
        if n == 1:
            return self.vertex_freq(sub, next(iter(sub.vertices)))
        if n == 2 and sub.n_edges() == 1:
            e = sub.edges[0]
            f = self.edge_freq(e)
            f *= self.selectivity(sub, e.src) * self.selectivity(sub, e.dst)
            return max(f, 1e-9)
        exact = self._glogue_lookup(sub)
        if exact is not None:
            return exact
        # iterative Eq. 6: peel the last vertex in a canonical greedy order
        # (min-degree-last keeps the source connected).
        order = sorted(sub.vertices)
        # choose a leaf-ish vertex to peel whose removal keeps connectivity
        for cand in sorted(order, key=lambda a: sub.degree(a)):
            rest = frozenset(set(order) - {cand})
            if not rest:
                continue
            rsub = sub.induced(rest)
            if rsub.is_connected():
                edges = [e for e in sub.edges if cand in (e.src, e.dst)]
                f_src = self.pattern_freq(sub, rest)
                sigma = 1.0
                first = True
                for e in edges:
                    sigma *= self.expand_sigma(sub, e,
                                               cand if first else None)
                    first = False
                f = f_src * sigma
                # cache union estimates into GLogue (Alg. 2 lines 15-17)
                if self.glogue is not None and sub.n_vertices() <= self.glogue.k:
                    stripped = sub.copy()
                    for v in stripped.vertices.values():
                        v.predicates = []
                    if self.glogue.get_freq(stripped) is None:
                        self.glogue.put_freq(stripped, f)
                return max(f, 1e-9)
        raise ValueError("disconnected sub-pattern in cardinality estimation")

    def join_freq(self, pattern: Pattern, s1: frozenset[str],
                  s2: frozenset[str]) -> float:
        """Eq. 4 for a pattern join of induced subgraphs s1, s2."""
        inter = s1 & s2
        f1 = self.pattern_freq(pattern, s1)
        f2 = self.pattern_freq(pattern, s2)
        fi = self.pattern_freq(pattern, inter) if inter else 1.0
        return max(f1 * f2 / max(fi, 1e-9), 1e-9)
