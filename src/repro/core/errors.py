"""Shared exception types for the GOpt front-end (DESIGN.md §3).

``BuildError`` is raised eagerly by ``GraphIrBuilder`` at the offending
construction step (unknown label / alias / property), with the step position
in the message — queries fail at build time, not deep inside the optimizer
or the engine.  ``ParamError`` covers every parameter-lifecycle failure:
structural parameters missing at build time, unbound parameters at
execution, and bindings that name no declared parameter.
"""
from __future__ import annotations


class GOptError(Exception):
    """Base class for all GOpt front-end errors."""


class BuildError(GOptError, ValueError):
    """Build-time validation failure in ``GraphIrBuilder``."""

    def __init__(self, message: str, step: tuple[int, str] | None = None):
        self.step = step
        if step is not None:
            message = f"step {step[0]} ({step[1]}): {message}"
        super().__init__(message)


class PipelineError(GOptError, ValueError):
    """Invalid ``OptimizerPipeline`` registration: unknown phase, duplicate
    pass name, or a ``before=``/``after=`` anchor that does not exist (or
    lives in a different phase)."""


class PlanInvariantError(GOptError, AssertionError):
    """A plan failed the ``PlanVerifier``'s static invariant checks
    (``core/verify.py``).

    Under ``verify="always"`` the optimizer pipeline verifies after every
    registered pass, so ``pass_name``/``phase`` identify the rewrite that
    produced the invalid plan and ``trace`` is its ``PassTrace`` — including
    the before/after plan diff — at the moment of the violation.
    ``pass_name`` is ``None`` when the violation was only detected on the
    pipeline's final output (``verify="cached"``)."""

    def __init__(self, violations, pass_name: str | None = None,
                 phase: str | None = None, trace=None):
        self.violations = tuple(violations)
        self.pass_name = pass_name
        self.phase = phase
        self.trace = trace
        where = (f"after pass {pass_name!r} ({phase})"
                 if pass_name else "in pipeline output")
        lines = [f"invalid plan {where}: "
                 f"{len(self.violations)} invariant violation(s)"]
        lines.extend(f"  - {v}" for v in self.violations)
        diff = list(getattr(trace, "diff", []) or [])
        if diff:
            lines.append("  plan diff:")
            lines.extend(f"    {d}" for d in diff)
        super().__init__("\n".join(lines))


class ExecError(GOptError, RuntimeError):
    """Structured execution failure (DESIGN.md §13).

    Classifies a failed operator/plan execution for the serving layer's
    containment machinery: ``kind`` is ``"transient"`` (retry may succeed:
    capacity overflow, injected flake, lost device), ``"permanent"`` (the
    binding or plan is poison — retrying the same work cannot help), or
    ``"deadline"`` (the request's budget expired mid-execution).  The
    remaining fields carry the failure's context: the operator boundary it
    surfaced at, the engine phase tag active at the time (``pattern`` /
    ``tail`` / ``deliver``), the plan cache key, how many attempts were
    made, and the underlying exception (also chained via ``__cause__``).
    """

    kind: str = "permanent"

    def __init__(self, message: str, *, kind: str | None = None,
                 operator: str | None = None, phase: str | None = None,
                 plan=None, attempts: int = 1,
                 cause: BaseException | None = None):
        if kind is not None:
            self.kind = kind
        self.operator = operator
        self.phase = phase
        self.plan = plan
        self.attempts = attempts
        self.cause = cause
        ctx = [f"kind={self.kind}"]
        if operator:
            ctx.append(f"op={operator}")
        if phase:
            ctx.append(f"phase={phase}")
        if plan is not None:
            # plan cache keys embed the whole normalized query; keep the
            # message scannable, the full key stays on ``self.plan``
            p = str(plan).replace("\n", " ")
            ctx.append(f"plan={p[:60]}…" if len(p) > 60 else f"plan={p}")
        if attempts != 1:
            ctx.append(f"attempts={attempts}")
        super().__init__(f"{message} [{', '.join(ctx)}]")
        if cause is not None:
            self.__cause__ = cause

    @property
    def transient(self) -> bool:
        return self.kind == "transient"


class TransientExecError(ExecError):
    """An execution failure that a bounded retry may clear (capacity
    overflow, flaky kernel dispatch, lost device)."""

    kind = "transient"


class PermanentExecError(ExecError):
    """An execution failure retrying cannot fix: the binding or plan is
    poison for this backend."""

    kind = "permanent"


class DeadlineExceeded(ExecError):
    """A request's ``deadline_s`` expired mid-execution; the engine aborted
    the tail cooperatively (checked between operators, DESIGN.md §13.4)."""

    kind = "deadline"


#: exception types that are transient by nature even when raised outside
#: the structured taxonomy (OS-level hiccups, queue overflow).
_TRANSIENT_TYPES = (TimeoutError, ConnectionError, InterruptedError)


def classify_error(exc: BaseException) -> str:
    """Map an arbitrary execution exception to an ``ExecError`` kind.

    Structured errors carry their own ``kind``; OS-flavored hiccups are
    transient; everything else defaults to permanent so unknown failures
    never trigger a retry storm.
    """
    if isinstance(exc, ExecError):
        return exc.kind
    if isinstance(exc, _TRANSIENT_TYPES):
        return "transient"
    return "permanent"


class ParamError(GOptError, LookupError):
    """A query-parameter problem, naming the offending parameters and the
    declared set."""

    def __init__(self, message: str, missing=(), extra=(), declared=()):
        self.missing = tuple(sorted(missing))
        self.extra = tuple(sorted(extra))
        self.declared = tuple(sorted(declared))
        detail = []
        if self.missing:
            detail.append("missing: " + ", ".join(f"${p}" for p in self.missing))
        if self.extra:
            detail.append("unexpected: " + ", ".join(f"${p}" for p in self.extra))
        detail.append("declared: {" + ", ".join(f"${p}" for p in self.declared)
                      + "}")
        super().__init__(f"{message} ({'; '.join(detail)})")
