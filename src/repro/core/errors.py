"""Shared exception types for the GOpt front-end (DESIGN.md §3).

``BuildError`` is raised eagerly by ``GraphIrBuilder`` at the offending
construction step (unknown label / alias / property), with the step position
in the message — queries fail at build time, not deep inside the optimizer
or the engine.  ``ParamError`` covers every parameter-lifecycle failure:
structural parameters missing at build time, unbound parameters at
execution, and bindings that name no declared parameter.
"""
from __future__ import annotations


class GOptError(Exception):
    """Base class for all GOpt front-end errors."""


class BuildError(GOptError, ValueError):
    """Build-time validation failure in ``GraphIrBuilder``."""

    def __init__(self, message: str, step: tuple[int, str] | None = None):
        self.step = step
        if step is not None:
            message = f"step {step[0]} ({step[1]}): {message}"
        super().__init__(message)


class PipelineError(GOptError, ValueError):
    """Invalid ``OptimizerPipeline`` registration: unknown phase, duplicate
    pass name, or a ``before=``/``after=`` anchor that does not exist (or
    lives in a different phase)."""


class PlanInvariantError(GOptError, AssertionError):
    """A plan failed the ``PlanVerifier``'s static invariant checks
    (``core/verify.py``).

    Under ``verify="always"`` the optimizer pipeline verifies after every
    registered pass, so ``pass_name``/``phase`` identify the rewrite that
    produced the invalid plan and ``trace`` is its ``PassTrace`` — including
    the before/after plan diff — at the moment of the violation.
    ``pass_name`` is ``None`` when the violation was only detected on the
    pipeline's final output (``verify="cached"``)."""

    def __init__(self, violations, pass_name: str | None = None,
                 phase: str | None = None, trace=None):
        self.violations = tuple(violations)
        self.pass_name = pass_name
        self.phase = phase
        self.trace = trace
        where = (f"after pass {pass_name!r} ({phase})"
                 if pass_name else "in pipeline output")
        lines = [f"invalid plan {where}: "
                 f"{len(self.violations)} invariant violation(s)"]
        lines.extend(f"  - {v}" for v in self.violations)
        diff = list(getattr(trace, "diff", []) or [])
        if diff:
            lines.append("  plan diff:")
            lines.extend(f"    {d}" for d in diff)
        super().__init__("\n".join(lines))


class ParamError(GOptError, LookupError):
    """A query-parameter problem, naming the offending parameters and the
    declared set."""

    def __init__(self, message: str, missing=(), extra=(), declared=()):
        self.missing = tuple(sorted(missing))
        self.extra = tuple(sorted(extra))
        self.declared = tuple(sorted(declared))
        detail = []
        if self.missing:
            detail.append("missing: " + ", ".join(f"${p}" for p in self.missing))
        if self.extra:
            detail.append("unexpected: " + ", ".join(f"${p}" for p in self.extra))
        detail.append("declared: {" + ", ".join(f"${p}" for p in self.declared)
                      + "}")
        super().__init__(f"{message} ({'; '.join(detail)})")
