"""GraphIrBuilder — the unified front-end API (paper §4.1–4.2, DESIGN.md §3).

The *only* sanctioned way to construct GIR ``LogicalPlan`` objects.  Every
query language lowers through this builder: the Cypher parser
(``core/parser.py``) is tokenizer + grammar driving builder steps, and the
Gremlin traversal (``core/gremlin.py``) is a thin sugar layer over it.  The
builder owns the three concerns the frontends used to duplicate:

- **alias management** — fresh anonymous aliases, renames (``alias_as``),
  cycle-closing merges, and MATCH-reuse constraint intersection;
- **schema-constraint lookup** — vertex-type / edge-label constraints are
  resolved here, once;
- **eager per-step validation** — unknown labels, aliases and properties
  raise ``BuildError`` at the offending step with its position in the
  message, instead of surfacing deep in the optimizer or the engine.

Parameters are first-class: ``param(name)`` returns an ``ir.Param`` node
that survives into the physical plan and is bound at execution time.
*Structural* parameters (hop counts, which change the pattern shape) must be
bound at build time via the ``params`` argument; value parameters stay late
bound, and any build-time bindings are kept on the plan as defaults and as
selectivity hints for the CBO.

    b = GraphIrBuilder(schema, params={"hops": 2})
    plan = (b.scan("p", ["PERSON"])
            .expand(["KNOWS"], direction=BOTH, hops="hops")
            .get_vertex("friend", ["PERSON"])
            .select(ir.Cmp("=", ir.Prop("p", "id"), b.param("pid")))
            .group([(ir.Var("friend"), "friend")],
                   [(ir.Agg("COUNT", ir.Var("p")), "c")])
            .order([(ir.Var("c"), False)], limit=20)
            .build())
"""
from __future__ import annotations

from repro.core import ir
from repro.core.errors import BuildError, ParamError
from repro.core.pattern import BOTH, IN, OUT, Pattern, PatternEdge
from repro.core.schema import GraphSchema

_DIRECTIONS = (OUT, IN, BOTH)


class GraphIrBuilder:
    """Fluent, eagerly-validated construction of unified-IR logical plans."""

    def __init__(self, schema: GraphSchema, params: dict | None = None):
        self.schema = schema
        self.pattern = Pattern()
        self._params = dict(params or {})     # build-time bindings (defaults)
        self._declared: set[str] = set(self._params)
        self._consumed: dict = {}             # structural params used so far
        self._preds: list = []                # WHERE conjuncts (one Select)
        self._rel_ops: list = []              # Project/Group/Order/Limit
        self._out_names: set[str] = set()     # output columns of project/group
        self._cur: str | None = None          # cursor vertex alias
        self._pending: dict | None = None     # expand() awaiting get_vertex()
        self._anon = 0
        self._nsteps = 0
        self._step: tuple[int, str] = (0, "init")

    # ------------------------------------------------------------ utilities
    @property
    def current(self) -> str | None:
        """The cursor: the vertex alias the next ``expand`` starts from."""
        return self._cur

    def _begin(self, name: str) -> None:
        self._nsteps += 1
        self._step = (self._nsteps, name)

    def _err(self, msg: str) -> BuildError:
        return BuildError(msg, step=self._step)

    def _fresh(self, prefix: str) -> str:
        self._anon += 1
        return f"_{prefix}{self._anon}"

    def _vertex_constraint(self, types) -> frozenset[str]:
        try:
            return self.schema.vertex_constraint(
                list(types) if types else None)
        except ValueError as exc:
            raise self._err(f"{exc}; known vertex types: "
                            f"{sorted(self.schema.vertex_types)}") from None

    def _edge_constraint(self, labels) -> frozenset:
        try:
            return self.schema.edge_constraint(
                list(labels) if labels else None)
        except ValueError as exc:
            raise self._err(f"{exc}; known edge labels: "
                            f"{sorted(self.schema.edge_labels())}") from None

    def _edge_aliases(self) -> set[str]:
        return {e.alias for e in self.pattern.edges}

    def _resolve_structural(self, value, what: str) -> int:
        """Hop counts change the pattern shape, so they must be bound now."""
        if isinstance(value, ir.Param):
            value = value.name
        if isinstance(value, str):
            name = value[1:] if value.startswith("$") else value
            self._declared.add(name)
            if name not in self._params:
                raise ParamError(
                    f"structural parameter ${name} ({what}) must be bound at "
                    f"build time", missing=[name], declared=self._declared)
            self._consumed[name] = self._params[name]
            value = self._params[name]
        try:
            return int(value)
        except (TypeError, ValueError):
            raise self._err(f"{what} must be an integer, got {value!r}") \
                from None

    # ------------------------------------------------------------ params
    def param(self, name: str) -> ir.Param:
        """Declare (or re-reference) a late-bound parameter."""
        name = name[1:] if name.startswith("$") else name
        if not name.isidentifier():
            raise self._err(f"invalid parameter name ${name}")
        self._declared.add(name)
        return ir.Param(name)

    def declared_params(self) -> frozenset[str]:
        return frozenset(self._declared)

    def consumed_params(self) -> dict:
        """Structural bindings consumed while building (e.g. hop counts) —
        the part of ``params`` that is baked into the pattern shape."""
        return dict(self._consumed)

    # ------------------------------------------------- expression validation
    def _validate_expr(self, e, allow_outputs: bool = False) -> None:
        known = set(self.pattern.vertices) | self._edge_aliases()
        for a in ir.expr_aliases(e):
            if a in known:
                continue
            if allow_outputs and a in self._out_names:
                continue
            raise self._err(
                f"unknown alias {a!r}; pattern aliases: {sorted(known)}"
                + (f"; output columns: {sorted(self._out_names)}"
                   if allow_outputs and self._out_names else ""))
        for p in ir.expr_props(e):
            self._validate_prop(p)
        self._declared |= ir.expr_params(e)

    def _validate_prop(self, p: ir.Prop) -> None:
        v = self.pattern.vertices.get(p.alias)
        if v is not None:
            if any(p.name in self.schema.vertex_props.get(t, {})
                   for t in v.types):
                return
            raise self._err(
                f"no vertex type of {p.alias!r} "
                f"({'|'.join(sorted(v.types))}) has property {p.name!r}")
        edge = next((e for e in self.pattern.edges if e.alias == p.alias),
                    None)
        if edge is not None:
            if any(p.name in self.schema.edge_props.get(t.label, {})
                   for t in edge.triples):
                return
            raise self._err(
                f"no edge label of {p.alias!r} "
                f"({'|'.join(sorted(edge.labels()))}) has property "
                f"{p.name!r}")
        # alias unknown — reported by the alias check with a better message
        raise self._err(f"unknown alias {p.alias!r} in property access "
                        f"{p.alias}.{p.name}")

    def _require_open_pattern(self, what: str) -> None:
        if self._rel_ops:
            raise self._err(f"{what} must precede relational steps")
        if self._pending is not None:
            raise self._err(f"{what} while an expand() awaits get_vertex()")

    # ---------------------------------------------------------- graph steps
    def scan(self, alias: str | None = None, types=None) -> "GraphIrBuilder":
        """Bind a (new or existing) pattern vertex and move the cursor there.
        Re-scanning an existing alias intersects its type constraint
        (MATCH-reuse semantics)."""
        self._begin("scan")
        self._require_open_pattern("scan")
        constraint = self._vertex_constraint(types)
        alias = alias or self._fresh("v")
        if alias in self._edge_aliases():
            raise self._err(f"alias {alias!r} already names an edge")
        self.pattern.add_vertex(alias, constraint)
        self._cur = alias
        return self

    def expand(self, labels=None, direction: str = OUT,
               alias: str | None = None, hops=1) -> "GraphIrBuilder":
        """Start an edge from the cursor; ``get_vertex`` binds the target.
        ``hops`` may be an int, a parameter name, or an ``ir.Param`` —
        parameters here are structural and resolved immediately."""
        self._begin("expand")
        self._require_open_pattern("expand")
        if self._cur is None:
            raise self._err("expand() before any scan()")
        if direction not in _DIRECTIONS:
            raise self._err(f"direction must be one of {_DIRECTIONS}, "
                            f"got {direction!r}")
        triples = self._edge_constraint(labels)
        hops = self._resolve_structural(hops, "hop count")
        if hops < 1:
            raise self._err(f"hop count must be >= 1, got {hops}")
        if alias is not None and (alias in self._edge_aliases()
                                  or alias in self.pattern.vertices):
            raise self._err(f"edge alias {alias!r} already in use")
        self._pending = {"alias": alias or self._fresh("e"), "src": self._cur,
                         "triples": triples, "direction": direction,
                         "hops": hops}
        return self

    def expand_path(self, labels=None, hops=2, direction: str = OUT,
                    alias: str | None = None) -> "GraphIrBuilder":
        """EXPAND_PATH sugar: a multi-hop edge (unfolded by the optimizer)."""
        return self.expand(labels, direction=direction, alias=alias,
                           hops=hops)

    def get_vertex(self, alias: str | None = None,
                   types=None) -> "GraphIrBuilder":
        """Bind the target of the pending ``expand``.  An existing alias
        closes a cycle (constraints intersect); a new/omitted alias creates
        the vertex."""
        self._begin("get_vertex")
        if self._pending is None:
            raise self._err("get_vertex() without a preceding expand()")
        pend, self._pending = self._pending, None
        constraint = self._vertex_constraint(types)
        alias = alias or self._fresh("v")
        if alias in self._edge_aliases():
            raise self._err(f"alias {alias!r} already names an edge")
        self.pattern.add_vertex(alias, constraint)
        self.pattern.add_edge(PatternEdge(
            pend["alias"], pend["src"], alias, pend["triples"],
            pend["direction"], pend["hops"]))
        self._cur = alias
        return self

    def alias_as(self, name: str, types=None) -> "GraphIrBuilder":
        """Rename the cursor vertex (Gremlin ``as_``).  Renaming onto an
        existing alias merges the two vertices (closing a cycle)."""
        self._begin("alias_as")
        self._require_open_pattern("alias_as")
        old = self._cur
        if old is None:
            raise self._err("alias_as() before any vertex step")
        if name in self._edge_aliases():
            raise self._err(f"alias {name!r} already names an edge")
        if name != old:
            if name in self.pattern.vertices:
                tgt = self.pattern.vertices[name]
                ov = self.pattern.vertices.pop(old)
                tgt.types = tgt.types & ov.types
                tgt.predicates.extend(ov.predicates)
            else:
                v = self.pattern.vertices.pop(old)
                v.alias = name
                self.pattern.vertices[name] = v
            for e in self.pattern.edges:
                if e.src == old:
                    e.src = name
                if e.dst == old:
                    e.dst = name
        if types:
            v = self.pattern.vertices[name]
            v.types = v.types & self._vertex_constraint(types)
        self._cur = name
        return self

    def at(self, alias: str) -> "GraphIrBuilder":
        """Move the cursor to a bound vertex (Gremlin ``select``)."""
        self._begin("at")
        if alias not in self.pattern.vertices:
            raise self._err(f"unknown alias {alias!r}; pattern aliases: "
                            f"{sorted(self.pattern.vertices)}")
        self._cur = alias
        return self

    def join(self, other: "GraphIrBuilder") -> "GraphIrBuilder":
        """Merge another builder's pattern and predicates into this one
        (multi-MATCH composition).  Shared vertex aliases intersect their
        constraints; edge aliases must not collide."""
        self._begin("join")
        self._require_open_pattern("join")
        if other._pending is not None or other._rel_ops:
            raise self._err("joined builder must be a bare pattern "
                            "(no pending expand, no relational steps)")
        clash = self._edge_aliases() & other._edge_aliases()
        named_clash = {a for a in clash if not a.startswith("_")}
        if named_clash:
            raise self._err(f"edge aliases {sorted(named_clash)} bound on "
                            "both sides of join()")
        # anonymous aliases are builder-local: a collision means two
        # *distinct* anonymous elements that happen to share a minted name,
        # so re-mint the other side's (named vertex collisions, by contrast,
        # are the join keys and merge intentionally)
        taken = (set(self.pattern.vertices) | set(other.pattern.vertices)
                 | self._edge_aliases() | other._edge_aliases())
        vmap: dict[str, str] = {}
        for a in other.pattern.vertices:
            if a.startswith("_") and a in self.pattern.vertices:
                na = self._fresh("v")
                while na in taken:
                    na = self._fresh("v")
                vmap[a] = na
                taken.add(na)
        for e in other.pattern.edges:
            if e.alias in clash:
                na = self._fresh("e")
                while na in taken:
                    na = self._fresh("e")
                vmap[e.alias] = na
                taken.add(na)
        for a, v in other.pattern.vertices.items():
            mine = self.pattern.add_vertex(vmap.get(a, a), v.types)
            mine.predicates.extend(ir.subst_aliases(p, vmap)
                                   for p in v.predicates)
        for e in other.pattern.edges:
            self.pattern.add_edge(PatternEdge(
                vmap.get(e.alias, e.alias), vmap.get(e.src, e.src),
                vmap.get(e.dst, e.dst), e.triples, e.direction, e.hops,
                [ir.subst_aliases(p, vmap) for p in e.predicates]))
        self._preds.extend(ir.subst_aliases(p, vmap) for p in other._preds)
        self._declared |= other._declared
        self._consumed.update(other._consumed)
        for k, v in other._params.items():
            self._params.setdefault(k, v)
        return self

    # ----------------------------------------------------- relational steps
    def select(self, predicate) -> "GraphIrBuilder":
        """Add a filter conjunct (all conjuncts form one SELECT op placed
        right after the pattern — so it must precede project/group/order)."""
        self._begin("select")
        if self._pending is not None:
            raise self._err("select() while an expand() awaits get_vertex()")
        if self._rel_ops:
            raise self._err(
                "select() must precede relational steps — filtering an "
                "aggregation's output (HAVING) is not supported")
        self._validate_expr(predicate)
        self._preds.append(predicate)
        return self

    where = select          # frontend-facing synonym

    @staticmethod
    def _named(items, default=lambda e: repr(e)):
        out = []
        for it in items:
            if isinstance(it, tuple):
                out.append(it)
            else:
                out.append((it, default(it)))
        return out

    def project(self, items, distinct: bool = False) -> "GraphIrBuilder":
        self._begin("project")
        items = self._named(items)
        for e, _ in items:
            self._validate_expr(e)
        self._rel_ops.append(ir.Project(items, distinct=distinct))
        self._out_names.update(n for _, n in items)
        return self

    def group(self, keys, aggs) -> "GraphIrBuilder":
        """GROUP: ``keys``/``aggs`` are (expr, out_name) pairs."""
        self._begin("group")
        keys = self._named(keys)
        aggs = self._named(aggs)
        for e, _ in keys:
            self._validate_expr(e)
        for a, _ in aggs:
            if not isinstance(a, ir.Agg):
                raise self._err(f"group aggregate must be ir.Agg, got {a!r}")
            self._validate_expr(a)
        self._rel_ops.append(ir.GroupBy(keys, aggs))
        self._out_names.update(n for _, n in keys)
        self._out_names.update(n for _, n in aggs)
        return self

    def order(self, items, limit: int | None = None) -> "GraphIrBuilder":
        """ORDER BY: items are (expr, ascending) pairs (bare expr == ASC).
        Expressions may reference output columns of a prior project/group."""
        self._begin("order")
        norm = []
        for it in items:
            e, asc = it if isinstance(it, tuple) else (it, True)
            self._validate_expr(e, allow_outputs=True)
            norm.append((e, bool(asc)))
        self._rel_ops.append(ir.OrderBy(norm, limit=limit))
        return self

    def limit(self, n: int) -> "GraphIrBuilder":
        self._begin("limit")
        n = int(n)
        if n < 0:
            raise self._err(f"LIMIT must be >= 0, got {n}")
        self._rel_ops.append(ir.Limit(n))
        return self

    # ----------------------------------------------------------------- build
    def build(self) -> ir.LogicalPlan:
        self._begin("build")
        if self._pending is not None:
            raise self._err("dangling expand(): call get_vertex() first")
        if not self.pattern.vertices:
            raise self._err("empty pattern: add at least one scan()")
        ops: list = [ir.MatchPattern(self.pattern)]
        pred = ir.make_and(self._preds)
        if pred is not None:
            ops.append(ir.Select(pred))
        ops.extend(self._rel_ops)
        plan = ir.LogicalPlan(ops, dict(self._params))
        # which bindings were consumed *structurally* (baked into the
        # pattern shape): the engine refuses to rebind exactly these, and
        # the prepared-plan caches key their variants on them
        plan.hints["structural_params"] = dict(self._consumed)
        return plan
