"""Cypher-subset frontend (paper §4.2).

Tokenizer + grammar only: parsing PatRelQuery text drives the unified
``GraphIrBuilder`` (``core/ir_builder.py``), which owns alias management,
schema-constraint lookup and eager validation.  ``$params`` are late bound —
they lower to first-class ``ir.Param`` nodes resolved at execution time, so
a parsed/optimized plan is reusable across bindings (the prepared-query
path, DESIGN.md §3).  The only exception is *structural* parameters (hop
counts ``*$h``), which change the pattern shape and must be bound at parse
time via the ``params`` argument; any ``params`` given here also become the
plan's default bindings and the CBO's selectivity hints.

Supported grammar (enough for every query in the paper's Appendix A):

    query     := (EXPLAIN | PROFILE)?
                 MATCH path (',' path)* (MATCH ...)* (WHERE expr)?
                 RETURN [DISTINCT] item (',' item)*
                 (ORDER BY expr [ASC|DESC] (',' ...)*)? (LIMIT int)?
    path      := node (edge node)*
    node      := '(' [alias] [':' NAME ('|' NAME)*] [props] ')'
    edge      := '-[' [alias] [':' NAME ('|' NAME)*] ['*' (int|$param)] ']->'

A Gremlin-style builder API is provided by ``repro.core.gremlin``.
"""
from __future__ import annotations

import re

from repro.core import ir
from repro.core.ir_builder import GraphIrBuilder
from repro.core.pattern import BOTH, IN, OUT
from repro.core.schema import GraphSchema

_TOKEN_RE = re.compile(r"""
    (?P<ws>\s+)
  | (?P<num>\d+\.\d+|\d+)
  | (?P<str>'[^']*'|"[^"]*")
  | (?P<param>\$[A-Za-z_][A-Za-z0-9_]*)
  | (?P<name>[A-Za-z_][A-Za-z0-9_]*)
  | (?P<op><=|>=|<>|!=|<-|->|=|<|>|\(|\)|\[|\]|\{|\}|,|:|\||\*|\.|-)
""", re.X)

_KEYWORDS = {"MATCH", "WHERE", "RETURN", "ORDER", "BY", "LIMIT", "AS", "AND",
             "OR", "NOT", "IN", "DISTINCT", "ASC", "DESC", "COUNT", "SUM",
             "MIN", "MAX", "AVG"}


def _tokenize(text: str):
    toks = []
    pos = 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            raise SyntaxError(f"cannot tokenize at: {text[pos:pos+20]!r}")
        pos = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        val = m.group()
        if kind == "name" and val.upper() in _KEYWORDS:
            toks.append(("kw", val.upper()))
        else:
            toks.append((kind, val))
    toks.append(("eof", ""))
    return toks


class CypherParser:
    def __init__(self, schema: GraphSchema, params: dict | None = None):
        self.schema = schema
        self.b = GraphIrBuilder(schema, params)

    # ------------------------------------------------------------------ util
    def _peek(self):
        return self.toks[self.i]

    def _next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def _accept(self, kind, val=None):
        k, v = self._peek()
        if k == kind and (val is None or v == val):
            self.i += 1
            return v
        return None

    def _expect(self, kind, val=None):
        got = self._accept(kind, val)
        if got is None:
            raise SyntaxError(f"expected {val or kind}, got {self._peek()}")
        return got

    # ----------------------------------------------------------------- parse
    def parse(self, text: str) -> ir.LogicalPlan:
        self.toks = _tokenize(text)
        self.i = 0
        b = self.b
        # EXPLAIN/PROFILE prefix: parse the query as usual, record the
        # requested mode as a plan hint (GOpt.run routes it to explain();
        # the hint is not part of the canonical form, so the underlying
        # query shares its cached plan with the plain form).  Recognized
        # positionally — only as the very first token — so identifiers
        # named "explain"/"profile" stay valid everywhere else.
        explain_mode = None
        k, v = self._peek()
        if k == "name" and v.upper() in ("EXPLAIN", "PROFILE"):
            self._next()
            explain_mode = v.lower()
            k2, v2 = self._peek()
            if (explain_mode == "profile" and k2 == "name"
                    and v2.upper() == "SYNC"):
                self._next()                 # PROFILE SYNC: per-op device sync
                explain_mode = "profile_sync"
        saw_match = False
        while self._accept("kw", "MATCH"):
            saw_match = True
            self._parse_path()
            while self._accept("op", ","):
                self._parse_path()
        if not saw_match:
            raise SyntaxError("query must start with MATCH")

        if self._accept("kw", "WHERE"):
            b.select(self._expr())

        self._expect("kw", "RETURN")
        distinct = bool(self._accept("kw", "DISTINCT"))
        items = [self._return_item()]
        while self._accept("op", ","):
            items.append(self._return_item())

        has_agg = any(isinstance(e, ir.Agg) for e, _ in items)
        if has_agg:
            b.group([(e, n) for e, n in items if not isinstance(e, ir.Agg)],
                    [(e, n) for e, n in items if isinstance(e, ir.Agg)])
        else:
            b.project(items, distinct=distinct)

        if self._accept("kw", "ORDER"):
            self._expect("kw", "BY")
            oitems = [self._order_item(items)]
            while self._accept("op", ","):
                oitems.append(self._order_item(items))
            b.order(oitems)
        if self._accept("kw", "LIMIT"):
            b.limit(int(self._expect("num")))
        self._expect("eof")
        plan = b.build()
        if explain_mode is not None:
            plan.hints["explain"] = explain_mode
        return plan

    # ------------------------------------------------------------- patterns
    def _parse_path(self):
        alias, types, props = self._node()
        self.b.scan(alias, types)
        self._node_props(self.b.current, props)
        while self._peek() in (("op", "-"), ("op", "<-")):
            direction, ealias, labels, hops = self._edge()
            nalias, ntypes, nprops = self._node()
            self.b.expand(labels, direction=direction, alias=ealias,
                          hops=hops)
            self.b.get_vertex(nalias, ntypes)
            self._node_props(self.b.current, nprops)

    def _node_props(self, alias: str, props: list):
        for prop, val in props:
            self.b.select(ir.Cmp("=", ir.Prop(alias, prop), val))

    def _node(self):
        """Grammar only: returns (alias|None, types|None, [(prop, value)])."""
        self._expect("op", "(")
        alias = self._accept("name")
        types = None
        if self._accept("op", ":"):
            types = [self._expect("name").upper()]
            while self._accept("op", "|"):
                types.append(self._expect("name").upper())
        props = []
        if self._peek() == ("op", "{"):
            self._next()
            while True:
                prop = self._expect("name")
                self._expect("op", ":")
                props.append((prop, self._value()))
                if not self._accept("op", ","):
                    break
            self._expect("op", "}")
        self._expect("op", ")")
        return alias, types, props

    def _edge(self):
        """Returns (direction, alias|None, labels|None, hops)."""
        left = self._accept("op", "<-")
        if left is None:
            self._expect("op", "-")
        alias, labels, hops = None, None, 1
        if self._accept("op", "["):
            alias = self._accept("name")
            if self._accept("op", ":"):
                labels = [self._expect("name").upper()]
                while self._accept("op", "|"):
                    labels.append(self._expect("name").upper())
            if self._accept("op", "*"):
                k, v = self._peek()
                if k == "num":
                    hops = int(self._next()[1])
                elif k == "param":
                    hops = self._next()[1]    # structural: builder resolves
                else:
                    raise SyntaxError("EXPAND_PATH needs an explicit hop "
                                      "count")
            self._expect("op", "]")
        if left:
            self._expect("op", "-")
            return IN, alias, labels, hops
        # either -> or -
        if self._accept("op", "->"):
            return OUT, alias, labels, hops
        self._expect("op", "-")
        return BOTH, alias, labels, hops

    # ----------------------------------------------------------- expressions
    def _return_item(self):
        e = self._expr()
        name = None
        if self._accept("kw", "AS"):
            name = self._expect("name")
        if name is None:
            name = repr(e)
        return (e, name)

    def _order_item(self, ritems):
        e = self._expr()
        asc = True
        if self._accept("kw", "DESC"):
            asc = False
        else:
            self._accept("kw", "ASC")
        # normalize: ordering by a RETURN expression refers to its output
        # column (e.g. ORDER BY count(v1) with RETURN count(v1) AS cnt)
        for re_, rn in ritems:
            if e == re_:
                return (ir.Var(rn), asc)
        return (e, asc)

    def _expr(self):
        return self._or()

    def _or(self):
        l = self._and()
        args = [l]
        while self._accept("kw", "OR"):
            args.append(self._and())
        return args[0] if len(args) == 1 else ir.BoolOp("OR", tuple(args))

    def _and(self):
        l = self._not()
        args = [l]
        while self._accept("kw", "AND"):
            args.append(self._not())
        return args[0] if len(args) == 1 else ir.BoolOp("AND", tuple(args))

    def _not(self):
        if self._accept("kw", "NOT"):
            return ir.BoolOp("NOT", (self._not(),))
        return self._cmp()

    def _cmp(self):
        l = self._atom()
        k, v = self._peek()
        if k == "op" and v in ("=", "<>", "!=", "<", ">", "<=", ">="):
            self._next()
            r = self._atom()
            return ir.Cmp("<>" if v == "!=" else v, l, r)
        if k == "kw" and v == "IN":
            self._next()
            return ir.InSet(l, self._value_list())
        return l

    def _value_list(self):
        k, v = self._peek()
        if k == "param":
            self._next()
            return self.b.param(v)           # whole-list parameter
        self._expect("op", "[")
        vals = [self._literal()]
        while self._accept("op", ","):
            vals.append(self._literal())
        self._expect("op", "]")
        return tuple(vals)

    def _literal(self):
        k, v = self._next()
        if k == "num":
            return float(v) if "." in v else int(v)
        if k == "str":
            return v[1:-1]
        raise SyntaxError(f"expected literal, got {v!r}")

    def _value(self):
        """A literal or a late-bound parameter, as an expression node."""
        if self._peek()[0] == "param":
            return self.b.param(self._next()[1])
        return ir.Lit(self._literal())

    def _atom(self):
        k, v = self._peek()
        if k in ("num", "str"):
            return ir.Lit(self._literal())
        if k == "param":
            self._next()
            return self.b.param(v)
        if k == "op" and v == "(":
            self._next()
            e = self._expr()
            self._expect("op", ")")
            return e
        if k == "kw" and v in ("COUNT", "SUM", "MIN", "MAX", "AVG"):
            self._next()
            self._expect("op", "(")
            self._accept("kw", "DISTINCT")
            if self._accept("op", "*"):
                arg = None
            else:
                arg = self._expr()
            self._expect("op", ")")
            return ir.Agg(v, arg)
        if k == "name":
            self._next()
            if self._accept("op", "."):
                prop = self._expect("name")
                return ir.Prop(v, prop)
            return ir.Var(v)
        raise SyntaxError(f"unexpected token {v!r} in expression")


def parse_cypher(text: str, schema: GraphSchema,
                 params: dict | None = None) -> ir.LogicalPlan:
    return CypherParser(schema, params).parse(text)
