"""Cypher-subset frontend (paper §4.2).

Parses PatRelQuery written in Cypher into the unified IR LogicalPlan:
``MATCH`` clauses become a MATCH_PATTERN (built from SCAN / EXPAND_EDGE /
GET_VERTEX / EXPAND_PATH parses, kept here directly as the semantically
equivalent Pattern), ``WHERE`` becomes SELECT, ``RETURN``/``ORDER``/``LIMIT``
become PROJECT / GROUP / ORDER / LIMIT.

Supported grammar (enough for every query in the paper's Appendix A):

    query     := MATCH path (',' path)* (MATCH ...)* (WHERE expr)?
                 RETURN [DISTINCT] item (',' item)*
                 (ORDER BY expr [ASC|DESC] (',' ...)*)? (LIMIT int)?
    path      := node (edge node)*
    node      := '(' [alias] [':' NAME ('|' NAME)*] [props] ')'
    edge      := '-[' [alias] [':' NAME ('|' NAME)*] ['*' int] ']->' etc.

A Gremlin-style builder API is provided by ``repro.core.gremlin``.
"""
from __future__ import annotations

import re

from repro.core import ir
from repro.core.pattern import BOTH, IN, OUT, Pattern, PatternEdge
from repro.core.schema import GraphSchema

_TOKEN_RE = re.compile(r"""
    (?P<ws>\s+)
  | (?P<num>\d+\.\d+|\d+)
  | (?P<str>'[^']*'|"[^"]*")
  | (?P<param>\$[A-Za-z_][A-Za-z0-9_]*)
  | (?P<name>[A-Za-z_][A-Za-z0-9_]*)
  | (?P<op><=|>=|<>|!=|<-|->|=|<|>|\(|\)|\[|\]|\{|\}|,|:|\||\*|\.|-)
""", re.X)

_KEYWORDS = {"MATCH", "WHERE", "RETURN", "ORDER", "BY", "LIMIT", "AS", "AND",
             "OR", "NOT", "IN", "DISTINCT", "ASC", "DESC", "COUNT", "SUM",
             "MIN", "MAX", "AVG"}


def _tokenize(text: str):
    toks = []
    pos = 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            raise SyntaxError(f"cannot tokenize at: {text[pos:pos+20]!r}")
        pos = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        val = m.group()
        if kind == "name" and val.upper() in _KEYWORDS:
            toks.append(("kw", val.upper()))
        else:
            toks.append((kind, val))
    toks.append(("eof", ""))
    return toks


class CypherParser:
    def __init__(self, schema: GraphSchema, params: dict | None = None):
        self.schema = schema
        self.params = params or {}
        self._anon = 0

    # ------------------------------------------------------------------ util
    def _fresh(self, prefix):
        self._anon += 1
        return f"_{prefix}{self._anon}"

    def _peek(self):
        return self.toks[self.i]

    def _next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def _accept(self, kind, val=None):
        k, v = self._peek()
        if k == kind and (val is None or v == val):
            self.i += 1
            return v
        return None

    def _expect(self, kind, val=None):
        got = self._accept(kind, val)
        if got is None:
            raise SyntaxError(f"expected {val or kind}, got {self._peek()}")
        return got

    def _param(self, name):
        key = name[1:]
        if key not in self.params:
            raise KeyError(f"missing query parameter ${key}")
        return self.params[key]

    # ----------------------------------------------------------------- parse
    def parse(self, text: str) -> ir.LogicalPlan:
        self.toks = _tokenize(text)
        self.i = 0
        pattern = Pattern()
        prop_preds = []
        while self._accept("kw", "MATCH"):
            self._parse_path(pattern, prop_preds)
            while self._accept("op", ","):
                self._parse_path(pattern, prop_preds)
        if not pattern.vertices:
            raise SyntaxError("query must start with MATCH")

        ops: list = [ir.MatchPattern(pattern)]

        where = None
        if self._accept("kw", "WHERE"):
            where = self._expr()
        where = ir.make_and([p for p in prop_preds] + ([where] if where else []))
        if where is not None:
            ops.append(ir.Select(where))

        self._expect("kw", "RETURN")
        distinct = bool(self._accept("kw", "DISTINCT"))
        items = [self._return_item()]
        while self._accept("op", ","):
            items.append(self._return_item())

        has_agg = any(isinstance(e, ir.Agg) for e, _ in items)
        if has_agg:
            keys = [(e, n) for e, n in items if not isinstance(e, ir.Agg)]
            aggs = [(e, n) for e, n in items if isinstance(e, ir.Agg)]
            ops.append(ir.GroupBy(keys, aggs))
        else:
            ops.append(ir.Project(items, distinct=distinct))

        if self._accept("kw", "ORDER"):
            self._expect("kw", "BY")
            oitems = [self._order_item(items)]
            while self._accept("op", ","):
                oitems.append(self._order_item(items))
            ops.append(ir.OrderBy(oitems))
        if self._accept("kw", "LIMIT"):
            n = int(self._expect("num"))
            ops.append(ir.Limit(n))
        self._expect("eof")
        return ir.LogicalPlan(ops, dict(self.params))

    # ------------------------------------------------------------- patterns
    def _parse_path(self, pattern: Pattern, prop_preds: list):
        prev = self._node(pattern, prop_preds)
        while self._peek() in (("op", "-"), ("op", "<-")):
            direction, alias, labels, hops = self._edge()
            nxt = self._node(pattern, prop_preds)
            triples = self.schema.edge_constraint(labels)
            if direction == "L":  # <-[..]-  : edge from nxt to prev
                e = PatternEdge(alias, prev, nxt, triples, IN, hops)
            elif direction == "R":
                e = PatternEdge(alias, prev, nxt, triples, OUT, hops)
            else:
                e = PatternEdge(alias, prev, nxt, triples, BOTH, hops)
            pattern.add_edge(e)
            prev = nxt

    def _node(self, pattern: Pattern, prop_preds: list) -> str:
        self._expect("op", "(")
        alias = self._accept("name") or self._fresh("v")
        types = None
        if self._accept("op", ":"):
            types = [self._expect("name").upper()]
            while self._accept("op", "|"):
                types.append(self._expect("name").upper())
        if self._peek() == ("op", "{"):
            self._next()
            while True:
                prop = self._expect("name")
                self._expect("op", ":")
                val = self._literal()
                prop_preds.append(ir.Cmp("=", ir.Prop(alias, prop), ir.Lit(val)))
                if not self._accept("op", ","):
                    break
            self._expect("op", "}")
        self._expect("op", ")")
        pattern.add_vertex(alias, self.schema.vertex_constraint(types))
        return alias

    def _edge(self):
        """Returns (direction L|R|B, alias, labels|None, hops)."""
        left = self._accept("op", "<-")
        if left is None:
            self._expect("op", "-")
        alias, labels, hops = None, None, 1
        if self._accept("op", "["):
            alias = self._accept("name")
            if self._accept("op", ":"):
                labels = [self._expect("name").upper()]
                while self._accept("op", "|"):
                    labels.append(self._expect("name").upper())
            if self._accept("op", "*"):
                k, v = self._peek()
                if k == "num":
                    hops = int(self._next()[1])
                elif k == "param":
                    hops = int(self._param(self._next()[1]))
                else:
                    raise SyntaxError("EXPAND_PATH needs an explicit hop count")
            self._expect("op", "]")
        alias = alias or self._fresh("e")
        if left:
            self._expect("op", "-")
            return "L", alias, labels, hops
        # either -> or -
        if self._accept("op", "->"):
            return "R", alias, labels, hops
        self._expect("op", "-")
        return "B", alias, labels, hops

    # ----------------------------------------------------------- expressions
    def _return_item(self):
        e = self._expr()
        name = None
        if self._accept("kw", "AS"):
            name = self._expect("name")
        if name is None:
            name = repr(e)
        return (e, name)

    def _order_item(self, ritems):
        e = self._expr()
        asc = True
        if self._accept("kw", "DESC"):
            asc = False
        else:
            self._accept("kw", "ASC")
        # normalize: ordering by a RETURN expression refers to its output
        # column (e.g. ORDER BY count(v1) with RETURN count(v1) AS cnt)
        for re_, rn in ritems:
            if e == re_:
                return (ir.Var(rn), asc)
        return (e, asc)

    def _expr(self):
        return self._or()

    def _or(self):
        l = self._and()
        args = [l]
        while self._accept("kw", "OR"):
            args.append(self._and())
        return args[0] if len(args) == 1 else ir.BoolOp("OR", tuple(args))

    def _and(self):
        l = self._not()
        args = [l]
        while self._accept("kw", "AND"):
            args.append(self._not())
        return args[0] if len(args) == 1 else ir.BoolOp("AND", tuple(args))

    def _not(self):
        if self._accept("kw", "NOT"):
            return ir.BoolOp("NOT", (self._not(),))
        return self._cmp()

    def _cmp(self):
        l = self._atom()
        k, v = self._peek()
        if k == "op" and v in ("=", "<>", "!=", "<", ">", "<=", ">="):
            self._next()
            r = self._atom()
            return ir.Cmp("<>" if v == "!=" else v, l, r)
        if k == "kw" and v == "IN":
            self._next()
            return ir.InSet(l, tuple(self._value_list()))
        return l

    def _value_list(self):
        k, v = self._peek()
        if k == "param":
            self._next()
            return list(self._param(v))
        self._expect("op", "[")
        vals = [self._literal()]
        while self._accept("op", ","):
            vals.append(self._literal())
        self._expect("op", "]")
        return vals

    def _literal(self):
        k, v = self._next()
        if k == "num":
            return float(v) if "." in v else int(v)
        if k == "str":
            return v[1:-1]
        if k == "param":
            return self._param(v)
        raise SyntaxError(f"expected literal, got {v!r}")

    def _atom(self):
        k, v = self._peek()
        if k == "num" or k == "str":
            return ir.Lit(self._literal())
        if k == "param":
            self._next()
            return ir.Lit(self._param(v))
        if k == "op" and v == "(":
            self._next()
            e = self._expr()
            self._expect("op", ")")
            return e
        if k == "kw" and v in ("COUNT", "SUM", "MIN", "MAX", "AVG"):
            self._next()
            self._expect("op", "(")
            self._accept("kw", "DISTINCT")
            if self._accept("op", "*"):
                arg = None
            else:
                arg = self._expr()
            self._expect("op", ")")
            return ir.Agg(v, arg)
        if k == "name":
            self._next()
            if self._accept("op", "."):
                prop = self._expect("name")
                return ir.Prop(v, prop)
            return ir.Var(v)
        raise SyntaxError(f"unexpected token {v!r} in expression")


def parse_cypher(text: str, schema: GraphSchema,
                 params: dict | None = None) -> ir.LogicalPlan:
    return CypherParser(schema, params).parse(text)
