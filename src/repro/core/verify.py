"""PlanVerifier — static invariant checking for GIR and physical plans
(DESIGN.md §12).

The optimizer is deliberately open: ``OptimizerPipeline`` accepts registered
third-party passes/rules and ``PhysicalSpec`` third-party operator sets —
but an invalid rewrite used to surface only as wrong rows (or a crash) deep
inside the engine.  ``PlanVerifier`` proves, *statically*, that a plan is
still well-formed:

- **plan shape** — a single leading MATCH_PATTERN, edges anchored on
  declared pattern vertices, no alias collisions, hops >= 1;
- **alias scope** — def-before-use and liveness of every alias/column
  reference through the relational tail, mirroring the engine's binding
  table semantics (``Var`` needs an id column, ``Prop`` resolves for vertex
  aliases and for edge aliases via their ``#t``/``#p`` identity columns,
  PROJECT/GROUP replace the column set, ORDER BY may name aggregate
  outputs by their serialized form);
- **parameter discipline** — no expression references a *structural*
  parameter that was baked into the pattern shape at build time;
- **satisfiability & schema soundness** — runs type inference (Algorithm
  1): an unsatisfiable pattern short-circuits to a clean ``verified-empty``
  report (the engine returns zero rows; that is a *result*, not an
  invariant violation) unless the caller asserts the plan was satisfiable
  before the pass under test ran; on the inferred pattern, every edge's
  triples must be schema triples consistent with its endpoints' type sets
  and every property access must exist on the alias's inferred types;
- **physical cover** — the physical plan binds exactly the pattern's
  vertices, traverses exactly its edges, expands each new alias along
  pattern edges into already-bound endpoints, joins on bound keys, and
  scopes every bind-time predicate over aliases bound at that point;
- **chain contracts** — ``ExpandChainNode`` hop continuity (each
  ``from_alias`` bound by the child or an earlier step), endpoint
  agreement, def-once hops, WCOJ ``intersect_edges`` only on the *last*
  step and only into bound aliases, and bound-at-step predicate scoping;
- **delta/epoch consistency** — a chain's memoized ``ChainSpec`` for this
  store must have been compiled at the store's current compaction epoch;
- **capacity monotonicity** — every live fused-chain program's capacity
  schedule is power-of-two buckets and no cached program exceeds the
  handle's current caps (caps only grow, element-wise);
- **operator dtype contracts** — the active backend's built operator set
  honors the bool-mask / integer-column dtype contract
  (``physical_spec.dtype_contract_failures``, checked once per operator
  set).

``verify`` returns a ``VerifyReport``; the pipeline wiring
(``OptimizerPipeline(verify="off"|"cached"|"always")``) raises
``PlanInvariantError`` naming the offending pass when a report carries
violations.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import ir
from repro.core.pattern import Pattern
from repro.core.physical import (ExpandChainNode, ExpandNode, JoinNode,
                                 PlanNode, ScanNode)
from repro.core.schema import GraphSchema
from repro.core.type_inference import (INVALID, _edge_triples_consistent,
                                       infer_types)

OK = "ok"
VERIFIED_EMPTY = "verified-empty"
INVALID_PLAN = "invalid"


@dataclasses.dataclass
class VerifyReport:
    """Outcome of one ``PlanVerifier.verify`` run.

    ``status`` is ``"ok"``, ``"verified-empty"`` (type inference proved the
    pattern unsatisfiable — zero rows, by proof, with the structural checks
    still clean) or ``"invalid"``; ``checks`` names the check groups that
    ran; ``cached`` marks a report served from the pipeline's per-canonical-
    form memo rather than re-verified."""
    status: str
    checks: tuple[str, ...] = ()
    violations: tuple[str, ...] = ()
    wall_s: float = 0.0
    cached: bool = False

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> dict:
        return {"status": self.status, "checks": len(self.checks),
                "violations": list(self.violations),
                "wall_ms": round(self.wall_s * 1e3, 3),
                "cached": self.cached}


class PlanVerifier:
    """Static checker for one (schema, backend spec, store) context.

    ``spec``/``store`` are optional: without them the physical-contract
    checks that need a built operator set (capacity monotonicity, dtype
    contracts) and the delta-epoch check are skipped — the plan-level
    checks never need a store."""

    def __init__(self, schema: GraphSchema, spec=None, store=None):
        self.schema = schema
        self.spec = spec
        self.store = store

    # ------------------------------------------------------------------ drive
    def verify(self, plan: ir.LogicalPlan, physical: PlanNode | None = None,
               *, invalid: bool = False,
               expect_satisfiable: bool = False) -> VerifyReport:
        t0 = time.perf_counter()
        v: list[str] = []
        checks: list[str] = []
        pattern = plan.pattern()

        checks.append("plan-shape")
        self._check_shape(plan, pattern, v)
        if pattern is None or v:
            # no pattern (or a malformed one): the scoped walks below would
            # only cascade noise off the same defect
            return self._report(v, checks, t0, unsat=invalid and not v)

        checks.append("alias-scope")
        self._check_alias_scope(plan, pattern, v)
        checks.append("param-bindings")
        self._check_params(plan, v)

        checks.append("satisfiability")
        if invalid:
            # the pipeline already proved unsatisfiability; structural
            # checks above still apply, schema/physical checks need the
            # inferred types that do not exist
            return self._report(v, checks, t0, unsat=True)
        inferred = infer_types(pattern, self.schema)
        if inferred == INVALID:
            if expect_satisfiable:
                v.append("satisfiability: pass turned a satisfiable "
                         "pattern unsatisfiable (type inference now "
                         "proves zero rows)")
                return self._report(v, checks, t0)
            return self._report(v, checks, t0, unsat=True)

        checks.append("schema-edges")
        self._check_schema_edges(inferred, v)
        checks.append("schema-props")
        self._check_schema_props(plan, pattern, inferred, v)

        if physical is not None:
            checks.append("physical-cover")
            checks.append("chain-contract")
            self._check_physical(pattern, physical, v)
            checks.append("delta-epoch")
            self._check_delta_epochs(physical, v)
            checks.append("capacity-pow2")
            self._check_capacities(v)
            checks.append("operator-contracts")
            self._check_operator_contracts(v)
        return self._report(v, checks, t0)

    def _report(self, v, checks, t0, unsat: bool = False) -> VerifyReport:
        status = (INVALID_PLAN if v else
                  VERIFIED_EMPTY if unsat else OK)
        return VerifyReport(status, tuple(checks), tuple(v),
                            wall_s=time.perf_counter() - t0)

    # ------------------------------------------------------------- plan shape
    def _check_shape(self, plan, pattern, v: list[str]) -> None:
        if not plan.ops:
            v.append("plan-shape: plan has no operators")
            return
        matches = [i for i, op in enumerate(plan.ops)
                   if isinstance(op, ir.MatchPattern)]
        if not matches:
            v.append("plan-shape: plan has no MATCH_PATTERN")
            return
        if matches != [0]:
            v.append(f"plan-shape: MATCH_PATTERN must be the single leading "
                     f"operator (found at positions {matches})")
        if pattern is None or not pattern.vertices:
            v.append("plan-shape: pattern has no vertices")
            return
        seen_edges: set[str] = set()
        for e in pattern.edges:
            for end in (e.src, e.dst):
                if end not in pattern.vertices:
                    v.append(f"plan-shape: edge {e.alias!r} endpoint "
                             f"{end!r} is not a pattern vertex")
            if e.alias in pattern.vertices:
                v.append(f"plan-shape: edge alias {e.alias!r} collides "
                         f"with a vertex alias")
            if e.alias in seen_edges:
                v.append(f"plan-shape: duplicate edge alias {e.alias!r}")
            seen_edges.add(e.alias)
            if e.hops < 1:
                v.append(f"plan-shape: edge {e.alias!r} has hops={e.hops}")

    # ------------------------------------------------------------ alias scope
    def _check_alias_scope(self, plan, pattern: Pattern,
                           v: list[str]) -> None:
        vertex_aliases = set(pattern.vertices)
        edge_aliases = {e.alias for e in pattern.edges}
        known = vertex_aliases | edge_aliases

        for pv in pattern.vertices.values():
            for p in pv.predicates:
                bad = ir.expr_aliases(p) - known
                if bad:
                    v.append(f"alias-scope: predicate on vertex "
                             f"{pv.alias!r} references unknown alias(es) "
                             f"{sorted(bad)}: {p!r}")
        for pe in pattern.edges:
            for p in pe.predicates:
                bad = ir.expr_aliases(p) - known
                if bad:
                    v.append(f"alias-scope: predicate on edge "
                             f"{pe.alias!r} references unknown alias(es) "
                             f"{sorted(bad)}: {p!r}")

        # walk the relational tail with the engine's column semantics:
        # var_cols = names usable as a bare Var (id / output columns),
        # prop_ok  = names usable as a Prop base (vertex id columns and,
        # before any PROJECT/GROUP, edge aliases via their #t/#p columns)
        var_cols = set(vertex_aliases)
        prop_ok = set(vertex_aliases) | edge_aliases

        def scoped(e, where: str) -> None:
            bad_var = ir.expr_var_aliases(e) - var_cols
            if bad_var:
                v.append(f"alias-scope: {where} references unbound "
                         f"column(s) {sorted(bad_var)}: {e!r}")
            bad_prop = {p.alias for p in ir.expr_props(e)} - prop_ok
            if bad_prop:
                v.append(f"alias-scope: {where} dereferences propert"
                         f"{'ies' if len(bad_prop) > 1 else 'y'} of "
                         f"dropped alias(es) {sorted(bad_prop)}: {e!r}")

        for op in plan.ops[1:]:
            if isinstance(op, ir.Select):
                scoped(op.predicate, "SELECT")
            elif isinstance(op, ir.Project):
                for e, name in op.items:
                    scoped(e, f"PROJECT item {name!r}")
                var_cols = {name for _, name in op.items}
                prop_ok = {name for e, name in op.items
                           if isinstance(e, ir.Var) and e.alias in prop_ok}
            elif isinstance(op, ir.GroupBy):
                for e, name in op.keys:
                    scoped(e, f"GROUP key {name!r}")
                for a, name in op.aggs:
                    scoped(a, f"GROUP aggregate {name!r}")
                new_vars = ({name for _, name in op.keys}
                            | {name for _, name in op.aggs})
                prop_ok = {name for e, name in op.keys
                           if isinstance(e, ir.Var) and e.alias in prop_ok}
                var_cols = new_vars
            elif isinstance(op, ir.OrderBy):
                for e, _asc in op.items:
                    if isinstance(e, ir.Var) and e.alias in var_cols:
                        continue
                    if repr(e) in var_cols:   # aggregate-output trick
                        continue
                    scoped(e, "ORDER BY")
            elif isinstance(op, (ir.Limit, ir.MatchPattern)):
                pass

    # ------------------------------------------------------------- parameters
    def _check_params(self, plan, v: list[str]) -> None:
        structural = set(plan.hints.get("structural_params") or {})
        rebound = plan.referenced_params() & structural
        if rebound:
            v.append(f"param-bindings: structural parameter(s) "
                     f"{sorted('$' + p for p in rebound)} were baked into "
                     f"the pattern at build time but are referenced by a "
                     f"plan expression — a rewrite re-introduced a consumed "
                     f"parameter")

    # ------------------------------------------------------ schema soundness
    def _check_schema_edges(self, inferred: Pattern, v: list[str]) -> None:
        legal = self.schema.all_edge_triples()
        for e in inferred.edges:
            rogue = e.triples - legal
            if rogue:
                v.append(f"schema-edges: edge {e.alias!r} carries triple(s) "
                         f"not in the schema: {sorted(map(repr, rogue))}")
            ok = _edge_triples_consistent(
                e, inferred.vertices[e.src].types,
                inferred.vertices[e.dst].types)
            if not ok:
                v.append(f"schema-edges: edge {e.alias!r} "
                         f"({e.src!r}-{sorted(e.labels())}->{e.dst!r}) has "
                         f"no triple consistent with its endpoints' "
                         f"inferred types")

    def _iter_plan_props(self, plan, pattern: Pattern):
        for pv in pattern.vertices.values():
            for p in pv.predicates:
                yield from ir.expr_props(p)
        for pe in pattern.edges:
            for p in pe.predicates:
                yield from ir.expr_props(p)
        for op in plan.ops[1:]:
            if isinstance(op, ir.Select):
                yield from ir.expr_props(op.predicate)
            elif isinstance(op, ir.Project):
                for e, _ in op.items:
                    yield from ir.expr_props(e)
            elif isinstance(op, ir.GroupBy):
                for e, _ in op.keys:
                    yield from ir.expr_props(e)
                for a, _ in op.aggs:
                    yield from ir.expr_props(a)
            elif isinstance(op, ir.OrderBy):
                for e, _ in op.items:
                    yield from ir.expr_props(e)

    def _check_schema_props(self, plan, pattern: Pattern, inferred: Pattern,
                            v: list[str]) -> None:
        edge_labels = {e.alias: e.labels() for e in inferred.edges}
        seen: set[ir.Prop] = set()
        for p in self._iter_plan_props(plan, pattern):
            if p in seen:
                continue
            seen.add(p)
            if p.alias in inferred.vertices:
                types = inferred.vertices[p.alias].types
                names = set()
                for t in types:
                    names |= set(self.schema.vertex_props.get(t, {}))
                if p.name not in names:
                    v.append(f"schema-props: {p!r} — no vertex type in "
                             f"{sorted(types)} declares property "
                             f"{p.name!r}")
            elif p.alias in edge_labels:
                names = set()
                for lb in edge_labels[p.alias]:
                    names |= set(self.schema.edge_props.get(lb, {}))
                if p.name not in names:
                    v.append(f"schema-props: {p!r} — no edge label in "
                             f"{sorted(edge_labels[p.alias])} declares "
                             f"property {p.name!r}")
            # aliases minted by PROJECT/GROUP outputs are column names,
            # not schema elements; the alias-scope walk owns those

    # ---------------------------------------------------------- physical plan
    def _check_physical(self, pattern: Pattern, physical: PlanNode,
                        v: list[str]) -> None:
        pat_edges = {e.alias: e for e in pattern.edges}

        def check_edge(e, new_alias: str, bound: set[str],
                       where: str) -> None:
            pe = pat_edges.get(e.alias)
            if pe is None:
                v.append(f"physical-cover: {where} traverses edge "
                         f"{e.alias!r} that is not in the pattern")
                return
            if {e.src, e.dst} != {pe.src, pe.dst}:
                v.append(f"physical-cover: {where} edge {e.alias!r} "
                         f"endpoints ({e.src!r},{e.dst!r}) disagree with "
                         f"the pattern's ({pe.src!r},{pe.dst!r})")
            if new_alias not in (e.src, e.dst):
                v.append(f"physical-cover: {where} edge {e.alias!r} does "
                         f"not touch the alias {new_alias!r} it binds")
                return
            other = e.other(new_alias)
            if other not in bound:
                v.append(f"physical-cover: {where} edge {e.alias!r} "
                         f"anchors on {other!r} which is not bound yet")

        def check_preds(preds, scope: set[str], where: str) -> None:
            for p in preds or ():
                bad = ir.expr_aliases(p) - scope
                if bad:
                    v.append(f"physical-cover: {where} predicate {p!r} "
                             f"references alias(es) {sorted(bad)} not "
                             f"bound at that point")

        def vertex_preds(alias: str):
            pv = pattern.vertices.get(alias)
            return pv.predicates if pv is not None else ()

        def walk(node) -> tuple[set[str], set[str]]:
            """Returns (bound vertex aliases, traversed edge aliases)."""
            if isinstance(node, ScanNode):
                if node.alias not in pattern.vertices:
                    v.append(f"physical-cover: Scan({node.alias!r}) is not "
                             f"a pattern vertex")
                    return {node.alias}, set()
                check_preds(vertex_preds(node.alias), {node.alias},
                            f"Scan({node.alias})")
                return {node.alias}, set()
            if isinstance(node, ExpandNode):
                bound, used = walk(node.child)
                where = f"Expand(+{node.new_alias})"
                if node.new_alias in bound:
                    v.append(f"physical-cover: {where} re-binds an "
                             f"already-bound alias")
                if node.new_alias not in pattern.vertices:
                    v.append(f"physical-cover: {where} binds an alias that "
                             f"is not a pattern vertex")
                if not node.edges:
                    v.append(f"physical-cover: {where} has no edges")
                local = set()
                for e in node.edges:
                    check_edge(e, node.new_alias, bound, where)
                    if e.alias in used:
                        v.append(f"physical-cover: {where} re-traverses "
                                 f"edge {e.alias!r}")
                    local.add(e.alias)
                scope = bound | {node.new_alias} | local
                check_preds(vertex_preds(node.new_alias), scope, where)
                for e in node.edges:
                    check_preds(e.predicates, scope, where)
                return bound | {node.new_alias}, used | local
            if isinstance(node, ExpandChainNode):
                bound, used = walk(node.child)
                return self._check_chain(pattern, node, bound, used,
                                         pat_edges, check_edge, check_preds,
                                         vertex_preds, v)
            if isinstance(node, JoinNode):
                lb, lu = walk(node.left)
                rb, ru = walk(node.right)
                for k in node.keys:
                    if k not in lb or k not in rb:
                        v.append(f"physical-cover: Join key {k!r} is not "
                                 f"bound on both sides "
                                 f"(left={sorted(lb)}, right={sorted(rb)})")
                return lb | rb, lu | ru
            v.append(f"physical-cover: unknown physical node "
                     f"{type(node).__name__}")
            return set(), set()

        bound, used = walk(physical)
        missing_v = set(pattern.vertices) - bound
        if missing_v:
            v.append(f"physical-cover: pattern vertex alias(es) "
                     f"{sorted(missing_v)} are never bound by the plan")
        extra_v = bound - set(pattern.vertices)
        if extra_v:
            v.append(f"physical-cover: plan binds alias(es) "
                     f"{sorted(extra_v)} that are not pattern vertices")
        missing_e = set(pat_edges) - used
        if missing_e:
            v.append(f"physical-cover: pattern edge(s) "
                     f"{sorted(missing_e)} are never traversed — their "
                     f"constraints would be silently dropped")

    def _check_chain(self, pattern, node: ExpandChainNode, bound: set[str],
                     used: set[str], pat_edges, check_edge, check_preds,
                     vertex_preds, v: list[str]) -> tuple[set[str], set[str]]:
        where0 = "ExpandChain"
        if not node.steps:
            v.append(f"chain-contract: {where0} has no steps")
            return bound, used
        cur = set(bound)
        local_edges: set[str] = set()
        last = len(node.steps) - 1
        for i, s in enumerate(node.steps):
            where = f"{where0} step {i} (+{s.alias})"
            if s.from_alias not in cur:
                v.append(f"chain-contract: {where} expands from "
                         f"{s.from_alias!r} which is not bound by the "
                         f"child or an earlier step — hop discontinuity")
            if s.alias in cur:
                v.append(f"chain-contract: {where} re-binds an "
                         f"already-bound alias")
            if {s.edge.src, s.edge.dst} != {s.from_alias, s.alias}:
                v.append(f"chain-contract: {where} edge {s.edge.alias!r} "
                         f"connects ({s.edge.src!r},{s.edge.dst!r}), not "
                         f"({s.from_alias!r},{s.alias!r})")
            check_edge(s.edge, s.alias, cur, where)
            if s.edge.alias in used or s.edge.alias in local_edges:
                v.append(f"chain-contract: {where} re-traverses edge "
                         f"{s.edge.alias!r}")
            local_edges.add(s.edge.alias)
            if s.intersect_edges and i != last:
                v.append(f"chain-contract: {where} carries intersect "
                         f"edges but is not the chain's last step — the "
                         f"WCOJ tail must come last")
            for e in s.intersect_edges:
                check_edge(e, s.alias, cur | {s.alias}, f"{where} intersect")
                if e.alias in used or e.alias in local_edges:
                    v.append(f"chain-contract: {where} re-traverses "
                             f"intersect edge {e.alias!r}")
                local_edges.add(e.alias)
            cur.add(s.alias)
            scope = cur | local_edges
            check_preds(vertex_preds(s.alias), scope, where)
            for e in (s.edge, *s.intersect_edges):
                check_preds(e.predicates, scope, where)
        return cur, used | local_edges

    # --------------------------------------------------- store-level contracts
    def _check_delta_epochs(self, physical: PlanNode, v: list[str]) -> None:
        if self.store is None:
            return
        epoch = getattr(self.store, "compaction_epoch", 0)

        def rec(n):
            if isinstance(n, ExpandChainNode):
                cached = n.__dict__.get("_chain_spec")
                if cached is not None:
                    key = cached[0]
                    if key[0] == id(self.store) and key[1] != epoch:
                        v.append(
                            f"delta-epoch: chain spec memo on "
                            f"ExpandChain(+{'/'.join(s.alias for s in n.steps)})"
                            f" was compiled at compaction epoch {key[1]} "
                            f"but the store is at epoch {epoch} — stale "
                            f"CSR topology")
                rec(n.child)
            elif isinstance(n, ExpandNode):
                rec(n.child)
            elif isinstance(n, JoinNode):
                rec(n.left)
                rec(n.right)

        rec(physical)

    def _built_ops(self):
        if self.spec is None or self.store is None:
            return None
        cache = self.store.__dict__.get("_physical_ops_cache")
        if not cache:
            return None
        return cache.get(self.spec.name)

    def _check_capacities(self, v: list[str]) -> None:
        ops = self._built_ops()
        chains = getattr(ops, "_chains", None)
        if not chains:
            return
        for prog in chains.values():
            caps = getattr(prog, "caps", None)
            if caps is None:
                continue
            for c in caps:
                if c < 1 or (c & (c - 1)):
                    v.append(f"capacity-pow2: fused chain capacity "
                             f"schedule {caps} contains non-power-of-two "
                             f"bucket {c}")
                    break
            for key in getattr(prog, "_progs", {}):
                kcaps = key[0]
                if (len(kcaps) == len(caps)
                        and any(k > c for k, c in zip(kcaps, caps))):
                    v.append(f"capacity-pow2: cached chain program compiled "
                             f"for caps {kcaps} exceeds the handle's "
                             f"current caps {caps} — capacity schedule "
                             f"must grow monotonically")

    def _check_operator_contracts(self, v: list[str]) -> None:
        ops = self._built_ops()
        if ops is None:
            return
        report = ops.__dict__.get("_dtype_contract_failures")
        if report is None:
            from repro.core.physical_spec import dtype_contract_failures
            report = tuple(dtype_contract_failures(ops))
            ops.__dict__["_dtype_contract_failures"] = report
        for f in report:
            v.append(f"operator-contracts: {ops.name}: {f}")
