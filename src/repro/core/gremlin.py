"""Gremlin-style traversal builder (paper §4.2's second frontend).

A fluent builder that constructs the same unified-IR LogicalPlan the Cypher
parser produces — demonstrating the IR's language independence:

    g(schema).V().as_("v1").out().as_("v2").out("LOCATEDIN", "PRODUCEDIN") \
        .as_("v3", types=["PLACE"]) \
        .where(Cmp("=", Prop("v3", "name"), Lit("China"))) \
        .group_count("v1").plan()
"""
from __future__ import annotations

from repro.core import ir
from repro.core.pattern import BOTH, IN, OUT, Pattern, PatternEdge
from repro.core.schema import GraphSchema


class GremlinTraversal:
    def __init__(self, schema: GraphSchema):
        self.schema = schema
        self.pattern = Pattern()
        self._preds: list = []
        self._anon = 0
        self._cur: str | None = None

    def _fresh(self, p):
        self._anon += 1
        return f"_{p}{self._anon}"

    def V(self, *types: str) -> "GremlinTraversal":
        alias = self._fresh("v")
        self.pattern.add_vertex(alias, self.schema.vertex_constraint(list(types)))
        self._cur = alias
        return self

    def _expand(self, labels, direction):
        # materialize target immediately with an anonymous alias; `as_` renames
        src = self._cur
        dst = self._fresh("v")
        self.pattern.add_vertex(dst, self.schema.all_vertex_types())
        e = PatternEdge(self._fresh("e"), src, dst,
                        self.schema.edge_constraint(list(labels) or None),
                        direction, 1)
        self.pattern.add_edge(e)
        self._cur = dst
        return self

    def out(self, *labels):
        return self._expand(labels, OUT)

    def in_(self, *labels):
        return self._expand(labels, IN)

    def both(self, *labels):
        return self._expand(labels, BOTH)

    def as_(self, name: str, types=None) -> "GremlinTraversal":
        """Rename the current anonymous vertex; optionally constrain types."""
        old = self._cur
        if name in self.pattern.vertices:
            # closing a cycle: merge old into existing alias
            tgt = self.pattern.vertices[name]
            ov = self.pattern.vertices.pop(old)
            tgt.types = tgt.types & ov.types
            for e in self.pattern.edges:
                if e.src == old:
                    e.src = name
                if e.dst == old:
                    e.dst = name
        else:
            v = self.pattern.vertices.pop(old)
            v.alias = name
            self.pattern.vertices[name] = v
            for e in self.pattern.edges:
                if e.src == old:
                    e.src = name
                if e.dst == old:
                    e.dst = name
        if types:
            v = self.pattern.vertices[name]
            v.types = v.types & self.schema.vertex_constraint(list(types))
        self._cur = name
        return self

    def select(self, name: str) -> "GremlinTraversal":
        if name not in self.pattern.vertices:
            raise KeyError(name)
        self._cur = name
        return self

    def where(self, pred) -> "GremlinTraversal":
        self._preds.append(pred)
        return self

    def has(self, prop: str, value) -> "GremlinTraversal":
        self._preds.append(ir.Cmp("=", ir.Prop(self._cur, prop), ir.Lit(value)))
        return self

    # -- terminal steps -----------------------------------------------------
    def _base_ops(self):
        ops: list = [ir.MatchPattern(self.pattern)]
        pred = ir.make_and(self._preds)
        if pred is not None:
            ops.append(ir.Select(pred))
        return ops

    def count(self, alias: str | None = None) -> ir.LogicalPlan:
        ops = self._base_ops()
        arg = ir.Var(alias or self._cur)
        ops.append(ir.GroupBy([], [(ir.Agg("COUNT", arg), "count")]))
        return ir.LogicalPlan(ops)

    def group_count(self, alias: str) -> ir.LogicalPlan:
        ops = self._base_ops()
        ops.append(ir.GroupBy([(ir.Var(alias), alias)],
                              [(ir.Agg("COUNT", None), "count")]))
        return ir.LogicalPlan(ops)

    def values(self, *items) -> ir.LogicalPlan:
        ops = self._base_ops()
        ops.append(ir.Project([(it, repr(it)) for it in items]))
        return ir.LogicalPlan(ops)


def g(schema: GraphSchema) -> GremlinTraversal:
    return GremlinTraversal(schema)
