"""Gremlin-style traversal frontend (paper §4.2's second frontend).

A thin sugar layer over ``GraphIrBuilder`` (DESIGN.md §3) — every step
delegates to the unified builder, demonstrating the IR's language
independence: the Cypher parser and this traversal produce canonically
identical GIR for equivalent queries.

    g(schema).V().as_("v1").out().as_("v2").out("LOCATEDIN", "PRODUCEDIN") \
        .as_("v3", types=["PLACE"]) \
        .where(Cmp("=", Prop("v3", "name"), Lit("China"))) \
        .group_count("v1")

Classic terminal steps (``count`` / ``group_count`` / ``values``) return the
``LogicalPlan`` directly.  For relational tails (ORDER BY / LIMIT), chain
``group_by`` / ``project`` / ``order_by`` / ``limit`` and finish with
``plan()``.  Late-bound parameters come from ``.param(name)``.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.ir_builder import GraphIrBuilder
from repro.core.pattern import BOTH, IN, OUT
from repro.core.schema import GraphSchema


class GremlinTraversal:
    def __init__(self, schema: GraphSchema, params: dict | None = None):
        self.b = GraphIrBuilder(schema, params)

    # -- pattern steps ------------------------------------------------------
    def V(self, *types: str) -> "GremlinTraversal":
        self.b.scan(None, list(types) or None)
        return self

    def _expand(self, labels, direction):
        # materialize target immediately with an anonymous alias; `as_`
        # renames (alias management lives in the builder)
        self.b.expand(list(labels) or None, direction=direction)
        self.b.get_vertex()
        return self

    def out(self, *labels):
        return self._expand(labels, OUT)

    def in_(self, *labels):
        return self._expand(labels, IN)

    def both(self, *labels):
        return self._expand(labels, BOTH)

    def out_path(self, hops, *labels, direction: str = OUT):
        """Multi-hop expansion (EXPAND_PATH); ``hops`` may be a structural
        parameter name bound via the traversal's ``params``."""
        self.b.expand_path(list(labels) or None, hops=hops,
                           direction=direction)
        self.b.get_vertex()
        return self

    def as_(self, name: str, types=None) -> "GremlinTraversal":
        """Rename the current anonymous vertex; optionally constrain types."""
        self.b.alias_as(name, types)
        return self

    def select(self, name: str) -> "GremlinTraversal":
        self.b.at(name)
        return self

    def where(self, pred) -> "GremlinTraversal":
        self.b.where(pred)
        return self

    def has(self, prop: str, value) -> "GremlinTraversal":
        val = value if isinstance(value, (ir.Param, ir.Lit)) else ir.Lit(value)
        self.b.where(ir.Cmp("=", ir.Prop(self.b.current, prop), val))
        return self

    def param(self, name: str) -> ir.Param:
        return self.b.param(name)

    # -- chainable relational steps (finish with .plan()) -------------------
    def project(self, items, distinct: bool = False) -> "GremlinTraversal":
        self.b.project(items, distinct=distinct)
        return self

    def group_by(self, keys, aggs) -> "GremlinTraversal":
        self.b.group(keys, aggs)
        return self

    def order_by(self, *items, limit: int | None = None) -> "GremlinTraversal":
        self.b.order(list(items), limit=limit)
        return self

    def limit(self, n: int) -> "GremlinTraversal":
        self.b.limit(n)
        return self

    def plan(self) -> ir.LogicalPlan:
        return self.b.build()

    # -- classic terminal steps --------------------------------------------
    def count(self, alias: str | None = None,
              as_: str = "count") -> ir.LogicalPlan:
        arg = ir.Var(alias or self.b.current)
        return self.b.group([], [(ir.Agg("COUNT", arg), as_)]).build()

    def group_count(self, alias: str, as_: str = "count") -> ir.LogicalPlan:
        return self.b.group([(ir.Var(alias), alias)],
                            [(ir.Agg("COUNT", None), as_)]).build()

    def values(self, *items) -> ir.LogicalPlan:
        return self.b.project(list(items)).build()


def g(schema: GraphSchema, params: dict | None = None) -> GremlinTraversal:
    return GremlinTraversal(schema, params)
