"""GLogue — high-order statistics provider (paper §3, §5.3.2, after [33]).

A hierarchical catalogue of BasicPatterns up to ``k`` vertices with their
exact frequencies in the data graph. Size-1/2 frequencies come straight from
the store; 2-edge paths are computed by vectorized degree dot-products;
triangles (3-cycles) by running the engine. Lookup keys are
alias-permutation-canonicalized so any isomorphic query sub-pattern hits.

Only BasicPatterns are stored (as in the paper); UnionPattern frequencies are
*estimated* on top via Eq. 4/5/6 in ``repro.core.cardinality``, which may
cache computed union frequencies back into GLogue (Algorithm 2 lines 15-17).
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.pattern import BOTH, IN, OUT, Pattern, PatternEdge, PatternVertex
from repro.core.schema import EdgeTriple, GraphSchema
from repro.graphdb.storage import GraphStore


def canonical_key(pattern: Pattern):
    """Isomorphism-canonical key for small patterns: minimum over alias
    permutations of the anonymized structural encoding."""
    names = sorted(pattern.vertices)
    best = None
    for perm in itertools.permutations(range(len(names))):
        relabel = {names[i]: f"x{perm[i]}" for i in range(len(names))}
        vs = tuple(sorted((relabel[a], tuple(sorted(v.types)))
                          for a, v in pattern.vertices.items()))
        es = []
        for e in pattern.edges:
            s, d = relabel[e.src], relabel[e.dst]
            if e.direction == BOTH and s > d:
                s, d = d, s
            dirn = e.direction
            # normalize orientation: store IN edges as OUT of the other side
            if dirn == IN:
                s, d, dirn = d, s, OUT
            es.append((s, d, dirn, tuple(sorted(t.label for t in e.triples)),
                       tuple(sorted(map(repr, e.triples)))))
        key = (vs, tuple(sorted(es)))
        if best is None or key < best:
            best = key
    return best


class GLogue:
    def __init__(self, store: GraphStore, k: int = 3,
                 count_triangles: bool = True):
        self.store = store
        self.schema: GraphSchema = store.schema
        self.k = k
        self.freq: dict = {}          # canonical key -> frequency (float)
        self._build(count_triangles)

    # --------------------------------------------------------------- lookups
    def get_freq(self, pattern: Pattern) -> float | None:
        return self.freq.get(canonical_key(pattern))

    def put_freq(self, pattern: Pattern, f: float) -> None:
        """Cache an estimated (e.g. union) frequency — Alg.2 lines 15-17."""
        self.freq[canonical_key(pattern)] = f

    # ---------------------------------------------------------------- build
    def _build(self, count_triangles: bool):
        st = self.store
        # size 1: vertices
        for t in self.schema.vertex_types:
            p = Pattern()
            p.add_vertex("a", frozenset({t}))
            self.freq[canonical_key(p)] = float(st.v_count[t])
        # size 2: single edges
        for tr, csr in st.out_csr.items():
            p = Pattern()
            p.add_vertex("a", frozenset({tr.src}))
            p.add_vertex("b", frozenset({tr.dst}))
            p.add_edge(PatternEdge("e", "a", "b", frozenset({tr}), OUT))
            self.freq[canonical_key(p)] = float(csr.nnz)
        if self.k < 3:
            return
        # size 3, 2-edge paths: F = sum over shared vertex of deg1*deg2.
        triples = sorted(st.out_csr, key=repr)
        for t1, t2 in itertools.product(triples, triples):
            # shared vertex can be: t1.src==t2.src, t1.src==t2.dst,
            # t1.dst==t2.src, t1.dst==t2.dst
            for side1, side2 in (("src", "src"), ("src", "dst"),
                                 ("dst", "src"), ("dst", "dst")):
                if getattr(t1, side1) != getattr(t2, side2):
                    continue
                p = Pattern()
                shared_t = getattr(t1, side1)
                p.add_vertex("m", frozenset({shared_t}))
                p.add_vertex("a", frozenset(
                    {t1.dst if side1 == "src" else t1.src}))
                p.add_vertex("b", frozenset(
                    {t2.dst if side2 == "src" else t2.src}))
                # edge 1 between m and a
                if side1 == "src":
                    p.add_edge(PatternEdge("e1", "m", "a",
                                           frozenset({t1}), OUT))
                else:
                    p.add_edge(PatternEdge("e1", "a", "m",
                                           frozenset({t1}), OUT))
                if side2 == "src":
                    p.add_edge(PatternEdge("e2", "m", "b",
                                           frozenset({t2}), OUT))
                else:
                    p.add_edge(PatternEdge("e2", "b", "m",
                                           frozenset({t2}), OUT))
                key = canonical_key(p)
                if key in self.freq:
                    continue
                d1 = self._degrees(t1, side1)
                d2 = self._degrees(t2, side2)
                f = float(np.dot(d1.astype(np.float64), d2.astype(np.float64)))
                # same triple both edges from the same vertex would count the
                # (e1==e2) pairing too; homomorphism semantics keeps it.
                self.freq[key] = f
        if count_triangles:
            self._count_triangles(triples)

    def _degrees(self, triple: EdgeTriple, side: str) -> np.ndarray:
        csr = (self.store.out_csr if side == "src" else
               self.store.in_csr)[triple]
        return np.diff(csr.indptr)

    def _count_triangles(self, triples):
        """Exact triangle-pattern frequencies via the engine (size-3 cycles).
        Enumerates type-compatible triple combos; counts via one WCOJ plan."""
        from repro.core.physical import ExpandNode, ScanNode
        from repro.graphdb.engine import Engine, ExecStats

        eng = Engine(self.store)
        seen = set()
        for t1, t2, t3 in itertools.product(triples, triples, triples):
            # orientationless triangle over vertex types A,B,C:
            #   e1 connects (a,b), e2 connects (b,c), e3 connects (a,c)
            for o1, o2, o3 in itertools.product((0, 1), repeat=3):
                A, B = (t1.src, t1.dst) if o1 == 0 else (t1.dst, t1.src)
                B2, C = (t2.src, t2.dst) if o2 == 0 else (t2.dst, t2.src)
                A2, C2 = (t3.src, t3.dst) if o3 == 0 else (t3.dst, t3.src)
                if B != B2 or A != A2 or C != C2:
                    continue
                p = Pattern()
                p.add_vertex("a", frozenset({A}))
                p.add_vertex("b", frozenset({B}))
                p.add_vertex("c", frozenset({C}))
                p.add_edge(PatternEdge("e1", "a", "b", frozenset({t1}),
                                       OUT if o1 == 0 else IN))
                p.add_edge(PatternEdge("e2", "b", "c", frozenset({t2}),
                                       OUT if o2 == 0 else IN))
                p.add_edge(PatternEdge("e3", "a", "c", frozenset({t3}),
                                       OUT if o3 == 0 else IN))
                key = canonical_key(p)
                if key in seen:
                    continue
                seen.add(key)
                plan = ExpandNode(
                    ExpandNode(ScanNode("a"), "b",
                               [p.edges[0]]), "c", [p.edges[1], p.edges[2]])
                stats = ExecStats()
                try:
                    tbl = eng.exec_pattern(p, plan, stats)
                    self.freq[key] = float(tbl.nrows)
                except RuntimeError:
                    pass  # blow-up cap; leave to estimation
