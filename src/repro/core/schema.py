"""Property-graph schema and type constraints (paper §2.1).

A schema lists vertex types and edge *triples* (src_type, label, dst_type).
Type constraints on pattern elements follow the paper's three kinds:

- BasicType: a single type;
- UnionType: a set of types ("A|B");
- AllType:   every type in the schema.

Internally every constraint is a frozenset of basic names; vertex constraints
hold vertex-type names, edge constraints hold *triples* — the paper models an
edge type as a triplet ``(src_type, label, dst_type)`` (§4.1, Edge datatype),
which is what makes the Algorithm-1 fixpoint precise.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class EdgeTriple:
    src: str
    label: str
    dst: str

    def __repr__(self) -> str:
        return f"{self.src}-[{self.label}]->{self.dst}"


@dataclasses.dataclass(frozen=True)
class GraphSchema:
    """Vertex types, edge triples and their property signatures."""

    vertex_types: tuple[str, ...]
    edge_triples: tuple[EdgeTriple, ...]
    vertex_props: Mapping[str, Mapping[str, str]] = dataclasses.field(
        default_factory=dict)
    edge_props: Mapping[str, Mapping[str, str]] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        vt = set(self.vertex_types)
        for t in self.edge_triples:
            if t.src not in vt or t.dst not in vt:
                raise ValueError(f"edge triple {t} references unknown vertex type")

    # -- lookups used by Algorithm 1 -------------------------------------
    def all_vertex_types(self) -> frozenset[str]:
        return frozenset(self.vertex_types)

    def all_edge_triples(self) -> frozenset[EdgeTriple]:
        return frozenset(self.edge_triples)

    def edge_labels(self) -> frozenset[str]:
        return frozenset(t.label for t in self.edge_triples)

    def triples_with_label(self, labels: frozenset[str]) -> frozenset[EdgeTriple]:
        return frozenset(t for t in self.edge_triples if t.label in labels)

    def out_triples(self, vtype: str) -> frozenset[EdgeTriple]:
        return frozenset(t for t in self.edge_triples if t.src == vtype)

    def in_triples(self, vtype: str) -> frozenset[EdgeTriple]:
        return frozenset(t for t in self.edge_triples if t.dst == vtype)

    def vertex_prop_dtype(self, vtype: str, prop: str) -> str | None:
        return self.vertex_props.get(vtype, {}).get(prop)

    # -- constraint constructors ------------------------------------------
    def vertex_constraint(self, spec: Sequence[str] | None) -> frozenset[str]:
        """BasicType (len==1), UnionType (len>1) or AllType (None/empty)."""
        if not spec:
            return self.all_vertex_types()
        unknown = set(spec) - set(self.vertex_types)
        if unknown:
            raise ValueError(f"unknown vertex types {sorted(unknown)}")
        return frozenset(spec)

    def edge_constraint(self, labels: Sequence[str] | None) -> frozenset[EdgeTriple]:
        if not labels:
            return self.all_edge_triples()
        unknown = set(labels) - set(self.edge_labels())
        if unknown:
            raise ValueError(f"unknown edge labels {sorted(unknown)}")
        return self.triples_with_label(frozenset(labels))


def ldbc_schema() -> GraphSchema:
    """The LDBC SNB schema subset used throughout the paper's experiments."""
    E = EdgeTriple
    return GraphSchema(
        vertex_types=(
            "PERSON", "POST", "COMMENT", "FORUM", "TAG", "TAGCLASS",
            "CITY", "COUNTRY", "ORGANISATION",
        ),
        edge_triples=(
            E("PERSON", "KNOWS", "PERSON"),
            E("PERSON", "LIKES", "POST"),
            E("PERSON", "LIKES", "COMMENT"),
            E("PERSON", "HASINTEREST", "TAG"),
            E("PERSON", "ISLOCATEDIN", "CITY"),
            E("PERSON", "WORKAT", "ORGANISATION"),
            E("POST", "HASCREATOR", "PERSON"),
            E("COMMENT", "HASCREATOR", "PERSON"),
            E("COMMENT", "REPLYOF", "POST"),
            E("COMMENT", "REPLYOF", "COMMENT"),
            E("POST", "HASTAG", "TAG"),
            E("COMMENT", "HASTAG", "TAG"),
            E("FORUM", "CONTAINEROF", "POST"),
            E("FORUM", "HASMEMBER", "PERSON"),
            E("FORUM", "HASMODERATOR", "PERSON"),
            E("FORUM", "HASTAG", "TAG"),
            E("TAG", "HASTYPE", "TAGCLASS"),
            E("CITY", "ISPARTOF", "COUNTRY"),
            E("ORGANISATION", "ISLOCATEDIN", "COUNTRY"),
        ),
        vertex_props={
            "PERSON": {"id": "int", "firstName": "str", "creationDate": "int"},
            "POST": {"id": "int", "length": "int", "creationDate": "int"},
            "COMMENT": {"id": "int", "length": "int", "creationDate": "int"},
            "FORUM": {"id": "int", "creationDate": "int"},
            "TAG": {"id": "int", "name": "str"},
            "TAGCLASS": {"id": "int", "name": "str"},
            "CITY": {"id": "int", "name": "str"},
            "COUNTRY": {"id": "int", "name": "str"},
            "ORGANISATION": {"id": "int", "name": "str"},
        },
        edge_props={"KNOWS": {"creationDate": "int"}},
    )


def motivating_schema() -> GraphSchema:
    """Fig. 1(a): Person/Product/Place with Purchases/LocatedIn/ProducedIn/Knows."""
    E = EdgeTriple
    return GraphSchema(
        vertex_types=("PERSON", "PRODUCT", "PLACE"),
        edge_triples=(
            E("PERSON", "KNOWS", "PERSON"),
            E("PERSON", "PURCHASES", "PRODUCT"),
            E("PERSON", "LOCATEDIN", "PLACE"),
            E("PRODUCT", "PRODUCEDIN", "PLACE"),
        ),
        vertex_props={
            "PERSON": {"id": "int", "name": "str"},
            "PRODUCT": {"id": "int", "name": "str"},
            "PLACE": {"id": "int", "name": "str"},
        },
    )
