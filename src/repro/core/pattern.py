"""Pattern graphs (paper §2.1): small connected graphs with type constraints.

``Pattern`` is the PATTERN structure built from a MATCH_PATTERN (§4.2); it is
what type inference (Algorithm 1) and the CBO (Algorithm 2) operate on.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable

from repro.core.schema import EdgeTriple, GraphSchema

OUT, IN, BOTH = "OUT", "IN", "BOTH"


@dataclasses.dataclass
class PatternVertex:
    alias: str
    types: frozenset[str]                 # vertex-type constraint
    predicates: list = dataclasses.field(default_factory=list)

    def is_basic(self) -> bool:
        return len(self.types) == 1


@dataclasses.dataclass
class PatternEdge:
    alias: str
    src: str                              # pattern-vertex alias
    dst: str
    triples: frozenset[EdgeTriple]        # edge-type constraint (as triples)
    direction: str = OUT                  # OUT: src->dst, IN: dst->src, BOTH
    hops: int = 1                         # >1 == EXPAND_PATH sugar
    predicates: list = dataclasses.field(default_factory=list)

    def labels(self) -> frozenset[str]:
        return frozenset(t.label for t in self.triples)

    def other(self, v: str) -> str:
        return self.dst if v == self.src else self.src


@dataclasses.dataclass
class Pattern:
    """A connected pattern graph; vertices keyed by alias."""

    vertices: dict[str, PatternVertex] = dataclasses.field(default_factory=dict)
    edges: list[PatternEdge] = dataclasses.field(default_factory=list)

    # -- construction ------------------------------------------------------
    def add_vertex(self, alias: str, types: frozenset[str]) -> PatternVertex:
        if alias in self.vertices:
            # Same alias re-used in MATCH: intersect constraints.
            v = self.vertices[alias]
            v.types = v.types & types if v.types else types
            return v
        v = PatternVertex(alias, types)
        self.vertices[alias] = v
        return v

    def add_edge(self, edge: PatternEdge) -> PatternEdge:
        self.edges.append(edge)
        return edge

    # -- queries -------------------------------------------------------------
    def adjacent(self, alias: str) -> list[PatternEdge]:
        return [e for e in self.edges if alias in (e.src, e.dst)]

    def neighbors(self, alias: str) -> list[str]:
        return [e.other(alias) for e in self.adjacent(alias)]

    def degree(self, alias: str) -> int:
        return len(self.adjacent(alias))

    def n_vertices(self) -> int:
        return len(self.vertices)

    def n_edges(self) -> int:
        return len(self.edges)

    def is_basic(self) -> bool:
        """BasicPattern: every vertex and edge carries a single type (§2.1)."""
        return all(v.is_basic() for v in self.vertices.values()) and all(
            len(e.triples) == 1 for e in self.edges)

    def is_connected(self) -> bool:
        if not self.vertices:
            return False
        seen: set[str] = set()
        stack = [next(iter(self.vertices))]
        while stack:
            v = stack.pop()
            if v in seen:
                continue
            seen.add(v)
            stack.extend(self.neighbors(v))
        return seen == set(self.vertices)

    def copy(self) -> "Pattern":
        p = Pattern()
        for a, v in self.vertices.items():
            p.vertices[a] = PatternVertex(a, v.types, list(v.predicates))
        for e in self.edges:
            p.edges.append(PatternEdge(e.alias, e.src, e.dst, e.triples,
                                       e.direction, e.hops, list(e.predicates)))
        return p

    def induced(self, aliases: Iterable[str]) -> "Pattern":
        """Induced sub-pattern on the given vertex aliases."""
        keep = set(aliases)
        p = Pattern()
        for a in keep:
            v = self.vertices[a]
            p.vertices[a] = PatternVertex(a, v.types, list(v.predicates))
        for e in self.edges:
            if e.src in keep and e.dst in keep:
                p.edges.append(PatternEdge(e.alias, e.src, e.dst, e.triples,
                                           e.direction, e.hops,
                                           list(e.predicates)))
        return p

    # -- canonical keys for PlanMap / GLogue --------------------------------
    def vertex_key(self) -> frozenset[str]:
        return frozenset(self.vertices)

    def canonical_key(self):
        """A hashable structural key: sorted (alias,type)+edges. Aliases make
        this exact for sub-patterns of one query pattern (the CBO use case)."""
        vs = tuple(sorted((a, tuple(sorted(v.types)))
                          for a, v in self.vertices.items()))
        es = tuple(sorted((e.src, e.dst, e.direction,
                           tuple(sorted(map(repr, e.triples)))) for e in self.edges))
        return (vs, es)

    def connected_induced_subsets(self) -> list[frozenset[str]]:
        """All vertex subsets whose induced sub-pattern is connected."""
        names = sorted(self.vertices)
        out = []
        for r in range(1, len(names) + 1):
            for combo in itertools.combinations(names, r):
                if self.induced(combo).is_connected():
                    out.append(frozenset(combo))
        return out

    def __repr__(self) -> str:
        vs = ",".join(f"({a}:{'|'.join(sorted(v.types))})"
                      for a, v in sorted(self.vertices.items()))
        es = ",".join(f"{e.src}-[{'|'.join(sorted(e.labels()))}:{e.direction}]-{e.dst}"
                      for e in self.edges)
        return f"Pattern<{vs} ; {es}>"


def expand_path_edges(pattern: Pattern, schema: GraphSchema) -> Pattern:
    """Rewrite hops>1 edges (EXPAND_PATH) into chains of 1-hop edges with
    anonymous intermediate vertices — the composite-op unfolding of §4.1."""
    p = Pattern()
    for a, v in pattern.vertices.items():
        p.vertices[a] = PatternVertex(a, v.types, list(v.predicates))
    anon = 0
    for e in pattern.edges:
        if e.hops <= 1:
            p.edges.append(dataclasses.replace(e, predicates=list(e.predicates)))
            continue
        prev = e.src
        for h in range(e.hops):
            last = h == e.hops - 1
            nxt = e.dst if last else f"__{e.alias}_h{h}_{anon}"
            if not last:
                p.vertices[nxt] = PatternVertex(nxt, schema.all_vertex_types())
            p.edges.append(PatternEdge(f"{e.alias}#{h}", prev, nxt, e.triples,
                                       e.direction, 1))
            prev = nxt
        anon += 1
    return p
