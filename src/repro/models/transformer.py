"""Decoder-only transformer LM family.

One configurable implementation covers all five assigned LM architectures:
dense (qwen2.5-32b, phi3-medium) and MoE (olmoe-1b-7b, moonshot-16b-a3b) MLPs,
GQA with optional QKV bias, RoPE, gemma2-27b extras (alternating local/global
attention, attn+final logit soft-capping, pre+post RMSNorm, zero-centered
norm scales).

Attention is computed block-wise with an online-softmax accumulator (a
pure-jnp flash formulation) so 32k prefill compiles with bounded live memory;
`repro.kernels.flash_attention` is the Pallas twin for TPU. Layers run under
``lax.scan`` (+ remat) so the HLO stays one-layer-sized — that is what keeps
512-device dry-run compiles fast.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import (apply_rope, cross_entropy, dense_init,
                                 rms_norm, rope_angles, softcap)
from repro.models.sharding import shard_hint


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # gemma2 extras
    layer_pattern: str = "global"      # "global" | "local_global"
    window: int = 4096
    attn_softcap: float | None = None
    final_softcap: float | None = None
    post_norms: bool = False
    zero_centered_norm: bool = False
    # compute
    dtype: Any = jnp.bfloat16
    block_q: int = 512
    block_kv: int = 1024
    remat: bool = True
    # perf knobs (EXPERIMENTS.md §Perf):
    causal_block_skip: bool = False    # skip fully-masked causal kv blocks
    attn_remat: bool = False           # recompute p-matrices in backward
    attn_p_bf16: bool = False          # bf16 probabilities for the PV matmul
    aux_loss_weight: float = 0.01

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def is_local_flags(self) -> jnp.ndarray:
        """Per-layer bool: sliding-window layer? gemma2 alternates
        local(even)/global(odd)."""
        if self.layer_pattern == "local_global":
            return jnp.arange(self.n_layers) % 2 == 0
        return jnp.zeros(self.n_layers, dtype=bool)

    # ------------------------------------------------------------- analytics
    def param_count(self) -> int:
        D, H, K, hd, F, V, L = (self.d_model, self.n_heads, self.n_kv_heads,
                                self.hd, self.d_ff, self.vocab_size,
                                self.n_layers)
        attn = D * H * hd + 2 * D * K * hd + H * hd * D
        if self.moe:
            mlp = self.n_experts * 3 * D * F + D * self.n_experts
        else:
            mlp = 3 * D * F
        norms = (4 if self.post_norms else 2) * D
        return L * (attn + mlp + norms) + 2 * V * D + D

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        dead = L * (self.n_experts - self.top_k) * 3 * D * F
        return self.param_count() - dead

    def train_flops(self, batch: int, seq: int) -> float:
        """6*N_active*D model flops (the §Roofline MODEL_FLOPS convention)."""
        return 6.0 * self.active_param_count() * batch * seq

    def decode_flops(self, batch: int, kv_len: int) -> float:
        """Per decode token: 2*N_active + attention reads."""
        attn = (4.0 * self.n_layers * self.n_kv_heads * self.hd * kv_len
                * (self.n_heads // self.n_kv_heads))
        return batch * (2.0 * self.active_param_count() + attn)


# ============================================================== init


def init_params(cfg: TransformerConfig, rng: jax.Array) -> dict:
    D, H, K, hd, F, V, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                            cfg.d_ff, cfg.vocab_size, cfg.n_layers)
    ks = jax.random.split(rng, 12)
    dt = jnp.float32  # master params fp32; compute casts to cfg.dtype

    def stack(key, shape, scale=None):
        return dense_init(key, (L,) + shape, scale, dt)

    attn = {
        "wq": stack(ks[0], (D, H * hd)),
        "wk": stack(ks[1], (D, K * hd)),
        "wv": stack(ks[2], (D, K * hd)),
        "wo": stack(ks[3], (H * hd, D)),
    }
    if cfg.qkv_bias:
        attn["bq"] = jnp.zeros((L, H * hd), dt)
        attn["bk"] = jnp.zeros((L, K * hd), dt)
        attn["bv"] = jnp.zeros((L, K * hd), dt)
    if cfg.moe:
        E = cfg.n_experts
        mlp = {
            "router": stack(ks[4], (D, E)),
            "w1": dense_init(ks[5], (L, E, D, F), 1.0 / math.sqrt(D), dt),
            "w3": dense_init(ks[6], (L, E, D, F), 1.0 / math.sqrt(D), dt),
            "w2": dense_init(ks[7], (L, E, F, D), 1.0 / math.sqrt(F), dt),
        }
    else:
        mlp = {
            "w1": stack(ks[5], (D, F)),
            "w3": stack(ks[6], (D, F)),
            "w2": dense_init(ks[7], (L, F, D), 1.0 / math.sqrt(F), dt),
        }
    layers = {
        "attn": attn, "mlp": mlp,
        "ln1": jnp.zeros((L, D), dt) if cfg.zero_centered_norm
        else jnp.ones((L, D), dt),
        "ln2": jnp.zeros((L, D), dt) if cfg.zero_centered_norm
        else jnp.ones((L, D), dt),
    }
    if cfg.post_norms:
        layers["ln1_post"] = jnp.zeros((L, D), dt)
        layers["ln2_post"] = jnp.zeros((L, D), dt)
    return {
        "embed": dense_init(ks[8], (V, D), 1.0, dt),
        "head": dense_init(ks[9], (D, V), None, dt),
        "final_norm": jnp.zeros((D,), dt) if cfg.zero_centered_norm
        else jnp.ones((D,), dt),
    } | {"layers": layers}


# ====================================================== attention


def _block_attention(q, k, v, cfg: TransformerConfig, q_start, kv_len,
                     is_local, window_override=None):
    """Online-softmax attention over kv blocks.

    q: [B, Sq, K, G, hd]   (grouped heads)
    k,v: [B, Skv, K, hd]
    q_start: global position of q[0] (traced scalar ok)
    kv_len: number of valid kv positions (traced ok)
    is_local: traced bool — apply sliding window of cfg.window
    Returns [B, Sq, K, G, hd].
    """
    B, Sq, Kh, G, hd = q.shape
    Skv = k.shape[1]
    bkv = min(cfg.block_kv, Skv)
    n_blocks = (Skv + bkv - 1) // bkv
    pad = n_blocks * bkv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blocks, bkv, Kh, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, bkv, Kh, hd).transpose(1, 0, 2, 3, 4)

    scale = 1.0 / math.sqrt(hd)
    # q_start / kv_len may be scalars or per-batch [B] (serving slots)
    q_start = jnp.broadcast_to(jnp.asarray(q_start), (B,))
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len), (B,))
    q_pos = q_start[:, None] + jnp.arange(Sq)[None, :]        # [B, Sq]
    window = jnp.where(is_local, cfg.window,
                       jnp.asarray(1 << 30, jnp.int32))
    if window_override is not None:
        window = window_override

    m0 = jnp.full((B, Kh, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Kh, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Kh, G, Sq, hd), jnp.float32)

    def blk_update(m, l, acc, qv, kblk, vblk, qp, kv_start):
        """One online-softmax update; qv [B, sq, K, G, hd]."""
        kv_pos = kv_start + jnp.arange(kblk.shape[1])
        s = jnp.einsum("bqkgd,bskd->bkgqs", qv.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        s = softcap(s, cfg.attn_softcap)
        mask = (kv_pos[None, None, :] <= qp[:, :, None]) \
            & (kv_pos[None, None, :] > qp[:, :, None] - window) \
            & (kv_pos[None, None, :] < kv_len[:, None, None])
        s = jnp.where(mask[:, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        if cfg.attn_p_bf16:
            p = p.astype(jnp.bfloat16)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vblk.astype(p.dtype))
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        return m_new, l_new, acc_new

    use_skip = (cfg.causal_block_skip and Sq == Skv and Sq > 1
                and Sq % bkv == 0 and window_override is None)
    if use_skip:
        # static triangular pair-scan: only causal (qi, ki<=qi) block pairs
        # are computed — halves attention flops AND score-matrix traffic.
        bq = bkv
        n_q = Sq // bq
        qb = q.reshape(B, n_q, bq, Kh, G, hd).transpose(1, 0, 2, 3, 4, 5)
        pairs = [(qi, ki) for qi in range(n_q) for ki in range(qi + 1)]
        q_idx = jnp.asarray([p_[0] for p_ in pairs], jnp.int32)
        kv_idx = jnp.asarray([p_[1] for p_ in pairs], jnp.int32)
        first = jnp.asarray([p_[1] == 0 for p_ in pairs])
        last = jnp.asarray([p_[0] == p_[1] for p_ in pairs])

        mq0 = jnp.full((B, Kh, G, bq), -1e30, jnp.float32)
        lq0 = jnp.zeros((B, Kh, G, bq), jnp.float32)
        aq0 = jnp.zeros((B, Kh, G, bq, hd), jnp.float32)
        out0 = jnp.zeros((n_q, B, Kh, G, bq, hd), jnp.float32)

        def pair_body(carry, xs):
            m, l, acc, out = carry
            qi, ki, is_first, is_last = xs
            m = jnp.where(is_first, mq0, m)
            l = jnp.where(is_first, lq0, l)
            acc = jnp.where(is_first, aq0, acc)
            qv = jnp.take(qb, qi, axis=0)            # [B, bq, K, G, hd]
            kblk = jnp.take(kb, ki, axis=0)
            vblk = jnp.take(vb, ki, axis=0)
            qp = q_start[:, None] + qi * bq + jnp.arange(bq)[None, :]
            m2, l2, a2 = blk_update(m, l, acc, qv, kblk, vblk, qp, ki * bkv)
            done = (a2 / jnp.maximum(l2, 1e-30)[..., None])
            out = jax.lax.dynamic_update_index_in_dim(
                out, jnp.where(is_last, done, jnp.take(out, qi, axis=0)),
                qi, axis=0)
            return (m2, l2, a2, out), None

        body_fn = jax.checkpoint(pair_body) if cfg.attn_remat else pair_body
        (_, _, _, out), _ = jax.lax.scan(
            body_fn, (mq0, lq0, aq0, out0), (q_idx, kv_idx, first, last))
        out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Kh, G, hd)
        return out.astype(q.dtype)

    def body(carry, blk):
        m, l, acc, idx = carry
        kblk, vblk = blk
        m2, l2, a2 = blk_update(m, l, acc, q, kblk, vblk, q_pos, idx * bkv)
        return (m2, l2, a2, idx + 1), None

    body_fn = jax.checkpoint(body) if cfg.attn_remat else body
    (m, l, acc, _), _ = jax.lax.scan(body_fn, (m0, l0, a0, jnp.int32(0)),
                                     (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # [B,Sq,K,G,hd]


def attention(x, lp, cfg: TransformerConfig, positions, is_local,
              kv_cache=None, cache_index=None):
    """Self-attention sublayer. Returns (out, new_kv) where new_kv is the
    (k, v) for this layer (for cache writes) or None in pure training."""
    B, S, D = x.shape
    Kh, G, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    dt = cfg.dtype
    wq, wk, wv = (lp["wq"].astype(dt), lp["wk"].astype(dt),
                  lp["wv"].astype(dt))
    q = x @ wq
    k = x @ wk
    v = x @ wv
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(dt)
        k = k + lp["bk"].astype(dt)
        v = v + lp["bv"].astype(dt)
    q = q.reshape(B, S, Kh, G, hd)
    k = k.reshape(B, S, Kh, hd)
    v = v.reshape(B, S, Kh, hd)
    q = shard_hint(q, "act_q")
    k = shard_hint(k, "act_kv")
    v = shard_hint(v, "act_kv")
    sin, cos = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q.reshape(B, S, Kh * G, hd), sin, cos).reshape(
        B, S, Kh, G, hd)
    k = apply_rope(k, sin, cos)

    if kv_cache is None:
        q_start = positions[0, 0] if positions.ndim == 2 else positions[0]
        out = _block_attention(q, k, v, cfg, q_start, kv_len=S,
                               is_local=is_local)
        new_kv = (k, v)
    else:
        ck, cv = kv_cache          # [B, Smax, Kh, hd]
        t = cache_index            # scalar or [B]: tokens already cached
        if jnp.ndim(t) == 0:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, t, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, t, 0, 0))
        else:                      # per-slot positions (serving): S == 1
            rows = jnp.arange(B)
            ck = ck.at[rows, t].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[rows, t].set(v[:, 0].astype(cv.dtype))
        out = _block_attention(q, ck, cv, cfg, q_start=t, kv_len=t + S,
                               is_local=is_local)
        new_kv = (ck, cv)
    out = out.reshape(B, S, Kh * G * hd)
    out = out @ lp["wo"].astype(dt)
    return out, new_kv


# ====================================================== MLP / MoE


def dense_mlp(x, lp, cfg: TransformerConfig):
    dt = cfg.dtype
    h = jax.nn.silu(x @ lp["w1"].astype(dt)) * (x @ lp["w3"].astype(dt))
    h = shard_hint(h, "act_ff")
    return h @ lp["w2"].astype(dt)


def moe_mlp(x, lp, cfg: TransformerConfig):
    """Top-k token-choice MoE with static capacity (sort-based dispatch).
    Returns (out, aux_loss)."""
    B, S, D = x.shape
    dt = cfg.dtype
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    C = max(int(cfg.capacity_factor * T * k / E), 8)
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ lp["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # [T, E]
    topw, topi = jax.lax.top_k(probs, k)                         # [T, k]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e mean_prob_e * mean_assign_e
    assign = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], topi].set(1.0)
    aux = E * jnp.sum(probs.mean(0) * assign.mean(0))

    flat_e = topi.reshape(-1)                                    # [T*k]
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e)
    se, stok, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(E))                 # [E]
    rank = jnp.arange(T * k) - starts[se]
    keep = rank < C
    dest = jnp.where(keep, se * C + rank, E * C)                 # E*C = drop

    # Slot-indexed dispatch (perf iteration 2, EXPERIMENTS.md §Perf):
    # instead of materializing [T*k, D] gathered rows (whose resharding
    # all-gathered 51GB/layer), build small [E*C] slot->token/weight maps and
    # gather straight from the [T, D] token array.
    slot_token = jnp.zeros(E * C + 1, jnp.int32).at[dest].set(
        stok.astype(jnp.int32))[:-1]                             # [E*C]
    slot_w = jnp.zeros(E * C + 1, jnp.float32).at[dest].set(
        sw * keep)[:-1]                                          # [E*C]
    slot_valid = (slot_w > 0).astype(dt)

    buf = xf[slot_token].astype(dt) * slot_valid[:, None]
    buf = buf.reshape(E, C, D)
    buf = shard_hint(buf, "moe_buf")

    w1, w3, w2 = (lp["w1"].astype(dt), lp["w3"].astype(dt),
                  lp["w2"].astype(dt))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1)) * jnp.einsum(
        "ecd,edf->ecf", buf, w3)
    h = shard_hint(h, "moe_ff")
    eout = jnp.einsum("ecf,efd->ecd", h, w2).reshape(E * C, D)
    eout = eout * slot_w.astype(dt)[:, None]
    eout = shard_hint(eout, "moe_eout")

    # Combine on the expert shards: scatter-add partial [T, D] outputs and
    # let resharding to (dp) reduce them — avoids all-gathering [E*C, D].
    out = jnp.zeros((T, D), dt).at[slot_token].add(
        eout * slot_valid[:, None])
    out = shard_hint(out, "moe_rows")
    return out.reshape(B, S, D), aux


# ====================================================== forward


def _layer(x, lp, cfg: TransformerConfig, positions, is_local,
           kv_cache=None, cache_index=None):
    zc = cfg.zero_centered_norm
    h = rms_norm(x, lp["ln1"].astype(jnp.float32), zero_centered=zc)
    o, new_kv = attention(h, lp["attn"], cfg, positions, is_local,
                          kv_cache, cache_index)
    if cfg.post_norms:
        o = rms_norm(o, lp["ln1_post"].astype(jnp.float32), zero_centered=zc)
    x = x + o
    h = rms_norm(x, lp["ln2"].astype(jnp.float32), zero_centered=zc)
    if cfg.moe:
        f, aux = moe_mlp(h, lp["mlp"], cfg)
    else:
        f, aux = dense_mlp(h, lp["mlp"], cfg), jnp.float32(0)
    if cfg.post_norms:
        f = rms_norm(f, lp["ln2_post"].astype(jnp.float32), zero_centered=zc)
    x = shard_hint(x + f, "act_resid")
    return x, new_kv, aux


def forward(params, tokens, cfg: TransformerConfig,
            kv_caches=None, cache_index=None):
    """tokens [B, S] -> (logits [B, S, V], new_kv_caches or None, aux).

    kv_caches: optional dict {"k": [L,B,Smax,K,hd], "v": ...}; when given the
    step writes at cache_index and attends over the cache (prefill/decode).
    """
    B, S = tokens.shape
    dt = cfg.dtype
    x = params["embed"].astype(dt)[tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    x = shard_hint(x, "act_resid")
    if cache_index is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    else:
        off = (cache_index[:, None] if jnp.ndim(cache_index) == 1
               else cache_index)
        positions = off + jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    flags = cfg.is_local_flags()

    def body(carry, layer_in):
        x = carry
        if kv_caches is None:
            lp, flag = layer_in
            x, _, aux = _layer(x, lp, cfg, positions, flag)
            return x, aux
        lp, flag, ck, cv = layer_in
        x, (nk, nv), aux = _layer(x, lp, cfg, positions, flag,
                                  (ck, cv), cache_index)
        return x, (aux, nk, nv)

    body_fn = jax.checkpoint(body) if (cfg.remat and kv_caches is None) \
        else body
    if kv_caches is None:
        x, auxs = jax.lax.scan(body_fn, x, (params["layers"], flags))
        new_caches = None
        aux = auxs.mean()
    else:
        x, (auxs, nk, nv) = jax.lax.scan(
            body_fn, x, (params["layers"], flags,
                         kv_caches["k"], kv_caches["v"]))
        new_caches = {"k": nk, "v": nv}
        aux = auxs.mean()
    x = rms_norm(x, params["final_norm"].astype(jnp.float32),
                 zero_centered=cfg.zero_centered_norm)
    logits = x @ params["head"].astype(dt)
    logits = softcap(logits, cfg.final_softcap)
    logits = shard_hint(logits, "logits")
    return logits, new_caches, aux


# ====================================================== entry points


def loss_fn(params, batch, cfg: TransformerConfig):
    logits, _, aux = forward(params, batch["tokens"], cfg)
    loss = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
    return loss + cfg.aux_loss_weight * aux, {"ce": loss, "aux": aux}


def make_train_step(cfg: TransformerConfig, adam_cfg):
    from repro.train import optimizer as opt

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        params, opt_state, om = opt.update(adam_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **parts, **om}

    return train_step


def init_kv_cache(cfg: TransformerConfig, batch: int, max_len: int,
                  dtype=None) -> dict:
    dt = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def prefill(params, tokens, cfg: TransformerConfig, kv_caches):
    """Process the prompt, filling the cache. Returns (last_logits, caches)."""
    logits, caches, _ = forward(params, tokens, cfg, kv_caches,
                                cache_index=jnp.int32(0))
    return logits[:, -1], caches


def decode_step(params, tokens, cfg: TransformerConfig, kv_caches, t):
    """One decode step: tokens [B,1] at position t. Returns (logits [B,V],
    new caches)."""
    logits, caches, _ = forward(params, tokens, cfg, kv_caches,
                                cache_index=t)
    return logits[:, -1], caches


def decode_step_multi(params, tokens, cfg: TransformerConfig, kv_caches,
                      pos):
    """Continuous-batching decode: tokens [B,1] with per-slot positions
    pos [B] (each slot at a different point in its sequence)."""
    logits, caches, _ = forward(params, tokens, cfg, kv_caches,
                                cache_index=pos)
    return logits[:, -1], caches
