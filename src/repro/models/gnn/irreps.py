"""Minimal real-spherical-harmonic irrep machinery (NequIP / EquiformerV2).

Self-contained replacements for e3nn's tables, derived numerically once at
import time (host numpy) and then used as constants inside jit:

- ``real_sph_harm(l_max, u)``     — real SH via associated-Legendre recursion,
  any l (vectorized, jnp-traceable).
- ``wigner_D(l, R)``              — numeric real-basis Wigner matrix for one
  rotation (lstsq over random directions; host-side, used for tests & Jd).
- ``cg_tensor(l1, l2, l3)``       — the (unique up to scale) equivariant
  coupling tensor, via the nullspace of rotation-constraint equations.
- ``Jd(l)``                       — the y<->z conjugation matrix, so per-edge
  Wigner matrices reduce to two analytic z-rotations (e3nn's algorithm):
  ``D(Rz(a) Ry(b)) = Rz(a) @ J @ Rz(b) @ J``; we use the variant aligning
  edge vectors to the z axis for eSCN's SO(2) convolutions.

Everything is validated by `tests/test_irreps.py` (rotation equivariance to
float64 precision).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------- real SH (np)


def _legendre_all(l_max: int, x: np.ndarray) -> np.ndarray:
    """Associated Legendre P_l^m(x) for 0<=m<=l<=l_max. Returns
    [l_max+1, l_max+1, ...x.shape] with zeros for m>l."""
    P = np.zeros((l_max + 1, l_max + 1) + x.shape, dtype=np.float64)
    P[0, 0] = 1.0
    somx2 = np.sqrt(np.maximum(1.0 - x * x, 0.0))
    for m in range(1, l_max + 1):
        P[m, m] = -(2 * m - 1) * somx2 * P[m - 1, m - 1]
    for m in range(l_max):
        P[m + 1, m] = (2 * m + 1) * x * P[m, m]
    for m in range(l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[l, m] = ((2 * l - 1) * x * P[l - 1, m] -
                       (l + m - 1) * P[l - 2, m]) / (l - m)
    return P


def real_sph_harm_np(l_max: int, u: np.ndarray) -> np.ndarray:
    """Real SH Y[(l,m)] for unit vectors u [..., 3] -> [..., (l_max+1)^2].
    Ordering: l blocks, within block m = -l..l. Orthonormal on the sphere."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    phi = np.arctan2(y, x)
    P = _legendre_all(l_max, z)
    out = np.zeros(u.shape[:-1] + ((l_max + 1) ** 2,), dtype=np.float64)
    from math import factorial, pi, sqrt
    for l in range(l_max + 1):
        base = l * l + l
        for m in range(0, l + 1):
            norm = sqrt((2 * l + 1) / (4 * pi) *
                        factorial(l - m) / factorial(l + m))
            if m == 0:
                out[..., base] = norm * P[l, 0]
            else:
                out[..., base + m] = (sqrt(2) * norm * P[l, m]
                                      * np.cos(m * phi))
                out[..., base - m] = (sqrt(2) * norm * P[l, m]
                                      * np.sin(m * phi))
    return out


def real_sph_harm(l_max: int, u: jax.Array) -> jax.Array:
    """jnp-traceable real SH (same ordering/normalization as the np twin)."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    phi = jnp.arctan2(y, x)
    # Legendre recursion unrolled at trace time
    P = {}
    P[(0, 0)] = jnp.ones_like(z)
    somx2 = jnp.sqrt(jnp.maximum(1.0 - z * z, 0.0))
    for m in range(1, l_max + 1):
        P[(m, m)] = -(2 * m - 1) * somx2 * P[(m - 1, m - 1)]
    for m in range(l_max):
        P[(m + 1, m)] = (2 * m + 1) * z * P[(m, m)]
    for m in range(l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = ((2 * l - 1) * z * P[(l - 1, m)] -
                         (l + m - 1) * P[(l - 2, m)]) / (l - m)
    from math import factorial, pi, sqrt
    cols = []
    for l in range(l_max + 1):
        block = [None] * (2 * l + 1)
        for m in range(0, l + 1):
            norm = sqrt((2 * l + 1) / (4 * pi) *
                        factorial(l - m) / factorial(l + m))
            if m == 0:
                block[l] = norm * P[(l, 0)]
            else:
                block[l + m] = sqrt(2) * norm * P[(l, m)] * jnp.cos(m * phi)
                block[l - m] = sqrt(2) * norm * P[(l, m)] * jnp.sin(m * phi)
        cols.extend(block)
    return jnp.stack(cols, axis=-1)


# --------------------------------------------------- numeric Wigner (np)


def _rand_units(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(n, 3))
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def wigner_D_np(l: int, R: np.ndarray, n_samples: int = 0) -> np.ndarray:
    """Real-basis Wigner matrix: Y_l(R u) = D Y_l(u), via lstsq."""
    n = n_samples or (4 * (2 * l + 1))
    u = _rand_units(n, seed=l + 17)
    A = real_sph_harm_np(l, u)[:, l * l:(l + 1) ** 2]          # [n, 2l+1]
    B = real_sph_harm_np(l, u @ R.T)[:, l * l:(l + 1) ** 2]    # [n, 2l+1]
    D, *_ = np.linalg.lstsq(A, B, rcond=None)
    return D.T


@functools.lru_cache(maxsize=None)
def cg_tensor(l1: int, l2: int, l3: int) -> np.ndarray | None:
    """Equivariant coupling tensor C [2l3+1, 2l1+1, 2l2+1] (unique up to
    sign/scale; normalized to unit Frobenius norm), or None when the triple
    violates |l1-l2|<=l3<=l1+l2."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return None
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    rng = np.random.default_rng(l1 * 100 + l2 * 10 + l3)
    rows = []
    for _ in range(6):
        q = rng.normal(size=4)
        q /= np.linalg.norm(q)
        w, x, y, z = q
        R = np.array([
            [1 - 2 * (y * y + z * z), 2 * (x * y - z * w), 2 * (x * z + y * w)],
            [2 * (x * y + z * w), 1 - 2 * (x * x + z * z), 2 * (y * z - x * w)],
            [2 * (x * z - y * w), 2 * (y * z + x * w), 1 - 2 * (x * x + y * y)],
        ])
        D1, D2, D3 = (wigner_D_np(l1, R), wigner_D_np(l2, R),
                      wigner_D_np(l3, R))
        # constraint: D3 @ C == C @ (D1 (x) D2)  for all R
        K = np.kron(D1, D2)                       # [d1*d2, d1*d2]
        M = np.kron(np.eye(d1 * d2), D3) - np.kron(K.T, np.eye(d3))
        rows.append(M)
    M = np.concatenate(rows, axis=0)
    _, s, vh = np.linalg.svd(M)
    null = vh[-1]
    C = null.reshape(d1 * d2, d3).T.reshape(d3, d1, d2)
    if s[-1] > 1e-8:
        return None  # no equivariant map (shouldn't happen for valid triples)
    C = C / np.linalg.norm(C)
    # fix sign deterministically
    idx = np.unravel_index(np.argmax(np.abs(C)), C.shape)
    if C[idx] < 0:
        C = -C
    return C


@functools.lru_cache(maxsize=None)
def Jd_matrix(l: int) -> np.ndarray:
    """Conjugation matrix J_l = D_l(R_yz) where R_yz swaps y and z axes
    (rotation by pi/2 about x, composed per e3nn convention). With this,
    D(rot_z(a) rot_y(b) rot_z(c)) = Z(a) J Z(b) J Z(c)."""
    # rotation by +pi/2 about the x-axis maps (x,y,z)->(x,-z,y)
    R = np.array([[1.0, 0, 0], [0, 0, -1.0], [0, 1.0, 0]])
    # e3nn's Jd is for the involution; we build the two-sided identity below
    # directly from this quarter-turn: Ry(b) = Rx(-pi/2) Rz(b) Rx(pi/2)
    return wigner_D_np(l, R)


def z_rotation_block(l: int, theta: jax.Array) -> jax.Array:
    """Analytic real-SH z-rotation matrix [*theta.shape, 2l+1, 2l+1] for one
    l: m=0 fixed; (m,-m) pairs rotate by m*theta. Convention matches
    real_sph_harm (cos -> +m, sin -> -m)."""
    shape = theta.shape
    d = 2 * l + 1
    M = jnp.zeros(shape + (d, d), theta.dtype)
    M = M.at[..., l, l].set(1.0)
    for m in range(1, l + 1):
        c, s = jnp.cos(m * theta), jnp.sin(m * theta)
        # Y'_{+m} = cos(m t) Y_{+m} - sin(m t) Y_{-m}
        # Y'_{-m} = sin(m t) Y_{+m} + cos(m t) Y_{-m}
        M = M.at[..., l + m, l + m].set(c)
        M = M.at[..., l + m, l - m].set(-s)
        M = M.at[..., l - m, l + m].set(s)
        M = M.at[..., l - m, l - m].set(c)
    return M


def edge_wigner(l: int, rhat: jax.Array) -> jax.Array:
    """Per-edge real Wigner matrix [E, 2l+1, 2l+1] rotating the frame so the
    edge direction maps to +z: D = Z(-a) J Z(-b) J with (a, b) the azimuth
    and polar angles of rhat; applied to features as D @ f (f in world frame
    -> f in edge frame). Built from two analytic z-rotations and the numeric
    quarter-turn J (e3nn's algorithm)."""
    x, y, z = rhat[..., 0], rhat[..., 1], rhat[..., 2]
    a = jnp.arctan2(y, x)
    b = jnp.arccos(jnp.clip(z, -1.0, 1.0))
    J = jnp.asarray(Jd_matrix(l), rhat.dtype)
    Za = z_rotation_block(l, -a)
    Zb = z_rotation_block(l, -b)
    # rotation taking rhat to z: Ry(-b) Rz(-a); D(Ry(t)) = J^{-1} Z(t) J
    # with J = D(Rx(+pi/2)); J^{-1} = J^T (orthogonal).
    D_y = jnp.einsum("nm,...mk,kl->...nl", J.T, Zb, J)
    return jnp.einsum("...nm,...mk->...nk", D_y, Za)


def irrep_slices(l_max: int) -> list[slice]:
    return [slice(l * l, (l + 1) ** 2) for l in range(l_max + 1)]
