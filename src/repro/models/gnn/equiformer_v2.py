"""EquiformerV2 (Liao et al., arXiv:2306.12059) — equivariant graph attention
with eSCN SO(2) convolutions, TPU-adapted.

The eSCN trick (Passaro & Zitnick): rotate each edge's features into a frame
where the edge direction is +z; there, SH filters are diagonal in m, so the
O(l_max^6) tensor product collapses to dense SO(2) mixings per |m| <= m_max
— pure batched matmuls, ideal for the MXU. Per-edge Wigner matrices come from
two analytic z-rotations conjugated by a fixed quarter-turn (irreps.edge_wigner).

Faithful-in-spirit reductions vs the OC20 codebase (documented in DESIGN.md):
gate nonlinearity instead of separable-S2 activation, radial scaling per l
instead of per-(l,m,channel), single-hop attention logits from the m=0 stream.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init
from repro.models.gnn.common import (edge_vectors, gaussian_rbf, poly_cutoff,
                                     safe_edges, segment_softmax)
from repro.models.gnn.irreps import edge_wigner, irrep_slices
from repro.models.sharding import shard_hint


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128          # channels per irrep
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 64
    cutoff: float = 8.0
    n_atom_types: int = 100
    d_feat: int = 0
    avg_neighbors: float = 20.0
    task: str = "energy"
    n_graphs: int = 1
    n_classes: int = 0
    dtype: Any = jnp.float32
    # perf (§Perf): process edges in chunks with an online segment-softmax
    # (flash-attention over graph neighborhoods) so the per-edge
    # [E, C, (l_max+1)^2] tensors never materialize at full E.
    edge_chunk: int = 0
    # perf iteration 2: segment-aligned chunking — the pipeline pre-bins
    # edges by destination-node range (edges of chunk c target nodes in
    # [c*N/nch, (c+1)*N/nch)), so each chunk's softmax+aggregation completes
    # locally: the scan carries NOTHING and backward saves no accumulators.
    node_chunks: int = 0

    @property
    def dim(self) -> int:
        return (self.l_max + 1) ** 2

    def m_indices(self) -> list[tuple[np.ndarray, np.ndarray | None]]:
        """Per m in 0..m_max: (pos_idx, neg_idx|None) into the flat irrep dim,
        listing components of every l >= max(m,0)."""
        out = []
        for m in range(self.m_max + 1):
            ls = list(range(max(m, 0), self.l_max + 1)) if m == 0 else list(
                range(m, self.l_max + 1))
            pos = np.array([l * l + l + m for l in ls], dtype=np.int32)
            neg = (np.array([l * l + l - m for l in ls], dtype=np.int32)
                   if m > 0 else None)
            out.append((pos, neg))
        return out


def init_params(cfg: EquiformerV2Config, rng) -> dict:
    C, H = cfg.d_hidden, cfg.n_heads
    L = cfg.n_layers
    mi = cfg.m_indices()
    ks = jax.random.split(rng, 8 + 10 * L)
    if cfg.d_feat:
        embed = dense_init(ks[0], (cfg.d_feat, C))
    else:
        embed = dense_init(ks[0], (cfg.n_atom_types, C), 1.0)
    layers = []
    for i in range(L):
        k = jax.random.split(ks[8 + i], 16)
        so2 = []
        for mm, (pos, neg) in enumerate(mi):
            nl = len(pos)
            wr = dense_init(k[mm * 2], (nl * C, nl * C))
            wi = dense_init(k[mm * 2 + 1], (nl * C, nl * C)) if mm > 0 \
                else None
            so2.append({"wr": wr, "wi": wi} if wi is not None else {"wr": wr})
        layers.append({
            "so2": so2,
            "rad1": dense_init(k[8], (cfg.n_rbf, 32)), "rad1_b": jnp.zeros(32),
            "rad2": dense_init(k[9], (32, cfg.l_max + 1)),
            "alpha": dense_init(k[10], (C, H)),
            "mix": dense_init(k[11], (cfg.l_max + 1, C, C)),
            "ffn1": dense_init(k[12], (C, 2 * C)), "ffn1_b": jnp.zeros(2 * C),
            "ffn2": dense_init(k[13], (2 * C, C)),
            "gate_w": dense_init(k[14], (C, cfg.l_max * C)),
            "gate_b": jnp.zeros(cfg.l_max * C),
            "ln_scale": jnp.ones((cfg.l_max + 1, C)),
        })
    # stack layers along a leading axis so forward can lax.scan them
    # (one-layer-sized HLO + per-layer remat; §Perf Cell C iteration 3)
    layers = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "embed": embed, "layers": layers,
        "head1": dense_init(ks[1], (C, C)), "head1_b": jnp.zeros(C),
        "head2": dense_init(ks[2], (C, cfg.n_classes
                                    if cfg.task == "node_class" else 1)),
    }


def _equi_layernorm(x, scale, slices):
    """Per-l RMS over (channel, m) with learned per-channel scale."""
    outs = []
    for l, sl in enumerate(slices):
        blk = x[..., sl]
        rms = jnp.sqrt(jnp.mean(jnp.square(blk), axis=(-1, -2),
                                keepdims=True) + 1e-6)
        outs.append(blk / rms * scale[l][None, :, None])
    return jnp.concatenate(outs, axis=-1)


def _so2_conv(fe, lp, cfg, rad_scale):
    """fe: edge-frame features [E, C, dim]. SO(2) mixing per |m|<=m_max;
    components with |m|>m_max are dropped (the eSCN restriction)."""
    E, C, _ = fe.shape
    out = jnp.zeros_like(fe)
    for m, (pos, neg) in enumerate(cfg.m_indices()):
        nl = len(pos)
        xp = fe[..., pos].reshape(E, C * nl)
        wr = lp["so2"][m]["wr"].astype(fe.dtype)
        if m == 0:
            yp = xp @ wr
            out = out.at[..., pos].set(yp.reshape(E, C, nl))
        else:
            xn = fe[..., neg].reshape(E, C * nl)
            wi = lp["so2"][m]["wi"].astype(fe.dtype)
            yp = xp @ wr - xn @ wi
            yn = xp @ wi + xn @ wr
            out = out.at[..., pos].set(yp.reshape(E, C, nl))
            out = out.at[..., neg].set(yn.reshape(E, C, nl))
    return out * rad_scale


def forward(params, batch, cfg: EquiformerV2Config) -> jax.Array:
    edges = batch["edges"]
    src, dst, _ = safe_edges(edges)
    rhat, d, m = edge_vectors(batch["positions"].astype(cfg.dtype), edges)
    N = batch["positions"].shape[0]
    C, dim, H = cfg.d_hidden, cfg.dim, cfg.n_heads
    slices = irrep_slices(cfg.l_max)

    if cfg.d_feat:
        s0 = batch["node_feat"].astype(cfg.dtype) @ params["embed"]
    else:
        s0 = params["embed"][jnp.maximum(batch.get("atom_type",
                                                   jnp.zeros(N, jnp.int32)),
                                         0)]
    x = jnp.zeros((N, C, dim), cfg.dtype).at[..., 0].set(s0)

    rbf_all = gaussian_rbf(d, cfg.n_rbf, cfg.cutoff)
    env_all = (poly_cutoff(d, cfg.cutoff) * m)[:, None]

    def rotate_with(Ds, f, transpose=False):
        outs = []
        for l, sl in enumerate(slices):
            eq = "enm,ecm->ecn" if not transpose else "emn,ecm->ecn"
            outs.append(jnp.einsum(eq, Ds[l], f[..., sl]))
        return jnp.concatenate(outs, axis=-1)

    def edge_messages(lp, xn, src_c, rhat_c, rbf_c, env_c):
        """Messages + attention logits for one edge slice."""
        Ds = [edge_wigner(l, rhat_c).astype(cfg.dtype)
              for l in range(cfg.l_max + 1)]
        rad = jax.nn.silu(rbf_c.astype(cfg.dtype) @ lp["rad1"]
                          + lp["rad1_b"]) @ lp["rad2"]
        rad = rad * env_c.astype(cfg.dtype)                 # [e, l_max+1]
        rad_flat = jnp.concatenate(
            [jnp.repeat(rad[:, l:l + 1], 2 * l + 1, axis=1)
             for l in range(cfg.l_max + 1)], axis=1)[:, None, :]
        rad_flat = rad_flat.astype(cfg.dtype)
        fe = rotate_with(Ds, xn[jnp.maximum(src_c, 0)])     # [e, C, dim]
        fe = shard_hint(fe, "edge_msg")
        me = _so2_conv(fe, lp, cfg, rad_flat)
        logits = me[..., 0] @ lp["alpha"].astype(cfg.dtype)  # [e, H]
        # rotate messages back to the world frame before aggregation
        mw = rotate_with(Ds, me, transpose=True)            # [e, C, dim]
        return mw, logits

    E_total = src.shape[0]
    use_chunks = (cfg.edge_chunk and E_total > cfg.edge_chunk
                  and E_total % cfg.edge_chunk == 0)
    use_node_chunks = (cfg.node_chunks > 1 and N % cfg.node_chunks == 0
                       and E_total % cfg.node_chunks == 0)

    def layer_body(x, lp):
        lp = jax.tree.map(lambda a: a.astype(cfg.dtype)
                          if jnp.issubdtype(a.dtype, jnp.floating) else a, lp)
        xn = _equi_layernorm(x, lp["ln_scale"].astype(cfg.dtype), slices)
        if use_node_chunks:
            nch = cfg.node_chunks
            Nc = N // nch
            resh = lambda a: a.reshape((nch, E_total // nch) + a.shape[1:])
            xs = (jnp.arange(nch), resh(src), resh(dst), resh(m),
                  resh(rhat), resh(rbf_all), resh(env_all))

            def node_chunk_body(carry, xc):
                ci, src_c, dst_c, m_c, rhat_c, rbf_c, env_c = xc
                mw, logits = edge_messages(lp, xn, src_c, rhat_c, rbf_c,
                                           env_c)
                dloc = jnp.clip(dst_c - ci * Nc, 0, Nc - 1)
                ok = m_c & (dst_c >= ci * Nc) & (dst_c < (ci + 1) * Nc)
                alpha = segment_softmax(
                    logits.astype(jnp.float32), dloc, Nc, mask=ok[:, None])
                mv = (mw.reshape(-1, H, C // H, dim)
                      * alpha[..., None, None].astype(mw.dtype))
                part = jax.ops.segment_sum(mv.reshape(-1, C, dim), dloc,
                                           num_segments=Nc)
                return carry, part                   # ys: [Nc, C, dim]

            _, parts = jax.lax.scan(jax.checkpoint(node_chunk_body),
                                    0, xs)
            agg = parts.reshape(N, C, dim)
        elif not use_chunks:
            mw, logits = edge_messages(lp, xn, src, rhat, rbf_all, env_all)
            alpha = segment_softmax(logits, dst, N, mask=m[:, None])
            mv = mw.reshape(E_total, H, C // H, dim) * alpha[..., None, None]
            agg = jax.ops.segment_sum(mv.reshape(E_total, C, dim), dst,
                                      num_segments=N)
        else:
            nch = E_total // cfg.edge_chunk
            resh = lambda a: a.reshape((nch, cfg.edge_chunk) + a.shape[1:])
            xs = (resh(src), resh(dst), resh(m), resh(rhat), resh(rbf_all),
                  resh(env_all))
            mx0 = jnp.full((N, H), -1e30, jnp.float32)
            l0 = jnp.zeros((N, H), jnp.float32)
            acc0 = jnp.zeros((N, C, dim), jnp.float32)

            def chunk_body(carry, xc):
                mx, lsum, acc = carry
                src_c, dst_c, m_c, rhat_c, rbf_c, env_c = xc
                mw, logits = edge_messages(lp, xn, src_c, rhat_c, rbf_c,
                                           env_c)
                logits = jnp.where(m_c[:, None], logits.astype(jnp.float32),
                                   -1e30)
                dseg = jnp.maximum(dst_c, 0)
                mx_c = jax.ops.segment_max(logits, dseg, num_segments=N)
                mx_new = jnp.maximum(mx, mx_c)
                corr = jnp.exp(mx - mx_new)                  # [N, H]
                p = jnp.exp(logits - mx_new[dseg])           # [e, H]
                p = jnp.where(m_c[:, None], p, 0.0)
                l_new = lsum * corr + jax.ops.segment_sum(
                    p, dseg, num_segments=N)
                pm = (mw.reshape(-1, H, C // H, dim).astype(jnp.float32)
                      * p[..., None, None]).reshape(-1, C, dim)
                acc_new = (acc * corr.repeat(C // H, axis=1)[..., None]
                           + jax.ops.segment_sum(pm, dseg, num_segments=N))
                return (mx_new, l_new, acc_new), None

            body = jax.checkpoint(chunk_body)
            (mx, lsum, acc), _ = jax.lax.scan(body, (mx0, l0, acc0), xs)
            denom = jnp.maximum(lsum, 1e-30).repeat(C // H, axis=1)
            agg = (acc / denom[..., None]).astype(cfg.dtype)
        agg = agg / jnp.asarray(np.sqrt(cfg.avg_neighbors), cfg.dtype)
        # node update: per-l mixing + gate
        upd = jnp.concatenate(
            [jnp.einsum("ncm,cd->ndm", agg[..., sl],
                        lp["mix"][l].astype(cfg.dtype))
             for l, sl in enumerate(slices)], axis=-1)
        scal = jax.nn.silu(upd[..., 0])
        gates = jax.nn.sigmoid(upd[..., 0] @ lp["gate_w"] + lp["gate_b"])
        gates = gates.reshape(N, cfg.l_max, C).transpose(0, 2, 1)
        upd = upd.at[..., 0].set(scal)
        for l in range(1, cfg.l_max + 1):
            upd = upd.at[..., slices[l]].multiply(
                gates[..., l - 1][..., None])
        x = x + upd
        # scalar FFN (per-node)
        ff = jax.nn.silu(x[..., 0] @ lp["ffn1"] + lp["ffn1_b"]) @ lp["ffn2"]
        x = x.at[..., 0].add(ff)
        return x, None

    # layers run under lax.scan + remat: one-layer HLO, per-layer recompute
    x, _ = jax.lax.scan(jax.checkpoint(layer_body), x, params["layers"])

    h = jax.nn.silu(x[..., 0] @ params["head1"] + params["head1_b"])
    h = h @ params["head2"]
    if cfg.task == "node_class":
        return h
    graph_ids = batch.get("graph_ids")
    n_graphs = cfg.n_graphs
    if graph_ids is None:
        return h.sum(axis=0)
    # padded nodes carry graph_id == -1: route them to a spill segment
    seg = jnp.where(graph_ids >= 0, graph_ids, n_graphs)
    return jax.ops.segment_sum(h[:, 0], seg,
                               num_segments=n_graphs + 1)[:n_graphs]


def loss_fn(params, batch, cfg: EquiformerV2Config):
    out = forward(params, batch, cfg)
    if cfg.task == "node_class":
        labels = batch["labels"]
        mask = batch.get("train_mask", jnp.ones(labels.shape)) * (labels >= 0)
        logp = jax.nn.log_softmax(out.astype(jnp.float32))
        nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[:, None],
                                   -1)[:, 0]
        return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1), {}
    err = out - batch["energy"]
    return jnp.mean(jnp.square(err)), {"mae": jnp.mean(jnp.abs(err))}


def make_train_step(cfg: EquiformerV2Config, adam_cfg):
    from repro.train import optimizer as opt

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg)
        params, opt_state, om = opt.update(adam_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **parts, **om}

    return train_step
