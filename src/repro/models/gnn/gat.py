"""GAT (Velickovic et al., arXiv:1710.10903) — SDDMM/segment-softmax regime.

Node-classification GNN over padded-COO graphs. The cora config is 2 layers,
8 hidden x 8 heads, ELU, attention aggregation. For molecule-style inputs
(atom types, no dense features) an embedding table replaces the feature
projection.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.gnn.common import safe_edges, segment_softmax
from repro.models.sharding import shard_hint


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_feat: int = 1433
    n_classes: int = 7
    n_atom_types: int = 0          # >0: embed atom types instead of features
    dropout: float = 0.0           # kept for config parity; eval-mode graphs
    negative_slope: float = 0.2
    dtype: Any = jnp.float32

    def param_count(self) -> int:
        import jax.random as jr
        return sum(x.size for x in jax.tree.leaves(
            init_params(self, jr.PRNGKey(0))))


def init_params(cfg: GATConfig, rng) -> dict:
    ks = jax.random.split(rng, 2 + cfg.n_layers * 3)
    layers = []
    d_in = cfg.d_feat if cfg.n_atom_types == 0 else cfg.d_hidden * cfg.n_heads
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        h = cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        layers.append({
            "w": dense_init(ks[3 * i], (d_in, h, d_out)),
            "a_src": dense_init(ks[3 * i + 1], (h, d_out)),
            "a_dst": dense_init(ks[3 * i + 2], (h, d_out)),
        })
        d_in = d_out * h if not last else d_out
    params = {"layers": layers}
    if cfg.n_atom_types:
        params["embed"] = dense_init(ks[-1],
                                     (cfg.n_atom_types,
                                      cfg.d_hidden * cfg.n_heads))
    return params


def forward(params, batch, cfg: GATConfig) -> jax.Array:
    """batch: node_feat [N,F] or atom_type [N]; edges [2,E] padded COO.
    Returns logits [N, n_classes]."""
    edges = batch["edges"]
    src, dst, m = safe_edges(edges)
    if cfg.n_atom_types:
        x = params["embed"][jnp.maximum(batch["atom_type"], 0)]
    else:
        x = batch["node_feat"].astype(cfg.dtype)
    N = x.shape[0]
    for i, lp in enumerate(params["layers"]):
        last = i == cfg.n_layers - 1
        h = jnp.einsum("nf,fhd->nhd", x, lp["w"].astype(cfg.dtype))
        h = shard_hint(h, "node_hidden")
        s_src = jnp.einsum("nhd,hd->nh", h, lp["a_src"].astype(cfg.dtype))
        s_dst = jnp.einsum("nhd,hd->nh", h, lp["a_dst"].astype(cfg.dtype))
        e = jax.nn.leaky_relu(s_src[src] + s_dst[dst],
                              cfg.negative_slope)          # [E, H] (SDDMM)
        alpha = segment_softmax(e, dst, N, mask=m[:, None])
        msg = alpha[..., None] * h[src]                     # [E, H, D]
        msg = shard_hint(msg, "edge_msg")
        out = jax.ops.segment_sum(msg, dst, num_segments=N)
        x = out.mean(axis=1) if last else jax.nn.elu(
            out.reshape(N, -1))
    return x


def loss_fn(params, batch, cfg: GATConfig):
    logits = forward(params, batch, cfg)
    labels = batch["labels"]
    mask = batch.get("train_mask",
                     jnp.ones(labels.shape, jnp.float32))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[:, None],
                               axis=-1)[:, 0]
    mask = mask * (labels >= 0)
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)
    acc = jnp.sum((logits.argmax(-1) == labels) * mask) / jnp.maximum(
        mask.sum(), 1)
    return loss, {"acc": acc}


def make_train_step(cfg: GATConfig, adam_cfg):
    from repro.train import optimizer as opt

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg)
        params, opt_state, om = opt.update(adam_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **parts, **om}

    return train_step
