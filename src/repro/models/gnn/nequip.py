"""NequIP (Batzner et al., arXiv:2101.03164) — E(3)-equivariant interatomic
potential via Clebsch-Gordan tensor-product message passing.

Features are C channels of every irrep l<=l_max, stored flat as
``[N, C, (l_max+1)^2]``. Each interaction block computes, per valid path
(l1 x l2 -> l3), messages ``w_path(d_ij) * CG(f_j^{l1}, Y^{l2}(r_ij))``
aggregated by segment_sum — the irrep-tensor-product kernel regime. CG
tensors come from `repro.models.gnn.irreps` (numerically derived, equivariance
tested to 1e-7).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init
from repro.models.gnn.common import (bessel_rbf, edge_vectors, poly_cutoff,
                                     safe_edges)
from repro.models.gnn.irreps import cg_tensor, irrep_slices, real_sph_harm
from repro.models.sharding import shard_hint


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32          # channels per irrep
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_atom_types: int = 100
    d_feat: int = 0
    avg_neighbors: float = 10.0
    task: str = "energy"
    n_graphs: int = 1
    n_classes: int = 0
    dtype: Any = jnp.float32

    def paths(self) -> list[tuple[int, int, int]]:
        out = []
        for l1 in range(self.l_max + 1):
            for l2 in range(self.l_max + 1):
                for l3 in range(self.l_max + 1):
                    if abs(l1 - l2) <= l3 <= l1 + l2:
                        out.append((l1, l2, l3))
        return out

    @property
    def dim(self) -> int:
        return (self.l_max + 1) ** 2


def init_params(cfg: NequIPConfig, rng) -> dict:
    C, R = cfg.d_hidden, cfg.n_rbf
    npaths = len(cfg.paths())
    L = cfg.n_layers
    ks = jax.random.split(rng, 8 + 6 * L)
    if cfg.d_feat:
        embed = dense_init(ks[0], (cfg.d_feat, C))
    else:
        embed = dense_init(ks[0], (cfg.n_atom_types, C), 1.0)
    layers = []
    for i in range(L):
        k = ks[8 + 6 * i: 14 + 6 * i]
        layers.append({
            "rad1": dense_init(k[0], (R, 32)), "rad1_b": jnp.zeros(32),
            "rad2": dense_init(k[1], (32, npaths * C)),
            # per-l channel mixings (self-interaction before/after conv)
            "mix_pre": dense_init(k[2], (cfg.l_max + 1, C, C)),
            "mix_post": dense_init(k[3], (cfg.l_max + 1, C, C)),
            "gate_w": dense_init(k[4], (C, cfg.l_max * C)),
            "gate_b": jnp.zeros(cfg.l_max * C),
        })
    return {
        "embed": embed, "layers": layers,
        "head1": dense_init(ks[1], (C, C)), "head1_b": jnp.zeros(C),
        "head2": dense_init(ks[2], (C, cfg.n_classes
                                    if cfg.task == "node_class" else 1)),
    }


def _per_l_mix(x: jax.Array, w: jax.Array, slices) -> jax.Array:
    """x [N, C, dim]; w [L+1, C, C] -> per-l channel mixing."""
    outs = []
    for l, sl in enumerate(slices):
        outs.append(jnp.einsum("ncm,cd->ndm", x[..., sl], w[l]))
    return jnp.concatenate(outs, axis=-1)


def forward(params, batch, cfg: NequIPConfig) -> jax.Array:
    edges = batch["edges"]
    src, dst, _ = safe_edges(edges)
    rhat, d, m = edge_vectors(batch["positions"].astype(cfg.dtype), edges)
    N = batch["positions"].shape[0]
    C, dim = cfg.d_hidden, cfg.dim
    slices = irrep_slices(cfg.l_max)
    paths = cfg.paths()
    CGs = {p: jnp.asarray(cg_tensor(*p), cfg.dtype) for p in paths}

    if cfg.d_feat:
        s0 = batch["node_feat"].astype(cfg.dtype) @ params["embed"]
    else:
        s0 = params["embed"][jnp.maximum(batch.get("atom_type",
                                                   jnp.zeros(N, jnp.int32)),
                                         0)]
    x = jnp.zeros((N, C, dim), cfg.dtype).at[..., 0].set(s0)

    Y = real_sph_harm(cfg.l_max, rhat).astype(cfg.dtype)       # [E, dim]
    rbf = bessel_rbf(d, cfg.n_rbf, cfg.cutoff)
    env = (poly_cutoff(d, cfg.cutoff) * m)[:, None]

    for lp in params["layers"]:
        rad = jax.nn.silu(rbf @ lp["rad1"] + lp["rad1_b"]) @ lp["rad2"]
        rad = rad.reshape(-1, len(paths), C) * env[..., None]   # [E, P, C]
        h = _per_l_mix(x, lp["mix_pre"], slices)
        hs = h[src]                                             # [E, C, dim]
        hs = shard_hint(hs, "edge_msg")
        msg = jnp.zeros((hs.shape[0], C, dim), cfg.dtype)
        for pi, (l1, l2, l3) in enumerate(paths):
            t = jnp.einsum("kij,eci,ej->eck", CGs[(l1, l2, l3)],
                           hs[..., slices[l1]], Y[..., slices[l2]])
            msg = msg.at[..., slices[l3]].add(t * rad[:, pi, :, None])
        agg = jax.ops.segment_sum(msg, dst, num_segments=N)
        agg = agg / jnp.asarray(np.sqrt(cfg.avg_neighbors), cfg.dtype)
        agg = _per_l_mix(agg, lp["mix_post"], slices)
        # gated nonlinearity: scalars silu; l>0 gated by scalar-derived sigm.
        scal = jax.nn.silu(agg[..., 0])
        gates = jax.nn.sigmoid(agg[..., 0] @ lp["gate_w"] + lp["gate_b"])
        gates = gates.reshape(N, cfg.l_max, C).transpose(0, 2, 1)
        out = agg.at[..., 0].set(scal)
        for l in range(1, cfg.l_max + 1):
            out = out.at[..., slices[l]].multiply(gates[..., l - 1][..., None])
        x = x + out
    h = jax.nn.silu(x[..., 0] @ params["head1"] + params["head1_b"])
    h = h @ params["head2"]
    if cfg.task == "node_class":
        return h
    graph_ids = batch.get("graph_ids")
    n_graphs = cfg.n_graphs
    if graph_ids is None:
        return h.sum(axis=0)
    # padded nodes carry graph_id == -1: route them to a spill segment
    seg = jnp.where(graph_ids >= 0, graph_ids, n_graphs)
    return jax.ops.segment_sum(h[:, 0], seg,
                               num_segments=n_graphs + 1)[:n_graphs]


def loss_fn(params, batch, cfg: NequIPConfig):
    out = forward(params, batch, cfg)
    if cfg.task == "node_class":
        labels = batch["labels"]
        mask = batch.get("train_mask", jnp.ones(labels.shape)) * (labels >= 0)
        logp = jax.nn.log_softmax(out.astype(jnp.float32))
        nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[:, None],
                                   -1)[:, 0]
        return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1), {}
    err = out - batch["energy"]
    return jnp.mean(jnp.square(err)), {"mae": jnp.mean(jnp.abs(err))}


def make_train_step(cfg: NequIPConfig, adam_cfg):
    from repro.train import optimizer as opt

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg)
        params, opt_state, om = opt.update(adam_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **parts, **om}

    return train_step
