"""SchNet (Schutt et al., arXiv:1706.08566) — continuous-filter convolutions.

Triplet-free molecular GNN: messages are element-wise products of neighbor
features with a learned filter of the interatomic distance (Gaussian RBF ->
filter MLP), aggregated by segment_sum. Energy = sum of per-atom outputs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.gnn.common import (edge_vectors, gaussian_rbf, poly_cutoff,
                                     safe_edges)
from repro.models.sharding import shard_hint


def ssp(x):
    """Shifted softplus, SchNet's activation."""
    return jax.nn.softplus(x) - jnp.log(2.0)


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_atom_types: int = 100
    d_feat: int = 0          # >0: project dense node features instead
    task: str = "energy"
    n_graphs: int = 1     # "energy" | "node_class"
    n_classes: int = 0
    dtype: Any = jnp.float32


def init_params(cfg: SchNetConfig, rng) -> dict:
    D, R = cfg.d_hidden, cfg.n_rbf
    ks = jax.random.split(rng, 4 + 6 * cfg.n_interactions)
    if cfg.d_feat:
        embed = dense_init(ks[0], (cfg.d_feat, D))
    else:
        embed = dense_init(ks[0], (cfg.n_atom_types, D), 1.0)
    inter = []
    for i in range(cfg.n_interactions):
        k = ks[4 + 6 * i: 10 + 6 * i]
        inter.append({
            "filt1": dense_init(k[0], (R, D)), "filt1_b": jnp.zeros(D),
            "filt2": dense_init(k[1], (D, D)), "filt2_b": jnp.zeros(D),
            "in_w": dense_init(k[2], (D, D)),
            "out1": dense_init(k[3], (D, D)), "out1_b": jnp.zeros(D),
            "out2": dense_init(k[4], (D, D)), "out2_b": jnp.zeros(D),
        })
    d_out = cfg.n_classes if cfg.task == "node_class" else 1
    return {
        "embed": embed,
        "inter": inter,
        "head1": dense_init(ks[1], (D, D // 2)), "head1_b": jnp.zeros(D // 2),
        "head2": dense_init(ks[2], (D // 2, d_out)),
    }


def forward(params, batch, cfg: SchNetConfig) -> jax.Array:
    """Returns per-graph energies [G] (task=energy) or node logits."""
    edges = batch["edges"]
    src, dst, m = safe_edges(edges)
    rhat, d, m = edge_vectors(batch["positions"].astype(cfg.dtype), edges)
    if cfg.d_feat:
        x = batch["node_feat"].astype(cfg.dtype) @ params["embed"]
    else:
        x = params["embed"][jnp.maximum(batch["atom_type"], 0)]
    N = x.shape[0]
    rbf = gaussian_rbf(d, cfg.n_rbf, cfg.cutoff)               # [E, R]
    env = (poly_cutoff(d, cfg.cutoff) * m)[:, None]
    for lp in params["inter"]:
        w = ssp(rbf @ lp["filt1"] + lp["filt1_b"]) @ lp["filt2"] + lp["filt2_b"]
        w = w * env                                            # [E, D]
        h = x @ lp["in_w"]
        msg = h[src] * w                                       # cfconv
        msg = shard_hint(msg, "edge_msg")
        agg = jax.ops.segment_sum(msg, dst, num_segments=N)
        v = ssp(agg @ lp["out1"] + lp["out1_b"]) @ lp["out2"] + lp["out2_b"]
        x = x + v
    h = ssp(x @ params["head1"] + params["head1_b"]) @ params["head2"]
    if cfg.task == "node_class":
        return h
    graph_ids = batch.get("graph_ids")
    n_graphs = cfg.n_graphs
    if graph_ids is None:
        return h.sum(axis=0)
    # padded nodes carry graph_id == -1: route them to a spill segment
    seg = jnp.where(graph_ids >= 0, graph_ids, n_graphs)
    return jax.ops.segment_sum(h[:, 0], seg,
                               num_segments=n_graphs + 1)[:n_graphs]


def loss_fn(params, batch, cfg: SchNetConfig):
    out = forward(params, batch, cfg)
    if cfg.task == "node_class":
        labels = batch["labels"]
        mask = batch.get("train_mask", jnp.ones(labels.shape)) * (labels >= 0)
        logp = jax.nn.log_softmax(out.astype(jnp.float32))
        nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[:, None],
                                   -1)[:, 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)
        return loss, {}
    err = out - batch["energy"]
    return jnp.mean(jnp.square(err)), {"mae": jnp.mean(jnp.abs(err))}


def make_train_step(cfg: SchNetConfig, adam_cfg):
    from repro.train import optimizer as opt

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg)
        params, opt_state, om = opt.update(adam_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **parts, **om}

    return train_step
