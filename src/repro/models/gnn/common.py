"""Shared GNN primitives: padded-COO message passing via segment ops.

JAX has no sparse message-passing engine (BCOO only) — per the assignment,
scatter/gather message passing over an edge index IS part of the system:
``segment_sum``/``segment_softmax`` over ``edges [2, E]`` with -1 padding.
The Pallas ``segment_matmul`` kernel is the TPU hot-path twin of
``gather_dense_scatter``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.sharding import shard_hint


def edge_mask(edges: jax.Array) -> jax.Array:
    return (edges[0] >= 0) & (edges[1] >= 0)


def safe_edges(edges: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(src, dst, mask) with padded entries clipped to 0."""
    m = edge_mask(edges)
    return jnp.maximum(edges[0], 0), jnp.maximum(edges[1], 0), m


def segment_softmax(logits: jax.Array, seg: jax.Array, num_segments: int,
                    mask: jax.Array | None = None) -> jax.Array:
    """Softmax of per-edge logits grouped by destination node."""
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    mx = jax.ops.segment_max(logits, seg, num_segments=num_segments)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(logits - mx[seg])
    if mask is not None:
        ex = jnp.where(mask, ex, 0.0)
    den = jax.ops.segment_sum(ex, seg, num_segments=num_segments)
    return ex / jnp.maximum(den[seg], 1e-16)


def scatter_mean(values: jax.Array, seg: jax.Array, num_segments: int,
                 mask: jax.Array | None = None) -> jax.Array:
    ones = jnp.ones(values.shape[0], values.dtype)
    if mask is not None:
        fm = mask.astype(values.dtype)
        values = values * fm.reshape((-1,) + (1,) * (values.ndim - 1))
        ones = fm
    s = jax.ops.segment_sum(values, seg, num_segments=num_segments)
    c = jax.ops.segment_sum(ones, seg, num_segments=num_segments)
    return s / jnp.maximum(c, 1.0).reshape((-1,) + (1,) * (values.ndim - 1))


def gather_dense_scatter(x: jax.Array, w: jax.Array, edges: jax.Array,
                         num_nodes: int) -> jax.Array:
    """The SpMM-regime kernel: gather source features, transform, scatter-add
    to destinations. x [N, F], w [F, G] -> [N, G]."""
    src, dst, m = safe_edges(edges)
    msg = (x[src] @ w) * m[:, None].astype(x.dtype)
    msg = shard_hint(msg, "edge_msg")
    return jax.ops.segment_sum(msg, dst, num_segments=num_nodes)


# -------------------------------------------------------- radial bases


def gaussian_rbf(d: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """SchNet-style Gaussian radial basis [..., n_rbf]."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = (n_rbf / cutoff) ** 2 * 0.5
    return jnp.exp(-gamma * (d[..., None] - centers) ** 2)


def bessel_rbf(d: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """NequIP-style Bessel basis."""
    n = jnp.arange(1, n_rbf + 1)
    dd = jnp.maximum(d[..., None], 1e-9)
    return (jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * dd / cutoff) / dd)


def poly_cutoff(d: jax.Array, cutoff: float, p: int = 6) -> jax.Array:
    """Smooth polynomial cutoff envelope (goes to 0 at d=cutoff)."""
    x = jnp.clip(d / cutoff, 0.0, 1.0)
    return (1.0 - 0.5 * (p + 1) * (p + 2) * x ** p
            + p * (p + 2) * x ** (p + 1)
            - 0.5 * p * (p + 1) * x ** (p + 2))


def edge_vectors(positions: jax.Array, edges: jax.Array):
    """(rhat [E,3], dist [E], mask [E]) from positions and padded COO."""
    src, dst, m = safe_edges(edges)
    vec = positions[dst] - positions[src]
    d = jnp.linalg.norm(vec, axis=-1)
    rhat = vec / jnp.maximum(d[:, None], 1e-9)
    return rhat, d, m
